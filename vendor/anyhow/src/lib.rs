//! Minimal, dependency-free stand-in for the [`anyhow`] error crate.
//!
//! The build environment is fully offline, so the real crates.io `anyhow`
//! cannot be fetched; this shim vendors the subset of its API that the
//! `zann` crate uses:
//!
//! * [`Error`] — an opaque error value carrying a human-readable cause
//!   chain (stored as strings; no downcasting support),
//! * [`Result`] — `Result<T, Error>` with a defaulted error type,
//! * the [`Context`] extension trait for `Result` and `Option`,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`; that is what allows the blanket
//! `impl<E: std::error::Error> From<E> for Error` to coexist with the
//! standard reflexive `From<Error> for Error`, so `?` works on both
//! concrete errors and `Error` itself.
//!
//! [`anyhow`]: https://docs.rs/anyhow

use std::fmt;

/// `Result<T, anyhow::Error>` with a defaulted error type, like the real
/// crate's alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Opaque error type: an outermost message plus a chain of causes.
///
/// The chain is stored as rendered strings (the shim does not keep the
/// source error values, so there is no `downcast`); `Display` prints the
/// outermost message and `Debug` prints the whole chain, mirroring the
/// real crate's formatting closely enough for logs and `expect` output.
pub struct Error {
    /// Outermost message first, root cause last. Never empty.
    chain: Vec<String>,
}

impl Error {
    /// Construct an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root (innermost) cause.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Conversion into [`Error`] used by the [`Context`] impls. Implemented
/// for both std errors and `Error` itself (which `From` cannot cover
/// without overlapping the reflexive impl).
#[doc(hidden)]
pub trait ToError {
    fn to_error(self) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> ToError for E {
    fn to_error(self) -> Error {
        Error::from(self)
    }
}

impl ToError for Error {
    fn to_error(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, like the real crate.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ToError> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.to_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.to_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn parse() -> Result<u32> {
            let v: u32 = "12".parse()?;
            Ok(v)
        }
        assert_eq!(parse().unwrap(), 12);

        fn fails() -> Result<u32> {
            let v: u32 = "nope".parse()?;
            Ok(v)
        }
        assert!(fails().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let err = r.context("loading index").unwrap_err();
        assert_eq!(err.to_string(), "loading index");
        assert_eq!(err.root_cause(), "disk on fire");

        let none: Option<u32> = None;
        let err = none.with_context(|| format!("missing field {}", "k")).unwrap_err();
        assert_eq!(err.to_string(), "missing field k");

        assert_eq!(Some(5u32).context("present").unwrap(), 5);
    }

    #[test]
    fn context_on_anyhow_result_chains() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let err = r.context("outer").unwrap_err();
        let chain: Vec<&str> = err.chain().collect();
        assert_eq!(chain, vec!["outer", "inner 7"]);
        let dbg = format!("{err:?}");
        assert!(dbg.contains("outer") && dbg.contains("inner 7"), "{dbg}");
    }

    #[test]
    fn macros() {
        fn check(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            Ok(1)
        }
        assert_eq!(check(true).unwrap(), 1);
        assert_eq!(check(false).unwrap_err().to_string(), "flag was false");

        fn early() -> Result<u32> {
            bail!("stop");
        }
        assert_eq!(early().unwrap_err().to_string(), "stop");

        fn bare(v: u32) -> Result<u32> {
            ensure!(v > 2);
            Ok(v)
        }
        assert!(bare(1).unwrap_err().to_string().contains("v > 2"));
        assert_eq!(bare(3).unwrap(), 3);
    }

    #[test]
    fn double_question_mark_pattern() {
        // The nested-result shape used by EngineHandle::spawn.
        fn inner() -> Result<u32> {
            Ok(9)
        }
        fn outer() -> Result<u32> {
            let nested: std::result::Result<Result<u32>, std::io::Error> = Ok(inner());
            let v = nested.context("thread died")??;
            Ok(v)
        }
        assert_eq!(outer().unwrap(), 9);
    }
}
