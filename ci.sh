#!/usr/bin/env bash
# Local CI gate for the zann workspace. Tier-1 (what the roadmap verifies)
# comes first; style/lint/doc gates follow so a tier-1 regression is
# reported before a formatting nit.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

echo "== compile bench harnesses and examples =="
cargo build --release --benches --examples

echo "== bench_search_qps smoke (JSON contract) =="
# Tiny-N end-to-end run; validate that the emitted BENCH_search.json
# parses and carries the documented keys, so the bench wiring cannot rot
# silently. Writes to a scratch path to keep the checkout clean in CI.
QPS_JSON="$(mktemp /tmp/zann_bench_search.XXXXXX.json)"
cargo bench --bench bench_search_qps -- \
  --n 2000 --nq 40 --k 16 --runs 1 --nprobe 4 --sweep-threads 2 \
  --codecs unc64,roc,pq-compressed --out "$QPS_JSON"
python3 - "$QPS_JSON" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    d = json.load(f)
assert d["bench"] == "search_qps", d.get("bench")
for key in ("dataset", "n", "nq", "dim", "k", "seed", "results"):
    assert key in d, f"missing top-level key {key}"
assert d["results"], "no result rows"
for row in d["results"]:
    for key in ("codec", "nprobe", "threads", "qps", "mean_ms", "p50_ms", "p95_ms"):
        assert key in row, f"missing row key {key}"
    assert row["qps"] > 0, row
    assert row["p95_ms"] >= row["p50_ms"], row
print(f"bench JSON ok: {len(d['results'])} rows")
EOF
rm -f "$QPS_JSON"

echo "== rustfmt =="
cargo fmt --all -- --check

echo "== clippy =="
cargo clippy --all-targets -- -D warnings

echo "== rustdoc =="
cargo doc --no-deps --quiet

echo "ci.sh: all gates green"
