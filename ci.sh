#!/usr/bin/env bash
# Local CI gate for the zann workspace. Tier-1 (what the roadmap verifies)
# comes first; style/lint/doc gates follow so a tier-1 regression is
# reported before a formatting nit.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

echo "== compile bench harnesses and examples =="
cargo build --release --benches --examples

echo "== bench_search_qps smoke (JSON contract, IVF + graph backends) =="
# Tiny-N end-to-end run; validate that the emitted BENCH_search.json
# parses and carries the documented keys — including at least one
# graph-backend row served through the same AnnIndex path — so the bench
# wiring cannot rot silently. Writes to a scratch path to keep the
# checkout clean in CI.
QPS_JSON="$(mktemp /tmp/zann_bench_search.XXXXXX.json)"
cargo bench --bench bench_search_qps -- \
  --n 2000 --nq 40 --k 16 --runs 1 --nprobe 4 --sweep-threads 2 \
  --codecs unc64,roc,pq-compressed,nsg:roc --out "$QPS_JSON"
python3 - "$QPS_JSON" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    d = json.load(f)
assert d["bench"] == "search_qps", d.get("bench")
for key in ("dataset", "n", "nq", "dim", "k", "seed", "results"):
    assert key in d, f"missing top-level key {key}"
assert d["results"], "no result rows"
for row in d["results"]:
    for key in ("backend", "codec", "nprobe", "threads", "qps", "mean_ms", "p50_ms", "p95_ms"):
        assert key in row, f"missing row key {key}"
    assert row["qps"] > 0, row
    assert row["p95_ms"] >= row["p50_ms"], row
backends = {row["backend"] for row in d["results"]}
assert "ivf" in backends, backends
assert backends & {"nsg", "hnsw"}, f"no graph-backend row: {backends}"
print(f"bench JSON ok: {len(d['results'])} rows, backends {sorted(backends)}")
EOF
rm -f "$QPS_JSON"

echo "== persistence smoke: build -> save -> info -> serve =="
# Round-trip both index families through the container format and assert
# (a) the reopened file weighs ~ the compressed payload (header/codebook
# overhead only) and (b) every served response is bit-identical to a
# direct search on the reopened index.
IDX_DIR="$(mktemp -d /tmp/zann_idx.XXXXXX)"
cargo run --release --bin zann -- build --out "$IDX_DIR/ivf.zann" \
  --backend ivf --codec roc --n 2000 --dim 16 --k 32
cargo run --release --bin zann -- info "$IDX_DIR/ivf.zann" > "$IDX_DIR/info_ivf.txt"
cat "$IDX_DIR/info_ivf.txt"
python3 - "$IDX_DIR/info_ivf.txt" <<'EOF'
import sys
line = next(l for l in open(sys.argv[1]) if l.startswith("zann-index"))
kv = dict(tok.split("=", 1) for tok in line.split()[1:])
id_bits, code_bits, link_bits = (int(kv[k]) for k in ("id_bits", "code_bits", "link_bits"))
file_bytes = int(kv["file_bytes"])
payload = (id_bits + code_bits + link_bits + 7) // 8
k, dim = 32, 16  # must match the build flags above
overhead = k * dim * 4 + 3 * (k + 1) * 8 + 4096  # centroids + offset tables + framing
assert payload <= file_bytes <= payload + overhead, (payload, file_bytes, overhead)
print(f"ivf container ok: {file_bytes} bytes for a {payload}-byte payload")
EOF
cargo run --release --bin zann -- serve "$IDX_DIR/ivf.zann" --nq 64 --nprobe 8 \
  | tee "$IDX_DIR/serve_ivf.txt"
grep -q "verified 64/64" "$IDX_DIR/serve_ivf.txt"
cargo run --release --bin zann -- build --out "$IDX_DIR/nsg.zann" \
  --backend nsg --codec roc --n 1500 --dim 16
cargo run --release --bin zann -- serve "$IDX_DIR/nsg.zann" --nq 32 --ef 32 \
  | tee "$IDX_DIR/serve_nsg.txt"
grep -q "verified 32/32" "$IDX_DIR/serve_nsg.txt"
rm -rf "$IDX_DIR"

echo "== rustfmt =="
cargo fmt --all -- --check

echo "== clippy (all targets, including the api module) =="
cargo clippy --all-targets -- -D warnings

echo "== rustdoc =="
cargo doc --no-deps --quiet

echo "ci.sh: all gates green"
