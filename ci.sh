#!/usr/bin/env bash
# Local CI gate for the zann workspace. Tier-1 (what the roadmap verifies)
# comes first; style/lint/doc gates follow so a tier-1 regression is
# reported before a formatting nit.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

echo "== compile bench harnesses and examples =="
cargo build --release --benches --examples

echo "== bench_search_qps smoke (JSON contract, IVF + graph backends) =="
# Tiny-N end-to-end run; validate that the emitted BENCH_search.json
# parses and carries the documented keys — including at least one
# graph-backend row served through the same AnnIndex path — so the bench
# wiring cannot rot silently. Writes to the repo-root default path so
# every CI run refreshes the committed perf-trajectory seed in place.
QPS_JSON="BENCH_search.json"
cargo bench --bench bench_search_qps -- \
  --n 2000 --nq 40 --k 16 --runs 1 --nprobe 4 --sweep-threads 2 \
  --codecs unc64,roc,ans-i4,pq-compressed,nsg:roc --out "$QPS_JSON"
python3 - "$QPS_JSON" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    d = json.load(f)
assert d["bench"] == "search_qps", d.get("bench")
for key in ("dataset", "n", "nq", "dim", "k", "seed", "env", "results"):
    assert key in d, f"missing top-level key {key}"
for key in ("rustc", "simd_level", "threads"):
    assert key in d["env"], f"missing env key {key}"
assert d["results"], "no result rows"
for row in d["results"]:
    for key in ("backend", "codec", "nprobe", "threads", "qps", "mean_ms", "p50_ms", "p95_ms"):
        assert key in row, f"missing row key {key}"
    assert row["qps"] > 0, row
    assert row["p95_ms"] >= row["p50_ms"], row
backends = {row["backend"] for row in d["results"]}
assert "ivf" in backends, backends
assert backends & {"nsg", "hnsw"}, f"no graph-backend row: {backends}"
print(f"bench JSON ok: {len(d['results'])} rows, backends {sorted(backends)}")
EOF

echo "== bench_decode smoke (decode-throughput JSON at repo root) =="
# Per-codec decode throughput (single-stream and interleaved ANS) plus
# the blocked ADC and fused coarse kernels scalar-vs-dispatched; the
# bench itself asserts bitwise kernel parity on this host. Refreshes the
# committed BENCH_decode.json in place.
cargo bench --bench bench_decode -- \
  --universe 200000 --list-lens 64,1024 --lists 8 --reps 2 \
  --adc-rows 4000 --coarse-k 64 --out BENCH_decode.json
python3 - BENCH_decode.json <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    d = json.load(f)
assert d["bench"] == "decode", d.get("bench")
for key in ("universe", "lists", "reps", "seed", "simd_level", "results", "adc", "coarse"):
    assert key in d, f"missing top-level key {key}"
assert d["simd_level"] in ("scalar", "sse4.1", "avx2"), d["simd_level"]
assert d["results"], "no decode rows"
codecs = {r["codec"] for r in d["results"]}
assert {"roc", "ans-i2", "ans-i4", "ans-i8"} <= codecs, codecs
for row in d["results"]:
    for key in ("codec", "list_len", "lists", "bits_per_id", "ids_per_s", "mb_per_s"):
        assert key in row, f"missing row key {key}"
    if row["list_len"] > 0:
        assert row["ids_per_s"] > 0, row
for section, keys in (("adc", ("codes_per_s_scalar", "codes_per_s_simd")),
                      ("coarse", ("rows_per_s_scalar", "rows_per_s_simd"))):
    for key in keys:
        assert d[section][key] > 0, (section, key, d[section])
print(f"decode JSON ok: {len(d['results'])} rows, simd_level={d['simd_level']}")
EOF
# A degenerate (zero-item) run must exit non-zero and leave no JSON.
DEGEN_JSON="$(mktemp -u /tmp/zann_degen.XXXXXX.json)"
if cargo bench --bench bench_decode -- --universe 1000 --list-lens 64 --lists 0 \
    --out "$DEGEN_JSON" >/dev/null 2>&1; then
  echo "bench_decode: degenerate zero-item run should have exited non-zero"; exit 1
fi
test ! -f "$DEGEN_JSON" || { echo "degenerate run wrote $DEGEN_JSON"; exit 1; }

echo "== SIMD vs scalar end-to-end identity (build->save->open->serve both ways) =="
# The dispatched kernels are documented bit-identical to the scalar
# reference; prove it end-to-end by serving the same saved containers —
# flat/ROC (coarse kernel) and PQ-compressed (blocked ADC scan) — under
# ZANN_SIMD=scalar and under the default dispatch, then byte-comparing
# the (query, rank, distance-bits, id) dumps.
SIMD_DIR="$(mktemp -d /tmp/zann_simd.XXXXXX)"
cargo run --release --bin zann -- build --out "$SIMD_DIR/flat.zann" \
  --backend ivf --codec roc --n 2000 --dim 16 --k 32
cargo run --release --bin zann -- build --out "$SIMD_DIR/pqc.zann" \
  --backend ivf --codec ans-i4 --vectors pq-compressed --m 4 --n 2000 --dim 16 --k 32
for IDX in flat pqc; do
  ZANN_SIMD=scalar cargo run --release --bin zann -- serve "$SIMD_DIR/$IDX.zann" \
    --nq 64 --nprobe 8 --dump-results "$SIMD_DIR/$IDX.scalar.txt" \
    | tee "$SIMD_DIR/$IDX.scalar.log"
  grep -q "verified 64/64" "$SIMD_DIR/$IDX.scalar.log"
  cargo run --release --bin zann -- serve "$SIMD_DIR/$IDX.zann" \
    --nq 64 --nprobe 8 --dump-results "$SIMD_DIR/$IDX.auto.txt" \
    | tee "$SIMD_DIR/$IDX.auto.log"
  grep -q "verified 64/64" "$SIMD_DIR/$IDX.auto.log"
  cmp "$SIMD_DIR/$IDX.scalar.txt" "$SIMD_DIR/$IDX.auto.txt" \
    || { echo "SIMD/scalar divergence on $IDX index"; exit 1; }
  test -s "$SIMD_DIR/$IDX.scalar.txt" || { echo "empty result dump for $IDX"; exit 1; }
done
echo "SIMD vs scalar: result dumps identical"
rm -rf "$SIMD_DIR"

echo "== persistence smoke: build -> save -> info -> serve =="
# Round-trip both index families through the container format and assert
# (a) the reopened file weighs ~ the compressed payload (header/codebook
# overhead only) and (b) every served response is bit-identical to a
# direct search on the reopened index.
IDX_DIR="$(mktemp -d /tmp/zann_idx.XXXXXX)"
cargo run --release --bin zann -- build --out "$IDX_DIR/ivf.zann" \
  --backend ivf --codec roc --n 2000 --dim 16 --k 32
cargo run --release --bin zann -- info "$IDX_DIR/ivf.zann" > "$IDX_DIR/info_ivf.txt"
cat "$IDX_DIR/info_ivf.txt"
python3 - "$IDX_DIR/info_ivf.txt" <<'EOF'
import sys
line = next(l for l in open(sys.argv[1]) if l.startswith("zann-index"))
kv = dict(tok.split("=", 1) for tok in line.split()[1:])
id_bits, code_bits, link_bits = (int(kv[k]) for k in ("id_bits", "code_bits", "link_bits"))
file_bytes = int(kv["file_bytes"])
payload = (id_bits + code_bits + link_bits + 7) // 8
k, dim = 32, 16  # must match the build flags above
overhead = k * dim * 4 + 3 * (k + 1) * 8 + 4096  # centroids + offset tables + framing
assert payload <= file_bytes <= payload + overhead, (payload, file_bytes, overhead)
print(f"ivf container ok: {file_bytes} bytes for a {payload}-byte payload")
EOF
cargo run --release --bin zann -- serve "$IDX_DIR/ivf.zann" --nq 64 --nprobe 8 \
  | tee "$IDX_DIR/serve_ivf.txt"
grep -q "verified 64/64" "$IDX_DIR/serve_ivf.txt"
cargo run --release --bin zann -- build --out "$IDX_DIR/nsg.zann" \
  --backend nsg --codec roc --n 1500 --dim 16
cargo run --release --bin zann -- serve "$IDX_DIR/nsg.zann" --nq 32 --ef 32 \
  | tee "$IDX_DIR/serve_nsg.txt"
grep -q "verified 32/32" "$IDX_DIR/serve_nsg.txt"
rm -rf "$IDX_DIR"

echo "== integrity: chaos sweep + corrupted-container rejection + deadline degradation =="
# (a) The fault-injection sweep: >=500 seeded mutations (bit flips,
# truncations, section swaps) across every codec x backend container;
# every mutant must be detected or harmless — a crash, hang or silently
# wrong answer exits non-zero (docs/REPRODUCING.md, failure-modes table).
CHAOS_DIR="$(mktemp -d /tmp/zann_chaos.XXXXXX)"
cargo run --release --bin zann -- inject-faults | tee "$CHAOS_DIR/chaos.log"
grep -q "verdict=PASS" "$CHAOS_DIR/chaos.log"
grep -Eq "mutations=([5-9][0-9][0-9]|[0-9]{4,})" "$CHAOS_DIR/chaos.log" \
  || { echo "chaos sweep ran fewer than 500 mutations"; exit 1; }
# (b) A v2 container advertises its checksums, and a single hand-flipped
# bit mid-file must be rejected by open (CRC-32C), not served.
cargo run --release --bin zann -- build --out "$CHAOS_DIR/victim.zann" \
  --backend ivf --codec roc --n 1000 --dim 8 --k 8
cargo run --release --bin zann -- info "$CHAOS_DIR/victim.zann" \
  | grep -q "checksummed=true"
python3 - "$CHAOS_DIR/victim.zann" <<'EOF'
import sys
path = sys.argv[1]
data = bytearray(open(path, "rb").read())
data[len(data) // 2] ^= 0x40  # one flipped bit, middle of a payload
open(path, "wb").write(bytes(data))
EOF
if cargo run --release --bin zann -- info "$CHAOS_DIR/victim.zann" \
    > "$CHAOS_DIR/corrupt_info.log" 2>&1; then
  echo "corrupted container was accepted by open"; exit 1
fi
echo "corrupted container rejected at open"
# (c) Deadline degradation: an oversized batch under a 1 ms per-query
# deadline must shed stragglers as structured Timeout responses (the
# metrics summary shows a nonzero timeouts= count) and still exit 0 —
# the hard timeout(1) wrapper proves "degrade", not "hang".
cargo run --release --bin zann -- build --out "$CHAOS_DIR/slow.zann" \
  --backend ivf --codec roc --n 2000 --dim 16 --k 32
timeout 120 cargo run --release --bin zann -- serve "$CHAOS_DIR/slow.zann" \
  --nq 4096 --batch 16 --nprobe 16 --deadline-ms 1 \
  | tee "$CHAOS_DIR/deadline.log"
grep -Eq "timeouts=[1-9]" "$CHAOS_DIR/deadline.log" \
  || { echo "tiny deadline produced no Timeout responses"; exit 1; }
rm -rf "$CHAOS_DIR"

echo "== durability: crash matrix + kill -9 mid-build proof + durable-dir info =="
# (a) The crash-injection matrix (docs/DURABILITY.md): >=200 injections
# across WAL ingest, checkpoints, node-dir shard swaps, torn WAL tails,
# boundary-torn containers and real kill -9 child processes. Every
# acknowledged write must recover bit-identically (lost_ack=0), no torn
# container may open (torn_open=0), and every directory must reopen.
CRASH_DIR="$(mktemp -d /tmp/zann_crash.XXXXXX)"
cargo run --release --bin zann -- inject-crashes | tee "$CRASH_DIR/crash.log"
grep -q "verdict=PASS" "$CRASH_DIR/crash.log"
grep -Eq "injections=([2-9][0-9][0-9]|[0-9]{4,}) " "$CRASH_DIR/crash.log" \
  || { echo "crash matrix ran fewer than 200 injections"; exit 1; }
grep -q "lost_ack=0 " "$CRASH_DIR/crash.log"
grep -q "torn_open=0 " "$CRASH_DIR/crash.log"
grep -q "no_recover=0 " "$CRASH_DIR/crash.log"
# (b) Shell-level atomic-commit proof: kill -9 a real `zann build` over
# an existing index at random moments; the destination must keep opening
# cleanly (complete old or complete new bytes, never torn). The binary is
# spawned directly — killing a `cargo run` wrapper would orphan the child.
ZANN_BIN=target/release/zann
cargo run --release --bin zann -- build --out "$CRASH_DIR/victim.zann" \
  --backend ivf --codec roc --n 1000 --dim 8 --k 8
for DELAY in 0.02 0.05 0.09; do
  "$ZANN_BIN" build --out "$CRASH_DIR/victim.zann" \
    --backend ivf --codec roc --n 60000 --dim 16 --k 64 >/dev/null 2>&1 &
  BUILD_PID=$!
  sleep "$DELAY"
  kill -9 "$BUILD_PID" 2>/dev/null || true
  wait "$BUILD_PID" 2>/dev/null || true
  "$ZANN_BIN" info "$CRASH_DIR/victim.zann" >/dev/null \
    || { echo "kill -9 mid-build tore the destination container"; exit 1; }
done
echo "atomic commit survives kill -9 mid-build"
# (c) `zann info` on a WAL-bearing durable directory reports the WAL and
# the pending (unreplayed-into-a-checkpoint) rows through the manifest.
# crash-victim seeds the directory, then ingests 24 acked batches of 8
# rows with checkpoints disabled, so all 192 rows are pending in the WAL.
"$ZANN_BIN" crash-victim "$CRASH_DIR/store" --seed 5 --rows 8 --batches 24 \
  --checkpoint-every 0 > /dev/null
"$ZANN_BIN" info "$CRASH_DIR/store" | tee "$CRASH_DIR/store_info.txt"
grep -q "durable kind=dynamic generation=0" "$CRASH_DIR/store_info.txt"
grep -Eq "wal_bytes=[1-9][0-9]*" "$CRASH_DIR/store_info.txt"
grep -q "pending_records=24 pending_rows=192 pending_deletes=0 torn_bytes=0" \
  "$CRASH_DIR/store_info.txt"
"$ZANN_BIN" info "$CRASH_DIR/store" --json > "$CRASH_DIR/store_info.json"
python3 - "$CRASH_DIR/store_info.json" <<'EOF'
import json, sys
d = json.load(open(sys.argv[1]))
dur = d["durable"]
assert dur["kind"] == "dynamic" and dur["generation"] == 0, dur
assert dur["pending_records"] == 24 and dur["pending_rows"] == 192, dur
assert dur["wal_bytes"] > 8 and dur["torn_bytes"] == 0, dur
assert d["stats"]["kind"] == "dynamic-ivf", d["stats"]
print(f"durable info ok: wal_bytes={dur['wal_bytes']}, "
      f"{dur['pending_rows']} pending rows")
EOF
rm -rf "$CRASH_DIR"

echo "== dynamic IVF smoke: build -> add -> delete -> compact -> parity =="
# Drive the mutable index through the CLI and assert (a) search recall
# parity: after churn + compaction, results are identical to a
# from-scratch static build over the same live set (check-parity exits
# non-zero on any divergence), and (b) the stats line reports the
# live/deleted/segment accounting.
DYN_DIR="$(mktemp -d /tmp/zann_dyn.XXXXXX)"
cargo run --release --bin zann -- build --out "$DYN_DIR/dyn.zann" \
  --backend dynamic --codec roc --n 3000 --dim 16 --k 32
cargo run --release --bin zann -- add "$DYN_DIR/dyn.zann" --add-n 600 --seed 7
cargo run --release --bin zann -- delete "$DYN_DIR/dyn.zann" --frac 0.2 --seed 8
cargo run --release --bin zann -- compact "$DYN_DIR/dyn.zann"
cargo run --release --bin zann -- info "$DYN_DIR/dyn.zann" | tee "$DYN_DIR/info_dyn.txt"
python3 - "$DYN_DIR/info_dyn.txt" <<'EOF'
import sys
line = next(l for l in open(sys.argv[1]) if l.startswith("zann-index"))
kv = dict(tok.split("=", 1) for tok in line.split()[1:])
assert kv["kind"] == "dynamic-ivf", kv["kind"]
# build 3000 + add 600, delete 20% of the 3600 live -> 2880 live.
assert int(kv["live"]) == 2880, kv["live"]
assert int(kv["deleted"]) == 0, f"post-compaction deleted={kv['deleted']}"
assert int(kv["buffer_rows"]) == 0, kv["buffer_rows"]
assert int(kv["segments"]) == 1, kv["segments"]
seg_bpi = [float(v) for v in kv["seg_bpi"].split(",")]
assert len(seg_bpi) == 1 and 0 < seg_bpi[0] < 64, seg_bpi
print(f"dynamic stats ok: live={kv['live']} seg_bpi={seg_bpi[0]:.3f}")
EOF
cargo run --release --bin zann -- check-parity "$DYN_DIR/dyn.zann" --nq 64 --nprobe 8 \
  | tee "$DYN_DIR/parity.txt"
grep -q "parity: 64/64" "$DYN_DIR/parity.txt"
# A compacted dynamic container serves through the same coordinator path.
cargo run --release --bin zann -- serve "$DYN_DIR/dyn.zann" --nq 32 --nprobe 8 \
  | tee "$DYN_DIR/serve_dyn.txt"
grep -q "verified 32/32" "$DYN_DIR/serve_dyn.txt"
rm -rf "$DYN_DIR"

echo "== bench_churn smoke (JSON contract + parity + compression gate) =="
CHURN_JSON="$(mktemp /tmp/zann_bench_churn.XXXXXX.json)"
cargo bench --bench bench_churn -- \
  --n 2500 --nq 40 --k 32 --churn 0.2 --nprobe 8 --out "$CHURN_JSON"
python3 - "$CHURN_JSON" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    d = json.load(f)
assert d["bench"] == "churn", d.get("bench")
for key in ("dataset", "n", "inserts", "deletes", "dim", "k", "codec", "seed", "nq",
            "insert_per_s", "delete_per_s", "compact_s", "segments_before_compact",
            "pre_compact_bits_per_id", "bits_per_id_dynamic", "bits_per_id_static",
            "bpi_ratio", "queries_identical", "results_identical"):
    assert key in d, f"missing key {key}"
assert d["results_identical"] is True, d
assert d["queries_identical"] == d["nq"] == 40, d
assert d["bpi_ratio"] <= 1.02, f"compression decayed under churn: {d['bpi_ratio']}"
assert d["insert_per_s"] > 0 and d["delete_per_s"] > 0, d
print(f"churn JSON ok: ratio={d['bpi_ratio']:.4f}, "
      f"{d['queries_identical']}/{d['nq']} queries identical")
EOF
rm -f "$CHURN_JSON"

echo "== bench_recall smoke + committed-baseline regression gate =="
# Recall-aware eval: sweep codec × backend × search knob against exact
# groundtruth at tiny scale, refresh the committed BENCH_recall.json in
# place, and gate recall against the committed baseline. Recall is
# exact-match (lossless ids + seeded pipeline ⇒ any drop at equal
# parameters is a correctness bug, not noise); QPS stays advisory on
# this runner. The gate is then *proven to fire* three ways: a
# corrupted-ids sweep, a hand-perturbed recall value, and a zero-query
# run that must refuse to write at all.
RECALL_JSON="BENCH_recall.json"
RECALL_BASE="rust/tests/fixtures/recall_baseline.json"
RECALL_FLAGS=(--n 3000 --nq 80 --dim 16 --k 32 --knobs 4,32 --runs 1
              --codecs unc64,roc,ans-i4 --churn 0.2 --seed 42 --dataset sift)
cargo bench --bench bench_recall -- "${RECALL_FLAGS[@]}" --out "$RECALL_JSON"
python3 tools/check_recall_baseline.py "$RECALL_JSON" "$RECALL_BASE" \
  --require-backends ivf,ivf-pq,nsg,hnsw,dynamic
# First toolchain-equipped run: replace the placeholder baseline with
# this run's measured numbers so later runs gate against real recall.
python3 - "$RECALL_JSON" "$RECALL_BASE" <<'EOF'
import json, sys
fresh_path, base_path = sys.argv[1], sys.argv[2]
with open(base_path) as f:
    base = json.load(f)
if base.get("provenance") == "placeholder":
    with open(fresh_path) as f:
        fresh = json.load(f)
    env = fresh["env"]
    fresh["provenance"] = "measured by ci.sh ({} / {})".format(
        env["rustc"], env["simd_level"])
    with open(base_path, "w") as f:
        json.dump(fresh, f, indent=2)
        f.write("\n")
    print(f"bootstrapped measured baseline into {base_path}")
else:
    print("baseline already measured; gate compared real numbers")
EOF
python3 tools/check_recall_baseline.py "$RECALL_JSON" "$RECALL_BASE" \
  --require-backends ivf,ivf-pq,nsg,hnsw,dynamic
# Gate-fires proof (a): a corrupted-ids sweep (every returned id
# bit-flipped at scoring time) must fail the checker.
SAB_JSON="$(mktemp /tmp/zann_recall_sab.XXXXXX.json)"
cargo bench --bench bench_recall -- "${RECALL_FLAGS[@]}" --corrupt-ids --out "$SAB_JSON"
if python3 tools/check_recall_baseline.py "$SAB_JSON" "$RECALL_BASE" >/dev/null 2>&1; then
  echo "recall gate FAILED TO FIRE on corrupted ids"; exit 1
fi
echo "recall gate fires on corrupted ids"
rm -f "$SAB_JSON"
# Gate-fires proof (b): a single hand-perturbed recall value (-0.05 on
# one row) must fail the numeric comparison path too.
PERT_JSON="$(mktemp /tmp/zann_recall_pert.XXXXXX.json)"
python3 - "$RECALL_JSON" "$PERT_JSON" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    d = json.load(f)
row = d["results"][0]
assert row["recall_at_10"] > 0.05, "smoke recall too low to perturb meaningfully"
row["recall_at_10"] -= 0.05
with open(sys.argv[2], "w") as f:
    json.dump(d, f)
EOF
if python3 tools/check_recall_baseline.py "$PERT_JSON" "$RECALL_BASE" >/dev/null 2>&1; then
  echo "recall gate FAILED TO FIRE on a perturbed recall value"; exit 1
fi
echo "recall gate fires on a perturbed recall value"
rm -f "$PERT_JSON"
# Gate-fires proof (c): a zero-query run must exit non-zero and write
# nothing — an empty report may never poison the recall trajectory.
DEGEN_RECALL="$(mktemp -u /tmp/zann_recall_degen.XXXXXX.json)"
if cargo bench --bench bench_recall -- --n 1000 --nq 0 --out "$DEGEN_RECALL" \
    >/dev/null 2>&1; then
  echo "bench_recall: zero-query run should have exited non-zero"; exit 1
fi
test ! -f "$DEGEN_RECALL" || { echo "degenerate run wrote $DEGEN_RECALL"; exit 1; }

echo "== bench_serve smoke (sharded node JSON contract) =="
# Tiny-scale mixed read/write run over a 4-shard mutable node; validate
# the documented BENCH_serve.json schema (docs/REPRODUCING.md): workload
# params, env manifest, shard balance, aggregate + per-tenant stats, the
# post-overload liveness bit and the snapshot/restore parity stamp.
SERVE_JSON="BENCH_serve.json"
cargo bench --bench bench_serve -- \
  --n 3000 --nq 100 --dim 16 --requests 400 --shards 4 --router kmeans \
  --codec roc --tenants 3 --theta 0.99 --write-frac 0.1 --clients 2 \
  --runs 1 --out "$SERVE_JSON"
python3 - "$SERVE_JSON" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    d = json.load(f)
assert d["bench"] == "serve", d.get("bench")
for key in ("dataset", "n", "nq", "dim", "seed", "shards", "router", "codec",
            "tenants", "theta", "write_frac", "requests", "env", "shard_rows",
            "shard_imbalance", "queue_hwm", "total", "post_ok", "snapshot",
            "tenants_rows"):
    assert key in d, f"missing top-level key {key}"
for key in ("rustc", "simd_level", "threads"):
    assert key in d["env"], f"missing env key {key}"
assert d["shards"] == 4 and len(d["shard_rows"]) == 4, d["shard_rows"]
assert all(r > 0 for r in d["shard_rows"]), f"empty shard: {d['shard_rows']}"
assert d["shard_imbalance"] >= 1.0, d["shard_imbalance"]
for row in [d["total"]] + d["tenants_rows"]:
    for key in ("requests", "ok", "rejected", "timeouts", "failed",
                "qps", "p50_ms", "p95_ms", "p99_ms"):
        assert key in row, f"missing stats key {key} in {row}"
assert d["total"]["ok"] > 0 and d["total"]["qps"] > 0, d["total"]
assert len(d["tenants_rows"]) == d["tenants"] == 3, d["tenants_rows"]
assert sum(r["requests"] for r in d["tenants_rows"]) == d["total"]["requests"]
assert d["post_ok"] is True, "node dead after the measured run"
assert d["snapshot"]["verified"] is True and d["snapshot"]["queries"] > 0, d["snapshot"]
print(f"serve JSON ok: {d['total']['ok']} served over {d['shards']} shards, "
      f"imbalance {d['shard_imbalance']:.2f}, p99 {d['total']['p99_ms']:.3f} ms")
EOF
# A zero-request run must exit non-zero before building anything and
# leave no JSON behind.
DEGEN_SERVE="$(mktemp -u /tmp/zann_serve_degen.XXXXXX.json)"
if cargo bench --bench bench_serve -- --n 1000 --requests 0 --out "$DEGEN_SERVE" \
    >/dev/null 2>&1; then
  echo "bench_serve: zero-request run should have exited non-zero"; exit 1
fi
test ! -f "$DEGEN_SERVE" || { echo "degenerate run wrote $DEGEN_SERVE"; exit 1; }

echo "== admission gate-fires proof (greedy tenant shed, quiet tenant served) =="
# Zipf-skewed tenants against a fixed per-tenant budget (rate 0 => the
# token bucket admits exactly --tenant-burst reads per tenant, so the
# shed counts are deterministic): the greedy head tenant must see
# nonzero rejections, a well-behaved tail tenant must see none, and the
# node must still answer afterwards (post_ok).
OVER_JSON="$(mktemp /tmp/zann_serve_over.XXXXXX.json)"
OVER_PROM="$(mktemp /tmp/zann_serve_over.XXXXXX.prom)"
cargo bench --bench bench_serve -- \
  --n 3000 --nq 100 --dim 16 --requests 300 --shards 2 --router hash \
  --codec roc --tenants 4 --theta 1.3 --write-frac 0.0 --clients 2 \
  --runs 1 --tenant-burst 60 --tenant-rate 0 --out "$OVER_JSON" \
  --metrics-prom "$OVER_PROM"
python3 - "$OVER_JSON" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    d = json.load(f)
rows = {r["tenant"]: r for r in d["tenants_rows"]}
greedy = rows["t0"]
assert greedy["rejected"] > 0, f"admission gate never fired: {greedy}"
assert greedy["ok"] == 60, f"rate=0 budget must admit exactly burst: {greedy}"
quiet = min(rows.values(), key=lambda r: r["requests"])
assert quiet["requests"] > 0, rows
assert quiet["rejected"] == 0, f"well-behaved tenant was shed: {quiet}"
assert quiet["ok"] == quiet["requests"], quiet
assert d["total"]["rejected"] == sum(r["rejected"] for r in rows.values())
assert d["post_ok"] is True, "node dead after overload"
print(f"admission gate ok: t0 shed {greedy['rejected']}, "
      f"{quiet['tenant']} fully served ({quiet['ok']}/{quiet['requests']})")
EOF
# The same run populates the observability registry's per-shard and
# per-tenant series — the only CLI workload that exercises both — so the
# exposition must carry them (docs/OBSERVABILITY.md catalog).
python3 - "$OVER_PROM" <<'EOF'
import sys
text = open(sys.argv[1]).read()
for needle in ('zann_shard_queries_total{shard="0"}',
               'zann_shard_queries_total{shard="1"}',
               'zann_tenant_admitted_total{tenant="t0"}',
               'zann_tenant_rejected_total{tenant="t0"}'):
    assert needle in text, f"missing per-shard/per-tenant series {needle}"
# The greedy tenant's registry totals must agree with the bench report:
# exactly burst=60 admitted reads per measured pass.
line = next(l for l in text.splitlines()
            if l.startswith('zann_tenant_admitted_total{tenant="t0"}'))
assert int(line.split()[-1]) >= 60, line
print("per-shard/per-tenant exposition ok")
EOF
rm -f "$OVER_JSON" "$OVER_PROM"

echo "== sharded scatter-gather == single index (build -> info -> serve cmp) =="
# The tentpole end-to-end identity: a 1-shard and a 4-shard container
# built from the same vectors must serve byte-identical
# (query, rank, distance-bits, id) dumps — scatter-gather with the
# (distance, id)-pinned merge is indistinguishable from one big index.
SHARD_DIR="$(mktemp -d /tmp/zann_shard.XXXXXX)"
cargo run --release --bin zann -- build --out "$SHARD_DIR/s1.zann" \
  --backend sharded --shards 1 --router hash --codec roc --n 2000 --dim 16 --k 32
cargo run --release --bin zann -- build --out "$SHARD_DIR/s4.zann" \
  --backend sharded --shards 4 --router kmeans --codec roc --n 2000 --dim 16 --k 32
cargo run --release --bin zann -- info "$SHARD_DIR/s4.zann" | tee "$SHARD_DIR/info_s4.txt"
grep -q "kind=sharded" "$SHARD_DIR/info_s4.txt"
grep -q "router=kmeans shards=4" "$SHARD_DIR/info_s4.txt"
test "$(grep -c '^shard [0-9]*: zann-index' "$SHARD_DIR/info_s4.txt")" -eq 4 \
  || { echo "info did not print one line per shard"; exit 1; }
for IDX in s1 s4; do
  cargo run --release --bin zann -- serve "$SHARD_DIR/$IDX.zann" \
    --nq 64 --nprobe 8 --dump-results "$SHARD_DIR/$IDX.txt" \
    --metrics-json "$SHARD_DIR/$IDX.metrics.json" | tee "$SHARD_DIR/$IDX.log"
  grep -q "verified 64/64" "$SHARD_DIR/$IDX.log"
done
cmp "$SHARD_DIR/s1.txt" "$SHARD_DIR/s4.txt" \
  || { echo "sharded scatter-gather diverged from the single index"; exit 1; }
test -s "$SHARD_DIR/s1.txt" || { echo "empty sharded result dump"; exit 1; }
echo "1-shard vs 4-shard result dumps identical"
# serve --metrics-json: machine-readable coordinator counters including
# the queue-depth high-water mark.
python3 - "$SHARD_DIR/s4.metrics.json" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    m = json.load(f)
for key in ("queries", "batches", "p50_us", "p99_us", "timeouts", "rejections",
            "worker_panics", "queue_hwm"):
    assert key in m, f"missing metrics key {key}"
assert m["queries"] >= 64, m
assert m["queue_hwm"] > 0, m
print(f"serve metrics ok: {m['queries']} queries, queue_hwm={m['queue_hwm']}")
EOF
# info over a *directory* of shard containers: aggregate + per-shard.
mkdir "$SHARD_DIR/fleet"
cp "$SHARD_DIR/s1.zann" "$SHARD_DIR/fleet/a.zann"
cp "$SHARD_DIR/s4.zann" "$SHARD_DIR/fleet/b.zann"
cargo run --release --bin zann -- info "$SHARD_DIR/fleet" | tee "$SHARD_DIR/info_dir.txt"
grep -q "2 shard containers" "$SHARD_DIR/info_dir.txt"
grep -q "n=4000" "$SHARD_DIR/info_dir.txt"
# info --json: machine-readable per-section bits for a sharded container
# and for a directory of containers; both must parse with a real JSON
# parser and agree with the grep-able stats line.
cargo run --release --bin zann -- info "$SHARD_DIR/s4.zann" --json \
  > "$SHARD_DIR/info_s4.json"
cargo run --release --bin zann -- info "$SHARD_DIR/fleet" --json \
  > "$SHARD_DIR/info_dir.json"
python3 - "$SHARD_DIR/info_s4.json" "$SHARD_DIR/info_dir.json" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    s4 = json.load(f)
assert s4["router"] == "kmeans" and s4["num_shards"] == 4, s4
for section in [s4["aggregate"]] + s4["shards"]:
    for key in ("kind", "codec", "n", "dim", "id_bits", "code_bits", "link_bits",
                "aux_bits", "bits_per_id", "bits_per_link", "checksummed",
                "segments", "seg_bits_per_id"):
        assert key in section, f"missing info key {key} in {section}"
assert s4["aggregate"]["kind"] == "sharded", s4["aggregate"]
assert s4["aggregate"]["n"] == 2000 and len(s4["shards"]) == 4, s4
assert s4["aggregate"]["checksummed"] is True, s4["aggregate"]
assert s4["aggregate"]["n"] == sum(sh["n"] for sh in s4["shards"]), s4
assert 0 < s4["aggregate"]["bits_per_id"] < 64, s4["aggregate"]
assert s4["aggregate"]["file_bytes"] > 0, s4["aggregate"]
with open(sys.argv[2]) as f:
    fleet = json.load(f)
assert fleet["num_shards"] == 2 and fleet["aggregate"]["n"] == 4000, fleet
print(f"info --json ok: sharded bits/id {s4['aggregate']['bits_per_id']:.3f}, "
      f"fleet n={fleet['aggregate']['n']}")
EOF
rm -rf "$SHARD_DIR"

echo "== observability: exposition contracts, tracer fires, obs-off identity =="
OBS_DIR="$(mktemp -d /tmp/zann_obs.XXXXXX)"
cargo run --release --bin zann -- build --out "$OBS_DIR/idx.zann" \
  --backend ivf --codec roc --n 2000 --dim 16 --k 32
# (a) Fully-sampled serve run: Prometheus text format, superset metrics
# JSON, and the span dump all come out of one run.
ZANN_TRACE_SAMPLE=1/1 cargo run --release --bin zann -- serve "$OBS_DIR/idx.zann" \
  --nq 64 --nprobe 8 --dump-results "$OBS_DIR/on.txt" \
  --metrics-json "$OBS_DIR/metrics.json" --metrics-prom "$OBS_DIR/metrics.prom" \
  --trace-dump "$OBS_DIR/spans.json" | tee "$OBS_DIR/on.log"
grep -q "verified 64/64" "$OBS_DIR/on.log"
# The text format must survive a real parser: TYPE before samples, every
# sample line well-formed, histogram buckets cumulative up to an
# explicit +Inf that equals _count, and the catalog's per-codec /
# per-coordinator / SIMD-tier series present.
python3 - "$OBS_DIR/metrics.prom" <<'EOF'
import re, sys
from collections import defaultdict
typed, series = {}, []
sample_re = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^{}]*\})? (-?[0-9]+(?:\.[0-9]+)?(?:[eE][+-]?[0-9]+)?)$')
label_re = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
for ln, line in enumerate(open(sys.argv[1]), 1):
    line = line.rstrip("\n")
    if not line:
        continue
    if line.startswith("# TYPE "):
        _, _, name, kind = line.split(" ")
        assert kind in ("counter", "gauge", "histogram"), line
        typed[name] = kind
        continue
    assert not line.startswith("#"), f"unexpected comment: {line}"
    m = sample_re.match(line)
    assert m, f"line {ln} is not a valid prometheus sample: {line!r}"
    name, labels = m.group(1), m.group(2) or ""
    base = re.sub(r'_(bucket|sum|count)$', '', name)
    assert name in typed or base in typed, f"sample before its TYPE line: {line}"
    series.append((name, labels, float(m.group(3))))
joined = "\n".join(f"{n}{l} {v}" for n, l, v in series)
for needle in ('zann_ids_decoded_total{codec="roc"}',
               'zann_lists_probed_total{codec="roc"}',
               'zann_id_bits_decoded_total{codec="roc"}',
               'zann_simd_dispatch_total{level=',
               'zann_queries_total{coord=',
               'zann_queue_hwm{coord='):
    assert needle in joined, f"missing catalog series {needle}"
hist, counts = defaultdict(list), {}
for n, l, v in series:
    labels = label_re.findall(l)
    if n.endswith("_bucket"):
        le = dict(labels)["le"]
        rest = tuple(sorted(kv for kv in labels if kv[0] != "le"))
        hist[(n[:-7], rest)].append((le, v))
    elif n.endswith("_count") and re.sub(r'_count$', '', n) in typed \
            and typed[re.sub(r'_count$', '', n)] == "histogram":
        counts[(n[:-6], tuple(sorted(labels)))] = v
assert hist, "no histogram buckets exposed"
for key, bs in hist.items():
    vals = [v for _, v in bs]
    assert vals == sorted(vals), f"non-cumulative buckets for {key}: {bs}"
    assert bs[-1][0] == "+Inf", f"missing +Inf bucket for {key}"
    assert bs[-1][1] == counts.get(key), f"+Inf != _count for {key}"
assert any(k[0] == "zann_query_latency_us" for k in hist), sorted(hist)
assert any(k[0] == "zann_stage_us" for k in hist), "tracer stage histograms missing"
print(f"prom exposition ok: {len(series)} samples, {len(typed)} TYPE decls, "
      f"{len(hist)} histogram series")
EOF
# Tracer-fires proof: a 1/1-sampled run must dump spans, and each span's
# stage timeline must account for its end-to-end latency within 10%.
python3 - "$OBS_DIR/spans.json" <<'EOF'
import json, sys
spans = json.load(open(sys.argv[1]))
assert isinstance(spans, list) and len(spans) >= 1, "sampled run recorded no spans"
for s in spans:
    assert s["total_ns"] > 0, s
    assert abs(s["stage_sum_ns"] - s["total_ns"]) <= 0.1 * s["total_ns"], s
    assert s["stages"], s
stages = set().union(*(s["stages"] for s in spans))
assert "queue_wait" in stages and "reply" in stages, stages
print(f"tracer ok: {len(spans)} spans, stage-sum within 10% of e2e, stages {sorted(stages)}")
EOF
# The metrics JSON stays a superset: historical flat keys unchanged,
# whole registry under "registry".
python3 - "$OBS_DIR/metrics.json" <<'EOF'
import json, sys
m = json.load(open(sys.argv[1]))
for key in ("queries", "batches", "mean_batch_fill", "pjrt_fraction", "p50_us",
            "p95_us", "p99_us", "timeouts", "rejections", "worker_panics",
            "queue_hwm", "registry"):
    assert key in m, f"missing metrics key {key}"
names = {s["name"] for s in m["registry"]["series"]}
assert "zann_queries_total" in names and "zann_query_latency_us" in names, sorted(names)
print(f"metrics superset ok: {len(names)} registry names alongside the flat keys")
EOF
# (b) Observation must not perturb: the sampled dump, the unsampled
# (sampling 0) dump, and the obs-feature-compiled-out dump must be
# byte-identical.
cargo run --release --bin zann -- serve "$OBS_DIR/idx.zann" \
  --nq 64 --nprobe 8 --dump-results "$OBS_DIR/unsampled.txt" >/dev/null
cmp "$OBS_DIR/on.txt" "$OBS_DIR/unsampled.txt" \
  || { echo "sampling changed search results"; exit 1; }
cargo run --release --no-default-features --bin zann -- serve "$OBS_DIR/idx.zann" \
  --nq 64 --nprobe 8 --dump-results "$OBS_DIR/obsoff.txt" \
  --metrics-prom "$OBS_DIR/obsoff.prom" --trace-dump "$OBS_DIR/obsoff_spans.json" \
  >/dev/null
cmp "$OBS_DIR/on.txt" "$OBS_DIR/obsoff.txt" \
  || { echo "obs feature changed search results"; exit 1; }
test -s "$OBS_DIR/on.txt" || { echo "empty obs result dump"; exit 1; }
# The obs-off build must compile (it just did) and emit nothing: no
# zann_ series in the exposition, no spans even under full sampling.
if grep -q "zann_" "$OBS_DIR/obsoff.prom"; then
  echo "obs-off build exported series"; exit 1
fi
ZANN_TRACE_SAMPLE=1/1 cargo run --release --no-default-features --bin zann -- \
  serve "$OBS_DIR/idx.zann" --nq 64 --nprobe 8 \
  --trace-dump "$OBS_DIR/obsoff_sampled.json" >/dev/null
python3 - "$OBS_DIR/obsoff_sampled.json" <<'EOF'
import json, sys
assert json.load(open(sys.argv[1])) == [], "obs-off build recorded spans"
print("obs-off identity ok: bit-identical results, zero series, zero spans")
EOF
# (c) `zann metrics` smoke: both renderings of a self-contained workload.
cargo run --release --bin zann -- metrics --n 2000 --nq 32 > "$OBS_DIR/cmd.prom"
grep -q "# TYPE zann_queries_total counter" "$OBS_DIR/cmd.prom"
grep -q "zann_ids_decoded_total" "$OBS_DIR/cmd.prom"
cargo run --release --bin zann -- metrics --n 2000 --nq 32 --json > "$OBS_DIR/cmd.json"
python3 - "$OBS_DIR/cmd.json" <<'EOF'
import json, sys
d = json.load(open(sys.argv[1]))
assert d["series"], "zann metrics --json produced no series"
assert {"name", "type"} <= set(d["series"][0]), d["series"][0]
print(f"zann metrics ok: {len(d['series'])} series in both renderings")
EOF
# (d) info --json on a plain (non-sharded) container.
cargo run --release --bin zann -- info "$OBS_DIR/idx.zann" --json \
  > "$OBS_DIR/info.json"
python3 - "$OBS_DIR/info.json" <<'EOF'
import json, sys
d = json.load(open(sys.argv[1]))
for key in ("kind", "codec", "n", "dim", "id_bits", "code_bits", "link_bits",
            "aux_bits", "bits_per_id", "bits_per_link", "checksummed",
            "file_bytes"):
    assert key in d, f"missing info key {key}"
assert d["kind"] == "ivf" and d["codec"] == "roc" and d["n"] == 2000, d
assert d["checksummed"] is True and 0 < d["bits_per_id"] < 64, d
print(f"info --json ok: {d['bits_per_id']:.3f} bits/id, {d['file_bytes']} bytes")
EOF
rm -rf "$OBS_DIR"

echo "== bench_obs: instrumentation self-measurement (overhead gate) =="
# The observability layer measures its own cost: the same serve workload
# with tracing off vs tracing every query. Refreshes BENCH_obs.json in
# place; full tracing must stay within 5% overhead and the sampled stage
# timelines must account for end-to-end latency within 10%
# (docs/REPRODUCING.md, docs/OBSERVABILITY.md).
cargo bench --bench bench_obs -- \
  --n 4000 --nq 512 --dim 16 --k 64 --nprobe 8 --runs 3 --out BENCH_obs.json
python3 - BENCH_obs.json <<'EOF'
import json, sys
d = json.load(open(sys.argv[1]))
assert d["bench"] == "obs", d.get("bench")
for key in ("dataset", "n", "nq", "dim", "seed", "k", "nprobe", "runs", "env",
            "wall_off_s", "wall_on_s", "overhead_frac", "sampled_spans",
            "span_sum_ratio", "registry_series", "stages"):
    assert key in d, f"missing top-level key {key}"
assert d["wall_off_s"] > 0 and d["wall_on_s"] > 0, d
assert d["sampled_spans"] >= 1, "self-measurement sampled no spans"
assert d["overhead_frac"] <= 0.05, \
    f"full tracing costs {d['overhead_frac']:.2%} (> 5% budget)"
assert abs(d["span_sum_ratio"] - 1.0) <= 0.1, d["span_sum_ratio"]
assert d["registry_series"] > 0, d
assert len(d["stages"]) == 9, [s["stage"] for s in d["stages"]]
assert all(s["mean_us"] >= 0 for s in d["stages"]), d["stages"]
print(f"obs bench ok: overhead {d['overhead_frac']:+.2%}, "
      f"{d['sampled_spans']} spans, stage-sum ratio {d['span_sum_ratio']:.4f}")
EOF

echo "== rustfmt =="
cargo fmt --all -- --check

echo "== clippy (all targets, including the api module) =="
cargo clippy --all-targets -- -D warnings

echo "== rustdoc =="
cargo doc --no-deps --quiet

echo "ci.sh: all gates green"
