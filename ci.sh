#!/usr/bin/env bash
# Local CI gate for the zann workspace. Tier-1 (what the roadmap verifies)
# comes first; style/lint/doc gates follow so a tier-1 regression is
# reported before a formatting nit.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

echo "== compile bench harnesses and examples =="
cargo build --release --benches --examples

echo "== rustfmt =="
cargo fmt --all -- --check

echo "== clippy =="
cargo clippy --all-targets -- -D warnings

echo "== rustdoc =="
cargo doc --no-deps --quiet

echo "ci.sh: all gates green"
