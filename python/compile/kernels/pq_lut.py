"""Layer-1 Pallas kernel: PQ ADC look-up-table construction.

For asymmetric distance computation, each query needs a table
``lut[m, k] = ||q[m] - C[m][k]||^2`` over the M sub-quantizers and their KS
centroids.  The kernel grids over (query block, sub-quantizer) and computes
one (BQ, KS) tile per step with a single MXU contraction over the sub-vector
dimension DS.

VMEM per step (f32): BQ*DS + KS*DS + BQ*KS floats — for BQ=64, KS=256,
DS<=16: 64*16 + 256*16 + 64*256 = 21.5K floats = 86 KiB.  The KS=256 lane
dimension is 2x the 128-lane width, i.e. two registers per sublane — fine.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 64


def _pq_lut_kernel(q_ref, c_ref, o_ref):
    """One (BQ, KS) tile of the LUT for a single sub-quantizer m."""
    q = q_ref[0].astype(jnp.float32)  # (BQ, DS)   [m axis is blocked to 1]
    c = c_ref[0].astype(jnp.float32)  # (KS, DS)
    qn = jnp.sum(q * q, axis=1, keepdims=True)  # (BQ, 1)
    cn = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, KS)
    dot = jax.lax.dot_general(
        q,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0] = qn + cn - 2.0 * dot


def _pad_axis0(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    rem = (-x.shape[0]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[0] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bq",))
def pq_lut(
    queries: jnp.ndarray, codebooks: jnp.ndarray, bq: int = DEFAULT_BQ
) -> jnp.ndarray:
    """ADC look-up tables.

    Args:
      queries:   (Q, M, DS) — queries split into sub-vectors.
      codebooks: (M, KS, DS) — PQ codebooks.
      bq:        query block size.
    Returns:
      (Q, M, KS) float32 tables.
    """
    if queries.ndim != 3 or codebooks.ndim != 3:
        raise ValueError("pq_lut expects (Q,M,DS) and (M,KS,DS)")
    nq, m, ds = queries.shape
    mc, ks, dsc = codebooks.shape
    if (m, ds) != (mc, dsc):
        raise ValueError(f"shape mismatch: {queries.shape} vs {codebooks.shape}")

    q = _pad_axis0(queries.astype(jnp.float32), bq)  # (Qp, M, DS)
    # Kernel wants the m axis leading per tile: (M, BQ, DS).
    qt = jnp.swapaxes(q, 0, 1)  # (M, Qp, DS)
    c = codebooks.astype(jnp.float32)  # (M, KS, DS)
    gq = q.shape[0] // bq

    out = pl.pallas_call(
        _pq_lut_kernel,
        grid=(gq, m),
        in_specs=[
            pl.BlockSpec((1, bq, ds), lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, ks, ds), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, ks), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, q.shape[0], ks), jnp.float32),
        interpret=True,
    )(qt, c)
    return jnp.swapaxes(out, 0, 1)[:nq]  # (Q, M, KS)
