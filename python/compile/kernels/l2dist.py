"""Layer-1 Pallas kernel: blocked pairwise squared-L2 distance.

The search-time hot spot of IVF coarse assignment is scoring a batch of
queries against all K coarse centroids.  On TPU this is MXU work: we tile
the (Q, D) x (D, K) contraction into VMEM-resident blocks of
(BQ, D) x (D, BK) and accumulate ``-2 q . c^T`` on the systolic array,
adding the squared norms on the way out.  The paper runs this part of the
pipeline on CPU; the kernel is lowered with ``interpret=True`` so the same
HLO executes on the PJRT CPU plugin (see DESIGN.md §Hardware-Adaptation).

VMEM accounting (per grid step, f32):
    BQ*D + D*BK + BQ*BK  floats.
With the default BQ=64, BK=128 and D<=128 this is at most
64*128 + 128*128 + 64*128 = 32K floats = 128 KiB, comfortably inside the
~16 MiB VMEM budget; the block shapes are MXU-aligned (multiples of 8x128).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes. BK is the lane dimension (128 = TPU lane width);
# BQ is the sublane dimension (multiple of 8 for f32).
DEFAULT_BQ = 64
DEFAULT_BK = 128


def _l2dist_kernel(q_ref, c_ref, qn_ref, cn_ref, o_ref):
    """One (BQ, BK) output tile: qn + cn - 2 * q @ c^T."""
    q = q_ref[...].astype(jnp.float32)  # (BQ, D)
    c = c_ref[...].astype(jnp.float32)  # (BK, D)
    # MXU contraction. preferred_element_type keeps accumulation in f32
    # even for bf16 inputs.
    dot = jax.lax.dot_general(
        q,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BQ, BK)
    o_ref[...] = qn_ref[...] + cn_ref[...] - 2.0 * dot


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bq", "bk"))
def l2dist(
    queries: jnp.ndarray,
    centroids: jnp.ndarray,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
) -> jnp.ndarray:
    """Pairwise squared-L2 distances via the blocked Pallas kernel.

    Args:
      queries:   (Q, D) float array.
      centroids: (K, D) float array.
      bq, bk:    block sizes along Q and K.
    Returns:
      (Q, K) float32 distances.
    """
    if queries.ndim != 2 or centroids.ndim != 2:
        raise ValueError("l2dist expects 2-D operands")
    if queries.shape[1] != centroids.shape[1]:
        raise ValueError(
            f"dim mismatch: {queries.shape[1]} vs {centroids.shape[1]}"
        )
    nq, _ = queries.shape
    nk, _ = centroids.shape

    q = queries.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    # Squared norms are computed once outside the grid (O(ND) vs O(NKD))
    # and streamed into each tile.
    qn = jnp.sum(q * q, axis=1, keepdims=True)  # (Q, 1)
    cn = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, K)

    # Pad every operand to block multiples; padded rows produce garbage
    # rows/cols that are sliced away at the end.
    qp = _pad_to(q, 0, bq)
    cp = _pad_to(c, 0, bk)
    qnp_ = _pad_to(qn, 0, bq)
    cnp_ = _pad_to(cn, 1, bk)
    gq = qp.shape[0] // bq
    gk = cp.shape[0] // bk
    d = qp.shape[1]

    out = pl.pallas_call(
        _l2dist_kernel,
        grid=(gq, gk),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),  # queries: row block
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),  # centroids: col block
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),  # |q|^2
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),  # |c|^2
        ],
        out_specs=pl.BlockSpec((bq, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], cp.shape[0]), jnp.float32),
        interpret=True,  # CPU-PJRT target; see module docstring.
    )(qp, cp, qnp_, cnp_)
    return out[:nq, :nk]
