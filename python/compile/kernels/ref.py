"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle here to float32 tolerance for all shapes/dtypes the
hypothesis sweep in python/tests generates.
"""

import jax.numpy as jnp


def l2dist_ref(queries: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """Exact pairwise squared-L2 distances.

    Args:
      queries:   (Q, D) float array.
      centroids: (K, D) float array.
    Returns:
      (Q, K) float32 array with ``out[i, j] = ||q_i - c_j||^2``.
    """
    q = queries.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    # Expanded form; numerically matches the kernel's |q|^2 + |c|^2 - 2qc.
    qn = jnp.sum(q * q, axis=1, keepdims=True)  # (Q, 1)
    cn = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, K)
    return qn + cn - 2.0 * (q @ c.T)


def pq_lut_ref(queries: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """ADC look-up tables for a product quantizer.

    Args:
      queries:   (Q, M, DS)    — queries split into M sub-vectors of dim DS.
      codebooks: (M, KS, DS)   — per-subquantizer codebooks (KS centroids).
    Returns:
      (Q, M, KS) float32, ``out[i, m, k] = ||q_i[m] - C[m][k]||^2``.
    """
    q = queries.astype(jnp.float32)  # (Q, M, DS)
    c = codebooks.astype(jnp.float32)  # (M, KS, DS)
    qn = jnp.sum(q * q, axis=2)[:, :, None]  # (Q, M, 1)
    cn = jnp.sum(c * c, axis=2)[None, :, :]  # (1, M, KS)
    dot = jnp.einsum("qmd,mkd->qmk", q, c)  # (Q, M, KS)
    return qn + cn - 2.0 * dot
