"""AOT lowering: JAX (L2+L1) -> HLO text artifacts for the rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Each artifact is named ``<entry>__<shape-sig>.hlo.txt`` so the rust engine
can key executables by (entry point, operand shapes).  A manifest file lists
everything that was emitted.

Usage:  python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Shape grid the benchmarks and the serving coordinator use.
# (batch, K, d) for coarse assignment — K spans the paper's IVF sweep for
# d=32 (the timing-bench dim) plus the per-dataset dims at K=1024.
COARSE_SHAPES = [
    (64, 256, 32),
    (64, 512, 32),
    (64, 1024, 32),
    (64, 2048, 32),
    (64, 1024, 64),
    (64, 1024, 128),
    (1, 1024, 32),
]
# (batch, M, KS, DS) for PQ LUTs — the PQ variants of Table 2 / Fig 2 at d=32.
LUT_SHAPES = [
    (64, 4, 256, 8),
    (64, 8, 256, 4),
    (64, 16, 256, 2),
    (64, 32, 256, 1),
    (64, 8, 1024, 4),  # PQ8x10: 10-bit sub-quantizers
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(fn, args, name: str, out_dir: str) -> dict:
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    sig = [list(a.shape) for a in args]
    return {"file": fname, "entry": name.split("__")[0], "arg_shapes": sig}


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick", action="store_true", help="emit only the smoke-test artifact"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    coarse = COARSE_SHAPES[:1] if args.quick else COARSE_SHAPES
    luts = [] if args.quick else LUT_SHAPES
    for b, k, d in coarse:
        name = f"coarse__b{b}_k{k}_d{d}"
        manifest.append(
            emit(model.coarse_assign, (f32(b, d), f32(k, d)), name, args.out_dir)
        )
        print(f"emitted {name}")
    for b, m, ks, ds in luts:
        name = f"pqlut__b{b}_m{m}_ks{ks}_ds{ds}"
        manifest.append(
            emit(
                model.pq_lut_model,
                (f32(b, m, ds), f32(m, ks, ds)),
                name,
                args.out_dir,
            )
        )
        print(f"emitted {name}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
