"""Layer-2 JAX compute graphs.

Two request-path computations are lowered to HLO and executed by the rust
runtime (`rust/src/runtime/`):

* ``coarse_assign``  — batched query -> coarse-centroid scoring for IVF
  probe selection.  Returns the full (Q, K) distance matrix; the rust side
  selects the nprobe smallest (cheap, K <= a few thousand) so the HLO stays
  free of data-dependent shapes.
* ``pq_lut_model``   — per-query ADC tables used by the IVF scan loop.

Both call the Layer-1 Pallas kernels so the kernels lower into the same HLO
module that rust loads.
"""

import jax.numpy as jnp

from compile.kernels.l2dist import l2dist
from compile.kernels.pq_lut import pq_lut


def coarse_assign(queries: jnp.ndarray, centroids: jnp.ndarray):
    """(Q, D), (K, D) -> (Q, K) float32 squared-L2 distances."""
    return (l2dist(queries, centroids),)


def pq_lut_model(queries: jnp.ndarray, codebooks: jnp.ndarray):
    """(Q, M, DS), (M, KS, DS) -> (Q, M, KS) float32 ADC tables."""
    return (pq_lut(queries, codebooks),)


def coarse_and_lut(
    queries: jnp.ndarray, centroids: jnp.ndarray, codebooks: jnp.ndarray
):
    """Fused variant: one device round-trip per batch.

    (Q, D), (K, D), (M, KS, DS) -> ((Q, K), (Q, M, KS)).
    The query is reshaped to sub-vectors inside the graph so the rust side
    feeds a single flat (Q, D) buffer for both outputs.
    """
    m, _, ds = codebooks.shape
    qsub = queries.reshape(queries.shape[0], m, ds)
    return (l2dist(queries, centroids), pq_lut(qsub, codebooks))
