"""Pallas l2dist kernel vs pure-jnp oracle — the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.l2dist import l2dist
from compile.kernels.ref import l2dist_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32) * 3.0
    return jnp.asarray(x, dtype=dtype)


@settings(max_examples=30, deadline=None)
@given(
    nq=st.integers(1, 130),
    nk=st.integers(1, 300),
    d=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_shape_sweep(nq, nk, d, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (nq, d), jnp.float32)
    c = _rand(rng, (nk, d), jnp.float32)
    got = l2dist(q, c)
    want = l2dist_ref(q, c)
    assert got.shape == (nq, nk)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    rng = np.random.default_rng(0)
    q = _rand(rng, (33, 16), dtype)
    c = _rand(rng, (70, 16), dtype)
    got = l2dist(q, c)
    want = l2dist_ref(q, c)
    tol = 1e-3 if dtype == jnp.float32 else 0.35
    np.testing.assert_allclose(got, want, rtol=0.05 if dtype != jnp.float32 else 1e-5, atol=tol)
    assert got.dtype == jnp.float32


@pytest.mark.parametrize(
    "nq,nk,d", [(64, 128, 32), (64, 1024, 32), (1, 1, 1), (65, 129, 33)]
)
def test_exact_and_offbyone_blocks(nq, nk, d):
    rng = np.random.default_rng(1)
    q = _rand(rng, (nq, d), jnp.float32)
    c = _rand(rng, (nk, d), jnp.float32)
    np.testing.assert_allclose(l2dist(q, c), l2dist_ref(q, c), rtol=1e-5, atol=1e-3)


def test_identical_vectors_zero_distance():
    rng = np.random.default_rng(2)
    q = _rand(rng, (16, 24), jnp.float32)
    dist = np.asarray(l2dist(q, q))
    assert np.all(np.abs(np.diag(dist)) < 1e-2)


def test_nearest_neighbor_agrees_with_ref():
    rng = np.random.default_rng(3)
    q = _rand(rng, (40, 32), jnp.float32)
    c = _rand(rng, (200, 32), jnp.float32)
    got = np.argmin(np.asarray(l2dist(q, c)), axis=1)
    want = np.argmin(np.asarray(l2dist_ref(q, c)), axis=1)
    np.testing.assert_array_equal(got, want)


def test_block_size_invariance():
    rng = np.random.default_rng(4)
    q = _rand(rng, (50, 20), jnp.float32)
    c = _rand(rng, (90, 20), jnp.float32)
    a = l2dist(q, c, bq=8, bk=16)
    b = l2dist(q, c, bq=64, bk=128)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-4)


def test_dim_mismatch_raises():
    q = jnp.zeros((4, 8))
    c = jnp.zeros((4, 9))
    with pytest.raises(ValueError):
        l2dist(q, c)
