"""Pallas pq_lut kernel vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pq_lut import pq_lut
from compile.kernels.ref import pq_lut_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    nq=st.integers(1, 100),
    m=st.integers(1, 16),
    ks=st.sampled_from([16, 64, 256]),
    ds=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_shape_sweep(nq, m, ks, ds, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (nq, m, ds))
    c = _rand(rng, (m, ks, ds))
    got = pq_lut(q, c)
    want = pq_lut_ref(q, c)
    assert got.shape == (nq, m, ks)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("shape", [(64, 16, 256, 2), (64, 4, 256, 8), (3, 8, 1024, 4)])
def test_paper_pq_variants(shape):
    nq, m, ks, ds = shape
    rng = np.random.default_rng(0)
    q = _rand(rng, (nq, m, ds))
    c = _rand(rng, (m, ks, ds))
    np.testing.assert_allclose(pq_lut(q, c), pq_lut_ref(q, c), rtol=1e-5, atol=1e-3)


def test_lut_argmin_is_code_assignment():
    """The LUT argmin must equal brute-force sub-vector assignment."""
    rng = np.random.default_rng(1)
    q = _rand(rng, (20, 8, 4))
    c = _rand(rng, (8, 64, 4))
    lut = np.asarray(pq_lut(q, c))
    got = np.argmin(lut, axis=2)  # (Q, M)
    want = np.argmin(np.asarray(pq_lut_ref(q, c)), axis=2)
    np.testing.assert_array_equal(got, want)


def test_shape_mismatch_raises():
    with pytest.raises(ValueError):
        pq_lut(jnp.zeros((4, 2, 3)), jnp.zeros((2, 16, 4)))
