"""L2 model graphs + AOT lowering round-trip (python side)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.ref import l2dist_ref, pq_lut_ref

jax.config.update("jax_platform_name", "cpu")


def test_coarse_assign_matches_ref():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((16, 32)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal((128, 32)).astype(np.float32))
    (out,) = model.coarse_assign(q, c)
    np.testing.assert_allclose(out, l2dist_ref(q, c), rtol=1e-5, atol=1e-3)


def test_coarse_and_lut_fused():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    cb = jnp.asarray(rng.standard_normal((4, 16, 8)).astype(np.float32))
    dist, lut = model.coarse_and_lut(q, c, cb)
    np.testing.assert_allclose(dist, l2dist_ref(q, c), rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(
        lut, pq_lut_ref(q.reshape(8, 4, 8), cb), rtol=1e-5, atol=1e-3
    )


def test_hlo_text_emission(tmp_path):
    """Lowering emits parseable-looking HLO text + manifest entry."""
    entry = aot.emit(
        model.coarse_assign,
        (aot.f32(4, 8), aot.f32(16, 8)),
        "coarse__b4_k16_d8",
        str(tmp_path),
    )
    path = tmp_path / entry["file"]
    text = path.read_text()
    assert "HloModule" in text
    assert "f32[4,16]" in text  # output shape appears in the module
    assert entry["arg_shapes"] == [[4, 8], [16, 8]]


def test_hlo_executes_via_xla_client(tmp_path):
    """Compile the emitted HLO with the CPU client and check numerics.

    This is the python-side half of the interchange contract; the rust
    integration test in rust/tests/ covers the other half.
    """
    lowered = jax.jit(model.coarse_assign).lower(aot.f32(4, 8), aot.f32(16, 8))
    out_ref = None
    rng = np.random.default_rng(2)
    q = rng.standard_normal((4, 8)).astype(np.float32)
    c = rng.standard_normal((16, 8)).astype(np.float32)
    out_ref = np.asarray(l2dist_ref(jnp.asarray(q), jnp.asarray(c)))
    exe = lowered.compile()
    (got,) = exe(q, c)
    np.testing.assert_allclose(got, out_ref, rtol=1e-5, atol=1e-3)


def test_manifest_schema(tmp_path):
    import subprocess
    import sys

    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--quick"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert len(manifest) == 1
    assert manifest[0]["entry"] == "coarse"
    assert (tmp_path / manifest[0]["file"]).exists()
