#!/usr/bin/env python3
"""Regression gate for BENCH_recall.json against a committed baseline.

Usage:
    check_recall_baseline.py FRESH BASELINE [options]

Compares a freshly measured recall report (written by `zann bench-recall`
/ `cargo bench --bench bench_recall`) against a committed baseline with
explicit per-metric tolerances:

* recall_at_1 / recall_at_10 / nn_recall_at_10 — exact by default
  (``--recall-tol 0``): every backend here stores ids losslessly and the
  whole pipeline is seeded, so any recall drop at equal sweep parameters
  is a correctness bug, not noise. A recall *rise* is a WARN suggesting a
  baseline refresh.
* bits_per_id — relative tolerance ``--bpi-tol`` (default 2%): compressed
  sizes are deterministic, but a small slack absorbs intentional codec
  tuning without a lockstep baseline edit.
* qps / latency — advisory WARN only, unless ``--enforce-qps FRAC`` asks
  to fail when fresh QPS < FRAC × baseline. Wall-clock depends on the
  runner; recall does not.

A baseline whose top-level ``provenance`` is ``"placeholder"`` (the
committed schema seed, before any toolchain-equipped runner has measured
one) only schema-checks the fresh report and exits 0 — ci.sh then
bootstraps the baseline from the fresh run.

Exit codes: 0 = gate passed, 1 = regression or schema violation,
2 = usage error.
"""

import argparse
import json
import sys

TOP_KEYS = (
    "bench", "dataset", "n", "nq", "dim", "seed", "clusters", "topk",
    "churn_frac", "corrupt_ids", "env", "results",
)
ENV_KEYS = (
    "rustc", "pkg_version", "target_arch", "simd_level", "simd_override", "threads",
)
ROW_KEYS = (
    "backend", "codec", "knob", "recall_at_1", "recall_at_10", "nn_recall_at_10",
    "qps", "mean_ms", "p50_ms", "p95_ms", "bits_per_id", "lossless_ids",
)
RECALL_METRICS = ("recall_at_1", "recall_at_10", "nn_recall_at_10")
# Sweep parameters that must match for rows to be comparable at all.
PARAM_KEYS = ("dataset", "n", "nq", "dim", "seed", "clusters", "topk", "churn_frac")

failures = []
warnings = []


def fail(msg):
    failures.append(msg)
    print(f"FAIL: {msg}")


def warn(msg):
    warnings.append(msg)
    print(f"WARN: {msg}")


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot load {path}: {e}")
        sys.exit(1)


def check_schema(d, label):
    ok = True
    for key in TOP_KEYS:
        if key not in d:
            fail(f"{label}: missing top-level key {key!r}")
            ok = False
    if not ok:
        return False
    if d["bench"] != "recall":
        fail(f"{label}: bench is {d['bench']!r}, expected 'recall'")
        return False
    for key in ENV_KEYS:
        if key not in d["env"]:
            fail(f"{label}: missing env key {key!r}")
            ok = False
    if not d["results"]:
        fail(f"{label}: empty results array")
        return False
    for row in d["results"]:
        for key in ROW_KEYS:
            if key not in row:
                fail(f"{label}: row {row.get('backend')}/{row.get('codec')} "
                     f"missing key {key!r}")
                return False
        tag = f"{label}: {row['backend']}/{row['codec']}@{row['knob']}"
        for m in RECALL_METRICS:
            if not 0.0 <= row[m] <= 1.0:
                fail(f"{tag}: {m}={row[m]} outside [0, 1]")
                ok = False
        if not row["qps"] > 0:
            fail(f"{tag}: qps={row['qps']} (no query ran?)")
            ok = False
        if not row["bits_per_id"] > 0:
            fail(f"{tag}: bits_per_id={row['bits_per_id']}")
            ok = False
    return ok


def key_of(row):
    return (row["backend"], row["codec"], row["knob"])


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="freshly measured BENCH_recall.json")
    ap.add_argument("baseline", help="committed baseline to gate against")
    ap.add_argument("--recall-tol", type=float, default=0.0,
                    help="allowed recall drop per metric (default 0: exact)")
    ap.add_argument("--bpi-tol", type=float, default=0.02,
                    help="allowed relative bits/id change (default 0.02)")
    ap.add_argument("--enforce-qps", type=float, default=None, metavar="FRAC",
                    help="fail if fresh qps < FRAC x baseline (default: warn only)")
    ap.add_argument("--require-backends", default=None,
                    help="comma-separated backends the fresh report must cover")
    args = ap.parse_args()

    fresh = load(args.fresh)
    base = load(args.baseline)

    if not check_schema(fresh, "fresh"):
        return 1
    if args.require_backends:
        have = {row["backend"] for row in fresh["results"]}
        need = {b.strip() for b in args.require_backends.split(",") if b.strip()}
        missing = need - have
        if missing:
            fail(f"fresh report covers {sorted(have)}, missing required "
                 f"backends {sorted(missing)}")

    if base.get("provenance") == "placeholder":
        # Committed schema seed: nothing measured to compare against yet.
        if failures:
            return 1
        print("baseline is a placeholder seed: schema-checked the fresh report "
              "only; bootstrap a measured baseline from this run")
        return 0

    if not check_schema(base, "baseline"):
        return 1

    for key in PARAM_KEYS:
        if fresh.get(key) != base.get(key):
            fail(f"sweep parameter {key!r} differs: fresh={fresh.get(key)!r} "
                 f"baseline={base.get(key)!r} — rows are not comparable")
    if failures:
        return 1
    if fresh["corrupt_ids"] or base["corrupt_ids"]:
        warn("corrupt_ids run in the comparison (sabotage mode) — recall is "
             "expected to collapse")
    for key in ("rustc", "simd_level"):
        if fresh["env"].get(key) != base["env"].get(key):
            warn(f"env {key} differs: fresh={fresh['env'].get(key)!r} "
                 f"baseline={base['env'].get(key)!r} — QPS not comparable, "
                 f"recall still gated")

    fresh_rows = {key_of(r): r for r in fresh["results"]}
    compared = 0
    for bkey, brow in ((key_of(r), r) for r in base["results"]):
        tag = "{}/{}@{}".format(*bkey)
        frow = fresh_rows.get(bkey)
        if frow is None:
            fail(f"{tag}: present in baseline but missing from the fresh "
                 f"sweep (coverage regressed)")
            continue
        compared += 1
        for m in RECALL_METRICS:
            drop = brow[m] - frow[m]
            if drop > args.recall_tol:
                fail(f"{tag}: {m} dropped {brow[m]:.6f} -> {frow[m]:.6f} "
                     f"(tolerance {args.recall_tol}); lossless ids make any "
                     f"drop at equal parameters a correctness bug")
            elif drop < -args.recall_tol and frow[m] > brow[m]:
                warn(f"{tag}: {m} improved {brow[m]:.6f} -> {frow[m]:.6f}; "
                     f"refresh the baseline to lock in the gain")
        if brow["bits_per_id"] > 0:
            rel = abs(frow["bits_per_id"] - brow["bits_per_id"]) / brow["bits_per_id"]
            if rel > args.bpi_tol:
                fail(f"{tag}: bits_per_id moved {brow['bits_per_id']:.4f} -> "
                     f"{frow['bits_per_id']:.4f} ({rel:.1%} > {args.bpi_tol:.1%})")
        if brow["qps"] > 0:
            ratio = frow["qps"] / brow["qps"]
            if args.enforce_qps is not None and ratio < args.enforce_qps:
                fail(f"{tag}: qps {brow['qps']:.1f} -> {frow['qps']:.1f} "
                     f"({ratio:.2f}x < enforced {args.enforce_qps}x)")
            elif ratio < 0.8:
                warn(f"{tag}: qps {brow['qps']:.1f} -> {frow['qps']:.1f} "
                     f"({ratio:.2f}x) — advisory only on this runner")

    if failures:
        print(f"recall gate: {len(failures)} failure(s), {len(warnings)} "
              f"warning(s) over {compared} compared row(s)")
        return 1
    print(f"recall gate passed: {compared} row(s) compared, "
          f"{len(warnings)} warning(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
