//! Observability self-measurement: the serve workload through a
//! coordinator with stage-trace sampling off and then on, recording the
//! instrumentation overhead delta, per-stage mean timelines, and span
//! accounting to `BENCH_obs.json`.
//!
//! `cargo bench --bench bench_obs -- [--full] [--n N] [--nq Q] [--k K]
//!  [--nprobe P] [--topk K] [--codec C] [--runs R] [--out PATH]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); pass
//! `--n`/`--full` for comparable runs (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::args_with_tiny_default(
        &["--full", "--n", "--nq"],
        &["--n", "4000", "--nq", "256", "--runs", "2"],
    ));
    zann::eval::bench_entries::obs(&args);
}
