//! Shared helper for the bench harnesses: collect CLI args (dropping the
//! `--bench` flag cargo appends) and, when the caller did not pick a scale
//! (none of `scale_flags` present), prepend a tiny smoke scale so a bare
//! `cargo bench` exercises every entry point end-to-end in seconds instead
//! of silently running the multi-minute default experiment scale.
//!
//! Defaults are *prepended*: `Args::parse` is last-wins, so any flag the
//! user did pass stays authoritative even when the smoke scale kicks in.

/// Raw args with `defaults` prepended unless one of `scale_flags` was
/// given (either as `--flag value` or `--flag=value`).
pub fn args_with_tiny_default(scale_flags: &[&str], defaults: &[&str]) -> Vec<String> {
    let user: Vec<String> =
        std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let scaled = user.iter().any(|a| {
        scale_flags
            .iter()
            .any(|f| a.as_str() == *f || a.starts_with(&format!("{f}=")))
    });
    let mut raw = Vec::new();
    if !scaled {
        eprintln!("(smoke scale: pass {} for paper-scale runs)", scale_flags.join("/"));
        raw.extend(defaults.iter().map(|s| s.to_string()));
    }
    raw.extend(user);
    raw
}

/// The smoke configuration shared by the table/figure harnesses that use
/// the common `--n/--nq/--full` scale flags.
// Each harness compiles this file as its own module; bench_table4 uses
// only `args_with_tiny_default`, so this helper is dead code there.
#[allow(dead_code)]
pub fn common_args() -> Vec<String> {
    args_with_tiny_default(
        &["--full", "--n", "--nq"],
        &["--n", "4000", "--nq", "100", "--runs", "1"],
    )
}
