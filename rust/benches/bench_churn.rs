//! Mutable-IVF churn bench: delete/insert a fraction of the index
//! through the LSM write path, compact, and audit throughput +
//! post-compaction compression + search parity against a from-scratch
//! static build. Writes a machine-readable `BENCH_churn.json` at the
//! repo root.
//!
//! `cargo bench --bench bench_churn -- [--full] [--n N] [--nq Q]
//!  [--k K] [--dataset sift|deep|ssnpp] [--codec roc] [--churn 0.2]
//!  [--nprobe 16] [--out PATH]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); the
//! bench exits non-zero if any query diverges from the static rebuild,
//! so it doubles as the churn-correctness gate (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::common_args());
    zann::eval::bench_entries::churn(&args);
}
