//! Regenerates the paper's Figure 3 (cluster-conditioned PQ code compression).
fn main() {
    let args = zann::util::cli::Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    zann::eval::bench_entries::fig3(&args);
}
