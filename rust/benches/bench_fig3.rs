//! Regenerates the paper's Figure 3 (cluster-conditioned PQ code compression).
//! `cargo bench --bench bench_fig3 -- [--full] [--dataset sift]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); pass
//! `--n`/`--full` for figure-comparable runs (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::common_args());
    zann::eval::bench_entries::fig3(&args);
}
