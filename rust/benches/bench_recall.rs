//! Recall-aware evaluation bench: sweep codec × backend × search knob
//! (nprobe/ef) against exact brute-force groundtruth and report
//! recall@1, set-intersection recall@10, 1-recall@10 (the paper's
//! Table-4 metric), QPS, latency percentiles and bits/id per operating
//! point. Writes a machine-readable `BENCH_recall.json` at the repo
//! root, stamped with an environment manifest (rustc / SIMD tier /
//! threads); CI gates it against a committed baseline with
//! tools/check_recall_baseline.py.
//!
//! `cargo bench --bench bench_recall -- [--full] [--n N] [--nq Q]
//!  [--k K] [--topk 10] [--knobs 4,16,64] [--codecs unc64,roc,ans-i4]
//!  [--pq-m M|--skip-pq] [--skip-graphs] [--skip-dynamic] [--churn 0.2]
//!  [--dataset sift|deep|ssnpp] [--runs R] [--corrupt-ids] [--out PATH]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`). The
//! bench exits non-zero without writing on any degenerate run — zero
//! queries, NaN/out-of-range recall, zero QPS — and on a
//! lossless-codec invariance violation (two lossless id codecs
//! returning different results is a correctness bug, not noise).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::args_with_tiny_default(
        &["--full", "--n", "--nq"],
        &["--n", "4000", "--nq", "60", "--k", "32", "--knobs", "4,16", "--runs", "1"],
    ));
    zann::eval::bench_entries::recall(&args);
}
