//! Decode-throughput bench: per-codec bulk-decode MB/s and ids/s across
//! list sizes (single-stream vs interleaved ANS), plus the blocked PQ
//! ADC scan and the fused coarse kernel scalar-vs-dispatched. Writes a
//! machine-readable `BENCH_decode.json` at the repo root.
//!
//! `cargo bench --bench bench_decode -- [--universe N] [--list-lens 64,1024,4096]
//!  [--lists L] [--reps R] [--adc-rows N] [--adc-m M] [--coarse-k K]
//!  [--coarse-dim D] [--seed S] [--out PATH]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); the
//! bench exits non-zero without writing on a degenerate (zero-item)
//! run, and asserts scalar/SIMD kernel parity bitwise on the host it
//! runs on (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::args_with_tiny_default(
        &["--full", "--universe", "--list-lens"],
        &[
            "--universe", "200000", "--list-lens", "64,1024", "--lists", "8", "--reps", "2",
            "--adc-rows", "4000", "--coarse-k", "64",
        ],
    ));
    zann::eval::bench_entries::decode(&args);
}
