//! Regenerates the paper's Table 1 (bits/id for IVF and NSG indices).
//! `cargo bench --bench bench_table1 -- [--full] [--dataset sift] [--n N]`
fn main() {
    let args = zann::util::cli::Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    zann::eval::bench_entries::table1(&args);
}
