//! Regenerates the paper's Table 1 (bits/id for IVF and NSG indices).
//! `cargo bench --bench bench_table1 -- [--full] [--dataset sift] [--n N]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); pass
//! `--n`/`--full` for table-comparable runs (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::common_args());
    zann::eval::bench_entries::table1(&args);
}
