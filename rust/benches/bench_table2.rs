//! Regenerates the paper's Table 2 (search wall-time per codec).
//! `cargo bench --bench bench_table2 -- [--full] [--dataset sift] [--runs R]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); pass
//! `--n`/`--full` for table-comparable runs (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::common_args());
    zann::eval::bench_entries::table2(&args);
}
