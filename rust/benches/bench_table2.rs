//! Regenerates the paper's Table 2 (search wall-time per codec).
fn main() {
    let args = zann::util::cli::Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    zann::eval::bench_entries::table2(&args);
}
