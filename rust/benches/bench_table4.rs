//! Regenerates the paper's Table 4 (scaled large-N IVF-PQ: bits/id + search time).
//! `cargo bench --bench bench_table4 -- [--n4 N] [--nq4 NQ] [--k4 K]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); pass
//! `--n4`/`--nq4`/`--k4` for the scaled large-N run (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let raw = smoke::args_with_tiny_default(
        &["--n4", "--nq4", "--k4"],
        &["--n4", "30000", "--nq4", "100", "--k4", "256"],
    );
    let args = zann::util::cli::Args::parse(raw);
    zann::eval::bench_entries::table4(&args);
}
