//! Regenerates the paper's Table 4 (scaled large-N IVF-PQ: bits/id + search time).
fn main() {
    let args = zann::util::cli::Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    zann::eval::bench_entries::table4(&args);
}
