//! Search-throughput bench: QPS and p50/p95 latency per codec, swept over
//! codec × nprobe × threads, with a machine-readable `BENCH_search.json`
//! written at the repo root.
//!
//! `cargo bench --bench bench_search_qps -- [--full] [--n N] [--nq Q]
//!  [--k K] [--dataset sift|deep|ssnpp] [--codecs unc64,roc,pq-compressed]
//!  [--nprobe 8,16] [--sweep-threads 1,8] [--runs R] [--out PATH]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); pass
//! `--n`/`--full` for comparable runs (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::common_args());
    zann::eval::bench_entries::search_qps(&args);
}
