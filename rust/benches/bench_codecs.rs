//! Codec microbenchmarks: encode/decode throughput per codec, plus wavelet
//! select throughput (paper §5.2 discussion: "Most of the wall-time spent
//! with ROC is due to the Fenwick Tree").
//!
//! `cargo bench --bench bench_codecs -- [--n 4096] [--universe 1000000]`

use std::time::Instant;
use zann::codecs::{CodecSpec, DecodeScratch};
use zann::eval::{fmt3, Table};
use zann::util::cli::Args;
use zann::util::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let universe = args.u64("universe", 1_000_000) as u32;
    let n = args.usize("n", 4096);
    let lists = args.usize("lists", 64);
    let reps = args.usize("reps", 5);

    let mut rng = Rng::new(args.u64("seed", 42));
    let data: Vec<Vec<u32>> = (0..lists)
        .map(|_| rng.sample_distinct(universe as u64, n).into_iter().map(|v| v as u32).collect())
        .collect();
    let total_ids = (lists * n) as f64;

    println!("== codec microbench: {lists} lists x {n} ids from [0, {universe}) ==");
    let mut t = Table::new(&["codec", "bits/id", "enc Mids/s", "dec Mids/s"]);
    for name in zann::codecs::PER_LIST_CODECS {
        let codec = CodecSpec::parse(name).unwrap().id_codec().unwrap();
        let mut enc_best = f64::INFINITY;
        let mut blobs = Vec::new();
        let mut bits = 0u64;
        for _ in 0..reps {
            let t0 = Instant::now();
            blobs.clear();
            bits = 0;
            for l in &data {
                let e = codec.encode(l, universe);
                bits += e.bits;
                blobs.push(e.bytes);
            }
            enc_best = enc_best.min(t0.elapsed().as_secs_f64());
        }
        let mut dec_best = f64::INFINITY;
        let mut out = Vec::with_capacity(n);
        for _ in 0..reps {
            let t0 = Instant::now();
            for blob in &blobs {
                out.clear();
                codec.decode(blob, universe, n, &mut out);
            }
            dec_best = dec_best.min(t0.elapsed().as_secs_f64());
        }
        t.row(vec![
            name.into(),
            fmt3(bits as f64 / total_ids),
            fmt3(total_ids / enc_best / 1e6),
            fmt3(total_ids / dec_best / 1e6),
        ]);
    }
    println!("{}", t.render());

    // Bulk id-store decode through a built IVF index: every cluster list
    // via `decode_list_into` with one reused buffer + DecodeScratch (the
    // allocation-free bulk path audits and migrations take).
    {
        use zann::datasets::{generate, Kind};
        use zann::index::{IvfBuildParams, IvfIndex};
        let bn = args.usize("index-n", 20_000);
        let ds = generate(Kind::DeepLike, bn, 1, 16, args.u64("seed", 42));
        println!("\n== IVF id-store bulk decode (N={bn}, K=64) ==");
        let mut t = Table::new(&["codec", "bits/id", "decode Mids/s"]);
        for name in ["compact", "ef", "roc"] {
            let idx = IvfIndex::build(
                &ds.data,
                ds.dim,
                &IvfBuildParams { k: 64, id_codec: name.into(), ..Default::default() },
            );
            let mut out = Vec::new();
            let mut scratch = DecodeScratch::default();
            let mut best = f64::INFINITY;
            let mut decoded = 0usize;
            for _ in 0..reps {
                decoded = 0;
                let t0 = Instant::now();
                for c in 0..idx.k {
                    idx.decode_list_into(c, &mut out, &mut scratch);
                    decoded += out.len();
                }
                best = best.min(t0.elapsed().as_secs_f64());
            }
            assert_eq!(decoded, bn, "{name}: decoded lists must cover the dataset");
            t.row(vec![
                name.into(),
                fmt3(idx.bits_per_id()),
                fmt3(decoded as f64 / best / 1e6),
            ]);
        }
        println!("{}", t.render());
    }

    // Wavelet tree select throughput (the full-random-access path).
    let seq: Vec<u32> = (0..(lists * n)).map(|_| rng.below(1024) as u32).collect();
    for (label, storage) in [
        ("wt", zann::codecs::wavelet::WtStorage::Flat),
        ("wt1", zann::codecs::wavelet::WtStorage::Rrr),
    ] {
        let wt = zann::codecs::wavelet::WaveletTree::new(&seq, 1024, storage);
        let t0 = Instant::now();
        let mut acc = 0usize;
        let queries = 100_000;
        for i in 0..queries {
            let sym = (i % 1024) as u32;
            let cnt = wt.count(sym);
            if cnt > 0 {
                acc += wt.select(sym, (i as u64) % cnt).unwrap();
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{label}: {} selects/s (bits/id {}), checksum {acc}",
            fmt3(queries as f64 / dt),
            fmt3(wt.size_bits() as f64 / seq.len() as f64)
        );
    }
}
