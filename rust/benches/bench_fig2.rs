//! Regenerates the paper's Figure 2 (slowdown vs PQ dimensionality).
//! `cargo bench --bench bench_fig2 -- [--full] [--dataset sift] [--runs R]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); pass
//! `--n`/`--full` for figure-comparable runs (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::common_args());
    zann::eval::bench_entries::fig2(&args);
}
