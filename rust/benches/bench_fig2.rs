//! Regenerates the paper's Figure 2 (slowdown vs PQ dimensionality).
fn main() {
    let args = zann::util::cli::Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    zann::eval::bench_entries::fig2(&args);
}
