//! Regenerates the paper's Table 3 (offline graph compression: REC vs Zuckerli).
fn main() {
    let args = zann::util::cli::Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    zann::eval::bench_entries::table3(&args);
}
