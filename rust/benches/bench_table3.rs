//! Regenerates the paper's Table 3 (offline graph compression: REC vs Zuckerli).
//! `cargo bench --bench bench_table3 -- [--full] [--dataset sift] [--r R]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); pass
//! `--n`/`--full` for table-comparable runs (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::common_args());
    zann::eval::bench_entries::table3(&args);
}
