//! Sharded-serving bench: a mutable serve node under mixed read/write
//! traffic with zipf-skewed tenants and write placement, measured with the
//! same workload/timing module as `bench_search_qps` (warm-up pass,
//! best-of-`--runs`, seeded RNG) and written to `BENCH_serve.json`.
//!
//! `cargo bench --bench bench_serve -- [--full] [--n N] [--nq Q]
//!  [--requests R] [--shards S] [--router hash|kmeans] [--codec C]
//!  [--tenants T] [--theta Z] [--write-frac F] [--clients C]
//!  [--tenant-burst B] [--tenant-rate R] [--queue-depth D]
//!  [--deadline-ms MS] [--runs R] [--out PATH]`
//!
//! Bare invocations run at a tiny smoke scale (see `smoke.rs`); pass
//! `--n`/`--full` for comparable runs (docs/REPRODUCING.md).

#[path = "smoke.rs"]
mod smoke;

fn main() {
    let args = zann::util::cli::Args::parse(smoke::args_with_tiny_default(
        &["--full", "--n", "--nq"],
        &["--n", "4000", "--nq", "100", "--requests", "400", "--runs", "1"],
    ));
    zann::eval::bench_entries::serve(&args);
}
