//! Lloyd's k-means with k-means++-style seeding and empty-cluster
//! splitting — trains the IVF coarse quantizer and the PQ sub-codebooks.
//!
//! Assignment (the O(N·K·d) inner loop) is data-parallel over points; at
//! serving time the same computation runs through the AOT-compiled Pallas
//! kernel (see `runtime::engine`), but training happens once per index so
//! the pure-rust path is used here to keep the build self-contained.

use crate::quant::coarse;
use crate::quant::l2_sq;
use crate::util::pool::parallel_map;
use crate::util::Rng;

pub struct KmeansConfig {
    pub k: usize,
    pub iters: usize,
    pub seed: u64,
    pub threads: usize,
    /// Subsample cap: train on at most this many points (Faiss-style).
    pub max_points: usize,
}

impl Default for KmeansConfig {
    fn default() -> Self {
        KmeansConfig {
            k: 16,
            iters: 10,
            seed: 0x5eed,
            threads: crate::util::pool::default_threads(),
            max_points: 256 * 256,
        }
    }
}

/// Train centroids on `data` (row-major, `dim` wide). Returns a
/// `k × dim` row-major centroid matrix.
pub fn train(data: &[f32], dim: usize, cfg: &KmeansConfig) -> Vec<f32> {
    let n = data.len() / dim;
    assert!(n > 0 && cfg.k > 0);
    let mut rng = Rng::new(cfg.seed);

    // Subsample training points if the dataset is large.
    let train_idx: Vec<usize> = if n > cfg.max_points {
        rng.sample_distinct(n as u64, cfg.max_points).into_iter().map(|v| v as usize).collect()
    } else {
        (0..n).collect()
    };
    let tn = train_idx.len();
    let k = cfg.k.min(tn);

    // Seeding: random distinct points (k-means++ D^2 weighting is overkill
    // for the synthetic workloads; distinct-point init avoids dup centroids).
    let mut centroids = Vec::with_capacity(k * dim);
    for &i in rng.sample_distinct(tn as u64, k).iter() {
        let p = train_idx[i as usize];
        centroids.extend_from_slice(&data[p * dim..(p + 1) * dim]);
    }

    let mut assign = vec![0u32; tn];
    for _iter in 0..cfg.iters {
        // Assignment step (parallel, fused kernel with per-iteration
        // centroid norms — the O(N·K·d) inner loop).
        let norms = coarse::centroid_norms(&centroids, dim);
        let cref = &centroids;
        let nref = &norms;
        let dref = data;
        let idxref = &train_idx;
        let new_assign = parallel_map(tn, cfg.threads, |i| {
            let p = idxref[i];
            coarse::nearest_fused(&dref[p * dim..(p + 1) * dim], cref, dim, nref).0 as u32
        });
        assign = new_assign;

        // Update step.
        let mut sums = vec![0f64; k * dim];
        let mut counts = vec![0u64; k];
        for (i, &a) in assign.iter().enumerate() {
            let p = train_idx[i];
            counts[a as usize] += 1;
            let row = &data[p * dim..(p + 1) * dim];
            let s = &mut sums[a as usize * dim..(a as usize + 1) * dim];
            for (sv, &x) in s.iter_mut().zip(row) {
                *sv += x as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Empty cluster: split the largest by perturbing its mean.
                let big = (0..k).max_by_key(|&j| counts[j]).unwrap();
                for d in 0..dim {
                    let v = sums[big * dim + d] as f32 / counts[big].max(1) as f32;
                    centroids[c * dim + d] = v * (1.0 + 0.01 * rng.normal());
                }
            } else {
                for d in 0..dim {
                    centroids[c * dim + d] = (sums[c * dim + d] / counts[c] as f64) as f32;
                }
            }
        }
    }
    centroids
}

/// Assign every row of `data` to its nearest centroid (parallel, fused
/// kernel with centroid norms computed once).
pub fn assign(data: &[f32], dim: usize, centroids: &[f32], threads: usize) -> Vec<u32> {
    let n = data.len() / dim;
    let norms = coarse::centroid_norms(centroids, dim);
    parallel_map(n, threads, |i| {
        coarse::nearest_fused(&data[i * dim..(i + 1) * dim], centroids, dim, &norms).0 as u32
    })
}

/// Mean squared quantization error of an assignment (for tests/monitoring).
pub fn quantization_mse(data: &[f32], dim: usize, centroids: &[f32], assign: &[u32]) -> f64 {
    let n = data.len() / dim;
    let mut acc = 0f64;
    for i in 0..n {
        let c = assign[i] as usize;
        acc += l2_sq(&data[i * dim..(i + 1) * dim], &centroids[c * dim..(c + 1) * dim]) as f64;
    }
    acc / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated blobs in 2-D.
    fn blobs(rng: &mut Rng, per: usize) -> Vec<f32> {
        let centers = [(0.0f32, 0.0f32), (10.0, 10.0), (-10.0, 8.0)];
        let mut data = Vec::with_capacity(per * 3 * 2);
        for &(cx, cy) in &centers {
            for _ in 0..per {
                data.push(cx + 0.3 * rng.normal());
                data.push(cy + 0.3 * rng.normal());
            }
        }
        data
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(60);
        let data = blobs(&mut rng, 200);
        let cfg = KmeansConfig { k: 3, iters: 12, seed: 1, threads: 2, ..Default::default() };
        let cents = train(&data, 2, &cfg);
        let a = assign(&data, 2, &cents, 2);
        // Each blob maps to a single cluster.
        for blob in 0..3 {
            let slice = &a[blob * 200..(blob + 1) * 200];
            assert!(slice.iter().all(|&c| c == slice[0]), "blob {blob} split");
        }
        let mse = quantization_mse(&data, 2, &cents, &a);
        assert!(mse < 0.5, "mse={mse}");
    }

    #[test]
    fn mse_decreases_with_iterations() {
        let mut rng = Rng::new(61);
        let data: Vec<f32> = (0..4000).map(|_| rng.normal()).collect();
        let mse_of = |iters| {
            let cfg = KmeansConfig { k: 16, iters, seed: 2, threads: 2, ..Default::default() };
            let c = train(&data, 4, &cfg);
            let a = assign(&data, 4, &c, 2);
            quantization_mse(&data, 4, &c, &a)
        };
        let early = mse_of(1);
        let late = mse_of(10);
        assert!(late <= early * 1.001, "early={early} late={late}");
    }

    #[test]
    fn no_empty_clusters_on_degenerate_data() {
        // Fewer distinct points than clusters to exercise splitting.
        let data = vec![1.0f32; 32 * 4]; // 32 identical points
        let cfg = KmeansConfig { k: 8, iters: 5, seed: 3, threads: 1, ..Default::default() };
        let cents = train(&data, 4, &cfg);
        assert_eq!(cents.len(), 8 * 4);
        assert!(cents.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let data = vec![0.0f32, 1.0, 2.0, 3.0]; // 2 points, dim 2
        let cfg = KmeansConfig { k: 10, iters: 2, seed: 4, threads: 1, ..Default::default() };
        let cents = train(&data, 2, &cfg);
        assert_eq!(cents.len() / 2, 2);
    }
}
