//! Fused coarse-scoring kernel: `d(q, c) = ‖q‖² − 2·q·c + ‖c‖²`.
//!
//! The naive coarse stage (`l2_sq` per centroid row) redoes the `‖c‖²`
//! work for every query and exposes no instruction-level parallelism
//! beyond one row. At serving rates the coarse stage is a dense
//! `(batch × K)` distance matrix, so this module precomputes `‖c‖²` once
//! per centroid table and turns the per-query inner loop into pure dot
//! products, register-blocked over a 4-centroid block (16 scalar
//! accumulators that LLVM keeps in vector registers) — the blocked-GEMM
//! shape Faiss uses for its coarse scan.
//!
//! Determinism contract: the value computed for one `(query, centroid)`
//! pair is identical no matter which entry point produced it — the
//! 4-wide block kernel, the remainder path, [`nearest_fused`] and the
//! batched/threaded [`batch_dists_into`] all accumulate that pair's lanes
//! in exactly the order of [`dot`]. The coordinator's batched fallback,
//! the runtime stub and `IvfIndex::search` therefore agree bit-for-bit,
//! which the serving tests assert with `assert_eq!` on full result lists.

/// Lane-unrolled dot product — the accumulation-order reference for every
/// path in this module (same 4-lane shape as [`crate::quant::l2_sq`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        for l in 0..4 {
            acc[l] += a[i * 4 + l] * b[i * 4 + l];
        }
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `‖c‖²` for each row of `centroids` — computed once per table (index
/// build, coordinator start, k-means iteration) and reused by every query.
pub fn centroid_norms(centroids: &[f32], dim: usize) -> Vec<f32> {
    debug_assert!(dim > 0 && centroids.len() % dim == 0);
    centroids.chunks_exact(dim).map(|c| dot(c, c)).collect()
}

/// Fused distances from one query to every centroid row, written into
/// `out` (`out.len()` must equal `norms.len()`).
///
/// Routed through the runtime-dispatched SIMD kernels
/// ([`crate::simd::coarse`]); every dispatch level reproduces
/// [`dists_into_scalar`] bit-for-bit (same lane layout, same reduction
/// order), so the determinism contract above is unchanged — force
/// `ZANN_SIMD=scalar` to pin the reference path.
pub fn dists_into(query: &[f32], centroids: &[f32], dim: usize, norms: &[f32], out: &mut [f32]) {
    crate::simd::coarse::dists_into(query, centroids, dim, norms, out);
}

/// The scalar reference kernel (4 centroids × 4 lanes in flight): the
/// accumulation-order ground truth every SIMD variant must reproduce
/// exactly.
pub fn dists_into_scalar(
    query: &[f32],
    centroids: &[f32],
    dim: usize,
    norms: &[f32],
    out: &mut [f32],
) {
    let k = norms.len();
    debug_assert_eq!(centroids.len(), k * dim);
    debug_assert_eq!(out.len(), k);
    debug_assert_eq!(query.len(), dim);
    let q_norm = dot(query, query);
    let blocks = k / 4;
    for b in 0..blocks {
        let base = b * 4 * dim;
        let c0 = &centroids[base..base + dim];
        let c1 = &centroids[base + dim..base + 2 * dim];
        let c2 = &centroids[base + 2 * dim..base + 3 * dim];
        let c3 = &centroids[base + 3 * dim..base + 4 * dim];
        // 4 centroids in flight × 4 lanes each = 16 accumulators.
        let mut acc = [[0f32; 4]; 4];
        let chunks = dim / 4;
        for i in 0..chunks {
            for l in 0..4 {
                let q = query[i * 4 + l];
                acc[0][l] += q * c0[i * 4 + l];
                acc[1][l] += q * c1[i * 4 + l];
                acc[2][l] += q * c2[i * 4 + l];
                acc[3][l] += q * c3[i * 4 + l];
            }
        }
        let mut d = [
            acc[0][0] + acc[0][1] + acc[0][2] + acc[0][3],
            acc[1][0] + acc[1][1] + acc[1][2] + acc[1][3],
            acc[2][0] + acc[2][1] + acc[2][2] + acc[2][3],
            acc[3][0] + acc[3][1] + acc[3][2] + acc[3][3],
        ];
        for i in chunks * 4..dim {
            let q = query[i];
            d[0] += q * c0[i];
            d[1] += q * c1[i];
            d[2] += q * c2[i];
            d[3] += q * c3[i];
        }
        for j in 0..4 {
            out[b * 4 + j] = (q_norm - 2.0 * d[j] + norms[b * 4 + j]).max(0.0);
        }
    }
    for c in blocks * 4..k {
        let d = dot(query, &centroids[c * dim..(c + 1) * dim]);
        out[c] = (q_norm - 2.0 * d + norms[c]).max(0.0);
    }
}

/// Append-variant of [`dists_into`] for `Vec`-building callers.
pub fn dists_append(
    query: &[f32],
    centroids: &[f32],
    dim: usize,
    norms: &[f32],
    out: &mut Vec<f32>,
) {
    let start = out.len();
    out.resize(start + norms.len(), 0.0);
    dists_into(query, centroids, dim, norms, &mut out[start..]);
}

/// Batched fused distances (`b × k`, row-major) into a reusable output
/// buffer, data-parallel over queries — the coordinator's coarse fallback.
pub fn batch_dists_into(
    queries: &[f32],
    b: usize,
    centroids: &[f32],
    dim: usize,
    norms: &[f32],
    threads: usize,
    out: &mut Vec<f32>,
) {
    let k = norms.len();
    debug_assert_eq!(queries.len(), b * dim);
    out.clear();
    out.resize(b * k, 0.0);
    if b == 0 || k == 0 {
        return;
    }
    let threads = threads.max(1).min(b);
    if threads <= 1 {
        for (qi, row) in out.chunks_exact_mut(k).enumerate() {
            dists_into(&queries[qi * dim..(qi + 1) * dim], centroids, dim, norms, row);
        }
        return;
    }
    let rows_per = b.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(rows_per * k).enumerate() {
            s.spawn(move || {
                for (off, row) in chunk.chunks_exact_mut(k).enumerate() {
                    let qi = t * rows_per + off;
                    dists_into(&queries[qi * dim..(qi + 1) * dim], centroids, dim, norms, row);
                }
            });
        }
    });
}

/// Index and fused distance of the nearest centroid (ties keep the first
/// index, like [`crate::quant::nearest`]). The k-means assignment loop.
pub fn nearest_fused(query: &[f32], centroids: &[f32], dim: usize, norms: &[f32]) -> (usize, f32) {
    let q_norm = dot(query, query);
    let mut best = (0usize, f32::INFINITY);
    for (c, row) in centroids.chunks_exact(dim).enumerate() {
        let d = (q_norm - 2.0 * dot(query, row) + norms[c]).max(0.0);
        if d < best.1 {
            best = (c, d);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::l2_sq;
    use crate::util::Rng;

    fn gaussian(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn fused_matches_naive_within_1e4_relative() {
        // Acceptance check: the fused expansion agrees with the row-wise
        // l2_sq loop to 1e-4 relative tolerance across dims incl. odd ones.
        let mut rng = Rng::new(0xc0a);
        for &dim in &[1usize, 3, 4, 7, 16, 32, 33, 96] {
            for &k in &[1usize, 2, 4, 5, 63, 128] {
                let q = gaussian(&mut rng, dim);
                let cents = gaussian(&mut rng, k * dim);
                let norms = centroid_norms(&cents, dim);
                let mut got = vec![0f32; k];
                dists_into(&q, &cents, dim, &norms, &mut got);
                for (c, row) in cents.chunks_exact(dim).enumerate() {
                    let want = l2_sq(&q, row);
                    assert!(
                        (got[c] - want).abs() <= 1e-4 * want.max(1.0),
                        "dim={dim} k={k} c={c}: fused={} naive={want}",
                        got[c]
                    );
                }
            }
        }
    }

    #[test]
    fn batch_matches_single_query_bitwise() {
        // The determinism contract: batched (and threaded) evaluation must
        // reproduce the single-query kernel exactly.
        let mut rng = Rng::new(0xc0b);
        let (b, k, dim) = (9usize, 37usize, 19usize);
        let queries = gaussian(&mut rng, b * dim);
        let cents = gaussian(&mut rng, k * dim);
        let norms = centroid_norms(&cents, dim);
        let mut single = vec![0f32; k];
        for threads in [1usize, 4] {
            let mut out = Vec::new();
            batch_dists_into(&queries, b, &cents, dim, &norms, threads, &mut out);
            assert_eq!(out.len(), b * k);
            for qi in 0..b {
                dists_into(&queries[qi * dim..(qi + 1) * dim], &cents, dim, &norms, &mut single);
                assert_eq!(&out[qi * k..(qi + 1) * k], &single[..], "threads={threads} qi={qi}");
            }
        }
    }

    #[test]
    fn batch_reuses_buffer_and_handles_empty() {
        let mut out = vec![1.0f32; 8];
        batch_dists_into(&[], 0, &[], 3, &[], 4, &mut out);
        assert!(out.is_empty());
        let mut rng = Rng::new(0xc0c);
        let q = gaussian(&mut rng, 2 * 5);
        let c = gaussian(&mut rng, 3 * 5);
        let norms = centroid_norms(&c, 5);
        batch_dists_into(&q, 2, &c, 5, &norms, 8, &mut out);
        assert_eq!(out.len(), 6);
    }

    #[test]
    fn nearest_fused_matches_naive_nearest() {
        let mut rng = Rng::new(0xc0d);
        for _ in 0..20 {
            let dim = 1 + rng.below(24) as usize;
            let k = 1 + rng.below(50) as usize;
            let q = gaussian(&mut rng, dim);
            let cents = gaussian(&mut rng, k * dim);
            let norms = centroid_norms(&cents, dim);
            let (ci, di) = nearest_fused(&q, &cents, dim, &norms);
            let (cw, dw) = crate::quant::nearest(&q, &cents, dim);
            // Distances agree within tolerance; the argmin may only differ
            // on a numerical near-tie.
            assert!((di - dw).abs() <= 1e-4 * dw.max(1.0), "{di} vs {dw}");
            if ci != cw {
                let naive_at_fused = l2_sq(&q, &cents[ci * dim..(ci + 1) * dim]);
                assert!((naive_at_fused - dw).abs() <= 1e-4 * dw.max(1.0));
            }
        }
    }
}
