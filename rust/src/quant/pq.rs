//! Product quantizer (Jégou et al. 2010) — the paper's PQ`m`x`b` variants.
//!
//! A `d`-dim vector is split into `m` sub-vectors of `dsub = d/m` dims;
//! each is quantized against its own `2^b`-entry codebook.  Search uses
//! asymmetric distance computation (ADC): a per-query look-up table of
//! sub-distances (built by the `pqlut` Pallas kernel at serving time, or
//! the rust fallback) turns each code scan into `m` table adds — the cost
//! that Fig. 2 sweeps against id-decode overhead.

use crate::quant::coarse;
use crate::quant::kmeans::{self, KmeansConfig};
use crate::util::{ReadBuf, WriteBuf};

#[derive(Clone)]
pub struct Pq {
    /// Number of sub-quantizers.
    pub m: usize,
    /// Bits per sub-quantizer code.
    pub bits: u32,
    /// Sub-vector dimensionality.
    pub dsub: usize,
    /// `m × ksub × dsub` codebooks, row-major.
    pub codebooks: Vec<f32>,
    /// `‖codeword‖²` per codebook row (`m × ksub`), derived from
    /// `codebooks` at train/deserialize time for the fused encode kernel.
    book_norms: Vec<f32>,
}

impl Pq {
    pub fn ksub(&self) -> usize {
        1 << self.bits
    }

    pub fn dim(&self) -> usize {
        self.m * self.dsub
    }

    /// Code size in bits per vector.
    pub fn code_bits(&self) -> usize {
        self.m * self.bits as usize
    }

    /// Train on `data` (row-major `n × dim`).
    pub fn train(data: &[f32], dim: usize, m: usize, bits: u32, seed: u64, threads: usize) -> Pq {
        assert_eq!(dim % m, 0, "dim {dim} not divisible by m {m}");
        assert!(bits <= 16);
        let dsub = dim / m;
        let ksub = 1usize << bits;
        let n = data.len() / dim;
        let mut codebooks = vec![0f32; m * ksub * dsub];
        // Train each subspace independently.
        let mut sub = vec![0f32; n.min(1 << 16) * dsub];
        for j in 0..m {
            let take = n.min(1 << 16);
            for i in 0..take {
                let src = &data[i * dim + j * dsub..i * dim + (j + 1) * dsub];
                sub[i * dsub..(i + 1) * dsub].copy_from_slice(src);
            }
            let cfg = KmeansConfig {
                k: ksub,
                iters: 8,
                seed: seed.wrapping_add(j as u64),
                threads,
                max_points: 1 << 16,
            };
            let cents = kmeans::train(&sub[..take * dsub], dsub, &cfg);
            // kmeans may clamp k when n < ksub; pad by repeating.
            let kgot = cents.len() / dsub;
            for c in 0..ksub {
                let src = &cents[(c % kgot) * dsub..(c % kgot + 1) * dsub];
                codebooks[(j * ksub + c) * dsub..(j * ksub + c + 1) * dsub].copy_from_slice(src);
            }
        }
        let book_norms = coarse::centroid_norms(&codebooks, dsub);
        Pq { m, bits, dsub, codebooks, book_norms }
    }

    /// Codebook slice for sub-quantizer `j`.
    #[inline]
    fn book(&self, j: usize) -> &[f32] {
        let ksub = self.ksub();
        &self.codebooks[j * ksub * self.dsub..(j + 1) * ksub * self.dsub]
    }

    /// Codeword-norm slice for sub-quantizer `j` (fused encode kernel).
    #[inline]
    fn book_norms(&self, j: usize) -> &[f32] {
        let ksub = self.ksub();
        &self.book_norms[j * ksub..(j + 1) * ksub]
    }

    /// Encode one vector into an `m`-code slice (no allocation).
    pub fn encode_into(&self, v: &[f32], out: &mut [u16]) {
        debug_assert_eq!(v.len(), self.dim());
        debug_assert_eq!(out.len(), self.m);
        for j in 0..self.m {
            let sub = &v[j * self.dsub..(j + 1) * self.dsub];
            let (idx, _) = coarse::nearest_fused(sub, self.book(j), self.dsub, self.book_norms(j));
            out[j] = idx as u16;
        }
    }

    /// Encode one vector to `m` codes, appended to `out`.
    pub fn encode(&self, v: &[f32], out: &mut Vec<u16>) {
        let start = out.len();
        out.resize(start + self.m, 0);
        self.encode_into(v, &mut out[start..]);
    }

    /// Encode a batch (row-major) in parallel, writing codes straight into
    /// one flat `n × m` buffer (no per-row allocations).
    pub fn encode_batch(&self, data: &[f32], threads: usize) -> Vec<u16> {
        let dim = self.dim();
        let n = data.len() / dim;
        let m = self.m;
        let mut codes = vec![0u16; n * m];
        if n == 0 {
            return codes;
        }
        let threads = threads.max(1).min(n);
        if threads <= 1 {
            for (i, row) in codes.chunks_exact_mut(m).enumerate() {
                self.encode_into(&data[i * dim..(i + 1) * dim], row);
            }
            return codes;
        }
        let rows_per = n.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, chunk) in codes.chunks_mut(rows_per * m).enumerate() {
                s.spawn(move || {
                    for (off, row) in chunk.chunks_exact_mut(m).enumerate() {
                        let i = t * rows_per + off;
                        self.encode_into(&data[i * dim..(i + 1) * dim], row);
                    }
                });
            }
        });
        codes
    }

    /// Reconstruct a vector from its codes.
    pub fn decode(&self, codes: &[u16], out: &mut Vec<f32>) {
        debug_assert_eq!(codes.len(), self.m);
        for (j, &c) in codes.iter().enumerate() {
            let book = self.book(j);
            out.extend_from_slice(&book[c as usize * self.dsub..(c as usize + 1) * self.dsub]);
        }
    }

    /// ADC look-up table for `query`, filled into a preshaped `m × ksub`
    /// slice — the allocation-free form the search scratch uses (the LUT
    /// buffer is sized once per query, written in place here, and hoisted
    /// out of the per-list probe loop by `IvfIndex::search`).
    pub fn lut_into(&self, query: &[f32], out: &mut [f32]) {
        debug_assert_eq!(query.len(), self.dim());
        let ksub = self.ksub();
        assert_eq!(out.len(), self.m * ksub, "LUT scratch must be m × ksub");
        for j in 0..self.m {
            let sub = &query[j * self.dsub..(j + 1) * self.dsub];
            let book = self.book(j);
            for (c, slot) in out[j * ksub..(j + 1) * ksub].iter_mut().enumerate() {
                *slot = crate::quant::l2_sq(sub, &book[c * self.dsub..(c + 1) * self.dsub]);
            }
        }
    }

    /// ADC look-up table for `query`: `m × ksub` squared sub-distances
    /// (reshapes `out`, then delegates to [`Pq::lut_into`]; at
    /// steady-state shape the resize is a no-op — no allocation, no
    /// zero-fill — and every slot is overwritten in place).
    pub fn lut(&self, query: &[f32], out: &mut Vec<f32>) {
        out.resize(self.m * self.ksub(), 0.0);
        self.lut_into(query, &mut out[..]);
    }

    /// ADC distance of one code row against a prebuilt LUT.
    #[inline]
    pub fn adc(&self, lut: &[f32], codes: &[u16]) -> f32 {
        let ksub = self.ksub();
        let mut s = 0f32;
        for (j, &c) in codes.iter().enumerate() {
            s += lut[j * ksub + c as usize];
        }
        s
    }

    /// Blocked ADC over a whole code list (row-major `n × m`), replacing
    /// `out` with one distance per row. Runs the runtime-dispatched SIMD
    /// scan ([`crate::simd::adc`]): 8 rows of LUT gathers in flight on
    /// AVX2, bit-identical to calling [`Pq::adc`] row by row — the IVF
    /// scan loop consumes this instead of per-row adds.
    pub fn adc_scan_into(&self, lut: &[f32], codes: &[u16], out: &mut Vec<f32>) {
        crate::simd::adc::adc_scan_into(lut, self.ksub(), self.m, codes, out);
    }

    pub fn serialize(&self, w: &mut WriteBuf) {
        w.put_u64(self.m as u64);
        w.put_u32(self.bits);
        w.put_u64(self.dsub as u64);
        w.put_f32s(&self.codebooks);
    }

    pub fn deserialize(r: &mut ReadBuf) -> anyhow::Result<Pq> {
        let m = r.get_u64()? as usize;
        let bits = r.get_u32()?;
        let dsub = r.get_u64()? as usize;
        let codebooks = r.get_f32s()?;
        anyhow::ensure!(codebooks.len() == m * (1 << bits) * dsub, "codebook size mismatch");
        anyhow::ensure!(dsub > 0, "zero dsub");
        let book_norms = coarse::centroid_norms(&codebooks, dsub);
        Ok(Pq { m, bits, dsub, codebooks, book_norms })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::l2_sq;
    use crate::util::Rng;

    fn gaussian(rng: &mut Rng, n: usize, dim: usize) -> Vec<f32> {
        (0..n * dim).map(|_| rng.normal()).collect()
    }

    #[test]
    fn encode_decode_reduces_error_vs_random_codes() {
        let mut rng = Rng::new(70);
        let dim = 16;
        let data = gaussian(&mut rng, 2000, dim);
        let pq = Pq::train(&data, dim, 4, 8, 1, 2);
        let mut codes = Vec::new();
        let mut recon = Vec::new();
        let mut err = 0f64;
        let mut base = 0f64;
        for i in 0..200 {
            let v = &data[i * dim..(i + 1) * dim];
            codes.clear();
            recon.clear();
            pq.encode(v, &mut codes);
            pq.decode(&codes, &mut recon);
            err += l2_sq(v, &recon) as f64;
            base += v.iter().map(|x| (x * x) as f64).sum::<f64>();
        }
        // PQ4x8 on 16-dim gaussians: strong reduction vs ||v||^2.
        assert!(err < 0.25 * base, "err={err} base={base}");
    }

    #[test]
    fn adc_matches_explicit_distance_to_reconstruction() {
        let mut rng = Rng::new(71);
        let dim = 32;
        let data = gaussian(&mut rng, 1000, dim);
        let pq = Pq::train(&data, dim, 8, 8, 2, 2);
        let q: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        let mut lut = Vec::new();
        pq.lut(&q, &mut lut);
        for i in 0..50 {
            let v = &data[i * dim..(i + 1) * dim];
            let mut codes = Vec::new();
            pq.encode(v, &mut codes);
            let mut recon = Vec::new();
            pq.decode(&codes, &mut recon);
            let want = l2_sq(&q, &recon);
            let got = pq.adc(&lut, &codes);
            assert!((got - want).abs() < 1e-3 * want.max(1.0), "{got} vs {want}");
        }
    }

    #[test]
    fn batch_encode_matches_single() {
        let mut rng = Rng::new(72);
        let dim = 8;
        let data = gaussian(&mut rng, 100, dim);
        let pq = Pq::train(&data, dim, 4, 4, 3, 2);
        let batch = pq.encode_batch(&data, 4);
        for i in 0..100 {
            let mut single = Vec::new();
            pq.encode(&data[i * dim..(i + 1) * dim], &mut single);
            assert_eq!(&batch[i * 4..(i + 1) * 4], &single[..]);
        }
    }

    #[test]
    fn ten_bit_codes() {
        // PQ8x10 (Table 2's large-LUT variant).
        let mut rng = Rng::new(73);
        let dim = 32;
        let data = gaussian(&mut rng, 3000, dim);
        let pq = Pq::train(&data, dim, 8, 10, 4, 2);
        assert_eq!(pq.ksub(), 1024);
        assert_eq!(pq.code_bits(), 80);
        let mut codes = Vec::new();
        pq.encode(&data[..dim], &mut codes);
        assert!(codes.iter().all(|&c| (c as usize) < 1024));
    }

    #[test]
    fn adc_scan_matches_per_row_adc_bitwise() {
        let mut rng = Rng::new(75);
        let dim = 32;
        let data = gaussian(&mut rng, 600, dim);
        let pq = Pq::train(&data, dim, 8, 8, 6, 2);
        let codes = pq.encode_batch(&data, 2);
        let q: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        let mut lut = Vec::new();
        pq.lut(&q, &mut lut);
        let mut dists = Vec::new();
        pq.adc_scan_into(&lut, &codes, &mut dists);
        assert_eq!(dists.len(), 600);
        for (r, row) in codes.chunks_exact(pq.m).enumerate() {
            assert_eq!(dists[r].to_bits(), pq.adc(&lut, row).to_bits(), "row {r}");
        }
        // lut_into over a reused slice equals the Vec wrapper.
        let mut lut2 = vec![0f32; lut.len()];
        pq.lut_into(&q, &mut lut2);
        assert_eq!(lut, lut2);
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Rng::new(74);
        let data = gaussian(&mut rng, 500, 8);
        let pq = Pq::train(&data, 8, 2, 6, 5, 1);
        let mut w = WriteBuf::new();
        pq.serialize(&mut w);
        let mut r = ReadBuf::new(&w.bytes);
        let back = Pq::deserialize(&mut r).unwrap();
        assert_eq!(back.m, pq.m);
        assert_eq!(back.codebooks, pq.codebooks);
    }
}
