//! Vector quantization: distance kernels, k-means and product quantization.
//!
//! The orange boxes of the paper's Fig. 1 — lossy vector compression — are
//! orthogonal to id compression but required substrate: IVF needs a coarse
//! k-means quantizer, Table 2 / Fig. 2 need PQ variants, and Fig. 3 needs
//! the PQ codes themselves.

pub mod coarse;
pub mod kmeans;
pub mod pq;

/// Squared L2 distance between two f32 slices.
///
/// Written as a 4-lane manual unroll that LLVM reliably autovectorizes;
/// this is the innermost loop of every Flat scan.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        for l in 0..4 {
            let d = a[i * 4 + l] - b[i * 4 + l];
            acc[l] += d * d;
        }
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Index of the nearest row of `base` to `query`.
pub fn nearest(query: &[f32], base: &[f32], dim: usize) -> (usize, f32) {
    let mut best = (0usize, f32::INFINITY);
    for (i, row) in base.chunks_exact(dim).enumerate() {
        let d = l2_sq(query, row);
        if d < best.1 {
            best = (i, d);
        }
    }
    best
}

/// Top-`k` smallest (dist, index) pairs from one query against `base`,
/// ascending. A bounded max-heap over (dist, idx).
pub fn top_k(query: &[f32], base: &[f32], dim: usize, k: usize) -> Vec<(f32, u32)> {
    let mut heap = TopK::new(k);
    for (i, row) in base.chunks_exact(dim).enumerate() {
        heap.push(l2_sq(query, row), i as u32);
    }
    heap.into_sorted()
}

/// Bounded top-k structure (max-heap of the k best), the IVF search-time
/// result collector of paper §4.1.
pub struct TopK {
    k: usize,
    /// Max-heap by distance: worst candidate at the root.
    heap: std::collections::BinaryHeap<HeapItem>,
}

#[derive(PartialEq)]
struct HeapItem(f32, u64);

impl Eq for HeapItem {}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

impl Default for TopK {
    fn default() -> Self {
        TopK::new(0)
    }
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK { k, heap: std::collections::BinaryHeap::with_capacity(k + 1) }
    }

    /// Reset for reuse with a (possibly different) `k`, keeping the heap
    /// allocation — the per-query path of `SearchScratch`.
    pub fn reset(&mut self, k: usize) {
        self.k = k;
        self.heap.clear();
        self.heap.reserve(k + 1);
    }

    /// Current admission threshold (distance of the worst kept candidate).
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap.peek().map(|h| h.0).unwrap_or(f32::INFINITY)
        }
    }

    /// Offer a candidate; payload is an opaque u64 (e.g. packed
    /// (cluster, offset) — ids are resolved after search, §4.1).
    ///
    /// When full, the worst kept candidate is replaced in place through
    /// `peek_mut` (one sift-down) instead of push-then-pop (two heap
    /// operations). Replacement compares the full `(dist, payload)` order,
    /// so for candidates that reach `push` the kept set is the k
    /// lexicographically smallest regardless of insertion order. (Callers
    /// that pre-filter with a strict `dist < threshold()` guard — the IVF
    /// scan — drop threshold-equal candidates before they get here, so
    /// end-to-end tie-breaking still follows visit order.)
    #[inline]
    pub fn push(&mut self, dist: f32, payload: impl Into<u64>) {
        let item = HeapItem(dist, payload.into());
        if self.heap.len() < self.k {
            self.heap.push(item);
        } else if let Some(mut worst) = self.heap.peek_mut() {
            if item < *worst {
                *worst = item;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Ascending by distance.
    pub fn into_sorted(self) -> Vec<(f32, u32)> {
        let mut v: Vec<(f32, u64)> = self.heap.into_iter().map(|h| (h.0, h.1)).collect();
        v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        v.into_iter().map(|(d, p)| (d, p as u32)).collect()
    }

    /// Ascending by distance, keeping the full u64 payload.
    pub fn into_sorted_u64(self) -> Vec<(f32, u64)> {
        let mut v: Vec<(f32, u64)> = self.heap.into_iter().map(|h| (h.0, h.1)).collect();
        v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        v
    }

    /// Drain ascending by `(distance, payload)` into `out` (which is
    /// cleared first), leaving the heap empty but its allocation intact —
    /// the reusable-scratch equivalent of [`TopK::into_sorted_u64`].
    pub fn drain_sorted_into(&mut self, out: &mut Vec<(f32, u64)>) {
        out.clear();
        out.reserve(self.heap.len());
        while let Some(HeapItem(d, p)) = self.heap.pop() {
            out.push((d, p));
        }
        out.reverse();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn l2_matches_naive() {
        let mut rng = Rng::new(50);
        for &d in &[1usize, 3, 4, 16, 33, 128] {
            let a: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!((l2_sq(&a, &b) - naive).abs() < 1e-4 * naive.max(1.0));
        }
    }

    #[test]
    fn top_k_matches_sort() {
        let mut rng = Rng::new(51);
        let dim = 8;
        let base: Vec<f32> = (0..100 * dim).map(|_| rng.normal()).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        let got = top_k(&q, &base, dim, 10);
        let mut all: Vec<(f32, u32)> = base
            .chunks_exact(dim)
            .enumerate()
            .map(|(i, row)| (l2_sq(&q, row), i as u32))
            .collect();
        all.sort_by(|a, b| a.0.total_cmp(&b.0));
        assert_eq!(got.len(), 10);
        for (g, w) in got.iter().zip(&all[..10]) {
            assert_eq!(g.1, w.1);
        }
    }

    #[test]
    fn top_k_threshold_semantics() {
        let mut t = TopK::new(2);
        assert_eq!(t.threshold(), f32::INFINITY);
        t.push(5.0, 0u32);
        t.push(3.0, 1u32);
        assert_eq!(t.threshold(), 5.0);
        t.push(4.0, 2u32); // evicts 5.0
        assert_eq!(t.threshold(), 4.0);
        t.push(9.0, 3u32); // rejected
        let v = t.into_sorted();
        assert_eq!(v.iter().map(|p| p.1).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn top_k_fewer_candidates_than_k() {
        let mut t = TopK::new(10);
        t.push(1.0, 7u32);
        assert_eq!(t.into_sorted(), vec![(1.0, 7)]);
    }

    #[test]
    fn top_k_peek_mut_property_matches_naive_sort() {
        // Property test for the peek_mut replacement path: many ties,
        // k = 1, and fewer-candidates-than-k, against a naive oracle that
        // sorts all candidates by (dist, payload) and truncates. One TopK
        // is reused across trials to also exercise `reset`.
        let mut rng = Rng::new(0x70b);
        let mut t = TopK::default();
        let mut got = Vec::new();
        for trial in 0..200 {
            let k = match trial % 4 {
                0 => 1,
                1 => 3,
                2 => 10,
                _ => 1 + rng.below(20) as usize,
            };
            // Few distinct distances -> heavy ties at the threshold.
            let n = rng.below(40) as usize; // sometimes fewer than k
            let cands: Vec<(f32, u64)> = (0..n)
                .map(|i| ((rng.below(6) as f32) * 0.25, i as u64))
                .collect();
            t.reset(k);
            for &(d, p) in &cands {
                t.push(d, p);
            }
            t.drain_sorted_into(&mut got);
            let mut want = cands.clone();
            want.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            want.truncate(k);
            assert_eq!(got, want, "trial={trial} k={k} n={n}");
            assert!(t.is_empty(), "drain must leave the heap empty");
        }
    }

    #[test]
    fn top_k_insertion_order_invariant_under_ties() {
        // The peek_mut path keeps the k smallest by (dist, payload), so
        // permuting insertion order cannot change the kept set.
        let cands = [(1.0f32, 5u64), (1.0, 2), (1.0, 9), (0.5, 7), (1.0, 1)];
        let mut fwd = TopK::new(2);
        let mut rev = TopK::new(2);
        for &(d, p) in cands.iter() {
            fwd.push(d, p);
        }
        for &(d, p) in cands.iter().rev() {
            rev.push(d, p);
        }
        let (mut a, mut b) = (Vec::new(), Vec::new());
        fwd.drain_sorted_into(&mut a);
        rev.drain_sorted_into(&mut b);
        assert_eq!(a, b);
        assert_eq!(a, vec![(0.5, 7), (1.0, 1)]);
    }
}
