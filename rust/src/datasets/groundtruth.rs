//! Exact nearest-neighbor ground truth (brute force, parallel) and recall.
//!
//! Two recall definitions coexist in the ANN literature and both are used
//! here, so they get distinct names instead of one overloaded function:
//!
//! * [`nn_recall_at_k`] — "1-recall@k": fraction of queries whose *single
//!   true nearest neighbor* appears in the first `k` results. This is the
//!   paper's Table-4 "recall@10" metric and the Faiss convention.
//! * [`recall_at_k`] — set-intersection "k-recall@k":
//!   `|results[..k] ∩ gt[..k]| / k` averaged over queries, the stricter
//!   metric used for kNN-graph quality and the eval-recall harness.

use crate::quant::top_k;
use crate::util::pool::parallel_map;

/// Exact top-`k` neighbors for every query (row-major inputs).
/// Returns `nq × k` ids, row-major.
///
/// Ties are pinned: candidates are ordered by `(distance, id)` with
/// `f32::total_cmp` (the [`crate::quant::TopK`] order), so the output is
/// identical for any `threads` value — queries are data-parallel and each
/// query's scan is sequential.
pub fn exact_knn(
    data: &[f32],
    queries: &[f32],
    dim: usize,
    k: usize,
    threads: usize,
) -> Vec<u32> {
    let nq = queries.len() / dim;
    let rows = parallel_map(nq, threads, |qi| {
        top_k(&queries[qi * dim..(qi + 1) * dim], data, dim, k)
            .into_iter()
            .map(|(_, id)| id)
            .collect::<Vec<u32>>()
    });
    rows.into_iter().flatten().collect()
}

fn check_recall_inputs(gt: &[u32], gt_k: usize, results: &[Vec<u32>], k: usize) {
    assert!(!results.is_empty(), "recall over zero queries is undefined");
    assert!(k > 0, "recall@0 is undefined");
    assert!(gt_k > 0, "groundtruth depth gt_k must be positive");
    assert_eq!(
        gt.len(),
        results.len() * gt_k,
        "groundtruth length {} does not match {} queries × gt_k {}",
        gt.len(),
        results.len(),
        gt_k
    );
}

/// Set-intersection recall@k: `|results[..k] ∩ gt[..min(k, gt_k)]| /
/// min(k, gt_k)` averaged over queries.
///
/// Each groundtruth id is credited at most once, so duplicate ids in a
/// result list cannot inflate the score. Degenerate inputs (zero
/// queries, `k == 0`, `gt_k == 0`, length mismatch) panic instead of
/// returning a silent `NaN`.
pub fn recall_at_k(gt: &[u32], gt_k: usize, results: &[Vec<u32>], k: usize) -> f64 {
    check_recall_inputs(gt, gt_k, results, k);
    let eff = k.min(gt_k);
    let mut hits = 0usize;
    let mut truth = Vec::with_capacity(eff);
    for (qi, res) in results.iter().enumerate() {
        truth.clear();
        truth.extend_from_slice(&gt[qi * gt_k..qi * gt_k + eff]);
        for &id in res.iter().take(k) {
            if let Some(pos) = truth.iter().position(|&t| t == id) {
                truth.swap_remove(pos);
                hits += 1;
            }
        }
    }
    hits as f64 / (results.len() * eff) as f64
}

/// 1-recall@k: fraction of queries whose true nearest neighbor
/// (`gt[qi * gt_k]`) appears in the first `k` results — the paper's
/// Table-4 "recall@10". Panics on degenerate inputs like
/// [`recall_at_k`].
pub fn nn_recall_at_k(gt: &[u32], gt_k: usize, results: &[Vec<u32>], k: usize) -> f64 {
    check_recall_inputs(gt, gt_k, results, k);
    let mut hits = 0usize;
    for (qi, res) in results.iter().enumerate() {
        let truth = gt[qi * gt_k];
        if res.iter().take(k).any(|&id| id == truth) {
            hits += 1;
        }
    }
    hits as f64 / results.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_knn_finds_planted_neighbor() {
        let mut rng = Rng::new(80);
        let dim = 8;
        let n = 500;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.normal()).collect();
        // Plant each query as a tiny perturbation of a known row.
        let mut queries = Vec::new();
        let mut planted = Vec::new();
        for q in 0..20 {
            let target = (q * 13) % n;
            planted.push(target as u32);
            for d in 0..dim {
                queries.push(data[target * dim + d] + 1e-4 * rng.normal());
            }
        }
        let gt = exact_knn(&data, &queries, dim, 5, 4);
        for q in 0..20 {
            assert_eq!(gt[q * 5], planted[q], "query {q}");
        }
    }

    #[test]
    fn nn_recall_counts_true_nn_only() {
        let gt = vec![1u32, 9, 8, 7, 2, 9, 8, 7]; // 2 queries, gt_k=4
        let results = vec![vec![5u32, 1, 7], vec![3u32, 4, 8]];
        // q0 has its true NN (1) in the top 3, q1 does not (2 missing).
        assert_eq!(nn_recall_at_k(&gt, 4, &results, 3), 0.5);
        assert_eq!(nn_recall_at_k(&gt, 4, &results, 1), 0.0);
    }

    #[test]
    fn intersection_recall_is_set_based() {
        let gt = vec![1u32, 9, 8, 7, 2, 9, 8, 7]; // 2 queries, gt_k=4
        let results = vec![vec![5u32, 1, 7], vec![3u32, 4, 8]];
        // q0 ∩ gt[..3] = {1}, q1 ∩ gt[..3] = {8}: (1 + 1) / (2 × 3).
        let r = recall_at_k(&gt, 4, &results, 3);
        assert!((r - 1.0 / 3.0).abs() < 1e-12, "r={r}");
        // k=1: q0 top-1 is 5 (miss), q1 top-1 is 3 (miss).
        assert_eq!(recall_at_k(&gt, 4, &results, 1), 0.0);
    }

    #[test]
    fn recall_with_gt_shallower_than_k() {
        // gt_k=2 < k=4: the denominator is min(k, gt_k)=2, and only the
        // two known-true ids can score, so a result list containing both
        // reaches exactly 1.0 instead of being capped below it.
        let gt = vec![3u32, 4];
        let full = vec![vec![9u32, 4, 8, 3]];
        assert_eq!(recall_at_k(&gt, 2, &full, 4), 1.0);
        let half = vec![vec![9u32, 4, 8, 7]];
        assert_eq!(recall_at_k(&gt, 2, &half, 4), 0.5);
    }

    #[test]
    fn duplicate_result_ids_do_not_inflate_recall() {
        // A buggy backend returning the same true id k times must score
        // one hit, not k hits.
        let gt = vec![3u32, 4, 5];
        let dup = vec![vec![4u32, 4, 4]];
        let r = recall_at_k(&gt, 3, &dup, 3);
        assert!((r - 1.0 / 3.0).abs() < 1e-12, "r={r}");
        // nn-recall is membership-based, so duplicates are harmless there.
        assert_eq!(nn_recall_at_k(&gt, 3, &[vec![3u32, 3, 3]], 3), 1.0);
    }

    #[test]
    #[should_panic(expected = "zero queries")]
    fn recall_over_zero_queries_panics() {
        let _ = recall_at_k(&[], 4, &[], 10);
    }

    #[test]
    #[should_panic(expected = "zero queries")]
    fn nn_recall_over_zero_queries_panics() {
        let _ = nn_recall_at_k(&[], 4, &[], 10);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn recall_length_mismatch_panics() {
        let _ = recall_at_k(&[1u32, 2, 3], 2, &[vec![1u32]], 1);
    }
}
