//! Exact nearest-neighbor ground truth (brute force, parallel) and recall.

use crate::quant::top_k;
use crate::util::pool::parallel_map;

/// Exact top-`k` neighbors for every query (row-major inputs).
/// Returns `nq × k` ids, row-major.
pub fn exact_knn(
    data: &[f32],
    queries: &[f32],
    dim: usize,
    k: usize,
    threads: usize,
) -> Vec<u32> {
    let nq = queries.len() / dim;
    let rows = parallel_map(nq, threads, |qi| {
        top_k(&queries[qi * dim..(qi + 1) * dim], data, dim, k)
            .into_iter()
            .map(|(_, id)| id)
            .collect::<Vec<u32>>()
    });
    rows.into_iter().flatten().collect()
}

/// recall@k: fraction of queries whose true nearest neighbor appears in
/// the first `k` results (the paper's recall@10 metric in Table 4).
pub fn recall_at_k(gt: &[u32], gt_k: usize, results: &[Vec<u32>], k: usize) -> f64 {
    let nq = results.len();
    assert_eq!(gt.len(), nq * gt_k);
    let mut hits = 0usize;
    for (qi, res) in results.iter().enumerate() {
        let truth = gt[qi * gt_k]; // the single true NN
        if res.iter().take(k).any(|&id| id == truth) {
            hits += 1;
        }
    }
    hits as f64 / nq as f64
}

/// Intersection recall: |result ∩ gt| / k averaged over queries
/// (the stricter "k-recall@k" used for kNN-graph quality checks).
pub fn intersection_recall(gt: &[u32], gt_k: usize, results: &[Vec<u32>], k: usize) -> f64 {
    let nq = results.len();
    let mut acc = 0f64;
    for (qi, res) in results.iter().enumerate() {
        let truth: std::collections::HashSet<u32> =
            gt[qi * gt_k..qi * gt_k + k.min(gt_k)].iter().copied().collect();
        let inter = res.iter().take(k).filter(|id| truth.contains(id)).count();
        acc += inter as f64 / k.min(gt_k) as f64;
    }
    acc / nq as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_knn_finds_planted_neighbor() {
        let mut rng = Rng::new(80);
        let dim = 8;
        let n = 500;
        let mut data: Vec<f32> = (0..n * dim).map(|_| rng.normal()).collect();
        // Plant each query as a tiny perturbation of a known row.
        let mut queries = Vec::new();
        let mut planted = Vec::new();
        for q in 0..20 {
            let target = (q * 13) % n;
            planted.push(target as u32);
            for d in 0..dim {
                queries.push(data[target * dim + d] + 1e-4 * rng.normal());
            }
        }
        let _ = &mut data;
        let gt = exact_knn(&data, &queries, dim, 5, 4);
        for q in 0..20 {
            assert_eq!(gt[q * 5], planted[q], "query {q}");
        }
    }

    #[test]
    fn recall_metrics() {
        let gt = vec![1u32, 9, 9, 9, 2, 9, 9, 9]; // 2 queries, gt_k=4
        let results = vec![vec![5u32, 1, 7], vec![3u32, 4, 8]];
        assert_eq!(recall_at_k(&gt, 4, &results, 3), 0.5);
        assert_eq!(recall_at_k(&gt, 4, &results, 1), 0.0);
        let r2 = intersection_recall(&gt, 4, &results, 2);
        assert!((r2 - 0.25).abs() < 1e-9); // q0 hits {1}, q1 hits none
    }
}
