//! Synthetic stand-ins for the paper's datasets, plus exact ground truth.
//!
//! The paper evaluates on SIFT1M, Deep1M and FB-ssnpp1M; none are
//! redistributable here, so `generate` synthesizes Gaussian-mixture
//! datasets that preserve the properties each experiment depends on
//! (DESIGN.md "Substitutions" maps each):
//!
//! * [`Kind::SiftLike`] — clustered, *anisotropic within clusters* with a
//!   per-subspace structure (half the dimensions nearly constant within a
//!   concept): PQ sub-codes concentrate within IVF clusters, giving the
//!   Fig.-3 conditional-coding gains, like real SIFT's 4×4×8 layout.
//! * [`Kind::DeepLike`] — clustered, mildly anisotropic, L2-normalized
//!   (CNN-embedding-like): intermediate conditional compressibility.
//! * [`Kind::SsnppLike`] — heavily overlapping mixture (centers small
//!   vs noise): PQ codes stay near max entropy, no conditional gain — the
//!   paper's negative control.

pub mod groundtruth;

use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    SiftLike,
    DeepLike,
    SsnppLike,
}

impl Kind {
    pub fn name(&self) -> &'static str {
        match self {
            Kind::SiftLike => "sift-like",
            Kind::DeepLike => "deep-like",
            Kind::SsnppLike => "ssnpp-like",
        }
    }

    pub fn parse(s: &str) -> Option<Kind> {
        match s {
            "sift" | "sift-like" | "sift1m" => Some(Kind::SiftLike),
            "deep" | "deep-like" | "deep1m" => Some(Kind::DeepLike),
            "ssnpp" | "ssnpp-like" | "fb-ssnpp" => Some(Kind::SsnppLike),
            _ => None,
        }
    }

    /// The three paper datasets, in table column order.
    pub fn all() -> [Kind; 3] {
        [Kind::SiftLike, Kind::DeepLike, Kind::SsnppLike]
    }
}

/// A generated dataset: base vectors + query vectors, row-major.
pub struct Dataset {
    pub kind: Kind,
    pub dim: usize,
    pub n: usize,
    pub nq: usize,
    pub data: Vec<f32>,
    pub queries: Vec<f32>,
}

/// Number of latent concepts (mixture components); chosen ≫ the IVF K
/// values so cluster structure is non-trivial at every K in the sweep.
fn n_concepts(n: usize) -> usize {
    (n / 200).clamp(16, 4096)
}

pub fn generate(kind: Kind, n: usize, nq: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xda7a_5eed);
    let nc = n_concepts(n);

    // Concept centers.
    let center_scale = match kind {
        Kind::SiftLike => 3.0f32,
        Kind::DeepLike => 2.0,
        Kind::SsnppLike => 0.4, // heavy overlap
    };
    let centers: Vec<f32> = (0..nc * dim).map(|_| center_scale * rng.normal()).collect();

    // Per-dimension within-cluster noise. Sift-like: strongly anisotropic
    // with a 4-dim subspace period (half the dims nearly frozen per
    // concept); others: isotropic.
    let sigma: Vec<f32> = (0..dim)
        .map(|d| match kind {
            Kind::SiftLike => {
                if d % 4 < 2 {
                    0.05
                } else {
                    0.6
                }
            }
            Kind::DeepLike => 0.35,
            Kind::SsnppLike => 1.0,
        })
        .collect();

    let emit = |rng: &mut Rng, count: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(count * dim);
        for _ in 0..count {
            let c = rng.below(nc as u64) as usize;
            let center = &centers[c * dim..(c + 1) * dim];
            let start = out.len();
            for d in 0..dim {
                out.push(center[d] + sigma[d] * rng.normal());
            }
            if kind == Kind::DeepLike {
                // L2-normalize, like CNN descriptors.
                let row = &mut out[start..start + dim];
                let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-9);
                for v in row {
                    *v /= norm;
                }
            }
        }
        out
    };

    let data = emit(&mut rng, n);
    let queries = emit(&mut rng, nq);
    Dataset { kind, dim, n, nq, data, queries }
}

impl Dataset {
    pub fn vector(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    pub fn query(&self, i: usize) -> &[f32] {
        &self.queries[i * self.dim..(i + 1) * self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = generate(Kind::SiftLike, 500, 20, 16, 7);
        assert_eq!(a.data.len(), 500 * 16);
        assert_eq!(a.queries.len(), 20 * 16);
        let b = generate(Kind::SiftLike, 500, 20, 16, 7);
        assert_eq!(a.data, b.data);
        let c = generate(Kind::SiftLike, 500, 20, 16, 8);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn deep_like_is_normalized() {
        let d = generate(Kind::DeepLike, 200, 5, 24, 1);
        for i in 0..200 {
            let norm: f32 = d.vector(i).iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4, "norm={norm}");
        }
    }

    #[test]
    fn cluster_separation_ordering() {
        // sift-like must be far more clustered than ssnpp-like: compare
        // k-means quantization error relative to data variance.
        use crate::quant::kmeans;
        let dim = 16;
        for (kind, max_ratio) in [(Kind::SiftLike, 0.45), (Kind::SsnppLike, 1.1)] {
            let ds = generate(kind, 3000, 10, dim, 3);
            let cfg = kmeans::KmeansConfig { k: 32, iters: 8, seed: 1, threads: 2, ..Default::default() };
            let cents = kmeans::train(&ds.data, dim, &cfg);
            let assign = kmeans::assign(&ds.data, dim, &cents, 2);
            let mse = kmeans::quantization_mse(&ds.data, dim, &cents, &assign);
            let var: f64 = {
                let mean: f64 = ds.data.iter().map(|&v| v as f64).sum::<f64>() / ds.data.len() as f64;
                ds.data.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / ds.data.len() as f64 * dim as f64
            };
            let ratio = mse / var;
            assert!(ratio < max_ratio, "{}: ratio={ratio}", kind.name());
            if kind == Kind::SsnppLike {
                assert!(ratio > 0.5, "ssnpp should be hard to cluster: {ratio}");
            }
        }
    }

    #[test]
    fn sift_like_pq_codes_are_cluster_conditioned() {
        // The Fig.-3 property: within an IVF cluster, PQ sub-codes must be
        // concentrated for sift-like data.
        use crate::codecs::pcodes::ClusterCodeCodec;
        use crate::quant::{kmeans, pq::Pq};
        let dim = 16;
        let ds = generate(Kind::SiftLike, 4000, 10, dim, 4);
        let cfg = kmeans::KmeansConfig { k: 16, iters: 6, seed: 1, threads: 2, ..Default::default() };
        let cents = kmeans::train(&ds.data, dim, &cfg);
        let assign = kmeans::assign(&ds.data, dim, &cents, 2);
        let pq = Pq::train(&ds.data, dim, 4, 8, 1, 2);
        let codes = pq.encode_batch(&ds.data, 2);
        // Collect the largest cluster's codes.
        let mut by_cluster: Vec<Vec<u16>> = vec![Vec::new(); 16];
        for (i, &c) in assign.iter().enumerate() {
            by_cluster[c as usize].extend_from_slice(&codes[i * 4..(i + 1) * 4]);
        }
        let big = by_cluster.iter().max_by_key(|v| v.len()).unwrap();
        let nrows = big.len() / 4;
        assert!(nrows > 50);
        let codec = ClusterCodeCodec::new(256, 4);
        let enc = codec.encode(big, nrows);
        let bpe = enc.bits as f64 / big.len() as f64;
        assert!(bpe < 7.2, "expected conditional gain, got {bpe} bits/code");
    }
}
