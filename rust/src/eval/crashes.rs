//! Crash-injection harness: a kill-point matrix over the durable write
//! path. Five parts, all seeded and deterministic:
//!
//! - **A. ingest/checkpoint countdown sweep** — run a fixed op script
//!   (adds, deletes, checkpoints) against a [`DurableDynamic`] copy with
//!   the n-th crash point armed, for every n until the script survives.
//!   The reopened store must answer queries bit-identically to a reference
//!   state holding every acknowledged op (crashing *during* op j+1 may
//!   legitimately recover to either side of that op — it was never acked).
//! - **B. shard-swap countdown sweep** — same idea over a node directory's
//!   `commit_shard`, crashing at every point of the snapshot-commit +
//!   manifest-flip sequence.
//! - **C. torn WAL tails** — truncate the log at (a stride of) every byte
//!   offset; recovery must reconstruct exactly the acknowledged prefix and
//!   disclose the torn bytes.
//! - **D. child-process kills** (needs `exe`) — kill -9 a real `zann
//!   crash-victim` ingest loop and a real `zann build` at seeded wall-clock
//!   offsets, then verify recovery from the surviving files alone.
//! - **E. boundary-torn containers** — every container prefix cut at a
//!   section boundary must be rejected as a structured
//!   `TruncatedContainer`, never opened.
//!
//! Each injection is classified [`CrashClass::Recovered`] /
//! [`CrashClass::LostAck`] / [`CrashClass::TornOpen`] /
//! [`CrashClass::NoRecover`]; the summary line is greppable and `ci.sh`
//! gates on `verdict=PASS` with ≥ `min_injections` injections.

use crate::api::{persist, AnnIndex, AnnScratch, QueryParams};
use crate::datasets::{generate, Kind};
use crate::durable::store::{apply, DurableDynamic};
use crate::durable::{crash, node as dnode, wal};
use crate::dynamic::{CompactionPolicy, DynamicBuildParams, DynamicIvf};
use crate::index::{IvfBuildParams, IvfIndex};
use crate::serve::sharded::{Router, RouterKind, ShardedBuildParams, ShardedIndex};
use crate::util::Rng;
use anyhow::{ensure, Context as _, Result};
use std::path::{Path, PathBuf};

/// Knobs of one crash sweep.
pub struct CrashConfig {
    pub seed: u64,
    /// Path of the `zann` binary for part D's child-process kills; `None`
    /// skips part D (unit tests; the CLI passes its own `current_exe`).
    pub exe: Option<PathBuf>,
    /// Kill -9 runs against the `crash-victim` ingest loop (part D).
    pub victim_kills: usize,
    /// Kill -9 runs against `zann build` mid-write (part D).
    pub build_kills: usize,
    /// Byte stride for part C's torn-tail offsets (1 = every offset).
    pub tail_stride: usize,
    /// The sweep fails when fewer injections than this were performed.
    pub min_injections: usize,
}

impl Default for CrashConfig {
    fn default() -> Self {
        CrashConfig {
            seed: 7,
            exe: None,
            victim_kills: 24,
            build_kills: 8,
            tail_stride: 1,
            min_injections: 200,
        }
    }
}

/// What one injected crash led to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashClass {
    /// Reopen + replay reproduced every acknowledged write bit-identically
    /// (and disclosed any torn tail).
    Recovered,
    /// An acknowledged write was missing after recovery. Always a failure.
    LostAck,
    /// A torn container opened successfully. Always a failure.
    TornOpen,
    /// The directory/file failed to reopen at all, or recovered into a
    /// state matching no reference. Always a failure.
    NoRecover,
}

/// Aggregated sweep result.
#[derive(Default)]
pub struct CrashReport {
    pub injections: usize,
    pub recovered: usize,
    pub lost_ack: usize,
    pub torn_open: usize,
    pub no_recover: usize,
    pub min_injections: usize,
    /// One line per failing injection.
    pub failures: Vec<String>,
}

impl CrashReport {
    fn count(&mut self, what: &str, class: CrashClass) {
        self.injections += 1;
        match class {
            CrashClass::Recovered => self.recovered += 1,
            CrashClass::LostAck => self.lost_ack += 1,
            CrashClass::TornOpen => self.torn_open += 1,
            CrashClass::NoRecover => self.no_recover += 1,
        }
        if class != CrashClass::Recovered {
            self.failures.push(format!("{what} -> {class:?}"));
        }
    }

    pub fn passed(&self) -> bool {
        self.lost_ack == 0
            && self.torn_open == 0
            && self.no_recover == 0
            && self.injections >= self.min_injections
    }

    /// One machine-greppable line (ci.sh keys off `verdict=` and the
    /// individual counters).
    pub fn summary(&self) -> String {
        format!(
            "crash: injections={} recovered={} lost_ack={} torn_open={} no_recover={} \
             verdict={}",
            self.injections,
            self.recovered,
            self.lost_ack,
            self.torn_open,
            self.no_recover,
            if self.passed() { "PASS" } else { "FAIL" },
        )
    }
}

/// Fixed probe workload: bit-exact (distance bits, id) signature over the
/// dataset's query set.
fn sig_of(idx: &dyn AnnIndex, queries: &[f32], dim: usize) -> Vec<(u32, u32)> {
    let p = QueryParams { k: 5, nprobe: 4, ef: 16 };
    let mut scratch = AnnScratch::default();
    let mut out = Vec::new();
    let mut sig = Vec::new();
    for q in queries.chunks_exact(dim) {
        idx.search_into(q, &p, &mut scratch, &mut out);
        sig.extend(out.iter().map(|&(d, id)| (d.to_bits(), id)));
    }
    sig
}

/// Copy every regular file of `src` into a fresh `dst`.
fn copy_dir(src: &Path, dst: &Path) -> Result<()> {
    let _ = std::fs::remove_dir_all(dst);
    std::fs::create_dir_all(dst)?;
    for entry in std::fs::read_dir(src)? {
        let p = entry?.path();
        if p.is_file() {
            std::fs::copy(&p, dst.join(p.file_name().context("file name")?))?;
        }
    }
    Ok(())
}

/// One scripted durable-store operation (part A).
#[derive(Clone)]
enum Op {
    /// Add rows `[start, end)` of the dataset (indices in rows).
    Add(usize, usize),
    /// Tombstone one id.
    Del(u32),
    /// Compact + roll the generation.
    Ckpt,
}

fn apply_op(store: &mut DurableDynamic, ds_data: &[f32], dim: usize, op: &Op) -> Result<()> {
    match op {
        Op::Add(a, b) => store.add(&ds_data[a * dim..b * dim]).map(|_| ()),
        Op::Del(id) => store.delete(*id).map(|_| ()),
        Op::Ckpt => store.checkpoint(),
    }
}

fn apply_op_ref(idx: &mut DynamicIvf, ds_data: &[f32], dim: usize, op: &Op) -> Result<()> {
    match op {
        Op::Add(a, b) => idx.add(&ds_data[a * dim..b * dim]).map(|_| ()),
        Op::Del(id) => idx.delete(*id).map(|_| ()),
        Op::Ckpt => idx.compact(),
    }
}

/// Part A: arm crash point n = 0, 1, 2, ... and run the op script until a
/// run completes with no point fired (the unarmed control). After each
/// injected crash the reopened store must match the reference state with
/// `completed` or `completed + 1` ops applied — anything less is lost
/// acknowledged data, anything else is a failed recovery.
fn sweep_dynamic_countdown(report: &mut CrashReport, root: &Path, seed: u64) -> Result<()> {
    let ds = generate(Kind::DeepLike, 320, 8, 8, seed);
    let dim = ds.dim;
    let base = DynamicIvf::build(
        &ds.data[..240 * dim],
        dim,
        &DynamicBuildParams {
            ivf: IvfBuildParams { k: 4, id_codec: "roc".into(), threads: 2, ..Default::default() },
            policy: CompactionPolicy { flush_rows: 24, auto: false, ..Default::default() },
        },
    )?;
    let mut del_rng = Rng::new(seed ^ 0xdead);
    let mut del = || del_rng.below(240) as u32;
    let ops = vec![
        Op::Add(240, 260),
        Op::Del(del()),
        Op::Del(del()),
        Op::Add(260, 280),
        Op::Ckpt,
        Op::Del(del()),
        Op::Del(del()),
        Op::Add(280, 320),
        Op::Ckpt,
    ];

    // Reference signatures: ref_sigs[j] = state after j ops.
    let mut reference = base.clone();
    let mut ref_sigs = vec![sig_of(&reference, &ds.queries, dim)];
    for op in &ops {
        apply_op_ref(&mut reference, &ds.data, dim, op)?;
        ref_sigs.push(sig_of(&reference, &ds.queries, dim));
    }

    let template = root.join("dyn-template");
    DurableDynamic::create(&template, base)?;

    let work = root.join("dyn-work");
    for nth in 0..10_000u64 {
        copy_dir(&template, &work)?;
        let (mut store, _) = DurableDynamic::open(&work)
            .context("part A: clean template copy failed to open")?;
        crash::arm(nth);
        let mut completed = 0usize;
        let mut failed = false;
        for op in &ops {
            if apply_op(&mut store, &ds.data, dim, op).is_err() {
                failed = true;
                break;
            }
            completed += 1;
        }
        let fired = crash::disarm();
        drop(store);
        match fired {
            None => {
                // Control run: the countdown outlived the script, so every
                // op ran crash-free — verify and stop the sweep.
                ensure!(!failed, "part A: op failed with no crash injected");
                let (store, stats) = DurableDynamic::open(&work)?;
                ensure!(stats.torn_bytes == 0, "control run left a torn tail");
                ensure!(
                    sig_of(store.index(), &ds.queries, dim) == ref_sigs[ops.len()],
                    "control run diverged from the reference"
                );
                break;
            }
            Some(site) => {
                let what = format!("ingest crash #{nth} at {site} (op {completed})");
                let class = match DurableDynamic::open(&work) {
                    Err(e) => {
                        report.failures.push(format!("{what}: reopen failed: {e:#}"));
                        CrashClass::NoRecover
                    }
                    Ok((store, _stats)) => {
                        let got = sig_of(store.index(), &ds.queries, dim);
                        if got == ref_sigs[completed]
                            || ref_sigs.get(completed + 1) == Some(&got)
                        {
                            CrashClass::Recovered
                        } else if ref_sigs[..completed].contains(&got) {
                            CrashClass::LostAck
                        } else {
                            CrashClass::NoRecover
                        }
                    }
                };
                report.count(&what, class);
            }
        }
    }
    let _ = std::fs::remove_dir_all(&work);
    let _ = std::fs::remove_dir_all(&template);
    Ok(())
}

/// Part B: countdown sweep over node-directory shard swaps. The script
/// commits a new snapshot into each of the two shards; a crash at any
/// point must leave the directory opening into either the previous or the
/// new generation — never a half-swapped mix.
fn sweep_node_countdown(report: &mut CrashReport, root: &Path, seed: u64) -> Result<()> {
    let ds = generate(Kind::DeepLike, 400, 8, 8, seed ^ 0x0de);
    let dim = ds.dim;
    let build = |rows: usize| -> Result<(Router, Vec<Vec<u8>>)> {
        let sharded = ShardedIndex::build(
            &ds.data[..rows * dim],
            dim,
            &ShardedBuildParams {
                shards: 2,
                router: RouterKind::Hash,
                ivf: IvfBuildParams {
                    k: 8,
                    id_codec: "roc".into(),
                    threads: 2,
                    ..Default::default()
                },
            },
        )?;
        let (router, shards, id_maps, _) = sharded.into_parts();
        let mut snaps = Vec::new();
        for (shard, map) in shards.into_iter().zip(id_maps) {
            let one = ShardedIndex::from_parts(
                Router::Hash { seed: 0 },
                vec![shard],
                vec![map],
                dim,
                true,
            )?;
            snaps.push(one.to_bytes()?);
        }
        Ok((router, snaps))
    };
    let (router, old_snaps) = build(300)?;
    let (_, new_snaps) = build(400)?;

    let template = root.join("node-template");
    dnode::init_node_dir(&template, &router, dim, &old_snaps)?;

    // Reference signatures after 0, 1, 2 completed commits.
    let work = root.join("node-work");
    let mut ref_sigs = Vec::new();
    copy_dir(&template, &work)?;
    let probe = |dir: &Path| -> Result<Vec<(u32, u32)>> {
        let (idx, _) = dnode::open_node_dir(dir)?;
        Ok(sig_of(&idx, &ds.queries, dim))
    };
    ref_sigs.push(probe(&work)?);
    dnode::commit_shard(&work, 0, &new_snaps[0])?;
    ref_sigs.push(probe(&work)?);
    dnode::commit_shard(&work, 1, &new_snaps[1])?;
    ref_sigs.push(probe(&work)?);

    for nth in 0..10_000u64 {
        copy_dir(&template, &work)?;
        crash::arm(nth);
        let mut completed = 0usize;
        for (s, snap) in new_snaps.iter().enumerate() {
            if dnode::commit_shard(&work, s, snap).is_err() {
                break;
            }
            completed += 1;
        }
        let fired = crash::disarm();
        match fired {
            None => {
                ensure!(completed == 2, "part B: commit failed with no crash injected");
                ensure!(
                    probe(&work)? == ref_sigs[2],
                    "part B: control run diverged from the reference"
                );
                break;
            }
            Some(site) => {
                let what = format!("swap crash #{nth} at {site} (commit {completed})");
                let class = match probe(&work) {
                    Err(e) => {
                        report.failures.push(format!("{what}: reopen failed: {e:#}"));
                        CrashClass::NoRecover
                    }
                    Ok(got) => {
                        if got == ref_sigs[completed]
                            || ref_sigs.get(completed + 1) == Some(&got)
                        {
                            CrashClass::Recovered
                        } else if ref_sigs[..completed].contains(&got) {
                            CrashClass::LostAck
                        } else {
                            CrashClass::NoRecover
                        }
                    }
                };
                report.count(&what, class);
            }
        }
    }
    let _ = std::fs::remove_dir_all(&work);
    let _ = std::fs::remove_dir_all(&template);
    Ok(())
}

/// Part C: truncate the WAL at every `tail_stride`-th byte offset. Recovery
/// must reproduce exactly the acknowledged records whose frames survived
/// whole, and disclose the rest as torn bytes.
fn sweep_torn_tails(
    report: &mut CrashReport,
    root: &Path,
    seed: u64,
    stride: usize,
) -> Result<()> {
    let ds = generate(Kind::DeepLike, 252, 8, 8, seed ^ 0x7ea);
    let dim = ds.dim;
    let base = DynamicIvf::build(
        &ds.data[..240 * dim],
        dim,
        &DynamicBuildParams {
            ivf: IvfBuildParams { k: 4, id_codec: "roc".into(), threads: 2, ..Default::default() },
            policy: CompactionPolicy { flush_rows: 64, auto: false, ..Default::default() },
        },
    )?;
    let template = root.join("tail-template");
    let mut store = DurableDynamic::create(&template, base.clone())?;
    store.add(&ds.data[240 * dim..246 * dim])?;
    store.delete(3)?;
    store.add(&ds.data[246 * dim..252 * dim])?;
    drop(store);

    // Frame boundaries of the intact WAL (cut exactly there = clean log).
    let wal_path = template.join("wal-0.log");
    let wal_bytes = std::fs::read(&wal_path)?;
    let mut boundaries = vec![wal::WAL_HEADER as usize];
    let mut pos = wal::WAL_HEADER as usize;
    while pos < wal_bytes.len() {
        let len = u32::from_le_bytes(wal_bytes[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 8 + len;
        boundaries.push(pos);
    }
    ensure!(pos == wal_bytes.len(), "part C: walked past the WAL end");
    let records = wal::replay(&wal_path)?.records;
    ensure!(records.len() + 1 == boundaries.len(), "part C: frame walk disagrees with replay");

    // Reference signature with the first r records applied.
    let mut ref_sigs = Vec::new();
    let mut reference = base;
    ref_sigs.push(sig_of(&reference, &ds.queries, dim));
    for rec in &records {
        apply(&mut reference, rec)?;
        ref_sigs.push(sig_of(&reference, &ds.queries, dim));
    }

    let work = root.join("tail-work");
    for cut in (wal::WAL_HEADER as usize..=wal_bytes.len()).step_by(stride.max(1)) {
        copy_dir(&template, &work)?;
        std::fs::write(work.join("wal-0.log"), &wal_bytes[..cut])?;
        let acked = boundaries.iter().filter(|&&b| b <= cut).count() - 1;
        let torn = cut - boundaries[acked];
        let what = format!("torn wal tail at byte {cut}/{}", wal_bytes.len());
        let class = match DurableDynamic::open(&work) {
            Err(e) => {
                report.failures.push(format!("{what}: reopen failed: {e:#}"));
                CrashClass::NoRecover
            }
            Ok((store, stats)) => {
                if stats.replayed_records != acked || stats.torn_bytes != torn as u64 {
                    report.failures.push(format!(
                        "{what}: recovery reported {} records / {} torn bytes, \
                         expected {acked} / {torn}",
                        stats.replayed_records, stats.torn_bytes
                    ));
                    CrashClass::NoRecover
                } else {
                    let got = sig_of(store.index(), &ds.queries, dim);
                    if got == ref_sigs[acked] {
                        CrashClass::Recovered
                    } else if ref_sigs[..acked].contains(&got) {
                        CrashClass::LostAck
                    } else {
                        CrashClass::NoRecover
                    }
                }
            }
        };
        report.count(&what, class);
    }
    let _ = std::fs::remove_dir_all(&work);
    let _ = std::fs::remove_dir_all(&template);
    Ok(())
}

/// Part E: cut real containers at every section boundary; each prefix has
/// flawless per-section framing, so only the v3 terminator stands between
/// a torn file and a successful open.
fn sweep_boundary_truncations(report: &mut CrashReport, seed: u64) -> Result<()> {
    let ds = generate(Kind::DeepLike, 300, 4, 8, seed ^ 0xb0d);
    let ivf = IvfIndex::build(
        &ds.data,
        ds.dim,
        &IvfBuildParams { k: 8, id_codec: "roc".into(), threads: 2, ..Default::default() },
    );
    let dynamic = DynamicIvf::build(
        &ds.data,
        ds.dim,
        &DynamicBuildParams {
            ivf: IvfBuildParams { k: 6, id_codec: "roc".into(), threads: 2, ..Default::default() },
            policy: CompactionPolicy::default(),
        },
    )?;
    let sharded = ShardedIndex::build(
        &ds.data,
        ds.dim,
        &ShardedBuildParams {
            shards: 2,
            router: RouterKind::Hash,
            ivf: IvfBuildParams { k: 8, id_codec: "roc".into(), threads: 2, ..Default::default() },
        },
    )?;
    let files: Vec<(&str, Vec<u8>)> = vec![
        ("ivf", ivf.to_container_bytes()?),
        ("dynamic", dynamic.to_bytes()?),
        ("sharded", sharded.to_bytes()?),
    ];
    for (name, bytes) in files {
        let mut pos = 8usize;
        while pos < bytes.len() {
            let what = format!("{name} container cut at section boundary {pos}/{}", bytes.len());
            let class = match persist::open_bytes(bytes[..pos].to_vec()) {
                Ok(_) => CrashClass::TornOpen,
                Err(e) if persist::is_truncated(&e) => CrashClass::Recovered,
                Err(e) => {
                    report
                        .failures
                        .push(format!("{what}: unstructured rejection: {e:#}"));
                    CrashClass::NoRecover
                }
            };
            report.count(&what, class);
            let len = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap()) as usize;
            let len_hi = u32::from_le_bytes(bytes[pos + 8..pos + 12].try_into().unwrap());
            ensure!(len_hi == 0, "part E: section at {pos} longer than 4 GiB?");
            pos += 12 + len + 4;
        }
        ensure!(pos == bytes.len(), "part E: {name} section walk misaligned");
    }
    Ok(())
}

/// Part D1: kill -9 a real `zann crash-victim` ingest loop at a seeded
/// wall-clock offset, then recover and compare against a reference built
/// from the acknowledged batches alone.
fn sweep_victim_kills(
    report: &mut CrashReport,
    root: &Path,
    exe: &Path,
    seed: u64,
    kills: usize,
) -> Result<()> {
    let ds = generate(Kind::DeepLike, 240, 8, 8, seed ^ 0x514);
    let dim = ds.dim;
    let rows_per_batch = 8usize;
    let base = DynamicIvf::build(
        &ds.data,
        dim,
        &DynamicBuildParams {
            ivf: IvfBuildParams { k: 4, id_codec: "roc".into(), threads: 2, ..Default::default() },
            policy: CompactionPolicy { flush_rows: 64, auto: false, ..Default::default() },
        },
    )?;
    let base_next = base.next_id();
    let template = root.join("victim-template");
    DurableDynamic::create(&template, base.clone())?;

    let mut rng = Rng::new(seed ^ 0x6b11);
    let work = root.join("victim-work");
    for ki in 0..kills {
        copy_dir(&template, &work)?;
        let victim_seed = seed.wrapping_add(ki as u64);
        let mut child = std::process::Command::new(exe)
            .arg("crash-victim")
            .arg(&work)
            .args(["--seed", &victim_seed.to_string()])
            .args(["--rows", &rows_per_batch.to_string()])
            .args(["--batches", "512"])
            .args(["--checkpoint-every", "16"])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::null())
            .spawn()
            .context("spawn crash-victim")?;
        let delay_ms = 1 + rng.below(40);
        std::thread::sleep(std::time::Duration::from_millis(delay_ms));
        let _ = child.kill();
        let output = child.wait_with_output().context("wait for crash-victim")?;
        let acked = String::from_utf8_lossy(&output.stdout)
            .lines()
            .filter(|l| l.starts_with("ack "))
            .count();

        let what = format!("kill -9 crash-victim #{ki} after {delay_ms}ms ({acked} acked)");
        let class = match DurableDynamic::open(&work) {
            Err(e) => {
                report.failures.push(format!("{what}: reopen failed: {e:#}"));
                CrashClass::NoRecover
            }
            Ok((store, _)) => {
                let grew = store.index().next_id() - base_next;
                if grew as usize % rows_per_batch != 0 {
                    report.failures.push(format!(
                        "{what}: {grew} recovered rows is a partial batch"
                    ));
                    CrashClass::LostAck
                } else {
                    let batches = grew as usize / rows_per_batch;
                    if batches < acked {
                        report.failures.push(format!(
                            "{what}: only {batches} batches survived, {acked} were acked"
                        ));
                        CrashClass::LostAck
                    } else {
                        // Reference: the template index plus the recovered
                        // number of seeded batches, no compaction (search
                        // parity is segmentation-independent).
                        let mut reference = base.clone();
                        for b in 0..batches {
                            reference.add(&victim_rows(victim_seed, b, rows_per_batch, dim))?;
                        }
                        if sig_of(store.index(), &ds.queries, dim)
                            == sig_of(&reference, &ds.queries, dim)
                        {
                            CrashClass::Recovered
                        } else {
                            report.failures.push(format!(
                                "{what}: recovered state diverges from the acked batches"
                            ));
                            CrashClass::NoRecover
                        }
                    }
                }
            }
        };
        report.count(&what, class);
    }
    let _ = std::fs::remove_dir_all(&work);
    let _ = std::fs::remove_dir_all(&template);
    Ok(())
}

/// Deterministic rows for `crash-victim` batch `b` — shared between the
/// victim process (which writes them) and the harness (which rebuilds the
/// reference), so both sides agree byte-for-byte.
pub fn victim_rows(seed: u64, batch: usize, rows: usize, dim: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ (batch as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    (0..rows * dim).map(|_| rng.normal()).collect()
}

/// Part D2: kill -9 a real `zann build` mid-write; the destination file
/// must keep opening (old bytes before the rename, new bytes after).
fn sweep_build_kills(
    report: &mut CrashReport,
    root: &Path,
    exe: &Path,
    seed: u64,
    kills: usize,
) -> Result<()> {
    let out = root.join("victim.zann");
    let ds = generate(Kind::DeepLike, 500, 1, 8, seed ^ 0xb1d);
    let seeded = IvfIndex::build(
        &ds.data,
        ds.dim,
        &IvfBuildParams { k: 8, id_codec: "roc".into(), threads: 2, ..Default::default() },
    );
    persist::save(&seeded, &out)?;

    let mut rng = Rng::new(seed ^ 0xbadbeef);
    for ki in 0..kills {
        let mut child = std::process::Command::new(exe)
            .args(["build", "--out"])
            .arg(&out)
            .args(["--backend", "ivf", "--codec", "roc", "--n", "3000", "--dim", "8"])
            .args(["--k", "16"])
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .spawn()
            .context("spawn zann build")?;
        let delay_ms = 5 + rng.below(120);
        std::thread::sleep(std::time::Duration::from_millis(delay_ms));
        let _ = child.kill();
        let _ = child.wait();
        let what = format!("kill -9 zann build #{ki} after {delay_ms}ms");
        let class = match persist::open(&out) {
            Ok(_) => CrashClass::Recovered,
            Err(e) => {
                report.failures.push(format!("{what}: {e:#}"));
                CrashClass::TornOpen
            }
        };
        report.count(&what, class);
    }
    Ok(())
}

/// Run every part of the crash matrix (see module docs).
pub fn run_crash_sweep(cfg: &CrashConfig) -> Result<CrashReport> {
    let tag = format!("zann-crash-{}-{:x}", std::process::id(), cfg.seed);
    let root = std::env::temp_dir().join(tag);
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root)?;

    let mut report = CrashReport { min_injections: cfg.min_injections, ..Default::default() };
    sweep_dynamic_countdown(&mut report, &root, cfg.seed)?;
    sweep_node_countdown(&mut report, &root, cfg.seed)?;
    sweep_torn_tails(&mut report, &root, cfg.seed, cfg.tail_stride)?;
    sweep_boundary_truncations(&mut report, cfg.seed)?;
    if let Some(exe) = &cfg.exe {
        sweep_victim_kills(&mut report, &root, exe, cfg.seed, cfg.victim_kills)?;
        sweep_build_kills(&mut report, &root, exe, cfg.seed, cfg.build_kills)?;
    }

    let _ = std::fs::remove_dir_all(&root);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_sweep_recovers_everything() {
        // Stride 5 keeps the torn-tail scan quick; the CLI gate runs
        // stride 1 with child-process kills on top.
        let cfg = CrashConfig {
            seed: 13,
            tail_stride: 5,
            min_injections: 100,
            ..Default::default()
        };
        let rep = run_crash_sweep(&cfg).unwrap();
        assert!(
            rep.passed(),
            "crash sweep failed: {}\n{}",
            rep.summary(),
            rep.failures.join("\n")
        );
        assert!(rep.injections >= 100, "{}", rep.summary());
        assert_eq!(rep.recovered, rep.injections, "{}", rep.summary());
        assert!(rep.summary().contains("verdict=PASS"));
    }
}
