//! Deterministic fault-injection sweep over the container format: every
//! codec × backend builds a tiny index, its serialized bytes are mutated
//! (seeded byte flips, truncations, word/block swaps), and every mutant
//! must be *detected* — rejected by the CRC check at open or by a
//! structured decode error — never a panic, a hang, or a silently wrong
//! answer. The CLI `inject-faults` subcommand runs this sweep and exits
//! non-zero on any crash/hang/silent-wrong, which is the CI chaos gate.

use crate::api::{persist, AnnIndex, AnnScratch, GraphIndex, QueryParams};
use crate::datasets::{generate, Kind};
use crate::dynamic::{CompactionPolicy, DynamicBuildParams, DynamicIvf};
use crate::graph::hnsw::{Hnsw, HnswParams};
use crate::graph::nsg::{Nsg, NsgParams};
use crate::index::{IvfBuildParams, IvfIndex, VectorMode};
use crate::util::Rng;
use anyhow::{ensure, Context as _, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::time::Duration;

/// Knobs of one sweep. Defaults give 13 targets × 40 mutants = 520
/// seeded corruptions, each bounded by `timeout`.
pub struct ChaosConfig {
    pub seed: u64,
    pub mutations_per_target: usize,
    /// Per-mutant wall-clock guard: open + probe past this is a hang.
    pub timeout: Duration,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig { seed: 7, mutations_per_target: 40, timeout: Duration::from_secs(5) }
    }
}

/// What one mutated container did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Open or decode returned a structured error — the corruption was
    /// caught.
    Detected,
    /// The mutant opened and answered the probe queries bit-identically
    /// to the clean container (the mutation hit a byte with no
    /// observable meaning, e.g. the reserved header byte).
    Harmless,
    /// The mutant opened and answered *differently* — undetected
    /// corruption. Always a failure.
    SilentWrong,
    /// Open or probe panicked. Always a failure.
    Crash,
    /// Open or probe exceeded the time guard. Always a failure.
    Hang,
}

/// Aggregated sweep result.
#[derive(Default)]
pub struct FaultReport {
    pub targets: usize,
    pub mutations: usize,
    pub detected: usize,
    pub harmless: usize,
    pub silent_wrong: usize,
    pub crashes: usize,
    pub hangs: usize,
    /// One line per failing mutant: `target: mutation -> outcome`.
    pub failures: Vec<String>,
}

impl FaultReport {
    pub fn passed(&self) -> bool {
        self.silent_wrong == 0 && self.crashes == 0 && self.hangs == 0
    }

    /// One machine-greppable line (ci.sh keys off `verdict=`).
    pub fn summary(&self) -> String {
        format!(
            "chaos: targets={} mutations={} detected={} harmless={} silent_wrong={} \
             crashes={} hangs={} verdict={}",
            self.targets,
            self.mutations,
            self.detected,
            self.harmless,
            self.silent_wrong,
            self.crashes,
            self.hangs,
            if self.passed() { "PASS" } else { "FAIL" },
        )
    }

    fn count(&mut self, target: &str, mutation: &str, o: Outcome) {
        self.mutations += 1;
        match o {
            Outcome::Detected => self.detected += 1,
            Outcome::Harmless => self.harmless += 1,
            Outcome::SilentWrong => self.silent_wrong += 1,
            Outcome::Crash => self.crashes += 1,
            Outcome::Hang => self.hangs += 1,
        }
        if !matches!(o, Outcome::Detected | Outcome::Harmless) {
            self.failures.push(format!("{target}: {mutation} -> {o:?}"));
        }
    }
}

/// Build the codec × backend container zoo: one tiny IVF per per-list
/// codec, the two PQ vector modes, both graph families, and a churned
/// multi-segment dynamic index. Each entry is (name, container bytes).
pub fn build_targets(seed: u64) -> Result<Vec<(String, Vec<u8>)>> {
    let ds = generate(Kind::DeepLike, 300, 4, 8, seed);
    let mut out = Vec::new();

    for codec in crate::codecs::PER_LIST_CODECS {
        let idx = IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 8, id_codec: codec.to_string(), threads: 2, ..Default::default() },
        );
        out.push((format!("ivf-flat/{codec}"), idx.to_container_bytes()?));
    }

    for (label, vectors) in [
        ("ivf-pq/roc", VectorMode::Pq { m: 4, bits: 4 }),
        ("ivf-pqc/roc", VectorMode::PqCompressed { m: 4, bits: 4 }),
    ] {
        let idx = IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams {
                k: 8,
                id_codec: "roc".into(),
                vectors,
                threads: 2,
                ..Default::default()
            },
        );
        out.push((label.to_string(), idx.to_container_bytes()?));
    }

    let nsg = Nsg::build(
        &ds.data,
        ds.dim,
        &NsgParams { r: 12, knn_k: 16, threads: 2, seed, ..Default::default() },
    );
    out.push(("nsg/roc".into(), GraphIndex::from_nsg(&nsg, &ds.data, "roc")?.to_bytes()?));

    let hnsw = Hnsw::build(&ds.data, ds.dim, &HnswParams { m: 8, ef_construction: 40, seed });
    out.push(("hnsw/ef".into(), GraphIndex::from_hnsw(&hnsw, &ds.data, "ef")?.to_bytes()?));

    // Churned dynamic index: segments + write buffer + tombstones all
    // present in the container.
    let mut dynamic = DynamicIvf::build(
        &ds.data[..200 * ds.dim],
        ds.dim,
        &DynamicBuildParams {
            ivf: IvfBuildParams { k: 6, id_codec: "roc".into(), threads: 2, ..Default::default() },
            policy: CompactionPolicy { flush_rows: 50, auto: true, ..Default::default() },
        },
    )?;
    let mut rng = Rng::new(seed ^ 0x5eed);
    for id in rng.sample_distinct(200, 30) {
        dynamic.delete(id as u32)?;
    }
    dynamic.add(&ds.data[200 * ds.dim..])?;
    out.push(("dynamic/roc".into(), dynamic.to_bytes()?));

    Ok(out)
}

/// Open a container and answer a fixed seeded probe workload; the
/// returned signature is bit-exact ((distance bits, id) per rank), so
/// any observable behavior change against the clean baseline shows up.
fn probe(bytes: Vec<u8>) -> Result<Vec<(u32, u32)>> {
    let idx = persist::open_bytes(bytes)?;
    let dim = idx.dim();
    let p = QueryParams { k: 5, nprobe: 4, ef: 16 };
    let mut rng = Rng::new(123);
    let mut scratch = AnnScratch::default();
    let mut out = Vec::new();
    let mut sig = Vec::new();
    for _ in 0..4 {
        let q: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        idx.search_into(&q, &p, &mut scratch, &mut out);
        sig.extend(out.iter().map(|&(d, id)| (d.to_bits(), id)));
    }
    Ok(sig)
}

/// One seeded corruption of `base`; returns the mutant + a description.
fn mutate(rng: &mut Rng, base: &[u8]) -> (Vec<u8>, String) {
    let len = base.len();
    let mut bytes = base.to_vec();
    match rng.below(10) {
        // Bit flips dominate: the classic single-event upset.
        0..=5 => {
            let pos = rng.below(len as u64) as usize;
            let mask = 1u8 << rng.below(8);
            bytes[pos] ^= mask;
            (bytes, format!("flip byte {pos} mask {mask:#04x}"))
        }
        // Truncation: torn write / short read.
        6..=7 => {
            let cut = rng.below(len as u64) as usize;
            bytes.truncate(cut);
            (bytes, format!("truncate to {cut} of {len}"))
        }
        // Word swap: misplaced 4-byte field (section tags, lengths,
        // CRCs, ids all live in little-endian words).
        8 if len >= 16 => {
            let a = rng.below((len - 4) as u64) as usize;
            let b = rng.below((len - 4) as u64) as usize;
            for i in 0..4 {
                bytes.swap(a + i, b + i);
            }
            (bytes, format!("swap words at {a} and {b}"))
        }
        // Block swap: transposed pages.
        _ if len >= 96 => {
            let a = rng.below((len - 32) as u64) as usize;
            let b = rng.below((len - 32) as u64) as usize;
            for i in 0..32 {
                bytes.swap(a + i, b + i);
            }
            (bytes, format!("swap 32-byte blocks at {a} and {b}"))
        }
        _ => {
            let pos = rng.below(len as u64) as usize;
            bytes[pos] ^= 0xff;
            (bytes, format!("invert byte {pos}"))
        }
    }
}

/// Open + probe one mutant on a watchdog thread: a panic is `Crash`, a
/// structured error is `Detected`, exceeding `timeout` is `Hang` (the
/// stuck thread is abandoned — this is a test harness, not a server).
fn run_guarded(bytes: Vec<u8>, baseline: &[(u32, u32)], timeout: Duration) -> Outcome {
    let (tx, rx) = mpsc::channel();
    let base = baseline.to_vec();
    std::thread::spawn(move || {
        let outcome = match catch_unwind(AssertUnwindSafe(|| probe(bytes))) {
            Err(_) => Outcome::Crash,
            Ok(Err(_)) => Outcome::Detected,
            Ok(Ok(sig)) => {
                if sig == base {
                    Outcome::Harmless
                } else {
                    Outcome::SilentWrong
                }
            }
        };
        let _ = tx.send(outcome);
    });
    rx.recv_timeout(timeout).unwrap_or(Outcome::Hang)
}

/// Run the full sweep: every target container, `mutations_per_target`
/// seeded corruptions each. Panics inside mutants are caught and print
/// their payload to stderr (rust's default hook) — a clean run is quiet
/// because a clean run has no panics.
pub fn run_chaos_sweep(cfg: &ChaosConfig) -> Result<FaultReport> {
    let targets = build_targets(cfg.seed)?;
    let mut report = FaultReport { targets: targets.len(), ..Default::default() };
    for (ti, (name, bytes)) in targets.iter().enumerate() {
        let baseline = probe(bytes.clone())
            .with_context(|| format!("{name}: clean container failed its own probe"))?;
        ensure!(!bytes.is_empty(), "{name}: empty container");
        let mut rng = Rng::new(cfg.seed.wrapping_mul(0x9e37_79b9).wrapping_add(ti as u64));
        for _ in 0..cfg.mutations_per_target {
            let (mutant, desc) = mutate(&mut rng, bytes);
            let outcome = run_guarded(mutant, &baseline, cfg.timeout);
            report.count(name, &desc, outcome);
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_detects_everything_without_crashing() {
        // Small per-target count to keep the test quick; the CLI gate
        // runs the full default sweep.
        let cfg = ChaosConfig { seed: 11, mutations_per_target: 6, ..Default::default() };
        let rep = run_chaos_sweep(&cfg).unwrap();
        assert!(rep.targets >= 13, "expected the full codec × backend zoo, got {}", rep.targets);
        assert_eq!(rep.mutations, rep.targets * 6);
        assert!(
            rep.passed(),
            "chaos sweep failed: {}\n{}",
            rep.summary(),
            rep.failures.join("\n")
        );
        assert_eq!(rep.detected + rep.harmless, rep.mutations);
        // Corruption of checksummed containers is overwhelmingly caught,
        // not silently benign.
        assert!(rep.detected > rep.harmless, "{}", rep.summary());
        assert!(rep.summary().contains("verdict=PASS"));
    }

    #[test]
    fn mutants_actually_differ_from_base() {
        let base: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let mut rng = Rng::new(3);
        let mut changed = 0;
        for _ in 0..50 {
            let (m, _) = mutate(&mut rng, &base);
            if m != base {
                changed += 1;
            }
        }
        // Word/block swaps of identical content can no-op; flips and
        // truncations cannot, and they dominate the mix.
        assert!(changed >= 40, "only {changed}/50 mutants differed");
    }
}
