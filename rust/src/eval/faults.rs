//! Deterministic fault-injection sweep over the container format: every
//! codec × backend builds a tiny index, its serialized bytes are mutated
//! (seeded byte flips, truncations, word/block swaps), and every mutant
//! must be *detected* — rejected by the CRC check at open or by a
//! structured decode error — never a panic, a hang, or a silently wrong
//! answer. The CLI `inject-faults` subcommand runs this sweep and exits
//! non-zero on any crash/hang/silent-wrong, which is the CI chaos gate.

use crate::api::{persist, AnnIndex, AnnScratch, GraphIndex, QueryParams};
use crate::datasets::{generate, Kind};
use crate::durable::store::DurableDynamic;
use crate::dynamic::{CompactionPolicy, DynamicBuildParams, DynamicIvf};
use crate::graph::hnsw::{Hnsw, HnswParams};
use crate::graph::nsg::{Nsg, NsgParams};
use crate::index::{IvfBuildParams, IvfIndex, VectorMode};
use crate::serve::sharded::{Router, RouterKind, ShardedBuildParams, ShardedIndex};
use crate::util::Rng;
use anyhow::{ensure, Context as _, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::Duration;

/// Knobs of one sweep. Defaults give (15 file + 2 directory) targets × 40
/// mutants = 680 seeded corruptions, each bounded by `timeout`.
pub struct ChaosConfig {
    pub seed: u64,
    pub mutations_per_target: usize,
    /// Per-mutant wall-clock guard: open + probe past this is a hang.
    pub timeout: Duration,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig { seed: 7, mutations_per_target: 40, timeout: Duration::from_secs(5) }
    }
}

/// What one mutated container did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Open or decode returned a structured error — the corruption was
    /// caught.
    Detected,
    /// The mutant opened and answered the probe queries bit-identically
    /// to the clean container (the mutation hit a byte with no
    /// observable meaning, e.g. the reserved header byte).
    Harmless,
    /// The mutant opened and answered *differently* — undetected
    /// corruption. Always a failure.
    SilentWrong,
    /// Open or probe panicked. Always a failure.
    Crash,
    /// Open or probe exceeded the time guard. Always a failure.
    Hang,
}

/// Aggregated sweep result.
#[derive(Default)]
pub struct FaultReport {
    pub targets: usize,
    pub mutations: usize,
    pub detected: usize,
    pub harmless: usize,
    pub silent_wrong: usize,
    pub crashes: usize,
    pub hangs: usize,
    /// One line per failing mutant: `target: mutation -> outcome`.
    pub failures: Vec<String>,
}

impl FaultReport {
    pub fn passed(&self) -> bool {
        self.silent_wrong == 0 && self.crashes == 0 && self.hangs == 0
    }

    /// One machine-greppable line (ci.sh keys off `verdict=`).
    pub fn summary(&self) -> String {
        format!(
            "chaos: targets={} mutations={} detected={} harmless={} silent_wrong={} \
             crashes={} hangs={} verdict={}",
            self.targets,
            self.mutations,
            self.detected,
            self.harmless,
            self.silent_wrong,
            self.crashes,
            self.hangs,
            if self.passed() { "PASS" } else { "FAIL" },
        )
    }

    fn count(&mut self, target: &str, mutation: &str, o: Outcome) {
        self.mutations += 1;
        match o {
            Outcome::Detected => self.detected += 1,
            Outcome::Harmless => self.harmless += 1,
            Outcome::SilentWrong => self.silent_wrong += 1,
            Outcome::Crash => self.crashes += 1,
            Outcome::Hang => self.hangs += 1,
        }
        if !matches!(o, Outcome::Detected | Outcome::Harmless) {
            self.failures.push(format!("{target}: {mutation} -> {o:?}"));
        }
    }
}

/// Build the codec × backend container zoo: one tiny IVF per per-list
/// codec, the two PQ vector modes, both graph families, and a churned
/// multi-segment dynamic index. Each entry is (name, container bytes).
pub fn build_targets(seed: u64) -> Result<Vec<(String, Vec<u8>)>> {
    let ds = generate(Kind::DeepLike, 300, 4, 8, seed);
    let mut out = Vec::new();

    for codec in crate::codecs::PER_LIST_CODECS {
        let idx = IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 8, id_codec: codec.to_string(), threads: 2, ..Default::default() },
        );
        out.push((format!("ivf-flat/{codec}"), idx.to_container_bytes()?));
    }

    for (label, vectors) in [
        ("ivf-pq/roc", VectorMode::Pq { m: 4, bits: 4 }),
        ("ivf-pqc/roc", VectorMode::PqCompressed { m: 4, bits: 4 }),
    ] {
        let idx = IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams {
                k: 8,
                id_codec: "roc".into(),
                vectors,
                threads: 2,
                ..Default::default()
            },
        );
        out.push((label.to_string(), idx.to_container_bytes()?));
    }

    let nsg = Nsg::build(
        &ds.data,
        ds.dim,
        &NsgParams { r: 12, knn_k: 16, threads: 2, seed, ..Default::default() },
    );
    out.push(("nsg/roc".into(), GraphIndex::from_nsg(&nsg, &ds.data, "roc")?.to_bytes()?));

    let hnsw = Hnsw::build(&ds.data, ds.dim, &HnswParams { m: 8, ef_construction: 40, seed });
    out.push(("hnsw/ef".into(), GraphIndex::from_hnsw(&hnsw, &ds.data, "ef")?.to_bytes()?));

    // Churned dynamic index: segments + write buffer + tombstones all
    // present in the container.
    let mut dynamic = DynamicIvf::build(
        &ds.data[..200 * ds.dim],
        ds.dim,
        &DynamicBuildParams {
            ivf: IvfBuildParams { k: 6, id_codec: "roc".into(), threads: 2, ..Default::default() },
            policy: CompactionPolicy { flush_rows: 50, auto: true, ..Default::default() },
        },
    )?;
    let mut rng = Rng::new(seed ^ 0x5eed);
    for id in rng.sample_distinct(200, 30) {
        dynamic.delete(id as u32)?;
    }
    dynamic.add(&ds.data[200 * ds.dim..])?;
    out.push(("dynamic/roc".into(), dynamic.to_bytes()?));

    // Sharded (kind 4) containers: routing header + embedded per-shard
    // containers + id maps, under both router families.
    for (label, router) in
        [("sharded-hash/roc", RouterKind::Hash), ("sharded-kmeans/roc", RouterKind::Kmeans)]
    {
        let sharded = ShardedIndex::build(
            &ds.data,
            ds.dim,
            &ShardedBuildParams {
                shards: 2,
                router,
                ivf: IvfBuildParams {
                    k: 8,
                    id_codec: "roc".into(),
                    threads: 2,
                    ..Default::default()
                },
            },
        )?;
        out.push((label.to_string(), sharded.to_bytes()?));
    }

    Ok(out)
}

/// Durable *directory* targets (a dynamic store and a sharded node dir),
/// built under `root`. Complements [`build_targets`]: here the mutation
/// surface is the multi-file layout — manifest, WAL, router, per-shard
/// containers — rather than one container's bytes.
pub fn build_dir_targets(seed: u64, root: &Path) -> Result<Vec<(String, PathBuf)>> {
    let ds = generate(Kind::DeepLike, 300, 4, 8, seed);
    let mut out = Vec::new();

    // Dynamic store: checkpointed base plus live WAL records (adds and
    // deletes) so every recovery surface is present on disk.
    let idx = DynamicIvf::build(
        &ds.data[..200 * ds.dim],
        ds.dim,
        &DynamicBuildParams {
            ivf: IvfBuildParams { k: 6, id_codec: "roc".into(), threads: 2, ..Default::default() },
            policy: CompactionPolicy { flush_rows: 50, auto: false, ..Default::default() },
        },
    )?;
    let dyn_dir = root.join("dynamic-store");
    let mut store = DurableDynamic::create(&dyn_dir, idx)?;
    store.add(&ds.data[200 * ds.dim..280 * ds.dim])?;
    let mut rng = Rng::new(seed ^ 0xd1e5);
    for id in rng.sample_distinct(200, 20) {
        store.delete(id as u32)?;
    }
    store.add(&ds.data[280 * ds.dim..])?;
    drop(store);
    out.push(("durable-dynamic-dir/roc".to_string(), dyn_dir));

    // Node directory: router file + two single-shard snapshot containers
    // behind a manifest, assembled exactly like `ServeNode::save_dir`.
    let sharded = ShardedIndex::build(
        &ds.data,
        ds.dim,
        &ShardedBuildParams {
            shards: 2,
            router: RouterKind::Hash,
            ivf: IvfBuildParams { k: 8, id_codec: "roc".into(), threads: 2, ..Default::default() },
        },
    )?;
    let dim = ds.dim;
    let (router, shards, id_maps, _) = sharded.into_parts();
    let mut snaps = Vec::with_capacity(shards.len());
    for (shard, map) in shards.into_iter().zip(id_maps) {
        let single =
            ShardedIndex::from_parts(Router::Hash { seed: 0 }, vec![shard], vec![map], dim, true)?;
        snaps.push(single.to_bytes()?);
    }
    let node_dir = root.join("node-dir");
    crate::durable::node::init_node_dir(&node_dir, &router, dim, &snaps)?;
    out.push(("durable-node-dir/roc".to_string(), node_dir));

    Ok(out)
}

/// Answer a fixed seeded probe workload on an opened index; the returned
/// signature is bit-exact ((distance bits, id) per rank), so any
/// observable behavior change against the clean baseline shows up.
fn probe_signature(idx: &dyn AnnIndex) -> Vec<(u32, u32)> {
    let dim = idx.dim();
    let p = QueryParams { k: 5, nprobe: 4, ef: 16 };
    let mut rng = Rng::new(123);
    let mut scratch = AnnScratch::default();
    let mut out = Vec::new();
    let mut sig = Vec::new();
    for _ in 0..4 {
        let q: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        idx.search_into(&q, &p, &mut scratch, &mut out);
        sig.extend(out.iter().map(|&(d, id)| (d.to_bits(), id)));
    }
    sig
}

/// Open a container and probe it.
fn probe(bytes: Vec<u8>) -> Result<Vec<(u32, u32)>> {
    let idx = persist::open_bytes(bytes)?;
    Ok(probe_signature(idx.as_ref()))
}

/// Open a durable dynamic directory and probe it. Recovery that *discloses*
/// an anomaly — a torn WAL tail, or a replayed-record count different from
/// the clean directory's — is an error here (counted `Detected`): the store
/// surfaced the damage instead of silently serving a diverged index.
fn probe_dynamic_dir(dir: &Path, expect_records: usize) -> Result<Vec<(u32, u32)>> {
    let (store, stats) = DurableDynamic::open(dir)?;
    ensure!(stats.torn_bytes == 0, "recovery disclosed {} torn wal bytes", stats.torn_bytes);
    ensure!(
        stats.replayed_records == expect_records,
        "recovery disclosed {} replayed records (expected {expect_records})",
        stats.replayed_records
    );
    Ok(probe_signature(store.index()))
}

/// Open a durable node directory and probe it.
fn probe_node_dir(dir: &Path) -> Result<Vec<(u32, u32)>> {
    let (idx, _generation) = crate::durable::node::open_node_dir(dir)?;
    Ok(probe_signature(&idx))
}

/// One seeded corruption of `base`; returns the mutant + a description.
fn mutate(rng: &mut Rng, base: &[u8]) -> (Vec<u8>, String) {
    let len = base.len();
    let mut bytes = base.to_vec();
    match rng.below(10) {
        // Bit flips dominate: the classic single-event upset.
        0..=5 => {
            let pos = rng.below(len as u64) as usize;
            let mask = 1u8 << rng.below(8);
            bytes[pos] ^= mask;
            (bytes, format!("flip byte {pos} mask {mask:#04x}"))
        }
        // Truncation: torn write / short read.
        6..=7 => {
            let cut = rng.below(len as u64) as usize;
            bytes.truncate(cut);
            (bytes, format!("truncate to {cut} of {len}"))
        }
        // Word swap: misplaced 4-byte field (section tags, lengths,
        // CRCs, ids all live in little-endian words).
        8 if len >= 16 => {
            let a = rng.below((len - 4) as u64) as usize;
            let b = rng.below((len - 4) as u64) as usize;
            for i in 0..4 {
                bytes.swap(a + i, b + i);
            }
            (bytes, format!("swap words at {a} and {b}"))
        }
        // Block swap: transposed pages.
        _ if len >= 96 => {
            let a = rng.below((len - 32) as u64) as usize;
            let b = rng.below((len - 32) as u64) as usize;
            for i in 0..32 {
                bytes.swap(a + i, b + i);
            }
            (bytes, format!("swap 32-byte blocks at {a} and {b}"))
        }
        _ => {
            let pos = rng.below(len as u64) as usize;
            bytes[pos] ^= 0xff;
            (bytes, format!("invert byte {pos}"))
        }
    }
}

/// Run one probe closure on a watchdog thread: a panic is `Crash`, a
/// structured error is `Detected`, exceeding `timeout` is `Hang` (the
/// stuck thread is abandoned — this is a test harness, not a server).
fn run_guarded_with<F>(f: F, baseline: &[(u32, u32)], timeout: Duration) -> Outcome
where
    F: FnOnce() -> Result<Vec<(u32, u32)>> + Send + 'static,
{
    let (tx, rx) = mpsc::channel();
    let base = baseline.to_vec();
    std::thread::spawn(move || {
        let outcome = match catch_unwind(AssertUnwindSafe(f)) {
            Err(_) => Outcome::Crash,
            Ok(Err(_)) => Outcome::Detected,
            Ok(Ok(sig)) => {
                if sig == base {
                    Outcome::Harmless
                } else {
                    Outcome::SilentWrong
                }
            }
        };
        let _ = tx.send(outcome);
    });
    rx.recv_timeout(timeout).unwrap_or(Outcome::Hang)
}

/// Open + probe one mutated container (see [`run_guarded_with`]).
fn run_guarded(bytes: Vec<u8>, baseline: &[(u32, u32)], timeout: Duration) -> Outcome {
    run_guarded_with(move || probe(bytes), baseline, timeout)
}

/// Run the full sweep: every target container, `mutations_per_target`
/// seeded corruptions each. Panics inside mutants are caught and print
/// their payload to stderr (rust's default hook) — a clean run is quiet
/// because a clean run has no panics.
pub fn run_chaos_sweep(cfg: &ChaosConfig) -> Result<FaultReport> {
    let targets = build_targets(cfg.seed)?;
    let mut report = FaultReport { targets: targets.len(), ..Default::default() };
    for (ti, (name, bytes)) in targets.iter().enumerate() {
        let baseline = probe(bytes.clone())
            .with_context(|| format!("{name}: clean container failed its own probe"))?;
        ensure!(!bytes.is_empty(), "{name}: empty container");
        let mut rng = Rng::new(cfg.seed.wrapping_mul(0x9e37_79b9).wrapping_add(ti as u64));
        for _ in 0..cfg.mutations_per_target {
            let (mutant, desc) = mutate(&mut rng, bytes);
            let outcome = run_guarded(mutant, &baseline, cfg.timeout);
            report.count(name, &desc, outcome);
        }
    }

    // Directory targets: corrupt one manifest-reachable file at a time,
    // probe the reopened directory, then restore the original bytes.
    let root = std::env::temp_dir()
        .join(format!("zann-chaos-{}-{:x}", std::process::id(), cfg.seed));
    let _ = std::fs::remove_dir_all(&root);
    let dir_targets = build_dir_targets(cfg.seed, &root)?;
    report.targets += dir_targets.len();
    for (ti, (name, dir)) in dir_targets.iter().enumerate() {
        let is_dynamic = name.starts_with("durable-dynamic");
        // The clean directory's replayed-record count anchors the
        // "disclosed loss" check in `probe_dynamic_dir`.
        let expect_records = if is_dynamic {
            let (_, stats) = DurableDynamic::open(dir)
                .with_context(|| format!("{name}: clean dir failed to open"))?;
            stats.replayed_records
        } else {
            0
        };
        let baseline = if is_dynamic {
            probe_dynamic_dir(dir, expect_records)
        } else {
            probe_node_dir(dir)
        }
        .with_context(|| format!("{name}: clean dir failed its own probe"))?;

        let mut files: Vec<PathBuf> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.is_file())
            .collect();
        files.sort();
        ensure!(!files.is_empty(), "{name}: directory target has no files");
        let mut rng = Rng::new(
            cfg.seed.wrapping_mul(0x51ed_2705).wrapping_add((1000 + ti) as u64),
        );
        for _ in 0..cfg.mutations_per_target {
            let victim = files[rng.below(files.len() as u64) as usize].clone();
            let orig = std::fs::read(&victim)?;
            let (mutant, mdesc) = mutate(&mut rng, &orig);
            std::fs::write(&victim, &mutant)?;
            let desc = format!(
                "{} in {}",
                mdesc,
                victim.file_name().unwrap_or_default().to_string_lossy()
            );
            let probe_dir = dir.clone();
            let outcome = run_guarded_with(
                move || {
                    if is_dynamic {
                        probe_dynamic_dir(&probe_dir, expect_records)
                    } else {
                        probe_node_dir(&probe_dir)
                    }
                },
                &baseline,
                cfg.timeout,
            );
            std::fs::write(&victim, &orig)?;
            report.count(name, &desc, outcome);
        }
    }
    let _ = std::fs::remove_dir_all(&root);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_detects_everything_without_crashing() {
        // Small per-target count to keep the test quick; the CLI gate
        // runs the full default sweep.
        let cfg = ChaosConfig { seed: 11, mutations_per_target: 6, ..Default::default() };
        let rep = run_chaos_sweep(&cfg).unwrap();
        assert!(
            rep.targets >= 17,
            "expected the codec × backend zoo plus sharded + directory targets, got {}",
            rep.targets
        );
        assert_eq!(rep.mutations, rep.targets * 6);
        assert!(
            rep.passed(),
            "chaos sweep failed: {}\n{}",
            rep.summary(),
            rep.failures.join("\n")
        );
        assert_eq!(rep.detected + rep.harmless, rep.mutations);
        // Corruption of checksummed containers is overwhelmingly caught,
        // not silently benign.
        assert!(rep.detected > rep.harmless, "{}", rep.summary());
        assert!(rep.summary().contains("verdict=PASS"));
    }

    #[test]
    fn mutants_actually_differ_from_base() {
        let base: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let mut rng = Rng::new(3);
        let mut changed = 0;
        for _ in 0..50 {
            let (m, _) = mutate(&mut rng, &base);
            if m != base {
                changed += 1;
            }
        }
        // Word/block swaps of identical content can no-op; flips and
        // truncations cannot, and they dominate the mix.
        assert!(changed >= 40, "only {changed}/50 mutants differed");
    }
}
