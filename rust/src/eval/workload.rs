//! Shared workload/timing discipline for the throughput benches
//! (`bench-search-qps`, `bench-recall`, `bench-serve`): per-worker scratch
//! reuse, a warm pass that also collects the (deterministic) result
//! lists, `runs` timed passes keeping the best wall-clock, and latency
//! percentiles over the best pass. Centralized here so every bench
//! measures the same steady-state allocation-free path and none of them
//! re-implements the loop with subtle drift.

use crate::api::{AnnIndex, AnnScratch, QueryParams};
use crate::coordinator::ResponseStatus;
use crate::serve::ServeNode;
use crate::util::{Rng, Zipf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// One measured (index, knob) cell: the deterministic result lists from
/// the warm pass plus best-of-runs throughput and latency percentiles.
pub struct Measured {
    pub results: Vec<Vec<(f32, u32)>>,
    pub qps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

/// Percentile over an **ascending-sorted** latency slice, `p` in [0, 1]
/// (nearest-rank on the closed index range, matching every bench's
/// historical convention). Returns 0.0 on an empty slice.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        0.0
    } else {
        sorted[((sorted.len() - 1) as f64 * p).round() as usize]
    }
}

/// Measure one (index, params) cell: a warm pass collects the
/// (deterministic) result lists and primes every per-worker scratch,
/// then `runs` timed passes take the best wall-clock, so latencies
/// reflect the steady-state allocation-free path.
pub fn measure(
    index: &dyn AnnIndex,
    queries: &[f32],
    dim: usize,
    nq: usize,
    sp: &QueryParams,
    threads: usize,
    runs: usize,
) -> Measured {
    let threads = threads.max(1);
    let scratches: Vec<Mutex<(AnnScratch, Vec<(f32, u32)>)>> =
        (0..threads).map(|_| Mutex::new((AnnScratch::default(), Vec::new()))).collect();
    let collected: Vec<Mutex<Vec<(f32, u32)>>> = (0..nq).map(|_| Mutex::new(Vec::new())).collect();
    let lat_cells: Vec<AtomicU64> = (0..nq).map(|_| AtomicU64::new(0)).collect();
    let run_pass = |record: bool, collect: bool| {
        crate::util::pool::parallel_chunks(nq, threads, |w, range| {
            let mut guard = scratches[w % scratches.len()].lock().unwrap();
            let (scratch, results) = &mut *guard;
            for qi in range {
                let q0 = Instant::now();
                index.search_into(&queries[qi * dim..(qi + 1) * dim], sp, scratch, results);
                if record {
                    lat_cells[qi].store(q0.elapsed().as_secs_f64().to_bits(), Ordering::Relaxed);
                }
                if collect {
                    collected[qi].lock().unwrap().clone_from(results);
                }
            }
        });
    };
    run_pass(false, true); // warm every scratch + collect result lists
    let mut best_wall = f64::INFINITY;
    let mut lat: Vec<f64> = Vec::new();
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        run_pass(true, false);
        let wall = t0.elapsed().as_secs_f64();
        if wall < best_wall {
            best_wall = wall;
            lat = lat_cells.iter().map(|c| f64::from_bits(c.load(Ordering::Relaxed))).collect();
        }
    }
    lat.sort_by(|a, b| a.total_cmp(b));
    let mean = lat.iter().sum::<f64>() / (lat.len().max(1) as f64);
    Measured {
        results: collected.into_iter().map(|m| m.into_inner().unwrap()).collect(),
        qps: nq as f64 / best_wall.max(1e-12),
        mean_ms: mean * 1e3,
        p50_ms: percentile(&lat, 0.5) * 1e3,
        p95_ms: percentile(&lat, 0.95) * 1e3,
        p99_ms: percentile(&lat, 0.99) * 1e3,
    }
}

/// One request in a serve workload: a tenant plus either a search query
/// or a row to ingest.
pub struct ServeOp {
    pub tenant: usize,
    pub write: bool,
    pub payload: Vec<f32>,
}

/// Precompute a deterministic mixed read/write schedule: tenants are
/// zipf-distributed (rank 0 is the greedy tenant), writes are sampled
/// near zipf-skewed base rows (so a kmeans router piles them onto hot
/// shards — the imbalance the serve bench reports) with small gaussian
/// noise. Rebuilding with the same arguments yields the same schedule,
/// so per-tenant request counts — and with a fixed admission budget,
/// rejection counts — are exactly reproducible.
pub fn serve_schedule(
    nops: usize,
    tenants: usize,
    theta: f64,
    write_frac: f64,
    queries: &[f32],
    dim: usize,
    seed: u64,
) -> Vec<ServeOp> {
    let nq = queries.len() / dim;
    assert!(nq > 0, "serve schedule needs a non-empty query pool");
    let mut rng = Rng::new(seed ^ 0x5e7e_5e7e);
    let zt = Zipf::new(tenants.max(1), theta);
    let zq = Zipf::new(nq, theta);
    (0..nops)
        .map(|_| {
            let tenant = zt.sample(&mut rng);
            if rng.f64() < write_frac {
                let base = zq.sample(&mut rng);
                let payload = queries[base * dim..(base + 1) * dim]
                    .iter()
                    .map(|&v| v + 0.01 * rng.normal())
                    .collect();
                ServeOp { tenant, write: true, payload }
            } else {
                let qi = rng.below(nq as u64) as usize;
                ServeOp {
                    tenant,
                    write: false,
                    payload: queries[qi * dim..(qi + 1) * dim].to_vec(),
                }
            }
        })
        .collect()
}

/// Number of measured passes [`run_serve`] will actually execute: the
/// requested `runs` for a read-only schedule, 1 as soon as the schedule
/// contains a write (each pass would ingest the same rows again, so
/// repeated passes measure ever-larger indexes). Exposed so the bench
/// can report the pass count that was really used.
pub fn effective_runs(schedule: &[ServeOp], runs: usize) -> usize {
    if schedule.iter().any(|o| o.write) {
        1
    } else {
        runs.max(1)
    }
}

/// Outcome of one scheduled request in the best measured pass.
#[derive(Clone, Copy, Debug)]
pub struct ServeOutcome {
    pub tenant: usize,
    pub write: bool,
    pub status: ResponseStatus,
    pub latency_s: f64,
}

/// Drive `schedule` against a serve node with `clients` concurrent
/// client threads, `runs` times (admission is refilled before each pass
/// so every pass starts from the same budget), keeping the pass with the
/// best wall-clock. Returns per-request outcomes of that pass plus its
/// wall time. Writes bypass admission (they are ingest, not queries) and
/// report `Ok`/`Failed`.
///
/// Best-of-runs is a *read-only* discipline: a schedule containing
/// writes mutates the node, so a second pass would replay the same
/// ingests over an already-grown index — passes would not be comparable
/// and `shard_rows` would double-count rows. Mixed schedules therefore
/// run exactly one measured pass regardless of `runs` (see
/// [`effective_runs`]).
pub fn run_serve(
    node: &ServeNode,
    schedule: &[ServeOp],
    clients: usize,
    runs: usize,
) -> (Vec<ServeOutcome>, f64) {
    let clients = clients.max(1);
    let runs = effective_runs(schedule, runs);
    let mut best_wall = f64::INFINITY;
    let mut best: Vec<ServeOutcome> = Vec::new();
    for _ in 0..runs {
        node.reset_admission();
        let cells: Vec<Mutex<Option<ServeOutcome>>> =
            (0..schedule.len()).map(|_| Mutex::new(None)).collect();
        let t0 = Instant::now();
        crate::util::pool::parallel_chunks(schedule.len(), clients, |_, range| {
            for i in range {
                let op = &schedule[i];
                let tenant = format!("t{}", op.tenant);
                let q0 = Instant::now();
                let (status, latency_s) = if op.write {
                    match node.add(&op.payload) {
                        Ok(_) => (ResponseStatus::Ok, q0.elapsed().as_secs_f64()),
                        Err(_) => (ResponseStatus::Failed, q0.elapsed().as_secs_f64()),
                    }
                } else {
                    match node.search(&tenant, &op.payload) {
                        Ok(r) => (r.status, r.latency.as_secs_f64()),
                        Err(_) => (ResponseStatus::Failed, q0.elapsed().as_secs_f64()),
                    }
                };
                *cells[i].lock().unwrap() = Some(ServeOutcome {
                    tenant: op.tenant,
                    write: op.write,
                    status,
                    latency_s,
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        if wall < best_wall {
            best_wall = wall;
            best = cells
                .into_iter()
                .map(|c| c.into_inner().unwrap().expect("every scheduled op ran"))
                .collect();
        }
    }
    (best, best_wall)
}

/// Aggregated counters + latency percentiles over a set of outcomes
/// (`tenant = None` aggregates everything). `qps` counts served (`Ok`)
/// requests against the pass wall-clock; percentiles are over served
/// requests only (a rejection answered in nanoseconds is not a latency
/// datapoint).
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub requests: u64,
    pub ok: u64,
    pub rejected: u64,
    pub timeouts: u64,
    pub failed: u64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

pub fn aggregate_serve(outcomes: &[ServeOutcome], tenant: Option<usize>, wall_s: f64) -> ServeStats {
    let mut s = ServeStats {
        requests: 0,
        ok: 0,
        rejected: 0,
        timeouts: 0,
        failed: 0,
        qps: 0.0,
        p50_ms: 0.0,
        p95_ms: 0.0,
        p99_ms: 0.0,
    };
    let mut lat: Vec<f64> = Vec::new();
    for o in outcomes {
        if tenant.is_some_and(|t| t != o.tenant) {
            continue;
        }
        s.requests += 1;
        match o.status {
            ResponseStatus::Ok => {
                s.ok += 1;
                lat.push(o.latency_s);
            }
            ResponseStatus::Overloaded => s.rejected += 1,
            ResponseStatus::Timeout => s.timeouts += 1,
            ResponseStatus::Failed => s.failed += 1,
        }
    }
    lat.sort_by(|a, b| a.total_cmp(b));
    s.qps = s.ok as f64 / wall_s.max(1e-12);
    s.p50_ms = percentile(&lat, 0.5) * 1e3;
    s.p95_ms = percentile(&lat, 0.95) * 1e3;
    s.p99_ms = percentile(&lat, 0.99) * 1e3;
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, Kind};
    use crate::index::{IvfBuildParams, IvfIndex};

    #[test]
    fn percentile_nearest_rank() {
        let lat = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&lat, 0.0), 1.0);
        assert_eq!(percentile(&lat, 0.5), 3.0); // round(1.5) = 2
        assert_eq!(percentile(&lat, 1.0), 4.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn measure_results_are_deterministic_and_latencies_sane() {
        let ds = generate(Kind::DeepLike, 2000, 16, 8, 7);
        let idx = IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 16, id_codec: "roc".into(), threads: 2, ..Default::default() },
        );
        let sp = QueryParams { k: 5, nprobe: 4, ..Default::default() };
        let a = measure(&idx, &ds.queries, ds.dim, ds.nq, &sp, 2, 2);
        let b = measure(&idx, &ds.queries, ds.dim, ds.nq, &sp, 1, 1);
        assert_eq!(a.results, b.results, "thread count must not change results");
        assert_eq!(a.results.len(), ds.nq);
        assert!(a.results.iter().all(|r| r.len() == 5));
        assert!(a.qps > 0.0 && a.mean_ms >= 0.0);
        assert!(a.p50_ms <= a.p95_ms && a.p95_ms <= a.p99_ms);
    }

    #[test]
    fn effective_runs_clamps_only_write_schedules() {
        let read = ServeOp { tenant: 0, write: false, payload: vec![0.0] };
        let write = ServeOp { tenant: 0, write: true, payload: vec![0.0] };
        let reads: Vec<ServeOp> =
            (0..4).map(|_| ServeOp { tenant: 0, write: false, payload: vec![0.0] }).collect();
        assert_eq!(effective_runs(&reads, 3), 3);
        assert_eq!(effective_runs(&reads, 0), 1);
        assert_eq!(effective_runs(&[read, write], 3), 1);
    }

    #[test]
    fn serve_schedule_is_deterministic_and_zipf_skewed() {
        let ds = generate(Kind::DeepLike, 200, 32, 8, 11);
        let a = serve_schedule(500, 4, 1.2, 0.2, &ds.queries, ds.dim, 9);
        let b = serve_schedule(500, 4, 1.2, 0.2, &ds.queries, ds.dim, 9);
        assert_eq!(a.len(), 500);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.write, y.write);
            assert_eq!(x.payload, y.payload);
        }
        let t0 = a.iter().filter(|o| o.tenant == 0).count();
        let t3 = a.iter().filter(|o| o.tenant == 3).count();
        assert!(t0 > 2 * t3, "theta=1.2 must skew hard toward tenant 0 ({t0} vs {t3})");
        let writes = a.iter().filter(|o| o.write).count();
        assert!((50..200).contains(&writes), "write_frac=0.2 of 500, got {writes}");
        assert!(a.iter().all(|o| o.payload.len() == ds.dim));
    }

    #[test]
    fn run_serve_answers_every_op_and_admission_counts_are_deterministic() {
        use crate::dynamic::CompactionPolicy;
        use crate::serve::{NodeConfig, RouterKind, ServeNode, ShardedBuildParams, TenantPolicy};
        let ds = generate(Kind::DeepLike, 1200, 16, 8, 12);
        let params = ShardedBuildParams {
            shards: 2,
            router: RouterKind::Hash,
            ivf: IvfBuildParams { k: 8, threads: 2, id_codec: "roc".into(), ..Default::default() },
        };
        let cfg = NodeConfig {
            serve: crate::coordinator::ServeConfig {
                search: QueryParams { k: 5, nprobe: 4, ef: 32 },
                scan_threads: 2,
                ..Default::default()
            },
            tenants: Some(TenantPolicy { burst: 50, rate: 0.0 }),
            ..Default::default()
        };
        let node =
            ServeNode::start_mutable(&ds.data, ds.dim, &params, CompactionPolicy::default(), cfg)
                .unwrap();
        let schedule = serve_schedule(200, 3, 1.2, 0.1, &ds.queries, ds.dim, 13);
        let writes = schedule.iter().filter(|o| o.write).count();
        assert!(writes > 0, "seed 13 at write_frac=0.1 must produce writes");
        assert_eq!(effective_runs(&schedule, 2), 1, "write schedules run a single pass");
        let (outcomes, wall) = run_serve(&node, &schedule, 2, 2);
        assert_eq!(outcomes.len(), 200);
        assert!(wall > 0.0);
        // The single measured pass ingested each scheduled write exactly
        // once — no duplicated rows from warm or repeated passes.
        assert_eq!(
            node.shard_rows().iter().sum::<usize>(),
            1200 + writes,
            "rows must grow by exactly the scheduled writes"
        );
        let total = aggregate_serve(&outcomes, None, wall);
        assert_eq!(total.requests, 200);
        assert_eq!(total.ok + total.rejected + total.timeouts + total.failed, 200);
        // Fixed budget (rate=0): each tenant's rejections are exactly its
        // reads minus the burst, independent of client interleaving.
        for t in 0..3 {
            let reads =
                schedule.iter().filter(|o| o.tenant == t && !o.write).count() as u64;
            let st = aggregate_serve(&outcomes, Some(t), wall);
            assert_eq!(st.rejected, reads.saturating_sub(50), "tenant {t}");
        }
        // The greedy tenant was shed; the tail tenant was not.
        let greedy = aggregate_serve(&outcomes, Some(0), wall);
        let tail = aggregate_serve(&outcomes, Some(2), wall);
        assert!(greedy.rejected > 0, "greedy tenant must hit its budget");
        assert_eq!(tail.rejected, 0, "tail tenant stays within budget");
        // Post-overload liveness: the node still answers.
        assert!(node.search_raw(&ds.queries[..ds.dim]).unwrap().is_ok());
        node.stop();
    }
}
