//! Shared entry points for the bench harnesses and the `zann` CLI: run an
//! experiment at the requested scale and print it next to the paper's
//! reference values.
//!
//! Paper reference numbers are from Tables 1–4 / Fig. 3 of the paper
//! (N=1e6, Xeon E5-2698); ours run at N=1e5 by default (pass `--full` for
//! 1e6). For ROC/EF/Comp the bits/id columns are directly comparable
//! (they depend on N/K, not N); wall-clock columns are testbed-specific
//! and should be compared as *ratios* to the Unc. baseline.

use crate::datasets::Kind;
use crate::eval::experiments::{self, Scale};
use crate::eval::{fmt3, Table};
use crate::index::VectorMode;
use crate::util::cli::Args;

pub fn scale_from(args: &Args) -> Scale {
    let full = args.bool("full");
    Scale {
        n: args.usize("n", if full { 1_000_000 } else { 100_000 }),
        nq: args.usize("nq", 10_000),
        dim: args.usize("dim", 32),
        seed: args.u64("seed", 42),
        threads: args.usize("threads", crate::util::pool::default_threads()),
    }
}

pub fn datasets_from(args: &Args) -> Vec<Kind> {
    match args.get("dataset") {
        Some(name) => vec![Kind::parse(name).expect("unknown dataset (sift|deep|ssnpp)")],
        None => Kind::all().to_vec(),
    }
}

/// Paper Table 1, SIFT1M reference values (bits/id) for the IVF rows.
const PAPER_T1_IVF_SIFT: [(usize, f64, f64, f64, f64, f64); 4] = [
    // (K, Comp., EF, WT, WT1, ROC)
    (256, 20.0, 9.85, 12.1, 8.13, 9.43),
    (512, 20.0, 10.9, 13.6, 9.23, 10.5),
    (1024, 20.0, 11.8, 15.0, 10.3, 11.4),
    (2048, 20.0, 12.8, 16.5, 11.3, 12.4),
];

pub fn table1(args: &Args) {
    let scale = scale_from(args);
    println!(
        "== Table 1: bits/id (N={}, paper N=1e6; ROC/EF columns comparable by K) ==",
        scale.n
    );
    let ks: Vec<usize> = match args.get("k") {
        Some(k) => vec![k.parse().unwrap()],
        None => experiments::IVF_KS.to_vec(),
    };
    for kind in datasets_from(args) {
        let rows = experiments::table1_ivf(&scale, kind, &ks, &experiments::T1_CODECS);
        let mut t = Table::new(&["index", "Unc.", "Comp.", "EF", "WT", "WT1", "ROC", "paper(EF/ROC)"]);
        for row in rows {
            let paper = PAPER_T1_IVF_SIFT
                .iter()
                .find(|p| p.0 == row.k)
                .map(|p| format!("{}/{}", p.2, p.5))
                .unwrap_or_else(|| "-".into());
            t.row(vec![
                format!("IVF{}", row.k),
                fmt3(row.bpe["unc64"]),
                fmt3(row.bpe["compact"]),
                fmt3(row.bpe["ef"]),
                fmt3(row.bpe["wt"]),
                fmt3(row.bpe["wt1"]),
                fmt3(row.bpe["roc"]),
                paper,
            ]);
        }
        println!("[{}]\n{}", kind.name(), t.render());
    }
    if !args.bool("skip-nsg") {
        let rs: Vec<usize> = match args.get("r") {
            Some(r) => vec![r.parse().unwrap()],
            None => experiments::NSG_RS.to_vec(),
        };
        for kind in datasets_from(args) {
            let rows = experiments::table1_nsg(&scale, kind, &rs, &["compact", "ef", "roc"]);
            let mut t = Table::new(&["index", "Unc.", "Comp.", "EF", "ROC", "edges"]);
            for row in &rows {
                t.row(vec![
                    format!("NSG{}", row.r),
                    "32".into(),
                    fmt3(row.bpe["compact"]),
                    fmt3(row.bpe["ef"]),
                    fmt3(row.bpe["roc"]),
                    format!("{}", row.adj.iter().map(|l| l.len() as u64).sum::<u64>()),
                ]);
            }
            println!("[{} NSG]\n{}", kind.name(), t.render());
        }
    }
}

pub fn table2(args: &Args) {
    let scale = scale_from(args);
    let runs = args.usize("runs", 3);
    println!(
        "== Table 2: search seconds for {} queries, nprobe=16 (paper: 10k queries, medians) ==",
        scale.nq
    );
    let codecs = ["unc64", "compact", "ef", "wt", "wt1", "roc"];
    let pq_variants: Vec<(&str, VectorMode)> = vec![
        ("PQ4", VectorMode::Pq { m: 4, bits: 8 }),
        ("PQ16", VectorMode::Pq { m: 16, bits: 8 }),
        ("PQ32", VectorMode::Pq { m: 32, bits: 8 }),
        ("PQ8x10", VectorMode::Pq { m: 8, bits: 10 }),
    ];
    for kind in datasets_from(args) {
        let rows =
            experiments::table2_ivf(&scale, kind, &experiments::IVF_KS, &pq_variants, &codecs, runs);
        let mut t = Table::new(&["index", "Unc.", "Comp.", "EF", "WT", "WT1", "ROC"]);
        for row in &rows {
            t.row(vec![
                row.label.clone(),
                fmt3(row.secs["unc64"]),
                fmt3(row.secs["compact"]),
                fmt3(row.secs["ef"]),
                fmt3(row.secs["wt"]),
                fmt3(row.secs["wt1"]),
                fmt3(row.secs["roc"]),
            ]);
        }
        println!("[{}]\n{}", kind.name(), t.render());
        if !args.bool("skip-nsg") {
            let rows = experiments::table2_nsg(
                &scale,
                kind,
                &experiments::NSG_RS,
                &["unc32", "compact", "ef", "roc"],
                runs,
            );
            let mut t = Table::new(&["index", "Unc.", "Comp.", "EF", "ROC"]);
            for row in &rows {
                t.row(vec![
                    row.label.clone(),
                    fmt3(row.secs["unc32"]),
                    fmt3(row.secs["compact"]),
                    fmt3(row.secs["ef"]),
                    fmt3(row.secs["roc"]),
                ]);
            }
            println!("[{} NSG]\n{}", kind.name(), t.render());
        }
    }
}

/// Paper Table 3 (SIFT1M, bits/id): (label, Zuckerli, REC).
const PAPER_T3_SIFT: [(&str, f64, f64); 5] = [
    ("NSG16", 17.23, 17.59),
    ("NSG32", 17.05, 16.98),
    ("NSG64", 16.93, 16.77),
    ("NSG128", 16.77, 16.60),
    ("NSG256", 16.57, 16.39),
];

pub fn table3(args: &Args) {
    let scale = scale_from(args);
    println!("== Table 3: offline whole-graph compression, bits/edge-id ==");
    let rs: Vec<usize> = match args.get("r") {
        Some(r) => vec![r.parse().unwrap()],
        None => experiments::NSG_RS.to_vec(),
    };
    for kind in datasets_from(args) {
        // NSG graphs.
        let nsg_rows = experiments::table1_nsg(&scale, kind, &rs, &["compact"]);
        let mut t =
            Table::new(&["graph", "Comp.", "Zuck.", "REC(urn)", "REC(unif)", "paper Z/REC (sift)"]);
        for row in &nsg_rows {
            let t3 =
                experiments::table3_for_graph(kind.name(), format!("NSG{}", row.r), &row.adj);
            let paper = PAPER_T3_SIFT
                .iter()
                .find(|p| p.0 == t3.label)
                .map(|p| format!("{}/{}", p.1, p.2))
                .unwrap_or_else(|| "-".into());
            t.row(vec![
                t3.label.clone(),
                fmt3(row.bpe["compact"]),
                fmt3(t3.zuckerli),
                fmt3(t3.rec),
                fmt3(t3.rec_uniform),
                paper,
            ]);
        }
        // HNSW base layers.
        if !args.bool("skip-hnsw") {
            use crate::graph::hnsw::{Hnsw, HnswParams};
            let ds = crate::datasets::generate(kind, scale.n, 1, scale.dim, scale.seed);
            for &m in &[16usize, 32, 64] {
                let h = Hnsw::build(
                    &ds.data,
                    ds.dim,
                    &HnswParams { m, ef_construction: 80, seed: scale.seed },
                );
                let t3 = experiments::table3_for_graph(
                    kind.name(),
                    format!("HNSW{m}"),
                    h.base_adj(),
                );
                t.row(vec![
                    t3.label.clone(),
                    fmt3(crate::util::bits_for(scale.n as u64) as f64),
                    fmt3(t3.zuckerli),
                    fmt3(t3.rec),
                    fmt3(t3.rec_uniform),
                    "-".into(),
                ]);
            }
        }
        println!("[{}]\n{}", kind.name(), t.render());
    }
}

pub fn table4(args: &Args) {
    // Scaled stand-in for the paper's 1B/QINCo run: default N=2e6, K=2^12.
    // Uses dedicated flags (--n4 etc.) so a shared `cargo bench -- --n X`
    // doesn't shrink the large-scale run.
    let n = args.usize("n4", 2_000_000);
    let nq = args.usize("nq4", 2_000);
    let k = args.usize("k4", 1 << 12);
    let dim = args.usize("dim", 32);
    let threads = args.usize("threads", crate::util::pool::default_threads());
    println!(
        "== Table 4 (scaled): N={n}, K={k}, IVF-PQ8, nprobe=128 \
         (paper: N=1e9, K=2^20, QINCo 8B) =="
    );
    let rows = experiments::table4(n, nq, dim, k, threads, args.u64("seed", 42));
    let mut t = Table::new(&["codec", "bits/id", "paper bits/id", "search s", "recall@10"]);
    let paper: std::collections::BTreeMap<&str, f64> =
        [("unc64", 64.0), ("compact", 30.0), ("ef", 21.81), ("roc", 21.46)].into();
    for r in &rows {
        t.row(vec![
            r.codec.clone(),
            fmt3(r.bits_per_id),
            fmt3(*paper.get(r.codec.as_str()).unwrap_or(&f64::NAN)),
            fmt3(r.search_secs),
            format!("{:.2}", r.recall_at_10),
        ]);
    }
    println!("{}", t.render());
}

pub fn fig2(args: &Args) {
    let scale = scale_from(args);
    let runs = args.usize("runs", 3);
    println!("== Figure 2: slowdown vs Uncompressed as PQ dim grows (IVF1024) ==");
    for kind in datasets_from(args) {
        let pts = experiments::fig2(&scale, kind, &["compact", "ef", "wt", "wt1", "roc"], runs);
        let mut t = Table::new(&["PQ", "Comp.", "EF", "WT", "WT1", "ROC"]);
        for p in &pts {
            t.row(vec![
                p.pq_label.clone(),
                fmt3(p.slowdown["compact"]),
                fmt3(p.slowdown["ef"]),
                fmt3(p.slowdown["wt"]),
                fmt3(p.slowdown["wt1"]),
                fmt3(p.slowdown["roc"]),
            ]);
        }
        println!("[{}] (1.0 = Unc.; paper: slowdown shrinks as PQ dim grows)\n{}", kind.name(), t.render());
    }
}

pub fn fig3(args: &Args) {
    let scale = scale_from(args);
    println!("== Figure 3: cluster-conditioned PQ code compression (8 bits uncompressed) ==");
    println!("paper: SIFT1M ~ -19%, Deep1M ~ -5%, FB-ssnpp ~ 0%");
    let mut t = Table::new(&["dataset", "PQ", "bits/element", "saving"]);
    for kind in datasets_from(args) {
        for p in experiments::fig3(&scale, kind, &[4, 8, 16, 32]) {
            t.row(vec![
                p.dataset.into(),
                p.pq_label.clone(),
                fmt3(p.bits_per_element),
                format!("{:+.1}%", 100.0 * (p.bits_per_element / 8.0 - 1.0)),
            ]);
        }
    }
    println!("{}", t.render());
}
