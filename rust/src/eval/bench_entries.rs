//! Shared entry points for the bench harnesses and the `zann` CLI: run an
//! experiment at the requested scale and print it next to the paper's
//! reference values.
//!
//! Paper reference numbers are from Tables 1–4 / Fig. 3 of the paper
//! (N=1e6, Xeon E5-2698); ours run at N=1e5 by default (pass `--full` for
//! 1e6). For ROC/EF/Comp the bits/id columns are directly comparable
//! (they depend on N/K, not N); wall-clock columns are testbed-specific
//! and should be compared as *ratios* to the Unc. baseline.

use crate::codecs::CodecSpec;
use crate::datasets::Kind;
use crate::eval::experiments::{self, Scale};
use crate::eval::recall;
use crate::eval::{fmt3, Table};
use crate::index::VectorMode;
use crate::util::cli::Args;

pub fn scale_from(args: &Args) -> Scale {
    let full = args.bool("full");
    Scale {
        n: args.usize("n", if full { 1_000_000 } else { 100_000 }),
        nq: args.usize("nq", 10_000),
        dim: args.usize("dim", 32),
        seed: args.u64("seed", 42),
        threads: args.usize("threads", crate::util::pool::default_threads()),
    }
}

pub fn datasets_from(args: &Args) -> Vec<Kind> {
    match args.get("dataset") {
        Some(name) => vec![Kind::parse(name).expect("unknown dataset (sift|deep|ssnpp)")],
        None => Kind::all().to_vec(),
    }
}

/// Paper Table 1, SIFT1M reference values (bits/id) for the IVF rows.
const PAPER_T1_IVF_SIFT: [(usize, f64, f64, f64, f64, f64); 4] = [
    // (K, Comp., EF, WT, WT1, ROC)
    (256, 20.0, 9.85, 12.1, 8.13, 9.43),
    (512, 20.0, 10.9, 13.6, 9.23, 10.5),
    (1024, 20.0, 11.8, 15.0, 10.3, 11.4),
    (2048, 20.0, 12.8, 16.5, 11.3, 12.4),
];

pub fn table1(args: &Args) {
    let scale = scale_from(args);
    println!(
        "== Table 1: bits/id (N={}, paper N=1e6; ROC/EF columns comparable by K) ==",
        scale.n
    );
    let ks: Vec<usize> = match args.get("k") {
        Some(k) => vec![k.parse().unwrap()],
        None => experiments::IVF_KS.to_vec(),
    };
    for kind in datasets_from(args) {
        let rows = experiments::table1_ivf(&scale, kind, &ks, &experiments::T1_CODECS);
        let mut t = Table::new(&["index", "Unc.", "Comp.", "EF", "WT", "WT1", "ROC", "paper(EF/ROC)"]);
        for row in rows {
            let paper = PAPER_T1_IVF_SIFT
                .iter()
                .find(|p| p.0 == row.k)
                .map(|p| format!("{}/{}", p.2, p.5))
                .unwrap_or_else(|| "-".into());
            t.row(vec![
                format!("IVF{}", row.k),
                fmt3(row.bpe["unc64"]),
                fmt3(row.bpe["compact"]),
                fmt3(row.bpe["ef"]),
                fmt3(row.bpe["wt"]),
                fmt3(row.bpe["wt1"]),
                fmt3(row.bpe["roc"]),
                paper,
            ]);
        }
        println!("[{}]\n{}", kind.name(), t.render());
    }
    if !args.bool("skip-nsg") {
        let rs: Vec<usize> = match args.get("r") {
            Some(r) => vec![r.parse().unwrap()],
            None => experiments::NSG_RS.to_vec(),
        };
        for kind in datasets_from(args) {
            let rows = experiments::table1_nsg(&scale, kind, &rs, &["compact", "ef", "roc"]);
            let mut t = Table::new(&["index", "Unc.", "Comp.", "EF", "ROC", "edges"]);
            for row in &rows {
                t.row(vec![
                    format!("NSG{}", row.r),
                    "32".into(),
                    fmt3(row.bpe["compact"]),
                    fmt3(row.bpe["ef"]),
                    fmt3(row.bpe["roc"]),
                    format!("{}", row.adj.iter().map(|l| l.len() as u64).sum::<u64>()),
                ]);
            }
            println!("[{} NSG]\n{}", kind.name(), t.render());
        }
    }
}

pub fn table2(args: &Args) {
    let scale = scale_from(args);
    let runs = args.usize("runs", 3);
    println!(
        "== Table 2: search seconds for {} queries, nprobe=16 (paper: 10k queries, medians) ==",
        scale.nq
    );
    let codecs = ["unc64", "compact", "ef", "wt", "wt1", "roc"];
    let pq_variants: Vec<(&str, VectorMode)> = vec![
        ("PQ4", VectorMode::Pq { m: 4, bits: 8 }),
        ("PQ16", VectorMode::Pq { m: 16, bits: 8 }),
        ("PQ32", VectorMode::Pq { m: 32, bits: 8 }),
        ("PQ8x10", VectorMode::Pq { m: 8, bits: 10 }),
    ];
    for kind in datasets_from(args) {
        let rows =
            experiments::table2_ivf(&scale, kind, &experiments::IVF_KS, &pq_variants, &codecs, runs);
        let mut t = Table::new(&["index", "Unc.", "Comp.", "EF", "WT", "WT1", "ROC"]);
        for row in &rows {
            t.row(vec![
                row.label.clone(),
                fmt3(row.secs["unc64"]),
                fmt3(row.secs["compact"]),
                fmt3(row.secs["ef"]),
                fmt3(row.secs["wt"]),
                fmt3(row.secs["wt1"]),
                fmt3(row.secs["roc"]),
            ]);
        }
        println!("[{}]\n{}", kind.name(), t.render());
        if !args.bool("skip-nsg") {
            let rows = experiments::table2_nsg(
                &scale,
                kind,
                &experiments::NSG_RS,
                &["unc32", "compact", "ef", "roc"],
                runs,
            );
            let mut t = Table::new(&["index", "Unc.", "Comp.", "EF", "ROC"]);
            for row in &rows {
                t.row(vec![
                    row.label.clone(),
                    fmt3(row.secs["unc32"]),
                    fmt3(row.secs["compact"]),
                    fmt3(row.secs["ef"]),
                    fmt3(row.secs["roc"]),
                ]);
            }
            println!("[{} NSG]\n{}", kind.name(), t.render());
        }
    }
}

/// Paper Table 3 (SIFT1M, bits/id): (label, Zuckerli, REC).
const PAPER_T3_SIFT: [(&str, f64, f64); 5] = [
    ("NSG16", 17.23, 17.59),
    ("NSG32", 17.05, 16.98),
    ("NSG64", 16.93, 16.77),
    ("NSG128", 16.77, 16.60),
    ("NSG256", 16.57, 16.39),
];

pub fn table3(args: &Args) {
    let scale = scale_from(args);
    println!("== Table 3: offline whole-graph compression, bits/edge-id ==");
    let rs: Vec<usize> = match args.get("r") {
        Some(r) => vec![r.parse().unwrap()],
        None => experiments::NSG_RS.to_vec(),
    };
    for kind in datasets_from(args) {
        // NSG graphs.
        let nsg_rows = experiments::table1_nsg(&scale, kind, &rs, &["compact"]);
        let mut t =
            Table::new(&["graph", "Comp.", "Zuck.", "REC(urn)", "REC(unif)", "paper Z/REC (sift)"]);
        for row in &nsg_rows {
            let t3 =
                experiments::table3_for_graph(kind.name(), format!("NSG{}", row.r), &row.adj);
            let paper = PAPER_T3_SIFT
                .iter()
                .find(|p| p.0 == t3.label)
                .map(|p| format!("{}/{}", p.1, p.2))
                .unwrap_or_else(|| "-".into());
            t.row(vec![
                t3.label.clone(),
                fmt3(row.bpe["compact"]),
                fmt3(t3.zuckerli),
                fmt3(t3.rec),
                fmt3(t3.rec_uniform),
                paper,
            ]);
        }
        // HNSW base layers.
        if !args.bool("skip-hnsw") {
            use crate::graph::hnsw::{Hnsw, HnswParams};
            let ds = crate::datasets::generate(kind, scale.n, 1, scale.dim, scale.seed);
            for &m in &[16usize, 32, 64] {
                let h = Hnsw::build(
                    &ds.data,
                    ds.dim,
                    &HnswParams { m, ef_construction: 80, seed: scale.seed },
                );
                let t3 = experiments::table3_for_graph(
                    kind.name(),
                    format!("HNSW{m}"),
                    h.base_adj(),
                );
                t.row(vec![
                    t3.label.clone(),
                    fmt3(crate::util::bits_for(scale.n as u64) as f64),
                    fmt3(t3.zuckerli),
                    fmt3(t3.rec),
                    fmt3(t3.rec_uniform),
                    "-".into(),
                ]);
            }
        }
        println!("[{}]\n{}", kind.name(), t.render());
    }
}

pub fn table4(args: &Args) {
    // Scaled stand-in for the paper's 1B/QINCo run: default N=2e6, K=2^12.
    // Uses dedicated flags (--n4 etc.) so a shared `cargo bench -- --n X`
    // doesn't shrink the large-scale run.
    let n = args.usize("n4", 2_000_000);
    let nq = args.usize("nq4", 2_000);
    let k = args.usize("k4", 1 << 12);
    let dim = args.usize("dim", 32);
    let threads = args.usize("threads", crate::util::pool::default_threads());
    println!(
        "== Table 4 (scaled): N={n}, K={k}, IVF-PQ8, nprobe=128 \
         (paper: N=1e9, K=2^20, QINCo 8B) =="
    );
    let rows = experiments::table4(n, nq, dim, k, threads, args.u64("seed", 42));
    let mut t = Table::new(&["codec", "bits/id", "paper bits/id", "search s", "recall@10"]);
    let paper: std::collections::BTreeMap<&str, f64> =
        [("unc64", 64.0), ("compact", 30.0), ("ef", 21.81), ("roc", 21.46)].into();
    for r in &rows {
        t.row(vec![
            r.codec.clone(),
            fmt3(r.bits_per_id),
            fmt3(*paper.get(r.codec.as_str()).unwrap_or(&f64::NAN)),
            fmt3(r.search_secs),
            format!("{:.2}", r.recall_at_10),
        ]);
    }
    println!("{}", t.render());
}

pub fn fig2(args: &Args) {
    let scale = scale_from(args);
    let runs = args.usize("runs", 3);
    println!("== Figure 2: slowdown vs Uncompressed as PQ dim grows (IVF1024) ==");
    for kind in datasets_from(args) {
        let pts = experiments::fig2(&scale, kind, &["compact", "ef", "wt", "wt1", "roc"], runs);
        let mut t = Table::new(&["PQ", "Comp.", "EF", "WT", "WT1", "ROC"]);
        for p in &pts {
            t.row(vec![
                p.pq_label.clone(),
                fmt3(p.slowdown["compact"]),
                fmt3(p.slowdown["ef"]),
                fmt3(p.slowdown["wt"]),
                fmt3(p.slowdown["wt1"]),
                fmt3(p.slowdown["roc"]),
            ]);
        }
        println!("[{}] (1.0 = Unc.; paper: slowdown shrinks as PQ dim grows)\n{}", kind.name(), t.render());
    }
}

/// Default location of the machine-readable QPS report: the repo root
/// (`CARGO_MANIFEST_DIR` is `<repo>/rust` at compile time).
fn default_bench_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_search.json")
}

/// One `"env": {...}` line shared by the bench JSON emitters, so every
/// report carries the same environment-manifest schema.
fn env_json_line(env: &recall::EnvManifest) -> String {
    format!(
        "  \"env\": {{\"rustc\": \"{}\", \"pkg_version\": \"{}\", \"target_arch\": \"{}\", \
         \"simd_level\": \"{}\", \"simd_override\": \"{}\", \"threads\": {}}},\n",
        jesc(env.rustc),
        jesc(env.pkg_version),
        env.target_arch,
        env.simd_level,
        jesc(&env.simd_override),
        env.threads
    )
}

/// Serialize QPS rows to the `BENCH_search.json` schema (see
/// docs/REPRODUCING.md): top-level run parameters, environment manifest,
/// plus one object per (backend, codec, nprobe, threads) cell.
fn qps_json(
    scale: &experiments::Scale,
    dataset: &str,
    k: usize,
    env: &recall::EnvManifest,
    rows: &[experiments::QpsRow],
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"search_qps\",\n  \"dataset\": \"{dataset}\",\n  \"n\": {},\n  \
         \"nq\": {},\n  \"dim\": {},\n  \"k\": {},\n  \"seed\": {},\n",
        scale.n, scale.nq, scale.dim, k, scale.seed
    ));
    s.push_str(&env_json_line(env));
    s.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"backend\": \"{}\", \"codec\": \"{}\", \"nprobe\": {}, \"threads\": {}, \
             \"qps\": {:.3}, \"mean_ms\": {:.6}, \"p50_ms\": {:.6}, \"p95_ms\": {:.6}}}{}\n",
            r.backend,
            r.codec,
            r.nprobe,
            r.threads,
            r.qps,
            r.mean_ms,
            r.p50_ms,
            r.p95_ms,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

fn parse_usize_list(args: &Args, name: &str, default: &[usize]) -> Vec<usize> {
    match args.get(name) {
        Some(s) => s
            .split(',')
            .map(|v| v.trim().parse().unwrap_or_else(|_| panic!("bad --{name} entry {v:?}")))
            .collect(),
        None => default.to_vec(),
    }
}

/// Search-throughput bench: QPS + p50/p95 latency, swept over
/// backend/codec × nprobe × threads, with a machine-readable
/// `BENCH_search.json` written at the repo root (override with `--out`).
///
/// `--codecs` accepts IVF store selectors (codec names, `pq`,
/// `pq-compressed`) and graph backends (`nsg[:codec]`, `hnsw[:codec]`);
/// the default sweep includes one graph row so the JSON always covers
/// both families. Invalid specs are reported with the valid-name list
/// up front — nothing runs, nothing panics.
pub fn search_qps(args: &Args) {
    let scale = scale_from(args);
    let runs = args.usize("runs", 3);
    let k = args.usize("k", 1024.min((scale.n / 16).max(4)));
    let kind = datasets_from(args)[0];
    let codecs: Vec<String> = match args.get("codecs") {
        Some(s) => s.split(',').map(|v| v.trim().to_string()).collect(),
        None => ["unc64", "compact", "ef", "roc", "pq-compressed", "nsg:roc"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    // Reject typos before any clustering/building happens. Exit
    // non-zero so scripts keying on the bench's status see the failure.
    for spec in &codecs {
        if let Err(e) = experiments::validate_qps_spec(spec) {
            eprintln!("bench-search-qps: bad --codecs entry {spec:?}: {e}");
            std::process::exit(2);
        }
    }
    let nprobes = parse_usize_list(args, "nprobe", &[16]);
    let mut threads_list =
        parse_usize_list(args, "sweep-threads", &[1, crate::util::pool::default_threads()]);
    threads_list.dedup();
    println!(
        "== search QPS: N={}, {} queries, K={k}, {} (runs={runs}; Table-2 runtime \
         columns as throughput; graph backends capped at N={}) ==",
        scale.n,
        scale.nq,
        kind.name(),
        scale.n.min(experiments::QPS_GRAPH_N_CAP)
    );
    let spec_refs: Vec<&str> = codecs.iter().map(|s| s.as_str()).collect();
    let rows =
        match experiments::search_qps(&scale, kind, &spec_refs, k, &nprobes, &threads_list, runs)
        {
            Ok(rows) => rows,
            Err(e) => {
                eprintln!("bench-search-qps: {e}");
                std::process::exit(1);
            }
        };
    let mut t = Table::new(&[
        "backend", "codec", "nprobe/ef", "threads", "QPS", "mean ms", "p50 ms", "p95 ms",
    ]);
    for r in &rows {
        t.row(vec![
            r.backend.clone(),
            r.codec.clone(),
            r.nprobe.to_string(),
            r.threads.to_string(),
            fmt3(r.qps),
            fmt3(r.mean_ms),
            fmt3(r.p50_ms),
            fmt3(r.p95_ms),
        ]);
    }
    println!("{}", t.render());
    let out_path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => default_bench_json_path(),
    };
    // A BENCH_search.json with no work behind it poisons cross-PR
    // throughput tracking; refuse to write it and exit non-zero so
    // scripts keying on the bench status see the failure.
    if let Some(reason) = degenerate_qps_reason(scale.nq, &rows) {
        eprintln!(
            "bench-search-qps: refusing to write {}: {reason}",
            out_path.display()
        );
        std::process::exit(1);
    }
    let json = qps_json(&scale, kind.name(), k, &recall::EnvManifest::capture(scale.threads), &rows);
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out_path.display()),
    }
}

/// Why a QPS run would produce a degenerate `BENCH_search.json`
/// (`None` when the report is sound). Factored out of [`search_qps`] so
/// the guard is unit-testable next to the JSON contract.
fn degenerate_qps_reason(nq: usize, rows: &[experiments::QpsRow]) -> Option<String> {
    if nq == 0 {
        return Some("zero queries executed (nq=0)".into());
    }
    if rows.is_empty() {
        return Some("no result rows (empty sweep)".into());
    }
    if let Some(r) = rows.iter().find(|r| r.qps <= 0.0 || r.qps.is_nan()) {
        return Some(format!(
            "row {}/{} (nprobe={}, threads={}) reports qps={}, which means no query ran",
            r.backend, r.codec, r.nprobe, r.threads, r.qps
        ));
    }
    None
}

/// Default location of the decode-throughput report, next to
/// `BENCH_search.json` at the repo root.
fn default_decode_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_decode.json")
}

/// Serialize a decode report to the `BENCH_decode.json` schema
/// (docs/REPRODUCING.md): per-codec decode throughput rows plus the two
/// scan kernels, scalar against the dispatched SIMD level.
fn decode_json(rep: &experiments::DecodeReport, seed: u64) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"decode\",\n  \"universe\": {},\n  \"lists\": {},\n  \
         \"reps\": {},\n  \"seed\": {seed},\n  \"simd_level\": \"{}\",\n",
        rep.universe, rep.lists, rep.reps, rep.simd_level
    ));
    s.push_str("  \"results\": [\n");
    for (i, r) in rep.rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"codec\": \"{}\", \"list_len\": {}, \"lists\": {}, \
             \"bits_per_id\": {:.6}, \"ids_per_s\": {:.3}, \"mb_per_s\": {:.6}}}{}\n",
            r.codec,
            r.list_len,
            r.lists,
            r.bits_per_id,
            r.ids_per_s,
            r.mb_per_s,
            if i + 1 == rep.rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"adc\": {{\"m\": {}, \"ksub\": {}, \"codes\": {}, \
         \"codes_per_s_scalar\": {:.3}, \"codes_per_s_simd\": {:.3}}},\n",
        rep.adc_m, rep.adc_ksub, rep.adc.items, rep.adc.scalar_per_s, rep.adc.simd_per_s
    ));
    s.push_str(&format!(
        "  \"coarse\": {{\"k\": {}, \"dim\": {}, \
         \"rows_per_s_scalar\": {:.3}, \"rows_per_s_simd\": {:.3}}}\n",
        rep.coarse_k, rep.coarse_dim, rep.coarse.scalar_per_s, rep.coarse.simd_per_s
    ));
    s.push_str("}\n");
    s
}

/// Why a decode run would produce a degenerate `BENCH_decode.json`
/// (`None` when the report is sound). A zero-item run — no lists, or
/// only empty lists — must exit non-zero instead of poisoning the
/// decode-throughput trajectory.
fn degenerate_decode_reason(rep: &experiments::DecodeReport) -> Option<String> {
    if rep.rows.is_empty() {
        return Some("no codec rows (empty sweep)".into());
    }
    if rep.total_ids() == 0 {
        return Some("zero-item run: no ids were decoded".into());
    }
    if let Some(r) = rep
        .rows
        .iter()
        .find(|r| r.list_len > 0 && (r.ids_per_s <= 0.0 || r.ids_per_s.is_nan()))
    {
        return Some(format!(
            "row {}/len {} reports ids_per_s={}, which means no decode ran",
            r.codec, r.list_len, r.ids_per_s
        ));
    }
    if rep.adc.scalar_per_s <= 0.0 || rep.adc.simd_per_s <= 0.0 {
        return Some("ADC kernel timing is degenerate".into());
    }
    if rep.coarse.scalar_per_s <= 0.0 || rep.coarse.simd_per_s <= 0.0 {
        return Some("coarse kernel timing is degenerate".into());
    }
    None
}

/// Decode-throughput bench: per-codec bulk-decode MB/s and ids/s across
/// list sizes (including the interleaved-ANS family), plus the blocked
/// ADC and fused coarse kernels scalar-vs-dispatched — the baseline
/// every future read-path change is measured against. Writes
/// `BENCH_decode.json` at the repo root (override with `--out`); exits
/// non-zero without writing on a degenerate (zero-item) run.
pub fn decode(args: &Args) {
    let universe = args.u64("universe", 1_000_000) as u32;
    let list_lens: Vec<usize> = parse_usize_list(args, "list-lens", &[64, 1024, 4096]);
    let lists = args.usize("lists", 32);
    let reps = args.usize("reps", 3);
    let seed = args.u64("seed", 42);
    let adc_rows = args.usize("adc-rows", 20_000);
    let adc_m = args.usize("adc-m", 8);
    let coarse_k = args.usize("coarse-k", 1024);
    let coarse_dim = args.usize("coarse-dim", 32);
    println!(
        "== decode throughput: {lists} lists × {:?} ids from [0, {universe}), reps={reps} ==",
        list_lens
    );
    let rep = match experiments::decode_bench(
        universe, &list_lens, lists, reps, seed, adc_rows, adc_m, coarse_k, coarse_dim,
    ) {
        Ok(rep) => rep,
        Err(e) => {
            eprintln!("bench-decode: {e}");
            std::process::exit(1);
        }
    };
    let mut t = Table::new(&["codec", "list len", "bits/id", "Mids/s", "MB/s"]);
    for r in &rep.rows {
        t.row(vec![
            r.codec.clone(),
            r.list_len.to_string(),
            fmt3(r.bits_per_id),
            fmt3(r.ids_per_s / 1e6),
            fmt3(r.mb_per_s),
        ]);
    }
    println!("{}", t.render());
    println!(
        "ADC scan ({}x{} codes):   scalar {} Mcodes/s | {} {} Mcodes/s",
        adc_rows,
        rep.adc_m,
        fmt3(rep.adc.scalar_per_s / 1e6),
        rep.simd_level,
        fmt3(rep.adc.simd_per_s / 1e6),
    );
    println!(
        "coarse kernel (K={}, dim={}): scalar {} Mrows/s | {} {} Mrows/s",
        rep.coarse_k,
        rep.coarse_dim,
        fmt3(rep.coarse.scalar_per_s / 1e6),
        rep.simd_level,
        fmt3(rep.coarse.simd_per_s / 1e6),
    );
    let out_path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => default_decode_json_path(),
    };
    if let Some(reason) = degenerate_decode_reason(&rep) {
        eprintln!("bench-decode: refusing to write {}: {reason}", out_path.display());
        std::process::exit(1);
    }
    let json = decode_json(&rep, seed);
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out_path.display()),
    }
}

/// Default location of the churn report, next to `BENCH_search.json`.
fn default_churn_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_churn.json")
}

/// Serialize a churn report to the `BENCH_churn.json` schema
/// (docs/REPRODUCING.md).
fn churn_json(r: &experiments::ChurnReport) -> String {
    format!(
        "{{\n  \"bench\": \"churn\",\n  \"dataset\": \"{}\",\n  \"n\": {},\n  \
         \"inserts\": {},\n  \"deletes\": {},\n  \"dim\": {},\n  \"k\": {},\n  \
         \"codec\": \"{}\",\n  \"seed\": {},\n  \"nq\": {},\n  \
         \"insert_per_s\": {:.3},\n  \"delete_per_s\": {:.3},\n  \"compact_s\": {:.6},\n  \
         \"segments_before_compact\": {},\n  \"pre_compact_bits_per_id\": {:.6},\n  \
         \"bits_per_id_dynamic\": {:.6},\n  \"bits_per_id_static\": {:.6},\n  \
         \"bpi_ratio\": {:.6},\n  \"queries_identical\": {},\n  \
         \"results_identical\": {}\n}}\n",
        r.dataset,
        r.n0,
        r.inserts,
        r.deletes,
        r.dim,
        r.k,
        r.codec,
        r.seed,
        r.nq,
        r.insert_per_s,
        r.delete_per_s,
        r.compact_secs,
        r.segments_before_compact,
        r.pre_compact_bits_per_id,
        r.bits_per_id_dynamic,
        r.bits_per_id_static,
        r.bpi_ratio(),
        r.queries_identical,
        r.results_identical(),
    )
}

/// Mutable-IVF churn bench: delete/insert `--churn` of N, compact, and
/// audit throughput + compression + search parity against a
/// from-scratch static build. Writes `BENCH_churn.json` (override with
/// `--out`) and exits non-zero if any query diverges from the static
/// rebuild — the bench doubles as the correctness gate for live churn.
pub fn churn(args: &Args) {
    let scale = scale_from(args);
    let kind = datasets_from(args)[0];
    let k = args.usize("k", 1024.min((scale.n / 16).max(4)));
    let codec = args.get_or("codec", "roc");
    match CodecSpec::parse(codec) {
        Ok(spec) if spec.is_per_list() => {}
        Ok(spec) => {
            eprintln!(
                "bench-churn: codec {:?} is not a per-list codec (dynamic indexes need one of: {})",
                spec.name(),
                crate::codecs::PER_LIST_CODECS.join(", ")
            );
            std::process::exit(2);
        }
        Err(e) => {
            eprintln!("bench-churn: {e}");
            std::process::exit(2);
        }
    }
    let churn_frac = args.f64("churn", 0.2);
    let nprobe = args.usize("nprobe", 16);
    println!(
        "== churn: N={}, ±{:.0}% via delete/insert, K={k}, {} ({codec} ids, nprobe={nprobe}) ==",
        scale.n,
        churn_frac * 100.0,
        kind.name()
    );
    let rep = match experiments::churn(&scale, kind, codec, k, churn_frac, nprobe) {
        Ok(rep) => rep,
        Err(e) => {
            eprintln!("bench-churn: {e}");
            std::process::exit(1);
        }
    };
    let mut t = Table::new(&[
        "metric",
        "inserts/s",
        "deletes/s",
        "compact s",
        "bits/id pre",
        "bits/id post",
        "bits/id static",
        "ratio",
        "parity",
    ]);
    t.row(vec![
        format!("{}·{}", rep.dataset, rep.codec),
        fmt3(rep.insert_per_s),
        fmt3(rep.delete_per_s),
        fmt3(rep.compact_secs),
        fmt3(rep.pre_compact_bits_per_id),
        fmt3(rep.bits_per_id_dynamic),
        fmt3(rep.bits_per_id_static),
        format!("{:.4}", rep.bpi_ratio()),
        format!("{}/{}", rep.queries_identical, rep.nq),
    ]);
    println!("{}", t.render());
    let out_path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => default_churn_json_path(),
    };
    let json = churn_json(&rep);
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out_path.display()),
    }
    if !rep.results_identical() {
        eprintln!(
            "bench-churn: {}/{} queries diverged from the from-scratch static build",
            rep.nq - rep.queries_identical,
            rep.nq
        );
        std::process::exit(1);
    }
}

/// Default location of the recall report, next to `BENCH_search.json`.
fn default_recall_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_recall.json")
}

/// Minimal JSON string escape (quotes/backslashes; enough for codec
/// names and `rustc --version` output).
fn jesc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Serialize a recall report to the `BENCH_recall.json` schema
/// (docs/REPRODUCING.md): run parameters, environment manifest, and one
/// object per (backend, codec, knob) operating point.
fn recall_json(rep: &recall::RecallReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"recall\",\n  \"dataset\": \"{}\",\n  \"n\": {},\n  \"nq\": {},\n  \
         \"dim\": {},\n  \"seed\": {},\n  \"clusters\": {},\n  \"topk\": {},\n  \
         \"churn_frac\": {:.6},\n  \"corrupt_ids\": {},\n",
        rep.dataset, rep.n, rep.nq, rep.dim, rep.seed, rep.clusters, rep.topk,
        rep.churn_frac, rep.corrupt_ids
    ));
    s.push_str(&env_json_line(&rep.env));
    s.push_str("  \"results\": [\n");
    for (i, p) in rep.points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"backend\": \"{}\", \"codec\": \"{}\", \"knob\": {}, \
             \"recall_at_1\": {:.6}, \"recall_at_10\": {:.6}, \"nn_recall_at_10\": {:.6}, \
             \"qps\": {:.3}, \"mean_ms\": {:.6}, \"p50_ms\": {:.6}, \"p95_ms\": {:.6}, \
             \"bits_per_id\": {:.6}, \"lossless_ids\": {}}}{}\n",
            p.backend,
            jesc(&p.codec),
            p.knob,
            p.recall_at_1,
            p.recall_at_10,
            p.nn_recall_at_10,
            p.qps,
            p.mean_ms,
            p.p50_ms,
            p.p95_ms,
            p.bits_per_id,
            p.lossless_ids,
            if i + 1 == rep.points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Why a recall run would produce a degenerate `BENCH_recall.json`
/// (`None` when the report is sound). Called twice: before the sweep
/// with `points: None` (an nq=0 run must exit before building anything)
/// and after with the measured points. Recall is a probability — a NaN
/// or out-of-range value means the scoring pipeline is broken, and a
/// zero/NaN QPS means no query actually ran; neither may land in the
/// committed trajectory file.
fn degenerate_recall_reason(nq: usize, points: Option<&[recall::RecallPoint]>) -> Option<String> {
    if nq == 0 {
        return Some("zero queries executed (nq=0)".into());
    }
    let points = points?;
    if points.is_empty() {
        return Some("no result rows (empty sweep)".into());
    }
    for p in points {
        for (name, v) in [
            ("recall_at_1", p.recall_at_1),
            ("recall_at_10", p.recall_at_10),
            ("nn_recall_at_10", p.nn_recall_at_10),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Some(format!(
                    "row {}/{} (knob={}) reports {name}={v}, outside [0, 1]",
                    p.backend, p.codec, p.knob
                ));
            }
        }
        if p.qps <= 0.0 || p.qps.is_nan() {
            return Some(format!(
                "row {}/{} (knob={}) reports qps={}, which means no query ran",
                p.backend, p.codec, p.knob, p.qps
            ));
        }
    }
    None
}

/// Recall-aware evaluation bench: sweep codec × backend × search knob
/// against exact groundtruth and write `BENCH_recall.json` (override
/// with `--out`) — the paper's "no impact on accuracy" claim as a
/// measured artifact, gated in CI by tools/check_recall_baseline.py.
///
/// `--corrupt-ids` sabotages every returned id at scoring time so the
/// CI gate can prove it fires; it requires an explicit `--out` so the
/// sabotaged report can never land on the committed trajectory file.
/// Exits non-zero without writing on any degenerate run, including a
/// lossless-codec invariance violation inside the sweep itself.
pub fn recall(args: &Args) {
    let mut scale = scale_from(args);
    if args.get("nq").is_none() {
        // Exact groundtruth is O(n·nq); default to a lighter query load
        // than the throughput benches.
        scale.nq = 2000;
    }
    let kind = datasets_from(args)[0];
    let clusters = args.usize("k", 1024.min((scale.n / 16).max(4)));
    let topk = args.usize("topk", 10);
    let knobs = parse_usize_list(args, "knobs", &[4, 16, 64]);
    let ivf_codecs: Vec<String> = match args.get("codecs") {
        Some(s) => s.split(',').map(|v| v.trim().to_string()).collect(),
        None => ["unc64", "roc", "ans-i4"].iter().map(|s| s.to_string()).collect(),
    };
    for codec in &ivf_codecs {
        match CodecSpec::parse(codec) {
            Ok(spec) if spec.is_per_list() || matches!(spec, CodecSpec::Wavelet(_)) => {}
            Ok(spec) => {
                eprintln!(
                    "bench-recall: codec {:?} is not an IVF id store (need a per-list codec or wt/wt1)",
                    spec.name()
                );
                std::process::exit(2);
            }
            Err(e) => {
                eprintln!("bench-recall: {e}");
                std::process::exit(2);
            }
        }
    }
    let pq_m = if args.bool("skip-pq") {
        0
    } else {
        // Largest of the Table-2 sub-quantizer counts that divides dim.
        args.usize(
            "pq-m",
            [8usize, 4, 2, 1].into_iter().find(|&m| scale.dim % m == 0).unwrap_or(1),
        )
    };
    let dynamic_codec = args.get_or("dynamic-codec", "roc").to_string();
    match CodecSpec::parse(&dynamic_codec) {
        Ok(spec) if spec.is_per_list() => {}
        Ok(spec) => {
            eprintln!(
                "bench-recall: --dynamic-codec {:?} is not a per-list codec (need one of: {})",
                spec.name(),
                crate::codecs::PER_LIST_CODECS.join(", ")
            );
            std::process::exit(2);
        }
        Err(e) => {
            eprintln!("bench-recall: {e}");
            std::process::exit(2);
        }
    }
    let cfg = recall::RecallConfig {
        scale: scale.clone(),
        kind,
        clusters,
        topk,
        knobs,
        ivf_codecs,
        pq_m,
        graphs: !args.bool("skip-graphs"),
        graph_codec: args.get_or("graph-codec", "roc").to_string(),
        dynamic: !args.bool("skip-dynamic"),
        dynamic_codec,
        churn_frac: args.f64("churn", 0.2),
        runs: args.usize("runs", 2),
        corrupt_ids: args.bool("corrupt-ids"),
    };
    let out_path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            if cfg.corrupt_ids {
                eprintln!(
                    "bench-recall: --corrupt-ids requires an explicit --out (refusing to \
                     overwrite the committed trajectory file with sabotaged numbers)"
                );
                std::process::exit(2);
            }
            default_recall_json_path()
        }
    };
    if let Some(reason) = degenerate_recall_reason(scale.nq, None) {
        eprintln!("bench-recall: refusing to write {}: {reason}", out_path.display());
        std::process::exit(1);
    }
    println!(
        "== recall: N={}, {} queries, K={clusters}, topk={topk}, {} \
         (knobs={:?}, runs={}; graph backends capped at N={}) ==",
        scale.n,
        scale.nq,
        kind.name(),
        cfg.knobs,
        cfg.runs,
        scale.n.min(experiments::QPS_GRAPH_N_CAP)
    );
    let rep = match recall::sweep(&cfg) {
        Ok(rep) => rep,
        Err(e) => {
            eprintln!("bench-recall: {e}");
            std::process::exit(1);
        }
    };
    let mut t = Table::new(&[
        "backend", "codec", "knob", "r@1", "r@10", "1-r@10", "QPS", "p50 ms", "p95 ms",
        "bits/id",
    ]);
    for p in &rep.points {
        t.row(vec![
            p.backend.into(),
            p.codec.clone(),
            p.knob.to_string(),
            format!("{:.4}", p.recall_at_1),
            format!("{:.4}", p.recall_at_10),
            format!("{:.4}", p.nn_recall_at_10),
            fmt3(p.qps),
            fmt3(p.p50_ms),
            fmt3(p.p95_ms),
            fmt3(p.bits_per_id),
        ]);
    }
    println!("{}", t.render());
    println!(
        "env: {} | simd={} (override={}) | threads={}",
        rep.env.rustc, rep.env.simd_level, rep.env.simd_override, rep.env.threads
    );
    if let Some(reason) = degenerate_recall_reason(rep.nq, Some(&rep.points)) {
        eprintln!("bench-recall: refusing to write {}: {reason}", out_path.display());
        std::process::exit(1);
    }
    let json = recall_json(&rep);
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out_path.display()),
    }
}

fn default_serve_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_serve.json")
}

/// Everything `BENCH_serve.json` records about one serve-bench run.
struct ServeReport {
    dataset: String,
    n: usize,
    nq: usize,
    dim: usize,
    seed: u64,
    shards: usize,
    router: String,
    codec: String,
    tenants: usize,
    theta: f64,
    write_frac: f64,
    requests: usize,
    k: usize,
    nprobe: usize,
    runs: usize,
    clients: usize,
    tenant_burst: Option<u64>,
    tenant_rate: f64,
    queue_depth: usize,
    deadline_ms: Option<u64>,
    env: recall::EnvManifest,
    shard_rows: Vec<usize>,
    queue_hwm: u64,
    total: crate::eval::workload::ServeStats,
    post_ok: bool,
    snapshot_queries: usize,
    per_tenant: Vec<(String, crate::eval::workload::ServeStats)>,
}

impl ServeReport {
    /// Hottest shard's rows over the mean — 1.0 is perfectly balanced.
    fn shard_imbalance(&self) -> f64 {
        let max = self.shard_rows.iter().copied().max().unwrap_or(0) as f64;
        let mean = self.shard_rows.iter().sum::<usize>() as f64
            / self.shard_rows.len().max(1) as f64;
        max / mean.max(1e-12)
    }
}

fn serve_stats_json(s: &crate::eval::workload::ServeStats) -> String {
    format!(
        "{{\"requests\": {}, \"ok\": {}, \"rejected\": {}, \"timeouts\": {}, \"failed\": {}, \
         \"qps\": {:.3}, \"p50_ms\": {:.6}, \"p95_ms\": {:.6}, \"p99_ms\": {:.6}}}",
        s.requests, s.ok, s.rejected, s.timeouts, s.failed, s.qps, s.p50_ms, s.p95_ms, s.p99_ms
    )
}

/// Serialize a serve report to the `BENCH_serve.json` schema
/// (docs/REPRODUCING.md): run/workload parameters, environment manifest,
/// shard balance, aggregate and per-tenant outcome rows, plus the
/// post-overload liveness and snapshot/restore verification bits.
fn serve_json(rep: &ServeReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"serve\",\n  \"dataset\": \"{}\",\n  \"n\": {},\n  \"nq\": {},\n  \
         \"dim\": {},\n  \"seed\": {},\n  \"shards\": {},\n  \"router\": \"{}\",\n  \
         \"codec\": \"{}\",\n  \"tenants\": {},\n  \"theta\": {:.4},\n  \
         \"write_frac\": {:.4},\n  \"requests\": {},\n  \"k\": {},\n  \"nprobe\": {},\n  \
         \"runs\": {},\n  \"clients\": {},\n",
        rep.dataset,
        rep.n,
        rep.nq,
        rep.dim,
        rep.seed,
        rep.shards,
        jesc(&rep.router),
        jesc(&rep.codec),
        rep.tenants,
        rep.theta,
        rep.write_frac,
        rep.requests,
        rep.k,
        rep.nprobe,
        rep.runs,
        rep.clients
    ));
    s.push_str(&format!(
        "  \"tenant_burst\": {},\n  \"tenant_rate\": {:.4},\n  \"queue_depth\": {},\n  \
         \"deadline_ms\": {},\n",
        rep.tenant_burst.map_or("null".into(), |b| b.to_string()),
        rep.tenant_rate,
        rep.queue_depth,
        rep.deadline_ms.map_or("null".into(), |d| d.to_string()),
    ));
    s.push_str(&env_json_line(&rep.env));
    s.push_str(&format!(
        "  \"shard_rows\": [{}],\n  \"shard_imbalance\": {:.4},\n  \"queue_hwm\": {},\n",
        rep.shard_rows.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(", "),
        rep.shard_imbalance(),
        rep.queue_hwm
    ));
    s.push_str(&format!("  \"total\": {},\n", serve_stats_json(&rep.total)));
    s.push_str(&format!(
        "  \"post_ok\": {},\n  \"snapshot\": {{\"shard\": 0, \"verified\": true, \
         \"queries\": {}}},\n",
        rep.post_ok, rep.snapshot_queries
    ));
    s.push_str("  \"tenants_rows\": [\n");
    for (i, (tenant, st)) in rep.per_tenant.iter().enumerate() {
        let obj = serve_stats_json(st);
        s.push_str(&format!(
            "    {{\"tenant\": \"{}\", {}{}\n",
            jesc(tenant),
            &obj[1..], // splice the tenant key into the stats object
            if i + 1 == rep.per_tenant.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Why a serve run would produce a degenerate `BENCH_serve.json` (`None`
/// when the report is sound). Called before the node is built (`total:
/// None` — a zero-request run must exit before any clustering) and after
/// the measured pass.
fn degenerate_serve_reason(
    requests: usize,
    total: Option<&crate::eval::workload::ServeStats>,
) -> Option<String> {
    if requests == 0 {
        return Some("zero requests scheduled (--requests 0)".into());
    }
    let total = total?;
    if total.ok == 0 {
        return Some(format!(
            "no request was served (ok=0 of {}; all shed or failed)",
            total.requests
        ));
    }
    if total.qps <= 0.0 || total.qps.is_nan() {
        return Some(format!("qps={} means no query actually ran", total.qps));
    }
    None
}

/// Sharded-serving bench: a mutable [`crate::serve::ServeNode`] under
/// mixed read/write traffic with zipf-skewed tenants and write placement,
/// measured with the shared workload module (warm pass + best-of-`runs`,
/// admission refilled between passes). Writes `BENCH_serve.json`
/// (override with `--out`): per-tenant QPS and latency percentiles, shed
/// counts, shard imbalance, queue high-water mark, a post-overload
/// liveness probe and a snapshot/restore parity verification of shard 0.
/// Refuses to write on degenerate runs (zero requests, nothing served).
pub fn serve(args: &Args) {
    let scale = scale_from(args);
    let requests = args.usize("requests", 2000);
    let out_path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => default_serve_json_path(),
    };
    if let Some(reason) = degenerate_serve_reason(requests, None) {
        eprintln!("bench-serve: refusing to write {}: {reason}", out_path.display());
        std::process::exit(1);
    }
    let kind = datasets_from(args)[0];
    let shards = args.usize("shards", 4).max(1);
    let router = match crate::serve::RouterKind::parse(args.get_or("router", "kmeans")) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("bench-serve: {e}");
            std::process::exit(2);
        }
    };
    let codec = args.get_or("codec", "roc").to_string();
    match CodecSpec::parse(&codec) {
        Ok(spec) if spec.is_per_list() => {}
        Ok(spec) => {
            eprintln!(
                "bench-serve: --codec {:?} is not a per-list codec (need one of: {})",
                spec.name(),
                crate::codecs::PER_LIST_CODECS.join(", ")
            );
            std::process::exit(2);
        }
        Err(e) => {
            eprintln!("bench-serve: {e}");
            std::process::exit(2);
        }
    }
    let clusters = args.usize("k", 1024.min((scale.n / 16).max(4)));
    let tenants = args.usize("tenants", 4).max(1);
    let theta = args.f64("theta", 0.99);
    let write_frac = args.f64("write-frac", 0.1).clamp(0.0, 1.0);
    let k = args.usize("topk", 10);
    let nprobe = args.usize("nprobe", 16);
    let runs = args.usize("runs", 3);
    let clients = args.usize("clients", 4).max(1);
    let tenant_burst: Option<u64> = args.get("tenant-burst").map(|v| {
        v.parse().unwrap_or_else(|_| {
            eprintln!("bench-serve: bad --tenant-burst {v:?}");
            std::process::exit(2);
        })
    });
    let tenant_rate = args.f64("tenant-rate", 0.0);
    let queue_depth = args.usize("queue-depth", 1024);
    let deadline_ms: Option<u64> = args.get("deadline-ms").map(|v| {
        v.parse().unwrap_or_else(|_| {
            eprintln!("bench-serve: bad --deadline-ms {v:?}");
            std::process::exit(2);
        })
    });
    println!(
        "== serve: N={}, {} shards ({} router, codec {codec}), {requests} requests, \
         {tenants} tenants (theta={theta}), write_frac={write_frac}, clients={clients}, \
         runs={runs} ==",
        scale.n,
        shards,
        args.get_or("router", "kmeans"),
    );
    let ds = crate::datasets::generate(kind, scale.n, scale.nq, scale.dim, scale.seed);
    let params = crate::serve::ShardedBuildParams {
        shards,
        router,
        ivf: crate::index::IvfBuildParams {
            k: clusters,
            seed: scale.seed,
            threads: scale.threads,
            id_codec: codec.clone(),
            vectors: VectorMode::Flat,
            ..Default::default()
        },
    };
    let node_cfg = crate::serve::NodeConfig {
        serve: crate::coordinator::ServeConfig {
            search: crate::api::QueryParams { k, nprobe, ef: nprobe },
            scan_threads: (scale.threads / shards).max(1),
            queue_depth,
            deadline: deadline_ms.map(std::time::Duration::from_millis),
            ..Default::default()
        },
        tenants: tenant_burst.map(|burst| crate::serve::TenantPolicy { burst, rate: tenant_rate }),
        ..Default::default()
    };
    let node = match crate::serve::ServeNode::start_mutable(
        &ds.data,
        ds.dim,
        &params,
        crate::dynamic::CompactionPolicy::default(),
        node_cfg,
    ) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("bench-serve: {e:#}");
            std::process::exit(1);
        }
    };
    let schedule = crate::eval::workload::serve_schedule(
        requests, tenants, theta, write_frac, &ds.queries, ds.dim, scale.seed,
    );
    // Write schedules run a single measured pass (repeated passes would
    // re-ingest the same rows); report the pass count actually used.
    let runs = crate::eval::workload::effective_runs(&schedule, runs);
    let (outcomes, wall) = crate::eval::workload::run_serve(&node, &schedule, clients, runs);
    let total = crate::eval::workload::aggregate_serve(&outcomes, None, wall);
    let per_tenant: Vec<(String, crate::eval::workload::ServeStats)> = (0..tenants)
        .map(|t| {
            (format!("t{t}"), crate::eval::workload::aggregate_serve(&outcomes, Some(t), wall))
        })
        .collect();
    // The node must still answer after any shedding the workload caused.
    let post_ok = node.search_raw(&ds.queries[..ds.dim]).map(|r| r.is_ok()).unwrap_or(false);
    // Snapshot/restore of shard 0 with search-parity verification — the
    // replication path exercised on every bench run, not just in tests.
    let parity_n = ds.nq.min(16);
    let snapshot_queries = match node
        .snapshot_shard(0)
        .and_then(|snap| node.restore_shard(0, &snap, &ds.queries[..parity_n * ds.dim]))
    {
        Ok(nq) => nq,
        Err(e) => {
            eprintln!("bench-serve: snapshot/restore verification failed: {e:#}");
            std::process::exit(1);
        }
    };
    let shard_rows = node.shard_rows();
    let queue_hwm = node.queue_hwm();
    println!("{}", node.metrics_summary());
    node.stop();
    // Prometheus rendering of everything the run registered — the only
    // workload in the CLI that populates per-shard *and* per-tenant
    // series, so the CI exposition gate taps it here.
    if let Some(p) = args.get("metrics-prom") {
        let text = crate::obs::global().render_prometheus();
        match std::fs::write(p, &text) {
            Ok(()) => println!("wrote {} exposition lines to {p}", text.lines().count()),
            Err(e) => {
                eprintln!("bench-serve: failed to write --metrics-prom {p}: {e}");
                std::process::exit(1);
            }
        }
    }

    let mut t = Table::new(&[
        "tenant", "requests", "ok", "rejected", "timeouts", "failed", "QPS", "p50 ms",
        "p95 ms", "p99 ms",
    ]);
    for (name, st) in
        std::iter::once(&("all".to_string(), total.clone())).chain(per_tenant.iter())
    {
        t.row(vec![
            name.clone(),
            st.requests.to_string(),
            st.ok.to_string(),
            st.rejected.to_string(),
            st.timeouts.to_string(),
            st.failed.to_string(),
            fmt3(st.qps),
            fmt3(st.p50_ms),
            fmt3(st.p95_ms),
            fmt3(st.p99_ms),
        ]);
    }
    println!("{}", t.render());
    println!(
        "shard rows: {shard_rows:?} (imbalance max/mean), queue_hwm={queue_hwm}, \
         post_ok={post_ok}, snapshot parity queries={snapshot_queries}"
    );
    if let Some(reason) = degenerate_serve_reason(requests, Some(&total)) {
        eprintln!("bench-serve: refusing to write {}: {reason}", out_path.display());
        std::process::exit(1);
    }
    let rep = ServeReport {
        dataset: kind.name().to_string(),
        n: scale.n,
        nq: scale.nq,
        dim: scale.dim,
        seed: scale.seed,
        shards,
        router: args.get_or("router", "kmeans").to_string(),
        codec,
        tenants,
        theta,
        write_frac,
        requests,
        k,
        nprobe,
        runs,
        clients,
        tenant_burst,
        tenant_rate,
        queue_depth,
        deadline_ms,
        env: recall::EnvManifest::capture(scale.threads),
        shard_rows,
        queue_hwm,
        total,
        post_ok,
        snapshot_queries,
        per_tenant,
    };
    let json = serve_json(&rep);
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out_path.display()),
    }
}

/// Default location of the observability self-measurement report, next
/// to `BENCH_search.json` at the repo root.
fn default_obs_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_obs.json")
}

/// Everything `BENCH_obs.json` records about one self-measurement run.
struct ObsReport {
    dataset: String,
    n: usize,
    nq: usize,
    dim: usize,
    seed: u64,
    k: usize,
    nprobe: usize,
    runs: usize,
    env: recall::EnvManifest,
    /// Best-of-`runs` wall time with trace sampling off / on (seconds).
    wall_off_s: f64,
    wall_on_s: f64,
    /// `wall_on / wall_off − 1` — the cost of tracing every query. Can
    /// be slightly negative on a noisy box; the CI gate only bounds it
    /// from above.
    overhead_frac: f64,
    sampled_spans: usize,
    /// Mean of `stage_sum_ns / total_ns` over the sampled spans — how
    /// much of each query's end-to-end latency the stage timeline
    /// accounts for (1.0 by construction of the residual stage).
    span_sum_ratio: f64,
    registry_series: usize,
    /// Mean time per stage across the sampled spans, in µs.
    stage_mean_us: Vec<(&'static str, f64)>,
}

/// Serialize an obs report to the `BENCH_obs.json` schema
/// (docs/REPRODUCING.md): run parameters, environment manifest, the
/// off/on wall times with the overhead fraction, span accounting, and
/// the per-stage mean timeline.
fn obs_json(rep: &ObsReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"obs\",\n  \"dataset\": \"{}\",\n  \"n\": {},\n  \"nq\": {},\n  \
         \"dim\": {},\n  \"seed\": {},\n  \"k\": {},\n  \"nprobe\": {},\n  \"runs\": {},\n",
        jesc(&rep.dataset),
        rep.n,
        rep.nq,
        rep.dim,
        rep.seed,
        rep.k,
        rep.nprobe,
        rep.runs
    ));
    s.push_str(&env_json_line(&rep.env));
    s.push_str(&format!(
        "  \"wall_off_s\": {:.6},\n  \"wall_on_s\": {:.6},\n  \"overhead_frac\": {:.6},\n  \
         \"sampled_spans\": {},\n  \"span_sum_ratio\": {:.6},\n  \"registry_series\": {},\n",
        rep.wall_off_s,
        rep.wall_on_s,
        rep.overhead_frac,
        rep.sampled_spans,
        rep.span_sum_ratio,
        rep.registry_series
    ));
    s.push_str("  \"stages\": [\n");
    for (i, (stage, us)) in rep.stage_mean_us.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"stage\": \"{}\", \"mean_us\": {:.3}}}{}\n",
            jesc(stage),
            us,
            if i + 1 == rep.stage_mean_us.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Why an obs run would produce a degenerate `BENCH_obs.json` (`None`
/// when the report is sound). A run that sampled nothing, never ticked
/// the clock, or whose stage timelines don't account for the measured
/// end-to-end latency must exit non-zero instead of landing in the
/// committed overhead trajectory.
fn degenerate_obs_reason(
    sampled_spans: usize,
    wall_off_s: f64,
    wall_on_s: f64,
    span_sum_ratio: f64,
) -> Option<String> {
    if !crate::obs::enabled() {
        return Some("built without the `obs` feature: nothing to measure".into());
    }
    if sampled_spans == 0 {
        return Some("sampled run recorded zero spans".into());
    }
    if !(wall_off_s.is_finite() && wall_off_s > 0.0 && wall_on_s.is_finite() && wall_on_s > 0.0) {
        return Some(format!(
            "degenerate wall times (off={wall_off_s}, on={wall_on_s}): no measured pass ran"
        ));
    }
    // The residual stage makes each span's stage-sum equal its total by
    // construction, so the acceptance bound (within 10% of e2e latency)
    // failing means the tracer itself is broken.
    if !(0.9..=1.1).contains(&span_sum_ratio) {
        return Some(format!(
            "span stage-sum accounts for {span_sum_ratio:.3} of e2e latency (want 0.9..=1.1)"
        ));
    }
    None
}

/// Observability self-measurement: the serve workload run twice through
/// a coordinator — trace sampling off, then tracing every query — with
/// the overhead delta, per-stage mean timeline, and span accounting
/// written to `BENCH_obs.json` (override with `--out`). Refuses to
/// write on degenerate runs (no spans, no clock, broken accounting).
pub fn obs(args: &Args) {
    let scale = scale_from(args);
    let out_path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => default_obs_json_path(),
    };
    let kind = datasets_from(args)[0];
    let codec = args.get_or("codec", "roc").to_string();
    let clusters = args.usize("k", 1024.min((scale.n / 16).max(4)));
    let nprobe = args.usize("nprobe", 16);
    let k = args.usize("topk", 10);
    let runs = args.usize("runs", 3).max(1);
    println!(
        "== obs: N={}, nq={}, IVF{clusters} ({codec}), nprobe={nprobe}, runs={runs} ==",
        scale.n, scale.nq
    );
    let ds = crate::datasets::generate(kind, scale.n, scale.nq, scale.dim, scale.seed);
    let idx = std::sync::Arc::new(crate::index::IvfIndex::build(
        &ds.data,
        ds.dim,
        &crate::index::IvfBuildParams {
            k: clusters,
            seed: scale.seed,
            threads: scale.threads,
            id_codec: codec,
            ..Default::default()
        },
    ));
    let coord = crate::coordinator::Coordinator::start(
        idx,
        None,
        crate::coordinator::ServeConfig {
            batch_size: 64,
            search: crate::api::QueryParams { k, nprobe, ef: nprobe },
            queue_depth: scale.nq.max(1024),
            ..Default::default()
        },
    );
    let queries: Vec<Vec<f32>> = (0..scale.nq).map(|qi| ds.query(qi).to_vec()).collect();
    // Warm pass (JIT-free, but caches/branch predictors and the thread
    // pool all settle), then best-of-`runs` with sampling off and on.
    // Off first: its pass must not inherit warmth the on pass lacks.
    crate::obs::trace::set_sample(0);
    let _ = coord.client.search_many(queries.clone()).unwrap();
    let mut wall_off = f64::INFINITY;
    for _ in 0..runs {
        let t0 = std::time::Instant::now();
        let _ = coord.client.search_many(queries.clone()).unwrap();
        wall_off = wall_off.min(t0.elapsed().as_secs_f64());
    }
    crate::obs::trace::set_sample(1);
    let _ = crate::obs::trace::take_spans(); // start the sampled passes clean
    let mut wall_on = f64::INFINITY;
    for _ in 0..runs {
        let t0 = std::time::Instant::now();
        let _ = coord.client.search_many(queries.clone()).unwrap();
        wall_on = wall_on.min(t0.elapsed().as_secs_f64());
    }
    let spans = crate::obs::trace::take_spans();
    crate::obs::trace::set_sample(0);
    coord.stop();

    let ratios: Vec<f64> = spans
        .iter()
        .filter(|t| t.total_ns > 0)
        .map(|t| t.stage_sum_ns() as f64 / t.total_ns as f64)
        .collect();
    let span_sum_ratio = if ratios.is_empty() {
        0.0
    } else {
        ratios.iter().sum::<f64>() / ratios.len() as f64
    };
    let stage_mean_us: Vec<(&'static str, f64)> = crate::obs::trace::Stage::ALL
        .iter()
        .map(|st| {
            let sum: u64 = spans.iter().map(|t| t.stage_ns[st.idx()]).sum();
            (st.name(), sum as f64 / spans.len().max(1) as f64 / 1_000.0)
        })
        .collect();
    let overhead_frac = wall_on / wall_off - 1.0;

    let mut t = Table::new(&["stage", "mean µs/query"]);
    for (stage, us) in &stage_mean_us {
        t.row(vec![stage.to_string(), fmt3(*us)]);
    }
    println!("{}", t.render());
    println!(
        "wall: off={:.4}s on={:.4}s overhead={:+.2}%; {} sampled spans, stage-sum/total={:.4}, \
         {} registry series",
        wall_off,
        wall_on,
        overhead_frac * 100.0,
        spans.len(),
        span_sum_ratio,
        crate::obs::global().series_len()
    );
    if let Some(reason) = degenerate_obs_reason(spans.len(), wall_off, wall_on, span_sum_ratio) {
        eprintln!("bench-obs: refusing to write {}: {reason}", out_path.display());
        std::process::exit(1);
    }
    let rep = ObsReport {
        dataset: kind.name().to_string(),
        n: scale.n,
        nq: scale.nq,
        dim: scale.dim,
        seed: scale.seed,
        k: clusters,
        nprobe,
        runs,
        env: recall::EnvManifest::capture(scale.threads),
        wall_off_s: wall_off,
        wall_on_s: wall_on,
        overhead_frac,
        sampled_spans: spans.len(),
        span_sum_ratio,
        registry_series: crate::obs::global().series_len(),
        stage_mean_us,
    };
    let json = obs_json(&rep);
    if let Err(e) = crate::obs::expo::check_json_shape(&json) {
        eprintln!("bench-obs: emitter produced malformed JSON ({e}); refusing to write");
        std::process::exit(1);
    }
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out_path.display()),
    }
}

pub fn fig3(args: &Args) {
    let scale = scale_from(args);
    println!("== Figure 3: cluster-conditioned PQ code compression (8 bits uncompressed) ==");
    println!("paper: SIFT1M ~ -19%, Deep1M ~ -5%, FB-ssnpp ~ 0%");
    let mut t = Table::new(&["dataset", "PQ", "bits/element", "saving"]);
    for kind in datasets_from(args) {
        for p in experiments::fig3(&scale, kind, &[4, 8, 16, 32]) {
            t.row(vec![
                p.dataset.into(),
                p.pq_label.clone(),
                fmt3(p.bits_per_element),
                format!("{:+.1}%", 100.0 * (p.bits_per_element / 8.0 - 1.0)),
            ]);
        }
    }
    println!("{}", t.render());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qps_json_contract() {
        let scale = experiments::Scale { n: 100, nq: 10, dim: 4, seed: 1, threads: 2 };
        let rows = vec![
            experiments::QpsRow {
                backend: "ivf".into(),
                codec: "roc".into(),
                nprobe: 4,
                threads: 2,
                qps: 123.0,
                mean_ms: 0.5,
                p50_ms: 0.4,
                p95_ms: 0.9,
            },
            experiments::QpsRow {
                backend: "nsg".into(),
                codec: "nsg:roc".into(),
                nprobe: 8,
                threads: 1,
                qps: 50.5,
                mean_ms: 1.5,
                p50_ms: 1.4,
                p95_ms: 2.9,
            },
        ];
        let s = qps_json(&scale, "deep-like", 16, &recall::EnvManifest::capture(2), &rows);
        for key in [
            "\"bench\"", "\"search_qps\"", "\"dataset\"", "\"n\"", "\"nq\"", "\"dim\"",
            "\"k\"", "\"results\"", "\"backend\"", "\"codec\"", "\"nprobe\"", "\"threads\"",
            "\"qps\"", "\"mean_ms\"", "\"p50_ms\"", "\"p95_ms\"", "\"env\"", "\"rustc\"",
            "\"simd_level\"",
        ] {
            assert!(s.contains(key), "missing {key} in\n{s}");
        }
        assert!(s.contains("\"nsg\""), "graph backend row must carry its family:\n{s}");
        crate::obs::expo::check_json_shape(&s).expect("qps_json must be well-formed");
    }

    fn qps_row(qps: f64) -> experiments::QpsRow {
        experiments::QpsRow {
            backend: "ivf".into(),
            codec: "roc".into(),
            nprobe: 4,
            threads: 2,
            qps,
            mean_ms: 0.5,
            p50_ms: 0.4,
            p95_ms: 0.9,
        }
    }

    #[test]
    fn degenerate_qps_runs_are_refused() {
        // Healthy run → no objection.
        assert_eq!(degenerate_qps_reason(100, &[qps_row(12.5)]), None);
        // Zero queries, an empty sweep, or a zero-QPS row must all be
        // named explicitly instead of landing in BENCH_search.json.
        let msg = degenerate_qps_reason(0, &[qps_row(12.5)]).expect("nq=0");
        assert!(msg.contains("zero queries"), "{msg}");
        let msg = degenerate_qps_reason(100, &[]).expect("no rows");
        assert!(msg.contains("no result rows"), "{msg}");
        let msg = degenerate_qps_reason(100, &[qps_row(12.5), qps_row(0.0)]).expect("qps=0");
        assert!(msg.contains("qps=0"), "{msg}");
        assert!(degenerate_qps_reason(100, &[qps_row(f64::NAN)]).is_some());
    }

    fn serve_stats(ok: u64, rejected: u64, qps: f64) -> crate::eval::workload::ServeStats {
        crate::eval::workload::ServeStats {
            requests: ok + rejected,
            ok,
            rejected,
            timeouts: 0,
            failed: 0,
            qps,
            p50_ms: 0.4,
            p95_ms: 0.9,
            p99_ms: 1.2,
        }
    }

    #[test]
    fn serve_json_contract() {
        let rep = ServeReport {
            dataset: "deep-like".into(),
            n: 4000,
            nq: 100,
            dim: 16,
            seed: 42,
            shards: 4,
            router: "kmeans".into(),
            codec: "roc".into(),
            tenants: 3,
            theta: 1.2,
            write_frac: 0.1,
            requests: 200,
            k: 10,
            nprobe: 8,
            runs: 2,
            clients: 2,
            tenant_burst: Some(50),
            tenant_rate: 0.0,
            queue_depth: 1024,
            deadline_ms: None,
            env: recall::EnvManifest::capture(2),
            shard_rows: vec![1100, 900, 1000, 1000],
            queue_hwm: 7,
            total: serve_stats(180, 20, 950.0),
            post_ok: true,
            snapshot_queries: 16,
            per_tenant: vec![
                ("t0".into(), serve_stats(90, 20, 500.0)),
                ("t1".into(), serve_stats(60, 0, 300.0)),
                ("t2".into(), serve_stats(30, 0, 150.0)),
            ],
        };
        let s = serve_json(&rep);
        for key in [
            "\"bench\"", "\"serve\"", "\"shards\"", "\"router\"", "\"codec\"",
            "\"tenants\"", "\"theta\"", "\"write_frac\"", "\"tenant_burst\"",
            "\"tenant_rate\"", "\"queue_depth\"", "\"deadline_ms\"", "\"env\"",
            "\"rustc\"", "\"shard_rows\"", "\"shard_imbalance\"", "\"queue_hwm\"",
            "\"total\"", "\"qps\"", "\"p50_ms\"", "\"p95_ms\"", "\"p99_ms\"",
            "\"rejected\"", "\"timeouts\"", "\"failed\"", "\"post_ok\"",
            "\"snapshot\"", "\"verified\"", "\"tenants_rows\"", "\"tenant\"",
        ] {
            assert!(s.contains(key), "missing {key} in\n{s}");
        }
        assert!(s.contains("\"tenant_burst\": 50"), "{s}");
        assert!(s.contains("\"deadline_ms\": null"), "{s}");
        assert!(s.contains("\"t2\""), "last tenant row present:\n{s}");
        // max 1100 over mean 1000 → 1.1
        assert!(s.contains("\"shard_imbalance\": 1.1000"), "{s}");
        crate::obs::expo::check_json_shape(&s).expect("serve_json must be well-formed");
    }

    #[test]
    fn degenerate_serve_runs_are_refused() {
        // A zero-request run is refused before anything is built.
        let msg = degenerate_serve_reason(0, None).expect("requests=0");
        assert!(msg.contains("zero requests"), "{msg}");
        // Pre-flight pass with requests > 0 and no stats yet: no objection.
        assert_eq!(degenerate_serve_reason(200, None), None);
        // Healthy post-run report: no objection.
        assert_eq!(degenerate_serve_reason(200, Some(&serve_stats(180, 20, 950.0))), None);
        // Every request shed or failed → refuse.
        let msg = degenerate_serve_reason(200, Some(&serve_stats(0, 200, 0.0))).expect("ok=0");
        assert!(msg.contains("no request was served"), "{msg}");
        // NaN/zero QPS means the clock never ran → refuse.
        let all_ok = serve_stats(200, 0, f64::NAN);
        assert!(degenerate_serve_reason(200, Some(&all_ok)).is_some());
    }

    fn decode_report(rows: Vec<experiments::DecodeRow>) -> experiments::DecodeReport {
        experiments::DecodeReport {
            universe: 100_000,
            lists: 8,
            reps: 2,
            simd_level: "avx2",
            rows,
            adc_m: 8,
            adc_ksub: 256,
            adc: experiments::KernelThroughput {
                items: 1600,
                scalar_per_s: 1e8,
                simd_per_s: 3e8,
            },
            coarse_k: 64,
            coarse_dim: 16,
            coarse: experiments::KernelThroughput {
                items: 64,
                scalar_per_s: 2e7,
                simd_per_s: 5e7,
            },
        }
    }

    fn decode_row(codec: &str, len: usize, ids_per_s: f64) -> experiments::DecodeRow {
        experiments::DecodeRow {
            codec: codec.into(),
            list_len: len,
            lists: 8,
            bits_per_id: 17.0,
            ids_per_s,
            mb_per_s: ids_per_s * 17.0 / 8.0 / 1e6,
        }
    }

    #[test]
    fn decode_json_contract() {
        let rep = decode_report(vec![
            decode_row("roc", 1024, 1.5e7),
            decode_row("ans-i4", 1024, 6.0e7),
        ]);
        let s = decode_json(&rep, 42);
        for key in [
            "\"bench\"", "\"decode\"", "\"universe\"", "\"lists\"", "\"reps\"", "\"seed\"",
            "\"simd_level\"", "\"results\"", "\"codec\"", "\"list_len\"", "\"bits_per_id\"",
            "\"ids_per_s\"", "\"mb_per_s\"", "\"adc\"", "\"codes_per_s_scalar\"",
            "\"codes_per_s_simd\"", "\"coarse\"", "\"rows_per_s_scalar\"",
            "\"rows_per_s_simd\"",
        ] {
            assert!(s.contains(key), "missing {key} in\n{s}");
        }
        assert!(s.contains("\"ans-i4\""), "interleaved family must appear:\n{s}");
        crate::obs::expo::check_json_shape(&s).expect("decode_json must be well-formed");
    }

    #[test]
    fn degenerate_decode_runs_are_refused() {
        // Healthy report → no objection (len-0 rows are fine alongside
        // real ones: the property suite covers empty lists, the bench
        // only needs nonzero total work).
        let ok = decode_report(vec![decode_row("roc", 0, 0.0), decode_row("roc", 64, 1e7)]);
        assert_eq!(degenerate_decode_reason(&ok), None);
        // No rows, a zero-item run, or a zero-throughput row must all be
        // named explicitly instead of landing in BENCH_decode.json.
        let msg = degenerate_decode_reason(&decode_report(vec![])).expect("no rows");
        assert!(msg.contains("no codec rows"), "{msg}");
        let msg = degenerate_decode_reason(&decode_report(vec![decode_row("roc", 0, 0.0)]))
            .expect("zero items");
        assert!(msg.contains("zero-item"), "{msg}");
        let msg = degenerate_decode_reason(&decode_report(vec![decode_row("ef", 64, 0.0)]))
            .expect("zero throughput");
        assert!(msg.contains("ids_per_s"), "{msg}");
        let mut bad = decode_report(vec![decode_row("roc", 64, 1e7)]);
        bad.adc.simd_per_s = 0.0;
        assert!(degenerate_decode_reason(&bad).unwrap().contains("ADC"));
    }

    fn recall_point(backend: &'static str, r10: f64, qps: f64) -> recall::RecallPoint {
        recall::RecallPoint {
            backend,
            codec: "roc".into(),
            knob: 16,
            recall_at_1: r10.min(1.0),
            recall_at_10: r10,
            nn_recall_at_10: r10.min(1.0),
            qps,
            mean_ms: 0.5,
            p50_ms: 0.4,
            p95_ms: 0.9,
            bits_per_id: 12.5,
            lossless_ids: true,
        }
    }

    fn recall_report(points: Vec<recall::RecallPoint>) -> recall::RecallReport {
        recall::RecallReport {
            dataset: "deep-like",
            n: 3000,
            nq: 80,
            dim: 16,
            seed: 42,
            clusters: 32,
            topk: 10,
            churn_frac: 0.2,
            corrupt_ids: false,
            env: recall::EnvManifest {
                rustc: "rustc 1.76.0 (07dca489a 2024-02-04)",
                pkg_version: "0.1.0",
                target_arch: "x86_64",
                simd_level: "avx2",
                simd_override: "auto".into(),
                threads: 8,
            },
            points,
        }
    }

    #[test]
    fn recall_json_contract() {
        let rep = recall_report(vec![
            recall_point("ivf", 0.98, 1200.0),
            recall_point("dynamic", 0.97, 900.0),
        ]);
        let s = recall_json(&rep);
        for key in [
            "\"bench\"", "\"recall\"", "\"dataset\"", "\"n\"", "\"nq\"", "\"dim\"",
            "\"seed\"", "\"clusters\"", "\"topk\"", "\"churn_frac\"", "\"corrupt_ids\"",
            "\"env\"", "\"rustc\"", "\"pkg_version\"", "\"target_arch\"", "\"simd_level\"",
            "\"simd_override\"", "\"threads\"", "\"results\"", "\"backend\"", "\"codec\"",
            "\"knob\"", "\"recall_at_1\"", "\"recall_at_10\"", "\"nn_recall_at_10\"",
            "\"qps\"", "\"mean_ms\"", "\"p50_ms\"", "\"p95_ms\"", "\"bits_per_id\"",
            "\"lossless_ids\"",
        ] {
            assert!(s.contains(key), "missing {key} in\n{s}");
        }
        assert!(s.contains("\"dynamic\""), "dynamic backend row must appear:\n{s}");
        assert!(s.contains("\"corrupt_ids\": false"), "{s}");
        crate::obs::expo::check_json_shape(&s).expect("recall_json must be well-formed");
    }

    #[test]
    fn degenerate_recall_runs_are_refused() {
        let ok = vec![recall_point("ivf", 0.98, 1200.0)];
        assert_eq!(degenerate_recall_reason(80, Some(&ok)), None);
        // The pre-sweep check only objects to nq=0.
        assert_eq!(degenerate_recall_reason(80, None), None);
        let msg = degenerate_recall_reason(0, None).expect("nq=0");
        assert!(msg.contains("zero queries"), "{msg}");
        let msg = degenerate_recall_reason(80, Some(&[])).expect("no rows");
        assert!(msg.contains("no result rows"), "{msg}");
        let msg = degenerate_recall_reason(80, Some(&[recall_point("ivf", 0.98, 0.0)]))
            .expect("qps=0");
        assert!(msg.contains("qps=0"), "{msg}");
        let msg = degenerate_recall_reason(80, Some(&[recall_point("ivf", f64::NAN, 10.0)]))
            .expect("NaN recall");
        assert!(msg.contains("recall_at_"), "{msg}");
        let msg = degenerate_recall_reason(80, Some(&[recall_point("ivf", 1.5, 10.0)]))
            .expect("recall > 1");
        assert!(msg.contains("outside [0, 1]"), "{msg}");
    }

    #[test]
    fn churn_json_contract() {
        let rep = experiments::ChurnReport {
            dataset: "deep-like",
            n0: 1000,
            inserts: 200,
            deletes: 200,
            dim: 8,
            k: 16,
            codec: "roc".into(),
            seed: 42,
            nq: 25,
            insert_per_s: 123456.0,
            delete_per_s: 654321.0,
            compact_secs: 0.25,
            segments_before_compact: 3,
            pre_compact_bits_per_id: 10.5,
            bits_per_id_dynamic: 8.01,
            bits_per_id_static: 8.0,
            queries_identical: 25,
        };
        let s = churn_json(&rep);
        for key in [
            "\"bench\"",
            "\"churn\"",
            "\"dataset\"",
            "\"n\"",
            "\"inserts\"",
            "\"deletes\"",
            "\"dim\"",
            "\"k\"",
            "\"codec\"",
            "\"seed\"",
            "\"nq\"",
            "\"insert_per_s\"",
            "\"delete_per_s\"",
            "\"compact_s\"",
            "\"segments_before_compact\"",
            "\"pre_compact_bits_per_id\"",
            "\"bits_per_id_dynamic\"",
            "\"bits_per_id_static\"",
            "\"bpi_ratio\"",
            "\"queries_identical\"",
            "\"results_identical\"",
        ] {
            assert!(s.contains(key), "missing {key} in\n{s}");
        }
        assert!(s.contains("\"results_identical\": true"), "{s}");
        crate::obs::expo::check_json_shape(&s).expect("churn_json must be well-formed");
        let partial = experiments::ChurnReport { queries_identical: 24, ..rep };
        assert!(churn_json(&partial).contains("\"results_identical\": false"));
        assert!((partial.bpi_ratio() - 8.01 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn obs_json_contract() {
        let rep = ObsReport {
            dataset: "deep-like".into(),
            n: 4000,
            nq: 128,
            dim: 16,
            seed: 42,
            k: 64,
            nprobe: 8,
            runs: 3,
            env: recall::EnvManifest::capture(2),
            wall_off_s: 0.5,
            wall_on_s: 0.51,
            overhead_frac: 0.02,
            sampled_spans: 128,
            span_sum_ratio: 1.0,
            registry_series: 37,
            stage_mean_us: vec![("queue_wait", 12.5), ("adc_scan", 80.0), ("reply", 1.25)],
        };
        let s = obs_json(&rep);
        for key in [
            "\"bench\"", "\"obs\"", "\"dataset\"", "\"n\"", "\"nq\"", "\"dim\"", "\"seed\"",
            "\"k\"", "\"nprobe\"", "\"runs\"", "\"env\"", "\"rustc\"", "\"wall_off_s\"",
            "\"wall_on_s\"", "\"overhead_frac\"", "\"sampled_spans\"", "\"span_sum_ratio\"",
            "\"registry_series\"", "\"stages\"", "\"stage\"", "\"mean_us\"",
        ] {
            assert!(s.contains(key), "missing {key} in\n{s}");
        }
        assert!(s.contains("\"overhead_frac\": 0.020000"), "{s}");
        assert!(s.contains("\"adc_scan\""), "stage rows carry the stage name:\n{s}");
        crate::obs::expo::check_json_shape(&s).expect("obs_json must be well-formed");
    }

    #[test]
    fn degenerate_obs_runs_are_refused() {
        if !crate::obs::enabled() {
            // Every run is degenerate without the feature; the reason
            // must say so instead of pretending a measurement happened.
            let msg = degenerate_obs_reason(128, 0.5, 0.5, 1.0).expect("obs off");
            assert!(msg.contains("obs"), "{msg}");
            return;
        }
        // Healthy run → no objection (slightly negative overhead is
        // measurement noise, not degeneracy).
        assert_eq!(degenerate_obs_reason(128, 0.5, 0.49, 1.0), None);
        let msg = degenerate_obs_reason(0, 0.5, 0.5, 1.0).expect("no spans");
        assert!(msg.contains("zero spans"), "{msg}");
        let msg = degenerate_obs_reason(128, 0.0, 0.5, 1.0).expect("no clock");
        assert!(msg.contains("wall times"), "{msg}");
        assert!(degenerate_obs_reason(128, f64::NAN, 0.5, 1.0).is_some());
        // Stage timelines failing to account for e2e latency means the
        // tracer's residual bookkeeping is broken.
        let msg = degenerate_obs_reason(128, 0.5, 0.5, 0.4).expect("bad accounting");
        assert!(msg.contains("stage-sum"), "{msg}");
        assert!(degenerate_obs_reason(128, 0.5, 0.5, 1.5).is_some());
    }
}
