//! Parameterized reproductions of every table and figure in the paper's
//! evaluation (§5). Benches call these at full scale; unit tests smoke
//! them at tiny scale.

use crate::codecs::rec::{Rec, RecModel};
use crate::codecs::zuckerli::Zuckerli;
use crate::datasets::{generate, Dataset, Kind};
use crate::graph::nsg::{Nsg, NsgParams};
use crate::graph::GraphStore;
use crate::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch, VectorMode};
use crate::util::pool::default_threads;
use std::collections::BTreeMap;
use std::time::Instant;

/// Common experiment scale knobs.
#[derive(Clone)]
pub struct Scale {
    pub n: usize,
    pub nq: usize,
    pub dim: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for Scale {
    fn default() -> Self {
        // Default bench scale: N=1e5 (paper: 1e6). Bits/id for ROC/EF
        // depend on N/K only, so the Table-1 columns stay comparable;
        // pass --full to benches for the 1e6 run.
        Scale { n: 100_000, nq: 10_000, dim: 32, seed: 42, threads: default_threads() }
    }
}

/// The paper's IVF sweep.
pub const IVF_KS: [usize; 4] = [256, 512, 1024, 2048];
/// The paper's NSG degree sweep.
pub const NSG_RS: [usize; 5] = [16, 32, 64, 128, 256];
/// Table-1 codec columns.
pub const T1_CODECS: [&str; 6] = ["unc64", "compact", "ef", "wt", "wt1", "roc"];

/// One Table-1 IVF cell: bits/id for (dataset, K, codec).
pub struct T1IvfRow {
    pub dataset: &'static str,
    pub k: usize,
    /// codec name → bits per id.
    pub bpe: BTreeMap<String, f64>,
}

/// Table 1 (IVF rows): compression in bits-per-id, Flat quantizer.
pub fn table1_ivf(scale: &Scale, kind: Kind, ks: &[usize], codecs: &[&str]) -> Vec<T1IvfRow> {
    let ds = generate(kind, scale.n, 1, scale.dim, scale.seed);
    let mut out = Vec::new();
    for &k in ks {
        // Cluster once per K; re-encode ids per codec over the same lists.
        let base = IvfBuildParams {
            k,
            id_codec: "unc32".into(),
            threads: scale.threads,
            seed: scale.seed,
            ..Default::default()
        };
        let cents = crate::quant::kmeans::train(
            &ds.data,
            ds.dim,
            &crate::quant::kmeans::KmeansConfig {
                k,
                iters: base.train_iters,
                seed: base.seed,
                threads: scale.threads,
                ..Default::default()
            },
        );
        let kk = cents.len() / ds.dim;
        let assign = crate::quant::kmeans::assign(&ds.data, ds.dim, &cents, scale.threads);
        let mut bpe = BTreeMap::new();
        for &codec in codecs {
            let params = IvfBuildParams { id_codec: codec.into(), ..clone_params(&base) };
            let idx = IvfIndex::build_preassigned(&ds.data, ds.dim, &cents, &assign, &params, kk);
            bpe.insert(codec.to_string(), idx.bits_per_id());
        }
        out.push(T1IvfRow { dataset: kind.name(), k, bpe });
    }
    out
}

fn clone_params(p: &IvfBuildParams) -> IvfBuildParams {
    IvfBuildParams {
        k: p.k,
        train_iters: p.train_iters,
        seed: p.seed,
        threads: p.threads,
        id_codec: p.id_codec.clone(),
        vectors: p.vectors.clone(),
    }
}

/// Table 1 (NSG rows): bits-per-edge-id for per-node friend-list streams.
pub struct T1NsgRow {
    pub dataset: &'static str,
    pub r: usize,
    pub bpe: BTreeMap<String, f64>,
    /// The built graph, reusable by Table 3.
    pub adj: Vec<Vec<u32>>,
}

pub fn table1_nsg(scale: &Scale, kind: Kind, rs: &[usize], codecs: &[&str]) -> Vec<T1NsgRow> {
    // NSG construction is O(n · candidates · r · d); cap the graph-bench
    // scale (bits/edge depends on log N and the degree profile, both of
    // which are stable under this cap — see DESIGN.md).
    let n = scale.n.min(50_000);
    let ds = generate(kind, n, 1, scale.dim, scale.seed);
    let knn_k = rs.iter().copied().max().unwrap_or(48).max(48);
    let knn = crate::graph::knn::build(&ds.data, ds.dim, knn_k, scale.threads, scale.seed);
    let mut out = Vec::new();
    for &r in rs {
        let nsg = Nsg::build_from_knn(
            &ds.data,
            ds.dim,
            &knn,
            &NsgParams { r, knn_k, threads: scale.threads, seed: scale.seed, ..Default::default() },
        );
        let mut bpe = BTreeMap::new();
        for &codec in codecs {
            if codec == "wt" || codec == "wt1" {
                continue; // "The Wavelet Tree was not implemented for NSG."
            }
            let store = GraphStore::compress(&nsg.adj, codec);
            bpe.insert(codec.to_string(), store.bits_per_edge());
        }
        bpe.insert("unc32".into(), 32.0);
        out.push(T1NsgRow { dataset: kind.name(), r, bpe, adj: nsg.adj });
    }
    out
}

/// Table 2: median search wall-time over the query batch.
pub struct T2Row {
    pub dataset: &'static str,
    pub label: String,
    /// codec → seconds to search the whole query batch.
    pub secs: BTreeMap<String, f64>,
}

/// Search `queries` through an index, batched like the paper (parallel
/// over queries), returning wall seconds.
pub fn timed_ivf_search(
    idx: &IvfIndex,
    ds: &Dataset,
    sp: &SearchParams,
    threads: usize,
    runs: usize,
) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        let _results = crate::util::pool::parallel_map(ds.nq, threads, |qi| {
            thread_local! {
                static SCRATCH: std::cell::RefCell<SearchScratch> =
                    std::cell::RefCell::new(SearchScratch::default());
            }
            SCRATCH.with(|s| idx.search(ds.query(qi), sp, &mut s.borrow_mut()).len())
        });
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Table 2 IVF rows (+ PQ rows) for one dataset.
/// Cluster once, then build one index per codec over the same assignment
/// (clustering dominates build time; codecs only re-encode the id lists).
fn indexes_per_codec(
    ds: &Dataset,
    k: usize,
    mode: &VectorMode,
    codecs: &[&str],
    threads: usize,
    seed: u64,
) -> Vec<(String, IvfIndex)> {
    let cents = crate::quant::kmeans::train(
        &ds.data,
        ds.dim,
        &crate::quant::kmeans::KmeansConfig {
            k,
            iters: 8,
            seed,
            threads,
            ..Default::default()
        },
    );
    let kk = cents.len() / ds.dim;
    let assign = crate::quant::kmeans::assign(&ds.data, ds.dim, &cents, threads);
    codecs
        .iter()
        .map(|&codec| {
            let idx = IvfIndex::build_preassigned(
                &ds.data,
                ds.dim,
                &cents,
                &assign,
                &IvfBuildParams {
                    k: kk,
                    id_codec: codec.into(),
                    vectors: mode.clone(),
                    threads,
                    seed,
                    ..Default::default()
                },
                kk,
            );
            (codec.to_string(), idx)
        })
        .collect()
}

pub fn table2_ivf(
    scale: &Scale,
    kind: Kind,
    ks: &[usize],
    pq_variants: &[(&str, VectorMode)],
    codecs: &[&str],
    runs: usize,
) -> Vec<T2Row> {
    let ds = generate(kind, scale.n, scale.nq, scale.dim, scale.seed);
    let sp = SearchParams { nprobe: 16, k: 10 };
    let mut out = Vec::new();
    for &k in ks {
        let mut secs = BTreeMap::new();
        for (codec, idx) in
            indexes_per_codec(&ds, k, &VectorMode::Flat, codecs, scale.threads, scale.seed)
        {
            secs.insert(codec, timed_ivf_search(&idx, &ds, &sp, scale.threads, runs));
        }
        out.push(T2Row { dataset: kind.name(), label: format!("IVF{k}"), secs });
    }
    for (label, mode) in pq_variants {
        let mut secs = BTreeMap::new();
        for (codec, idx) in indexes_per_codec(&ds, 1024, mode, codecs, scale.threads, scale.seed)
        {
            secs.insert(codec, timed_ivf_search(&idx, &ds, &sp, scale.threads, runs));
        }
        out.push(T2Row { dataset: kind.name(), label: label.to_string(), secs });
    }
    out
}

/// Table 2 NSG rows: timed beam search over compressed adjacency.
pub fn table2_nsg(
    scale: &Scale,
    kind: Kind,
    rs: &[usize],
    codecs: &[&str],
    runs: usize,
) -> Vec<T2Row> {
    let n = scale.n.min(50_000); // see table1_nsg
    let ds = generate(kind, n, scale.nq, scale.dim, scale.seed);
    let knn_k = rs.iter().copied().max().unwrap_or(48).max(48);
    let knn = crate::graph::knn::build(&ds.data, ds.dim, knn_k, scale.threads, scale.seed);
    let mut out = Vec::new();
    for &r in rs {
        let nsg = Nsg::build_from_knn(
            &ds.data,
            ds.dim,
            &knn,
            &NsgParams { r, knn_k, threads: scale.threads, seed: scale.seed, ..Default::default() },
        );
        let mut secs = BTreeMap::new();
        for &codec in codecs {
            let store = if codec == "unc32" || codec == "unc64" {
                GraphStore::Raw(nsg.adj.clone())
            } else {
                GraphStore::compress(&nsg.adj, codec)
            };
            let mut best = f64::INFINITY;
            for _ in 0..runs.max(1) {
                let t0 = Instant::now();
                crate::util::pool::parallel_chunks(ds.nq, scale.threads, |_, range| {
                    let mut visited = crate::graph::VisitedSet::default();
                    let mut scratch = Vec::new();
                    for qi in range {
                        let _ = nsg.search_store(
                            &store,
                            &ds.data,
                            ds.query(qi),
                            16, // paper: "number of nodes to explore ... 16"
                            10,
                            &mut visited,
                            &mut scratch,
                        );
                    }
                });
                best = best.min(t0.elapsed().as_secs_f64());
            }
            secs.insert(codec.to_string(), best);
        }
        out.push(T2Row { dataset: kind.name(), label: format!("NSG{r}"), secs });
    }
    out
}

/// Table 3: offline whole-graph compression, bits/edge, REC vs Zuckerli.
pub struct T3Row {
    pub dataset: &'static str,
    pub label: String,
    pub zuckerli: f64,
    pub rec: f64,
    pub rec_uniform: f64,
}

pub fn table3_for_graph(dataset: &'static str, label: String, adj: &[Vec<u32>]) -> T3Row {
    let e: u64 = adj.iter().map(|l| l.len() as u64).sum();
    let z = Zuckerli::default().encode_graph(adj).bits as f64 / e as f64;
    let rec = Rec::new(RecModel::PolyaUrn).encode_graph(adj).bits as f64 / e as f64;
    let rec_uniform = Rec::new(RecModel::Uniform).encode_graph(adj).bits as f64 / e as f64;
    T3Row { dataset, label, zuckerli: z, rec, rec_uniform }
}

/// Figure 2: slowdown of compressed ids relative to Unc. as PQ dim grows.
pub struct Fig2Point {
    pub pq_label: String,
    /// codec → slowdown factor (time / unc64 time).
    pub slowdown: BTreeMap<String, f64>,
}

pub fn fig2(scale: &Scale, kind: Kind, codecs: &[&str], runs: usize) -> Vec<Fig2Point> {
    let variants: Vec<(String, VectorMode)> = [4usize, 8, 16, 32]
        .iter()
        .map(|&m| (format!("PQ{m}"), VectorMode::Pq { m, bits: 8 }))
        .collect();
    let ds = generate(kind, scale.n, scale.nq, scale.dim, scale.seed);
    let sp = SearchParams { nprobe: 16, k: 10 };
    let mut out = Vec::new();
    for (label, mode) in variants {
        let mut all: Vec<&str> = codecs.to_vec();
        if !all.contains(&"unc64") {
            all.push("unc64");
        }
        let mut times = BTreeMap::new();
        for (codec, idx) in indexes_per_codec(&ds, 1024, &mode, &all, scale.threads, scale.seed) {
            times.insert(codec, timed_ivf_search(&idx, &ds, &sp, scale.threads, runs));
        }
        let base = times["unc64"];
        let slowdown =
            times.into_iter().map(|(c, t)| (c, t / base)).collect::<BTreeMap<_, _>>();
        out.push(Fig2Point { pq_label: label, slowdown });
    }
    out
}

/// Figure 3: bits/element of cluster-conditioned PQ codes (8 uncompressed).
pub struct Fig3Point {
    pub dataset: &'static str,
    pub pq_label: String,
    pub bits_per_element: f64,
}

pub fn fig3(scale: &Scale, kind: Kind, ms: &[usize]) -> Vec<Fig3Point> {
    let ds = generate(kind, scale.n, 1, scale.dim, scale.seed);
    let mut out = Vec::new();
    for &m in ms {
        let idx = IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams {
                k: 1024,
                id_codec: "compact".into(),
                vectors: VectorMode::PqCompressed { m, bits: 8 },
                threads: scale.threads,
                seed: scale.seed,
                ..Default::default()
            },
        );
        let elements = (idx.n * m) as f64;
        out.push(Fig3Point {
            dataset: kind.name(),
            pq_label: format!("PQ{m}"),
            bits_per_element: idx.code_bits() as f64 / elements,
        });
    }
    out
}

/// One row of the search-throughput bench: a (backend, spec, nprobe,
/// threads) cell with QPS and per-query latency percentiles.
pub struct QpsRow {
    /// Index family serving the row: `ivf`, `nsg` or `hnsw`.
    pub backend: String,
    pub codec: String,
    /// The swept breadth knob: IVF probes, or the graph beam width `ef`.
    pub nprobe: usize,
    pub threads: usize,
    pub qps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

/// Resolve a QPS-bench codec spec: plain per-list/wavelet names select
/// Flat vector storage under that id codec; `pq` / `pq-compressed` select
/// the PQ stores (over compact ids) so the bench covers every scan kind.
pub fn qps_variant(spec: &str) -> (String, VectorMode) {
    match spec {
        "pq" => ("compact".into(), VectorMode::Pq { m: 8, bits: 8 }),
        "pq-compressed" | "pqc" => ("compact".into(), VectorMode::PqCompressed { m: 8, bits: 8 }),
        name => (name.into(), VectorMode::Flat),
    }
}

/// A parsed `--codecs` entry: either an IVF store selector or a graph
/// backend (`nsg[:codec]` / `hnsw[:codec]`, defaulting to ROC links).
enum QpsBackend {
    Ivf { id_codec: String, vectors: VectorMode },
    Graph { family: &'static str, codec: String },
}

fn parse_qps_spec(spec: &str) -> anyhow::Result<QpsBackend> {
    use crate::codecs::CodecSpec;
    let graph = |family: &'static str, codec: &str| -> anyhow::Result<QpsBackend> {
        let parsed = CodecSpec::parse(codec)?;
        anyhow::ensure!(
            parsed.is_per_list(),
            "graph backends store per-node streams; {:?} is not a per-list codec",
            parsed.name()
        );
        Ok(QpsBackend::Graph { family, codec: parsed.name().to_string() })
    };
    match spec.split_once(':') {
        Some(("nsg", codec)) => graph("nsg", codec),
        Some(("hnsw", codec)) => graph("hnsw", codec),
        Some((family, _)) => anyhow::bail!(
            "unknown backend {family:?}; valid specs: a codec name \
             ({}), pq, pq-compressed, nsg[:codec], hnsw[:codec]",
            CodecSpec::VALID.join(", ")
        ),
        None => match spec {
            "nsg" => graph("nsg", "roc"),
            "hnsw" => graph("hnsw", "roc"),
            "pq" | "pq-compressed" | "pqc" => {
                let (id_codec, vectors) = qps_variant(spec);
                Ok(QpsBackend::Ivf { id_codec, vectors })
            }
            name => {
                let parsed = CodecSpec::parse(name)?;
                anyhow::ensure!(
                    parsed.is_per_list() || matches!(parsed, CodecSpec::Wavelet(_)),
                    "codec {:?} is a whole-graph codec and has no IVF id store; \
                     use it through bench-table3",
                    parsed.name()
                );
                Ok(QpsBackend::Ivf {
                    id_codec: parsed.name().to_string(),
                    vectors: VectorMode::Flat,
                })
            }
        },
    }
}

/// Validate a QPS spec without building anything — CLI/bench boundaries
/// call this first so a typo prints the valid-name list instead of
/// panicking mid-sweep.
pub fn validate_qps_spec(spec: &str) -> anyhow::Result<()> {
    parse_qps_spec(spec).map(|_| ())
}

/// Graph construction cost is superlinear, so graph-backend QPS rows are
/// built over at most this many vectors (logged by the bench driver).
pub const QPS_GRAPH_N_CAP: usize = 20_000;

/// Search-throughput sweep: spec × nprobe/ef × threads over one dataset.
/// IVF specs share one coarse clustering; graph specs build over a capped
/// prefix of the same data. Every backend is driven through the
/// [`AnnIndex`] trait — the same generic path the coordinator serves —
/// using the shared [`crate::eval::workload::measure`] discipline
/// (per-worker scratch reuse, warm pass, best-of-`runs` wall clock).
pub fn search_qps(
    scale: &Scale,
    kind: Kind,
    specs: &[&str],
    k: usize,
    nprobes: &[usize],
    thread_counts: &[usize],
    runs: usize,
) -> anyhow::Result<Vec<QpsRow>> {
    use crate::api::{AnnIndex, GraphIndex, QueryParams};
    let ds = generate(kind, scale.n, scale.nq, scale.dim, scale.seed);
    // Shared coarse clustering, trained on first IVF spec.
    let mut shared: Option<(Vec<f32>, usize, Vec<u32>)> = None;
    let graph_n = scale.n.min(QPS_GRAPH_N_CAP);
    let mut out = Vec::new();
    for &spec in specs {
        let (backend, index): (&'static str, Box<dyn AnnIndex>) = match parse_qps_spec(spec)? {
            QpsBackend::Ivf { id_codec, vectors } => {
                let (cents, kk, assign) = shared.get_or_insert_with(|| {
                    let cents = crate::quant::kmeans::train(
                        &ds.data,
                        ds.dim,
                        &crate::quant::kmeans::KmeansConfig {
                            k,
                            iters: 8,
                            seed: scale.seed,
                            threads: scale.threads,
                            ..Default::default()
                        },
                    );
                    let kk = cents.len() / ds.dim;
                    let assign =
                        crate::quant::kmeans::assign(&ds.data, ds.dim, &cents, scale.threads);
                    (cents, kk, assign)
                });
                let idx = IvfIndex::build_preassigned(
                    &ds.data,
                    ds.dim,
                    cents,
                    assign,
                    &IvfBuildParams {
                        k: *kk,
                        id_codec,
                        vectors,
                        threads: scale.threads,
                        seed: scale.seed,
                        ..Default::default()
                    },
                    *kk,
                );
                ("ivf", Box::new(idx) as Box<dyn AnnIndex>)
            }
            QpsBackend::Graph { family, codec } => {
                let data = &ds.data[..graph_n * ds.dim];
                if family == "nsg" {
                    let nsg = Nsg::build(
                        data,
                        ds.dim,
                        &NsgParams {
                            r: 32,
                            knn_k: 48,
                            threads: scale.threads,
                            seed: scale.seed,
                            ..Default::default()
                        },
                    );
                    ("nsg", Box::new(GraphIndex::from_nsg(&nsg, data, &codec)?))
                } else {
                    use crate::graph::hnsw::{Hnsw, HnswParams};
                    let h = Hnsw::build(
                        data,
                        ds.dim,
                        &HnswParams { m: 16, ef_construction: 100, seed: scale.seed },
                    );
                    ("hnsw", Box::new(GraphIndex::from_hnsw(&h, data, &codec)?))
                }
            }
        };
        for &nprobe in nprobes {
            for &threads in thread_counts {
                // The swept value drives IVF probes and the graph beam
                // width alike; each backend reads its own knob. Graph
                // backends clamp ef to at least k internally (a beam
                // must hold k results), so rows below ef=k coincide —
                // the standard ef ≥ k rule, documented in REPRODUCING.
                let sp = QueryParams { k: 10, nprobe, ef: nprobe };
                let m = crate::eval::workload::measure(
                    &*index, &ds.queries, ds.dim, ds.nq, &sp, threads, runs,
                );
                out.push(QpsRow {
                    backend: backend.to_string(),
                    codec: spec.to_string(),
                    nprobe,
                    threads,
                    qps: m.qps,
                    mean_ms: m.mean_ms,
                    p50_ms: m.p50_ms,
                    p95_ms: m.p95_ms,
                });
            }
        }
    }
    Ok(out)
}

/// Churn-bench report: live-mutation throughput and post-compaction
/// compression of a [`crate::dynamic::DynamicIvf`] against a
/// from-scratch static build over the same live set (what
/// `BENCH_churn.json` serializes).
pub struct ChurnReport {
    pub dataset: &'static str,
    /// Initial build size; `deletes` ids are tombstoned, then `inserts`
    /// fresh vectors are added, then the index is fully compacted.
    pub n0: usize,
    pub inserts: usize,
    pub deletes: usize,
    pub dim: usize,
    pub k: usize,
    pub codec: String,
    pub seed: u64,
    pub nq: usize,
    pub insert_per_s: f64,
    pub delete_per_s: f64,
    pub compact_secs: f64,
    /// Segments + (non-empty) write buffer right before the compaction.
    pub segments_before_compact: usize,
    pub pre_compact_bits_per_id: f64,
    pub bits_per_id_dynamic: f64,
    pub bits_per_id_static: f64,
    /// Queries (out of `nq`) whose results matched the static rebuild
    /// exactly.
    pub queries_identical: usize,
}

impl ChurnReport {
    /// Post-compaction compression relative to the static build
    /// (1.0 = no decay under churn; the PR acceptance bound is 1.02).
    pub fn bpi_ratio(&self) -> f64 {
        self.bits_per_id_dynamic / self.bits_per_id_static.max(f64::MIN_POSITIVE)
    }

    pub fn results_identical(&self) -> bool {
        self.queries_identical == self.nq
    }
}

/// The churn experiment behind `bench-churn`: build, delete
/// `churn_frac·n` random ids, insert `churn_frac·n` fresh vectors
/// (timed, through the auto flush policy), compact, then audit search
/// parity and bits/id against a fresh static build over the live set.
pub fn churn(
    scale: &Scale,
    kind: Kind,
    codec: &str,
    k: usize,
    churn_frac: f64,
    nprobe: usize,
) -> anyhow::Result<ChurnReport> {
    use crate::dynamic::{CompactionPolicy, DynamicBuildParams, DynamicIvf};
    let n0 = scale.n;
    let moved = ((n0 as f64) * churn_frac).round().max(1.0) as usize;
    let ds = generate(kind, n0 + moved, scale.nq, scale.dim, scale.seed);
    // Auto *flush* stays on (sealing segments is part of the ingest path
    // being measured) but threshold-triggered full compaction is
    // disabled, so the timed delete/insert loops never hide a compaction
    // inside them and compact_s measures the one explicit call below —
    // otherwise any --churn above max_dead_frac would corrupt
    // delete_per_s and report compact_s for a near-no-op.
    let mut idx = DynamicIvf::build(
        &ds.data[..n0 * scale.dim],
        scale.dim,
        &DynamicBuildParams {
            ivf: IvfBuildParams {
                k,
                id_codec: codec.into(),
                threads: scale.threads,
                seed: scale.seed,
                ..Default::default()
            },
            policy: CompactionPolicy {
                max_segments: usize::MAX,
                max_dead_frac: 1.0,
                ..Default::default()
            },
        },
    )?;

    let mut rng = crate::util::Rng::new(scale.seed ^ 0xc0ffee);
    let victims = rng.sample_distinct(n0 as u64, moved.min(n0));
    let t0 = Instant::now();
    for &id in &victims {
        idx.delete(id as u32)?;
    }
    let delete_secs = t0.elapsed().as_secs_f64();

    // Incremental ingest in serving-sized batches (assignment is
    // amortized per batch; the auto policy seals segments as it goes).
    let batch = 512 * scale.dim;
    let t0 = Instant::now();
    for chunk in ds.data[n0 * scale.dim..].chunks(batch) {
        idx.add(chunk)?;
    }
    let insert_secs = t0.elapsed().as_secs_f64();

    let pre_compact_bits_per_id = idx.bits_per_id();
    let segments_before_compact = idx.num_segments() + usize::from(idx.buffer_rows() > 0);
    let t0 = Instant::now();
    idx.compact()?;
    let compact_secs = t0.elapsed().as_secs_f64();

    let parity = idx.check_parity(&ds.queries, &SearchParams { nprobe, k: 10 })?;
    Ok(ChurnReport {
        dataset: kind.name(),
        n0,
        inserts: moved,
        deletes: victims.len(),
        dim: scale.dim,
        k,
        codec: codec.to_string(),
        seed: scale.seed,
        nq: parity.queries,
        insert_per_s: moved as f64 / insert_secs.max(1e-12),
        delete_per_s: victims.len() as f64 / delete_secs.max(1e-12),
        compact_secs,
        segments_before_compact,
        pre_compact_bits_per_id,
        bits_per_id_dynamic: parity.dynamic_bits_per_id,
        bits_per_id_static: parity.static_bits_per_id,
        queries_identical: parity.identical,
    })
}

/// Table 4 (scaled): large-N IVF-PQ with K=2^14 clusters standing in for
/// the paper's 1B / 2^20 setup. Reports bits/id + batch search seconds.
pub struct T4Row {
    pub codec: String,
    pub bits_per_id: f64,
    pub search_secs: f64,
    pub recall_at_10: f64,
}

pub fn table4(n: usize, nq: usize, dim: usize, k: usize, threads: usize, seed: u64) -> Vec<T4Row> {
    let ds = generate(Kind::DeepLike, n, nq, dim, seed);
    // One shared clustering.
    let cents = crate::quant::kmeans::train(
        &ds.data,
        dim,
        &crate::quant::kmeans::KmeansConfig {
            k,
            iters: 6,
            seed,
            threads,
            max_points: 1 << 17,
        },
    );
    let kk = cents.len() / dim;
    let assign = crate::quant::kmeans::assign(&ds.data, dim, &cents, threads);
    let gt = crate::datasets::groundtruth::exact_knn(
        &ds.data,
        &ds.queries[..dim * nq.min(200)],
        dim,
        10,
        threads,
    );
    let sp = SearchParams { nprobe: 128.min(kk), k: 10 };
    let mut out = Vec::new();
    for codec in ["unc64", "compact", "ef", "roc"] {
        let idx = IvfIndex::build_preassigned(
            &ds.data,
            dim,
            &cents,
            &assign,
            &IvfBuildParams {
                k: kk,
                id_codec: codec.into(),
                vectors: VectorMode::Pq { m: 8, bits: 8 },
                threads,
                seed,
                ..Default::default()
            },
            kk,
        );
        let secs = timed_ivf_search(&idx, &ds, &sp, threads, 1);
        // recall on the gt subset
        let mut scratch = SearchScratch::default();
        let results: Vec<Vec<u32>> = (0..nq.min(200))
            .map(|qi| {
                idx.search(ds.query(qi), &sp, &mut scratch).into_iter().map(|(_, id)| id).collect()
            })
            .collect();
        let recall = crate::datasets::groundtruth::nn_recall_at_k(&gt, 10, &results, 10);
        out.push(T4Row {
            codec: codec.into(),
            bits_per_id: idx.bits_per_id(),
            search_secs: secs,
            recall_at_10: recall,
        });
    }
    out
}

/// One decode-throughput cell: a per-list codec at one list size.
pub struct DecodeRow {
    pub codec: String,
    pub list_len: usize,
    pub lists: usize,
    /// Exact compressed payload per id (the rate this throughput buys).
    pub bits_per_id: f64,
    /// Ids decoded per second through `decode_into` + `DecodeScratch`
    /// (best of `reps`).
    pub ids_per_s: f64,
    /// Compressed megabytes consumed per second over the same run.
    pub mb_per_s: f64,
}

/// Scalar-vs-dispatched throughput of one SIMD-backed kernel.
pub struct KernelThroughput {
    /// Work items per invocation set (codes for ADC, centroid rows for
    /// the coarse kernel).
    pub items: usize,
    pub scalar_per_s: f64,
    pub simd_per_s: f64,
}

/// The `bench-decode` report: per-codec decode throughput plus the two
/// scan kernels, scalar against the active dispatch level.
pub struct DecodeReport {
    pub universe: u32,
    pub lists: usize,
    pub reps: usize,
    pub simd_level: &'static str,
    pub rows: Vec<DecodeRow>,
    pub adc_m: usize,
    pub adc_ksub: usize,
    pub adc: KernelThroughput,
    pub coarse_k: usize,
    pub coarse_dim: usize,
    pub coarse: KernelThroughput,
}

impl DecodeReport {
    /// Total ids decoded across every codec row (the degenerate-run
    /// detector keys on this being nonzero).
    pub fn total_ids(&self) -> usize {
        self.rows.iter().map(|r| r.list_len * r.lists).sum()
    }
}

/// Codecs the decode table sweeps: exactly the per-list registry, so a
/// codec added there can never silently drop out of the throughput
/// trajectory.
pub const DECODE_CODECS: [&str; crate::codecs::PER_LIST_CODECS.len()] =
    crate::codecs::PER_LIST_CODECS;

/// Decode-and-scan throughput bench (`bench-decode` / `BENCH_decode.json`).
///
/// Per codec × list size: encode `lists` random id lists from
/// `[0, universe)`, then time the bulk decode through the same
/// `decode_into` + scratch path the search scan uses. The two scan
/// kernels (blocked PQ ADC, fused coarse) are each timed at
/// `Level::Scalar` and at the dispatched level, with the outputs
/// asserted bit-identical — the bench doubles as a dispatch-parity
/// check on whatever machine it runs on.
#[allow(clippy::too_many_arguments)]
pub fn decode_bench(
    universe: u32,
    list_lens: &[usize],
    lists: usize,
    reps: usize,
    seed: u64,
    adc_rows: usize,
    adc_m: usize,
    coarse_k: usize,
    coarse_dim: usize,
) -> anyhow::Result<DecodeReport> {
    use crate::codecs::{CodecSpec, DecodeScratch};
    use crate::simd;
    let mut rng = crate::util::Rng::new(seed);
    let reps = reps.max(1);
    let mut rows = Vec::new();
    for &len in list_lens {
        anyhow::ensure!(
            len as u64 <= universe as u64,
            "list length {len} exceeds universe {universe}"
        );
        let data: Vec<Vec<u32>> = (0..lists)
            .map(|_| {
                rng.sample_distinct(universe as u64, len).into_iter().map(|v| v as u32).collect()
            })
            .collect();
        for name in DECODE_CODECS {
            let codec = CodecSpec::parse(name)?.id_codec()?;
            let mut bits = 0u64;
            let mut bytes = 0usize;
            let blobs: Vec<Vec<u8>> = data
                .iter()
                .map(|l| {
                    let e = codec.encode(l, universe);
                    bits += e.bits;
                    bytes += e.bytes.len();
                    e.bytes
                })
                .collect();
            let mut scratch = DecodeScratch::default();
            let mut out = Vec::with_capacity(len);
            let mut best = f64::INFINITY;
            let mut decoded = 0usize;
            for _ in 0..reps {
                decoded = 0;
                let t0 = Instant::now();
                for blob in &blobs {
                    out.clear();
                    codec.decode_into(blob, universe, len, &mut out, &mut scratch);
                    decoded += out.len();
                }
                best = best.min(t0.elapsed().as_secs_f64()).max(1e-12);
            }
            debug_assert_eq!(decoded, len * lists);
            rows.push(DecodeRow {
                codec: name.to_string(),
                list_len: len,
                lists,
                bits_per_id: if decoded == 0 { 0.0 } else { bits as f64 / decoded as f64 },
                ids_per_s: decoded as f64 / best,
                mb_per_s: bytes as f64 / best / 1e6,
            });
        }
    }

    // Blocked ADC scan, scalar vs dispatched, outputs compared bitwise.
    let adc_ksub = 256usize;
    let adc_m = adc_m.max(1);
    let lut: Vec<f32> = (0..adc_m * adc_ksub).map(|_| rng.normal()).collect();
    let codes: Vec<u16> =
        (0..adc_rows * adc_m).map(|_| rng.below(adc_ksub as u64) as u16).collect();
    let mut scalar_out = vec![0f32; adc_rows];
    let mut simd_out = vec![0f32; adc_rows];
    let time_adc = |level: simd::Level, out: &mut [f32]| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            simd::adc::adc_scan_level(level, &lut, adc_ksub, adc_m, &codes, out);
            best = best.min(t0.elapsed().as_secs_f64()).max(1e-12);
        }
        best
    };
    let adc_scalar_t = time_adc(simd::Level::Scalar, &mut scalar_out);
    let adc_simd_t = time_adc(simd::level(), &mut simd_out);
    anyhow::ensure!(
        scalar_out.iter().zip(&simd_out).all(|(a, b)| a.to_bits() == b.to_bits()),
        "ADC kernel parity violation: {} output differs from scalar",
        simd::level().name()
    );
    let adc_codes = adc_rows * adc_m;
    let adc = KernelThroughput {
        items: adc_codes,
        scalar_per_s: adc_codes as f64 / adc_scalar_t,
        simd_per_s: adc_codes as f64 / adc_simd_t,
    };

    // Fused coarse kernel, scalar vs dispatched, bitwise-compared.
    let query: Vec<f32> = (0..coarse_dim).map(|_| rng.normal()).collect();
    let cents: Vec<f32> = (0..coarse_k * coarse_dim).map(|_| rng.normal()).collect();
    let norms = crate::quant::coarse::centroid_norms(&cents, coarse_dim);
    let mut scalar_d = vec![0f32; coarse_k];
    let mut simd_d = vec![0f32; coarse_k];
    let time_coarse = |level: simd::Level, out: &mut [f32]| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(3) {
            let t0 = Instant::now();
            simd::coarse::dists_into_level(level, &query, &cents, coarse_dim, &norms, out);
            best = best.min(t0.elapsed().as_secs_f64()).max(1e-12);
        }
        best
    };
    let coarse_scalar_t = time_coarse(simd::Level::Scalar, &mut scalar_d);
    let coarse_simd_t = time_coarse(simd::level(), &mut simd_d);
    anyhow::ensure!(
        scalar_d.iter().zip(&simd_d).all(|(a, b)| a.to_bits() == b.to_bits()),
        "coarse kernel parity violation: {} output differs from scalar",
        simd::level().name()
    );
    let coarse = KernelThroughput {
        items: coarse_k,
        scalar_per_s: coarse_k as f64 / coarse_scalar_t,
        simd_per_s: coarse_k as f64 / coarse_simd_t,
    };

    Ok(DecodeReport {
        universe,
        lists,
        reps,
        simd_level: simd::level().name(),
        rows,
        adc_m,
        adc_ksub,
        adc,
        coarse_k,
        coarse_dim,
        coarse,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scale {
        Scale { n: 3000, nq: 50, dim: 16, seed: 9, threads: 2 }
    }

    #[test]
    fn table1_ivf_smoke_shape() {
        let rows = table1_ivf(&tiny(), Kind::SiftLike, &[64], &["compact", "ef", "roc"]);
        assert_eq!(rows.len(), 1);
        let bpe = &rows[0].bpe;
        // compact = ceil(log2 3000) = 12; roc ≈ log2(64)+1.44+64/47 ≈ 8.8
        assert_eq!(bpe["compact"], 12.0);
        assert!(bpe["roc"] < bpe["compact"]);
        assert!(bpe["ef"] < bpe["compact"]);
        assert!((bpe["roc"] - (64f64.log2() + 1.44)).abs() < 1.6, "roc={}", bpe["roc"]);
    }

    #[test]
    fn table1_nsg_smoke_shape() {
        let rows = table1_nsg(&tiny(), Kind::DeepLike, &[16], &["compact", "ef", "roc"]);
        let bpe = &rows[0].bpe;
        // Short friend lists: ROC must be near/above compact (initial bits).
        assert!(bpe["roc"] > bpe["compact"] - 1.0, "{:?}", bpe);
        assert!(!rows[0].adj.is_empty());
    }

    #[test]
    fn table3_smoke_rec_beats_zuckerli_on_dense_graphs() {
        let scale = tiny();
        let rows = table1_nsg(&scale, Kind::DeepLike, &[32], &["compact"]);
        let t3 = table3_for_graph("deep-like", "NSG32".into(), &rows[0].adj);
        assert!(t3.rec > 0.0 && t3.zuckerli > 0.0);
        // At deg 32, edge-order savings are large: REC < Comp(12 bits).
        assert!(t3.rec < 12.0, "rec={}", t3.rec);
    }

    #[test]
    fn fig3_ordering_across_datasets() {
        let scale = Scale { n: 6000, nq: 1, dim: 16, seed: 9, threads: 2 };
        let sift = fig3(&scale, Kind::SiftLike, &[4]);
        let ssnpp = fig3(&scale, Kind::SsnppLike, &[4]);
        assert!(
            sift[0].bits_per_element < ssnpp[0].bits_per_element,
            "sift={} ssnpp={}",
            sift[0].bits_per_element,
            ssnpp[0].bits_per_element
        );
        assert!(ssnpp[0].bits_per_element > 7.5, "ssnpp should be ~incompressible");
    }

    #[test]
    fn search_qps_smoke() {
        let rows = search_qps(
            &tiny(),
            Kind::DeepLike,
            &["unc64", "roc", "pq-compressed"],
            16,
            &[4, 8],
            &[2],
            1,
        )
        .unwrap();
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert_eq!(r.backend, "ivf");
            assert!(r.qps > 0.0, "{}: qps={}", r.codec, r.qps);
            assert!(r.p95_ms >= r.p50_ms, "{}: p95 < p50", r.codec);
            assert!(r.mean_ms >= 0.0 && r.p50_ms >= 0.0);
        }
        // The sweep axes are all present.
        assert!(rows.iter().any(|r| r.codec == "pq-compressed" && r.nprobe == 8));
    }

    #[test]
    fn search_qps_serves_graph_backends_and_rejects_typos() {
        let scale = Scale { n: 1200, nq: 30, dim: 8, seed: 9, threads: 2 };
        let rows = search_qps(&scale, Kind::DeepLike, &["nsg:roc"], 16, &[16], &[2], 1).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].backend, "nsg");
        assert_eq!(rows[0].codec, "nsg:roc");
        assert!(rows[0].qps > 0.0);

        let err = search_qps(&scale, Kind::DeepLike, &["rocc"], 16, &[4], &[1], 1)
            .expect_err("typo must not run");
        assert!(format!("{err}").contains("valid names"), "{err}");
        assert!(validate_qps_spec("hnsw:ef").is_ok());
        assert!(validate_qps_spec("nsg:zuckerli").is_err(), "whole-graph codec per node");
        assert!(validate_qps_spec("turbo:roc").is_err());
        assert!(validate_qps_spec("rec").is_err(), "no IVF id store for rec");
    }

    #[test]
    fn churn_smoke_parity_and_compression_hold() {
        let scale = Scale { n: 2500, nq: 25, dim: 8, seed: 5, threads: 2 };
        let rep = churn(&scale, Kind::DeepLike, "roc", 32, 0.2, 8).unwrap();
        assert_eq!(rep.deletes, 500);
        assert_eq!(rep.inserts, 500);
        assert!(rep.results_identical(), "{}/{} queries", rep.queries_identical, rep.nq);
        assert!((rep.bpi_ratio() - 1.0).abs() < 0.02, "bpi ratio {}", rep.bpi_ratio());
        assert!(rep.insert_per_s > 0.0 && rep.delete_per_s > 0.0);
        assert!(rep.segments_before_compact >= 1);
    }

    #[test]
    fn decode_bench_smoke_covers_every_codec_and_kernels_agree() {
        let rep = decode_bench(10_000, &[0, 1, 65, 500], 4, 1, 7, 512, 8, 64, 16).unwrap();
        assert_eq!(rep.rows.len(), 4 * DECODE_CODECS.len());
        // Each (len, codec) row decodes len × 4 lists.
        assert_eq!(rep.total_ids(), (1 + 65 + 500) * 4 * DECODE_CODECS.len());
        for r in &rep.rows {
            if r.list_len > 0 {
                assert!(r.ids_per_s > 0.0, "{} len {}", r.codec, r.list_len);
                assert!(r.bits_per_id > 0.0, "{} len {}", r.codec, r.list_len);
            }
        }
        // The ANS family's rate must sit between roc and unc32 on a
        // non-power-of-two universe at the large list size.
        let get = |name: &str| {
            rep.rows.iter().find(|r| r.codec == name && r.list_len == 500).unwrap().bits_per_id
        };
        assert!(get("roc") < get("ans-i4"), "roc stays rate-optimal");
        assert!(get("ans-i4") < get("unc32"));
        // Kernel sections carry positive throughput on both paths
        // (parity is asserted inside decode_bench itself).
        assert!(rep.adc.scalar_per_s > 0.0 && rep.adc.simd_per_s > 0.0);
        assert!(rep.coarse.scalar_per_s > 0.0 && rep.coarse.simd_per_s > 0.0);
        assert!(!rep.simd_level.is_empty());
        // Oversized lists are an error, not a silent clamp.
        assert!(decode_bench(10, &[100], 2, 1, 7, 8, 2, 4, 4).is_err());
    }

    #[test]
    fn table4_smoke() {
        let rows = table4(20_000, 50, 16, 128, 2, 3);
        assert_eq!(rows.len(), 4);
        let by: BTreeMap<_, _> = rows.iter().map(|r| (r.codec.as_str(), r)).collect();
        assert!(by["roc"].bits_per_id < by["compact"].bits_per_id);
        assert!(by["roc"].bits_per_id < by["ef"].bits_per_id + 0.2);
        assert!(by["roc"].recall_at_10 >= by["unc64"].recall_at_10 - 1e-9, "lossless ids");
    }
}
