//! Experiment drivers — one per table/figure of the paper — shared by the
//! bench harnesses (`rust/benches/`) and smoke-tested at tiny scale here.
//!
//! Each driver returns structured rows; benches print them next to the
//! paper's reference values (EXPERIMENTS.md records the comparison).

pub mod experiments;
pub mod bench_entries;
pub mod crashes;
pub mod faults;
pub mod recall;
pub mod workload;

/// Minimal fixed-width table printer for bench output.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format helper: 3 significant-ish digits like the paper's tables.
pub fn fmt3(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v >= 100.0 {
        format!("{v:.0}")
    } else if v >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["index", "EF", "ROC"]);
        t.row(vec!["IVF256".into(), "9.85".into(), "9.43".into()]);
        let s = t.render();
        assert!(s.contains("IVF256"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn fmt3_ranges() {
        assert_eq!(fmt3(9.433), "9.43");
        assert_eq!(fmt3(11.83), "11.8");
        assert_eq!(fmt3(123.4), "123");
        assert_eq!(fmt3(0.0), "0");
    }
}
