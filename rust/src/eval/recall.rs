//! Recall-aware evaluation: the accuracy half of the paper's headline
//! claim ("7× id compression with **no impact on accuracy** or search
//! runtime", §1).
//!
//! [`sweep`] drives every backend family — IVF-Flat per lossless id
//! codec, IVF-PQ, NSG, HNSW and the post-churn [`DynamicIvf`] — through
//! the same [`AnnIndex`] path the coordinator serves, sweeps the search
//! knob (`nprobe` for IVF, `ef` for graphs), and scores each operating
//! point against exact brute-force groundtruth: recall@1, set-intersection
//! recall@k, 1-recall@k (the paper's Table-4 metric), QPS, latency
//! percentiles and bits/id. The report carries an [`EnvManifest`] so
//! committed `BENCH_recall.json` baselines are only ever compared against
//! runs from a recorded toolchain/SIMD tier.
//!
//! The lossless claim is enforced *inside* the sweep, not just reported:
//! every IVF-Flat row produced by a lossless per-list codec must return
//! results bit-identical to the first codec's at the same knob, or the
//! sweep errors out (and the bench exits non-zero before writing JSON).

use crate::api::{AnnIndex, GraphIndex, QueryParams};
use crate::datasets::{generate, groundtruth, Kind};
use crate::dynamic::{CompactionPolicy, DynamicBuildParams, DynamicIvf};
use crate::eval::experiments::{Scale, QPS_GRAPH_N_CAP};
use crate::eval::workload::measure;
use crate::graph::hnsw::{Hnsw, HnswParams};
use crate::graph::nsg::{Nsg, NsgParams};
use crate::index::{IvfBuildParams, IvfIndex, VectorMode};
use crate::quant::kmeans;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Where a `BENCH_recall.json` run came from: toolchain, SIMD dispatch
/// tier and thread count. Recall rows are only comparable across runs
/// when these match (recall itself is deterministic, but QPS is not, and
/// a SIMD-tier change is exactly the kind of event the baseline gate
/// should surface instead of silently absorbing).
pub struct EnvManifest {
    /// `rustc --version` of the compiler that built this binary
    /// (captured by `build.rs`; "unknown" when unavailable).
    pub rustc: &'static str,
    pub pkg_version: &'static str,
    pub target_arch: &'static str,
    /// Active SIMD dispatch tier ("scalar" | "sse4.1" | "avx2").
    pub simd_level: &'static str,
    /// The `ZANN_SIMD` override in effect, or "auto".
    pub simd_override: String,
    pub threads: usize,
}

impl EnvManifest {
    pub fn capture(threads: usize) -> EnvManifest {
        EnvManifest {
            rustc: env!("ZANN_RUSTC_VERSION"),
            pkg_version: env!("CARGO_PKG_VERSION"),
            target_arch: std::env::consts::ARCH,
            simd_level: crate::simd::level().name(),
            simd_override: std::env::var("ZANN_SIMD").unwrap_or_else(|_| "auto".into()),
            threads,
        }
    }
}

/// One operating point of the accuracy/speed/size tradeoff: a (backend,
/// codec, knob) cell with its recall, throughput and storage rate.
pub struct RecallPoint {
    pub backend: &'static str,
    pub codec: String,
    /// The swept search knob: `nprobe` for IVF families, `ef` for graphs.
    pub knob: usize,
    /// 1-recall@1: the true NN ranked first among the top-1.
    pub recall_at_1: f64,
    /// Set-intersection recall@topk.
    pub recall_at_10: f64,
    /// 1-recall@topk — the paper's Table-4 "recall@10" definition.
    pub nn_recall_at_10: f64,
    pub qps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub bits_per_id: f64,
    /// Whether the id store is lossless (always true today; recorded so
    /// the baseline checker can keep exact-match tolerances scoped to
    /// lossless rows if a lossy store ever lands).
    pub lossless_ids: bool,
}

/// Everything [`sweep`] needs; the bench entry builds this from CLI
/// flags, tests build it literally.
pub struct RecallConfig {
    pub scale: Scale,
    pub kind: Kind,
    /// IVF coarse clusters (shared across every IVF-family backend).
    pub clusters: usize,
    /// Result depth and groundtruth depth (the "@10" in the JSON keys).
    pub topk: usize,
    /// Search-knob sweep: `nprobe` for IVF backends, `ef` for graphs.
    pub knobs: Vec<usize>,
    /// Lossless per-list id codecs for the IVF-Flat rows (first entry is
    /// the invariance reference).
    pub ivf_codecs: Vec<String>,
    /// PQ sub-quantizers for the IVF-PQ row; 0 skips the backend.
    pub pq_m: usize,
    /// Build the NSG + HNSW rows (over at most [`QPS_GRAPH_N_CAP`] rows).
    pub graphs: bool,
    pub graph_codec: String,
    /// Build the post-churn dynamic row (delete/insert `churn_frac`·n,
    /// then compact).
    pub dynamic: bool,
    pub dynamic_codec: String,
    pub churn_frac: f64,
    /// Timed passes per cell (QPS is best-of-runs; results come from a
    /// separate warm pass and are deterministic).
    pub runs: usize,
    /// Sabotage mode for the CI gate-fires check: corrupt every returned
    /// id (bit-flip of the low bit) *at scoring time*, after the
    /// invariance check, so recall collapses while the pipeline stays
    /// intact. The JSON records the flag so a sabotaged report can never
    /// pass for a measurement.
    pub corrupt_ids: bool,
}

/// The `BENCH_recall.json` payload: run parameters, environment manifest
/// and one [`RecallPoint`] per (backend, codec, knob).
pub struct RecallReport {
    pub dataset: &'static str,
    pub n: usize,
    pub nq: usize,
    pub dim: usize,
    pub seed: u64,
    pub clusters: usize,
    pub topk: usize,
    pub churn_frac: f64,
    pub corrupt_ids: bool,
    pub env: EnvManifest,
    pub points: Vec<RecallPoint>,
}

/// One backend ready to be measured: its index, the groundtruth in the
/// id space the index returns, and whether it participates in the
/// lossless-codec invariance check.
struct BackendRun {
    backend: &'static str,
    codec: String,
    index: Box<dyn AnnIndex>,
    gt: Arc<Vec<u32>>,
    check_invariance: bool,
}

/// Build every configured backend and measure each at every knob.
///
/// IVF-family backends share one coarse clustering (codec comparisons
/// stay apples-to-apples); graph backends build over at most
/// [`QPS_GRAPH_N_CAP`] rows with their own groundtruth over that prefix;
/// the dynamic backend goes through a full delete → insert → compact
/// churn cycle first and is scored against groundtruth computed over its
/// *live* vector set in external-id space.
pub fn sweep(cfg: &RecallConfig) -> Result<RecallReport> {
    let Scale { n, nq, dim, seed, threads } = cfg.scale;
    ensure!(nq > 0, "recall sweep needs at least one query (nq=0)");
    ensure!(cfg.topk > 0, "topk must be positive");
    ensure!(!cfg.knobs.is_empty(), "empty --knobs sweep");
    ensure!(
        !cfg.ivf_codecs.is_empty() || cfg.pq_m > 0 || cfg.graphs || cfg.dynamic,
        "no backends selected"
    );
    if cfg.pq_m > 0 {
        ensure!(dim % cfg.pq_m == 0, "--pq-m {} does not divide dim {dim}", cfg.pq_m);
    }
    let moved = if cfg.dynamic {
        ((n as f64) * cfg.churn_frac).round().max(1.0) as usize
    } else {
        0
    };
    let ds = generate(cfg.kind, n + moved, nq, dim, seed);
    let base = &ds.data[..n * dim];
    let gt_k = cfg.topk;
    let gt_base: Arc<Vec<u32>> =
        Arc::new(groundtruth::exact_knn(base, &ds.queries, dim, gt_k, threads));

    let mut backends: Vec<BackendRun> = Vec::new();

    // IVF family over one shared coarse clustering.
    let shared = if !cfg.ivf_codecs.is_empty() || cfg.pq_m > 0 {
        let cents = kmeans::train(
            base,
            dim,
            &kmeans::KmeansConfig {
                k: cfg.clusters,
                iters: 8,
                seed,
                threads,
                ..Default::default()
            },
        );
        let kk = cents.len() / dim;
        let assign = kmeans::assign(base, dim, &cents, threads);
        Some((cents, kk, assign))
    } else {
        None
    };
    if let Some((cents, kk, assign)) = &shared {
        let build = |id_codec: &str, vectors: VectorMode| -> IvfIndex {
            IvfIndex::build_preassigned(
                base,
                dim,
                cents,
                assign,
                &IvfBuildParams {
                    k: *kk,
                    id_codec: id_codec.into(),
                    vectors,
                    threads,
                    seed,
                    ..Default::default()
                },
                *kk,
            )
        };
        for codec in &cfg.ivf_codecs {
            backends.push(BackendRun {
                backend: "ivf",
                codec: codec.clone(),
                index: Box::new(build(codec, VectorMode::Flat)),
                gt: gt_base.clone(),
                check_invariance: true,
            });
        }
        if cfg.pq_m > 0 {
            backends.push(BackendRun {
                backend: "ivf-pq",
                codec: format!("compact+pq{}", cfg.pq_m),
                index: Box::new(build("compact", VectorMode::Pq { m: cfg.pq_m, bits: 8 })),
                gt: gt_base.clone(),
                check_invariance: false,
            });
        }
    }

    if cfg.graphs {
        let graph_n = n.min(QPS_GRAPH_N_CAP);
        let gdata = &ds.data[..graph_n * dim];
        let gt_graph = if graph_n == n {
            gt_base.clone()
        } else {
            Arc::new(groundtruth::exact_knn(gdata, &ds.queries, dim, gt_k, threads))
        };
        let nsg = Nsg::build(
            gdata,
            dim,
            &NsgParams { r: 32, knn_k: 48, threads, seed, ..Default::default() },
        );
        backends.push(BackendRun {
            backend: "nsg",
            codec: cfg.graph_codec.clone(),
            index: Box::new(GraphIndex::from_nsg(&nsg, gdata, &cfg.graph_codec)?),
            gt: gt_graph.clone(),
            check_invariance: false,
        });
        let h = Hnsw::build(gdata, dim, &HnswParams { m: 16, ef_construction: 100, seed });
        backends.push(BackendRun {
            backend: "hnsw",
            codec: cfg.graph_codec.clone(),
            index: Box::new(GraphIndex::from_hnsw(&h, gdata, &cfg.graph_codec)?),
            gt: gt_graph,
            check_invariance: false,
        });
    }

    if cfg.dynamic {
        // Same churn protocol as the churn bench: build over n, delete
        // `moved` random ids, insert `moved` fresh rows, compact.
        let mut idx = DynamicIvf::build(
            base,
            dim,
            &DynamicBuildParams {
                ivf: IvfBuildParams {
                    k: cfg.clusters,
                    id_codec: cfg.dynamic_codec.clone(),
                    threads,
                    seed,
                    ..Default::default()
                },
                policy: CompactionPolicy::default(),
            },
        )?;
        let mut rng = crate::util::Rng::new(seed ^ 0xc0ffee);
        for &id in &rng.sample_distinct(n as u64, moved.min(n)) {
            idx.delete(id as u32)?;
        }
        for chunk in ds.data[n * dim..].chunks(512 * dim) {
            idx.add(chunk)?;
        }
        idx.compact()?;
        // Groundtruth over the live set, in external-id space: searches
        // return external ids, so exact-knn row indices over the gathered
        // live vectors are translated through the live-id list.
        let live = idx.live_ids();
        ensure!(!live.is_empty(), "churn cycle left no live vectors");
        let mut live_data = Vec::with_capacity(live.len() * dim);
        for &e in &live {
            live_data.extend_from_slice(ds.vector(e as usize));
        }
        let gt_live: Arc<Vec<u32>> = Arc::new(
            groundtruth::exact_knn(&live_data, &ds.queries, dim, gt_k, threads)
                .into_iter()
                .map(|row| live[row as usize])
                .collect(),
        );
        backends.push(BackendRun {
            backend: "dynamic",
            codec: cfg.dynamic_codec.clone(),
            index: Box::new(idx),
            gt: gt_live,
            check_invariance: false,
        });
    }

    let mut points = Vec::new();
    for &knob in &cfg.knobs {
        // Reference results for the lossless-invariance check at this
        // knob: (codec name, per-query (distance-bits, id) lists).
        let mut inv_ref: Option<(&str, Vec<Vec<(u32, u32)>>)> = None;
        for br in &backends {
            let sp = QueryParams { k: cfg.topk, nprobe: knob, ef: knob };
            let m = measure(&*br.index, &ds.queries, dim, nq, &sp, threads, cfg.runs);
            if br.check_invariance {
                let bits: Vec<Vec<(u32, u32)>> = m
                    .results
                    .iter()
                    .map(|r| r.iter().map(|&(d, id)| (d.to_bits(), id)).collect())
                    .collect();
                match &inv_ref {
                    None => inv_ref = Some((&br.codec, bits)),
                    Some((first, want)) => ensure!(
                        &bits == want,
                        "lossless-codec invariance violated at nprobe={knob}: \
                         {:?} returned different results than {first:?}",
                        br.codec
                    ),
                }
            }
            let ids: Vec<Vec<u32>> = m
                .results
                .iter()
                .map(|r| {
                    r.iter()
                        .map(|&(_, id)| if cfg.corrupt_ids { id ^ 1 } else { id })
                        .collect()
                })
                .collect();
            points.push(RecallPoint {
                backend: br.backend,
                codec: br.codec.clone(),
                knob,
                recall_at_1: groundtruth::nn_recall_at_k(&br.gt, gt_k, &ids, 1),
                recall_at_10: groundtruth::recall_at_k(&br.gt, gt_k, &ids, cfg.topk),
                nn_recall_at_10: groundtruth::nn_recall_at_k(&br.gt, gt_k, &ids, cfg.topk),
                qps: m.qps,
                mean_ms: m.mean_ms,
                p50_ms: m.p50_ms,
                p95_ms: m.p95_ms,
                bits_per_id: br.index.stats().bits_per_id(),
                lossless_ids: true,
            });
        }
    }

    Ok(RecallReport {
        dataset: cfg.kind.name(),
        n,
        nq,
        dim,
        seed,
        clusters: cfg.clusters,
        topk: cfg.topk,
        churn_frac: if cfg.dynamic { cfg.churn_frac } else { 0.0 },
        corrupt_ids: cfg.corrupt_ids,
        env: EnvManifest::capture(threads),
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> RecallConfig {
        RecallConfig {
            scale: Scale { n: 1200, nq: 20, dim: 8, seed: 7, threads: 2 },
            kind: Kind::DeepLike,
            clusters: 16,
            topk: 10,
            knobs: vec![4, 16],
            ivf_codecs: vec!["unc64".into(), "roc".into()],
            pq_m: 4,
            graphs: true,
            graph_codec: "roc".into(),
            dynamic: true,
            dynamic_codec: "roc".into(),
            churn_frac: 0.2,
            runs: 1,
            corrupt_ids: false,
        }
    }

    #[test]
    fn sweep_covers_every_backend_and_scores_sanely() {
        let rep = sweep(&tiny_cfg()).expect("sweep");
        // (2 ivf codecs + pq + nsg + hnsw + dynamic) × 2 knobs.
        assert_eq!(rep.points.len(), 12);
        for want in ["ivf", "ivf-pq", "nsg", "hnsw", "dynamic"] {
            assert!(rep.points.iter().any(|p| p.backend == want), "missing {want}");
        }
        for p in &rep.points {
            for (name, v) in [
                ("recall_at_1", p.recall_at_1),
                ("recall_at_10", p.recall_at_10),
                ("nn_recall_at_10", p.nn_recall_at_10),
            ] {
                assert!((0.0..=1.0).contains(&v), "{}/{} {name}={v}", p.backend, p.codec);
            }
            // The true NN ranked first implies it is present in the
            // top-k, so recall@1 never exceeds 1-recall@k.
            assert!(p.recall_at_1 <= p.nn_recall_at_10 + 1e-12, "{}/{}", p.backend, p.codec);
            assert!(p.qps > 0.0 && p.bits_per_id > 0.0, "{}/{}", p.backend, p.codec);
        }
        // Lossless id codecs ⇒ identical recall at every knob (the sweep
        // already asserted bit-identical result lists internally).
        for &knob in &[4usize, 16] {
            let ivf: Vec<&RecallPoint> =
                rep.points.iter().filter(|p| p.backend == "ivf" && p.knob == knob).collect();
            assert_eq!(ivf.len(), 2);
            assert_eq!(ivf[0].recall_at_10, ivf[1].recall_at_10, "knob={knob}");
            assert_eq!(ivf[0].recall_at_1, ivf[1].recall_at_1, "knob={knob}");
        }
        // Full probe (knob = clusters) over Flat vectors is a near-exact
        // search; recall must be essentially perfect.
        let full = rep
            .points
            .iter()
            .find(|p| p.backend == "ivf" && p.knob == 16)
            .expect("full-probe row");
        assert!(full.recall_at_10 > 0.95, "full-probe recall {}", full.recall_at_10);
        // The environment manifest is populated.
        assert!(!rep.env.rustc.is_empty() && !rep.env.simd_level.is_empty());
        assert_eq!(rep.dataset, "deep-like");
    }

    #[test]
    fn corrupt_ids_mode_collapses_recall() {
        // The CI gate-fires mechanism: a bit-flip on every returned id
        // must tank recall relative to the clean run, while the report
        // itself stays well-formed and flagged.
        let mut cfg = tiny_cfg();
        cfg.ivf_codecs = vec!["roc".into()];
        cfg.pq_m = 0;
        cfg.graphs = false;
        cfg.dynamic = false;
        cfg.knobs = vec![16];
        let clean = sweep(&cfg).expect("clean sweep");
        cfg.corrupt_ids = true;
        let bad = sweep(&cfg).expect("corrupt sweep");
        assert!(!clean.corrupt_ids && bad.corrupt_ids);
        let (c, b) = (&clean.points[0], &bad.points[0]);
        assert!(
            b.recall_at_10 < c.recall_at_10 - 0.2,
            "corruption not visible: clean={} corrupt={}",
            c.recall_at_10,
            b.recall_at_10
        );
    }

    #[test]
    fn degenerate_configs_are_rejected() {
        let mut cfg = tiny_cfg();
        cfg.scale.nq = 0;
        assert!(sweep(&cfg).is_err(), "nq=0 must not produce a report");
        let mut cfg = tiny_cfg();
        cfg.knobs.clear();
        assert!(sweep(&cfg).is_err(), "empty knob sweep must not produce a report");
        let mut cfg = tiny_cfg();
        cfg.pq_m = 5; // does not divide dim=8
        assert!(sweep(&cfg).is_err(), "pq_m must divide dim");
    }
}
