//! Random Edge Coding (REC) — one-shot bits-back compression of a whole
//! directed graph (Severo et al. 2023; paper §3.2/§5.3, Table 3).
//!
//! The graph is its edge *multiset*: the order in which the 2E-long vertex
//! sequence lists the edges is worth `log₂(E!)` bits.  REC recovers them
//! exactly as ROC does for sets, but over edges, with a vertex probability
//! model shared across the whole stream:
//!
//! * encode step (r edges remaining): bits-back-decode `j ~ U([0,r))`,
//!   select the j-th remaining edge in canonical (lexicographic) order,
//!   remove it, and encode its `dst` then `src` under the vertex model;
//! * decode step: decode `src`, `dst`, then encode back the edge's rank
//!   among the edges decoded so far.
//!
//! Two vertex models are provided (an ablation the paper invites — its REC
//! model is tuned for power-law graphs, which NSG/HNSW are not):
//!
//! * [`RecModel::Uniform`]: P(v) = 1/N. Rate = `2E·log₂N − log₂(E!)`.
//! * [`RecModel::PolyaUrn`]: P(v | t-prefix) = (count(v)+1)/(t+N) — adapts
//!   to the in-degree skew, implemented with a decrementable Fenwick urn
//!   (the encoder walks the urn backwards from the remaining-graph counts).
//!
//! The paper's directed `b = 0` variant corresponds to both models here:
//! only edge order (not within-edge order) is monetized.

use super::Encoded;
use crate::ans::Ans;
use crate::fenwick::Fenwick;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecModel {
    Uniform,
    PolyaUrn,
}

pub struct Rec {
    pub model: RecModel,
}

impl Rec {
    pub fn new(model: RecModel) -> Self {
        Rec { model }
    }

    /// Encode the adjacency structure (`adj[src] = friend list`) of a
    /// directed graph with `adj.len()` nodes.
    pub fn encode_graph(&self, adj: &[Vec<u32>]) -> Encoded {
        let n_nodes = adj.len() as u32;
        // Canonical edge sequence: lexicographic (src, dst).
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for (src, list) in adj.iter().enumerate() {
            let mut dsts = list.clone();
            dsts.sort_unstable();
            debug_assert!(dsts.windows(2).all(|w| w[0] != w[1]), "duplicate edge");
            for d in dsts {
                debug_assert!(d < n_nodes);
                edges.push((src as u32, d));
            }
        }
        let e = edges.len();
        assert!(
            2 * e as u64 + n_nodes as u64 <= u32::MAX as u64,
            "graph too large for 32-bit ANS denominators"
        );
        let mut ans = Ans::new();
        if e == 0 {
            return Encoded { bits: ans.size_bits() as u64, bytes: ans.to_bytes() };
        }

        let mut occupancy = Fenwick::ones(e);
        // Urn starts from the counts of the *whole* vertex sequence and is
        // decremented as positions are consumed (prefix counts at each t).
        let mut urn = match self.model {
            RecModel::PolyaUrn => {
                let mut counts = vec![0u64; n_nodes as usize];
                for &(s, d) in &edges {
                    counts[s as usize] += 1;
                    counts[d as usize] += 1;
                }
                Some(Fenwick::from_counts(&counts))
            }
            RecModel::Uniform => None,
        };

        for r in (1..=e as u32).rev() {
            let j = ans.decode_uniform(r);
            let p = occupancy.select_kth(j as u64);
            occupancy.add(p, -1);
            let (src, dst) = edges[p];
            // Positions t = 2r-1 (dst) then t = 2r-2 (src); the model for
            // position t conditions on the t-prefix, so decrement first.
            self.encode_vertex(&mut ans, urn.as_mut(), dst, 2 * r as u64 - 1, n_nodes);
            self.encode_vertex(&mut ans, urn.as_mut(), src, 2 * r as u64 - 2, n_nodes);
        }
        let bits = ans.size_bits() as u64;
        Encoded { bytes: ans.to_bytes(), bits }
    }

    fn encode_vertex(&self, ans: &mut Ans, urn: Option<&mut Fenwick>, v: u32, t: u64, n: u32) {
        match urn {
            None => ans.encode_uniform(v, n),
            Some(urn) => {
                urn.add(v as usize, -1);
                let f = urn.get(v as usize) as u32 + 1;
                let c = urn.prefix_sum_with_linear(v as usize, 1) as u32;
                let m = (t + n as u64) as u32;
                debug_assert_eq!(urn.total(), t, "urn must hold exactly the t-prefix");
                ans.encode(f, c, m);
            }
        }
    }

    /// Decode a graph with `n_nodes` nodes and `n_edges` directed edges.
    pub fn decode_graph(&self, bytes: &[u8], n_nodes: u32, n_edges: u64) -> Vec<Vec<u32>> {
        let mut ans = Ans::from_bytes(bytes).expect("corrupt REC blob");
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n_nodes as usize];
        if n_edges == 0 {
            return adj;
        }
        let mut urn = match self.model {
            RecModel::PolyaUrn => Some(Fenwick::new(n_nodes as usize)),
            RecModel::Uniform => None,
        };
        // Rank-and-insert over decoded edges: Fenwick over src buckets +
        // sorted dst vec per src.
        let mut src_counts = Fenwick::new(n_nodes as usize);

        for r in 1..=n_edges {
            let src = self.decode_vertex(&mut ans, urn.as_mut(), 2 * r - 2, n_nodes);
            let dst = self.decode_vertex(&mut ans, urn.as_mut(), 2 * r - 1, n_nodes);
            // Rank of (src, dst) among decoded edges in canonical order.
            let list = &mut adj[src as usize];
            let pos = list.partition_point(|&y| y < dst);
            list.insert(pos, dst);
            let rank = src_counts.prefix_sum(src as usize) + pos as u64;
            src_counts.add(src as usize, 1);
            ans.encode_uniform(rank as u32, r as u32);
        }
        debug_assert_eq!(ans.head, 1 << 32, "state not drained — corrupt stream?");
        adj
    }

    fn decode_vertex(&self, ans: &mut Ans, urn: Option<&mut Fenwick>, t: u64, n: u32) -> u32 {
        match urn {
            None => ans.decode_uniform(n),
            Some(urn) => {
                debug_assert_eq!(urn.total(), t);
                let m = (t + n as u64) as u32;
                let slot = ans.peek(m);
                let (v, _) = urn.slot_of_with_linear(slot as u64, 1);
                let f = urn.get(v) as u32 + 1;
                let c = urn.prefix_sum_with_linear(v, 1) as u32;
                ans.pop(f, c, m);
                urn.add(v, 1);
                v as u32
            }
        }
    }

    /// Ideal rate (bits/edge-id, i.e. per edge endpoint beyond the implicit
    /// source) under the uniform model: `(2E log₂ N − log₂ E!) / E`.
    pub fn ideal_bits_per_edge(n_nodes: u32, n_edges: u64) -> f64 {
        2.0 * n_edges as f64 * (n_nodes as f64).log2() - crate::util::log2_factorial(n_edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_graph(rng: &mut Rng, n: u32, avg_deg: usize) -> Vec<Vec<u32>> {
        (0..n)
            .map(|_| {
                let deg = rng.below(2 * avg_deg as u64 + 1) as usize;
                rng.sample_distinct(n as u64, deg.min(n as usize))
                    .into_iter()
                    .map(|v| v as u32)
                    .collect()
            })
            .collect()
    }

    fn sorted(adj: &[Vec<u32>]) -> Vec<Vec<u32>> {
        adj.iter()
            .map(|l| {
                let mut l = l.clone();
                l.sort_unstable();
                l
            })
            .collect()
    }

    #[test]
    fn roundtrip_both_models() {
        let mut rng = Rng::new(20);
        for model in [RecModel::Uniform, RecModel::PolyaUrn] {
            for &(n, deg) in &[(1u32, 0usize), (10, 2), (500, 8), (2000, 16)] {
                let adj = random_graph(&mut rng, n, deg);
                let e: u64 = adj.iter().map(|l| l.len() as u64).sum();
                let rec = Rec::new(model);
                let enc = rec.encode_graph(&adj);
                let got = rec.decode_graph(&enc.bytes, n, e);
                assert_eq!(sorted(&got), sorted(&adj), "model={model:?} n={n}");
            }
        }
    }

    #[test]
    fn uniform_rate_matches_formula() {
        let mut rng = Rng::new(21);
        let n = 5000u32;
        let adj = random_graph(&mut rng, n, 32);
        let e: u64 = adj.iter().map(|l| l.len() as u64).sum();
        let enc = Rec::new(RecModel::Uniform).encode_graph(&adj);
        let ideal = Rec::ideal_bits_per_edge(n, e);
        let got = enc.bits as f64;
        assert!(
            (got - ideal).abs() < 0.01 * ideal + 128.0,
            "got={got} ideal={ideal}"
        );
        // Beats the 2×Compact baseline (26 bits/edge here): REC spends
        // 2·log2(5000)=24.6 minus ~17.6 recovered per edge.
        let bpe = got / e as f64;
        assert!(bpe < 13.0, "bpe={bpe}");
    }

    #[test]
    fn urn_beats_uniform_on_skewed_graphs() {
        // Hub-dominated in-degrees: the Pólya urn should win clearly.
        let mut rng = Rng::new(22);
        let n = 2000u32;
        let adj: Vec<Vec<u32>> = (0..n)
            .map(|_| {
                let mut set = std::collections::HashSet::new();
                // 80% of edges to the first 16 hubs.
                while set.len() < 24 {
                    let v = if rng.f64() < 0.8 {
                        rng.below(16) as u32
                    } else {
                        rng.below(n as u64) as u32
                    };
                    set.insert(v);
                }
                set.into_iter().collect()
            })
            .collect();
        let uni = Rec::new(RecModel::Uniform).encode_graph(&adj).bits;
        let urn = Rec::new(RecModel::PolyaUrn).encode_graph(&adj).bits;
        assert!(
            (urn as f64) < 0.9 * uni as f64,
            "urn={urn} uniform={uni}"
        );
        // And still decodes.
        let e: u64 = adj.iter().map(|l| l.len() as u64).sum();
        let got = Rec::new(RecModel::PolyaUrn).decode_graph(
            &Rec::new(RecModel::PolyaUrn).encode_graph(&adj).bytes,
            n,
            e,
        );
        assert_eq!(sorted(&got), sorted(&adj));
    }

    #[test]
    fn empty_graph() {
        let rec = Rec::new(RecModel::Uniform);
        let enc = rec.encode_graph(&[Vec::new(), Vec::new()]);
        let got = rec.decode_graph(&enc.bytes, 2, 0);
        assert_eq!(got, vec![Vec::<u32>::new(), Vec::new()]);
    }

    #[test]
    fn whole_graph_beats_per_list_roc_on_many_short_lists() {
        // The §5.3 observation: one stream amortizes initial bits and
        // log(E!) > sum log(m_i!).
        use crate::codecs::{roc::Roc, IdCodec};
        let mut rng = Rng::new(23);
        let n = 3000u32;
        let adj = random_graph(&mut rng, n, 16);
        let e: u64 = adj.iter().map(|l| l.len() as u64).sum();
        let rec_bits = Rec::new(RecModel::Uniform).encode_graph(&adj).bits;
        let roc_bits: u64 = adj.iter().map(|l| Roc.encode(l, n).bits).sum();
        let rec_bpe = rec_bits as f64 / e as f64;
        let roc_bpe = roc_bits as f64 / e as f64;
        assert!(rec_bpe < roc_bpe, "rec={rec_bpe} roc={roc_bpe}");
    }
}
