//! Wavelet tree over the IVF cluster-assignment sequence (paper §3.3/§4.1,
//! the **WT**/**WT1** columns).
//!
//! Instead of storing per-cluster id lists, the whole database is described
//! by one sequence `S ∈ [K)^N` where `S[id] = cluster(id)`.  The wavelet
//! tree indexes S so that `select(k, o)` — the id of the o-th member of
//! cluster k — runs in `O(log K)` rank/select steps.  That is *full random
//! access*: IVF search collects (cluster, offset) pairs and resolves only
//! the final top-k ids (paper §4.1).
//!
//! Two bitmap backends mirror the paper's variants: **WT** uses plain
//! rank/select bitvectors, **WT1** compresses every level with RRR —
//! smaller (it exploits the dependence between lists: together they
//! partition `[N)`), but each rank/select costs a block decode, the 2-3×
//! select slowdown of Table 2.

use crate::bitvec::rrr::RrrVec;
use crate::bitvec::RsBitVec;
use crate::util::bits::BitWriter;
use crate::util::bits_for;

/// Bitmap backend selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WtStorage {
    /// Plain bitvectors (paper's WT).
    Flat,
    /// RRR-compressed bitvectors (paper's WT1).
    Rrr,
}

enum Bitmap {
    Flat(RsBitVec),
    Rrr(RrrVec),
}

impl Bitmap {
    #[inline]
    fn rank1(&self, i: usize) -> u64 {
        match self {
            Bitmap::Flat(b) => b.rank1(i),
            Bitmap::Rrr(b) => b.rank1(i),
        }
    }

    #[inline]
    fn rank0(&self, i: usize) -> u64 {
        match self {
            Bitmap::Flat(b) => b.rank0(i),
            Bitmap::Rrr(b) => b.rank0(i),
        }
    }

    #[inline]
    fn select1(&self, k: u64) -> Option<usize> {
        match self {
            Bitmap::Flat(b) => b.select1(k),
            Bitmap::Rrr(b) => b.select1(k),
        }
    }

    #[inline]
    fn select0(&self, k: u64) -> Option<usize> {
        match self {
            Bitmap::Flat(b) => b.select0(k),
            Bitmap::Rrr(b) => b.select0(k),
        }
    }

    #[inline]
    fn get(&self, i: usize) -> bool {
        match self {
            Bitmap::Flat(b) => b.get(i),
            Bitmap::Rrr(b) => b.get(i),
        }
    }

    fn size_bits(&self) -> usize {
        match self {
            Bitmap::Flat(b) => b.size_bits(),
            Bitmap::Rrr(b) => b.size_bits(),
        }
    }
}

/// Levelwise (pointerless) wavelet tree.
pub struct WaveletTree {
    n: usize,
    levels: Vec<Bitmap>,
    /// Bits per symbol = number of levels.
    depth: u32,
    /// Occurrences per symbol (cluster sizes) — kept for bounds checks and
    /// as the IVF list-length table.
    counts: Vec<u64>,
}

impl WaveletTree {
    /// Build over `seq` with alphabet `[0, alphabet)`.
    pub fn new(seq: &[u32], alphabet: u32, storage: WtStorage) -> Self {
        assert!(alphabet >= 1);
        let depth = bits_for(alphabet as u64).max(1);
        let n = seq.len();
        let mut counts = vec![0u64; alphabet as usize];
        for &s in seq {
            assert!(s < alphabet, "symbol {s} out of [0,{alphabet})");
            counts[s as usize] += 1;
        }

        let mut levels = Vec::with_capacity(depth as usize);
        let mut cur: Vec<u32> = seq.to_vec();
        let mut next: Vec<u32> = Vec::with_capacity(n);
        for l in 0..depth {
            let shift = depth - 1 - l;
            let mut bw = BitWriter::with_capacity(n);
            for &s in &cur {
                bw.push_bit((s >> shift) & 1 == 1);
            }
            let buf = bw.finish();
            levels.push(match storage {
                WtStorage::Flat => Bitmap::Flat(RsBitVec::new(buf)),
                WtStorage::Rrr => Bitmap::Rrr(RrrVec::new(&buf)),
            });
            if l + 1 == depth {
                break;
            }
            // Stable partition within each node (same top-l bits run):
            // zeros first, then ones — the level-(l+1) layout.
            next.clear();
            let node_of = |s: u32| s >> (shift + 1);
            let mut i = 0;
            while i < n {
                let node = node_of(cur[i]);
                let mut j = i;
                while j < n && node_of(cur[j]) == node {
                    j += 1;
                }
                for &s in &cur[i..j] {
                    if (s >> shift) & 1 == 0 {
                        next.push(s);
                    }
                }
                for &s in &cur[i..j] {
                    if (s >> shift) & 1 == 1 {
                        next.push(s);
                    }
                }
                i = j;
            }
            std::mem::swap(&mut cur, &mut next);
        }
        WaveletTree { n, levels, depth, counts }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn alphabet(&self) -> u32 {
        self.counts.len() as u32
    }

    /// Occurrences of `sym` (cluster size).
    pub fn count(&self, sym: u32) -> u64 {
        self.counts[sym as usize]
    }

    /// `S[i]` — the cluster of id `i`.
    pub fn access(&self, i: usize) -> u32 {
        debug_assert!(i < self.n);
        let (mut a, mut b) = (0usize, self.n);
        let mut pos = i;
        let mut sym = 0u32;
        for level in &self.levels {
            let zeros = level.rank0(b) - level.rank0(a);
            let bit = level.get(pos);
            sym <<= 1;
            if bit {
                sym |= 1;
                pos = a + zeros as usize + (level.rank1(pos) - level.rank1(a)) as usize;
                a += zeros as usize;
            } else {
                pos = a + (level.rank0(pos) - level.rank0(a)) as usize;
                b = a + zeros as usize;
            }
        }
        sym
    }

    /// Occurrences of `sym` in `S[0, i)`.
    pub fn rank(&self, sym: u32, i: usize) -> u64 {
        debug_assert!(i <= self.n);
        let (mut a, mut b) = (0usize, self.n);
        let mut pos = i;
        for (l, level) in self.levels.iter().enumerate() {
            let shift = self.depth - 1 - l as u32;
            let zeros = level.rank0(b) - level.rank0(a);
            if (sym >> shift) & 1 == 0 {
                pos = a + (level.rank0(pos) - level.rank0(a)) as usize;
                b = a + zeros as usize;
            } else {
                pos = a + zeros as usize + (level.rank1(pos) - level.rank1(a)) as usize;
                a += zeros as usize;
            }
        }
        (pos - a) as u64
    }

    /// Position (= vector id) of the k-th occurrence of `sym` — the
    /// random-access operation of the paper's §4.1.
    pub fn select(&self, sym: u32, k: u64) -> Option<usize> {
        if sym >= self.alphabet() || k >= self.counts[sym as usize] {
            return None;
        }
        // Top-down: record each level's node interval on the path.
        let mut intervals = Vec::with_capacity(self.depth as usize);
        let (mut a, mut b) = (0usize, self.n);
        for (l, level) in self.levels.iter().enumerate() {
            intervals.push((a, b));
            let shift = self.depth - 1 - l as u32;
            let zeros = (level.rank0(b) - level.rank0(a)) as usize;
            if (sym >> shift) & 1 == 0 {
                b = a + zeros;
            } else {
                a += zeros;
            }
        }
        // Bottom-up: map offset within leaf back to a root position.
        let mut pos = k as usize; // offset within the leaf interval
        for (l, level) in self.levels.iter().enumerate().rev() {
            let (a, _b) = intervals[l];
            let shift = self.depth - 1 - l as u32;
            let abs = if (sym >> shift) & 1 == 0 {
                level.select0(level.rank0(a) + pos as u64)?
            } else {
                level.select1(level.rank1(a) + pos as u64)?
            };
            pos = abs - a;
        }
        Some(pos)
    }

    /// Total structure size in bits (all levels incl. rank/select support).
    pub fn size_bits(&self) -> usize {
        self.levels.iter().map(|l| l.size_bits()).sum()
    }

    /// Payload-only bits (N × depth for the flat variant) — matches the
    /// paper's note that the union of level bitmaps is N·log K bits.
    pub fn payload_bits(&self) -> usize {
        self.n * self.depth as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn check_all_ops(seq: &[u32], alphabet: u32, storage: WtStorage) {
        let wt = WaveletTree::new(seq, alphabet, storage);
        let n = seq.len();
        // access
        for (i, &s) in seq.iter().enumerate() {
            assert_eq!(wt.access(i), s, "access({i})");
        }
        // rank at sampled positions + select of every occurrence
        let mut occ = vec![0u64; alphabet as usize];
        for (i, &s) in seq.iter().enumerate() {
            assert_eq!(wt.rank(s, i), occ[s as usize], "rank({s},{i})");
            assert_eq!(wt.select(s, occ[s as usize]), Some(i), "select({s})");
            occ[s as usize] += 1;
        }
        for s in 0..alphabet {
            assert_eq!(wt.count(s), occ[s as usize]);
            assert_eq!(wt.select(s, occ[s as usize]), None);
            assert_eq!(wt.rank(s, n), occ[s as usize]);
        }
    }

    #[test]
    fn ops_small_alphabet_flat_and_rrr() {
        let seq = vec![3u32, 1, 0, 3, 2, 1, 1, 0, 3, 3, 2, 0];
        check_all_ops(&seq, 4, WtStorage::Flat);
        check_all_ops(&seq, 4, WtStorage::Rrr);
    }

    #[test]
    fn ops_non_power_of_two_alphabet() {
        let mut rng = Rng::new(14);
        for &k in &[1u32, 3, 5, 1000] {
            let seq: Vec<u32> = (0..2000).map(|_| rng.below(k as u64) as u32).collect();
            check_all_ops(&seq, k, WtStorage::Flat);
        }
    }

    #[test]
    fn ops_random_property_rrr() {
        let mut rng = Rng::new(15);
        for &k in &[2u32, 17, 256] {
            let seq: Vec<u32> = (0..3000).map(|_| rng.below(k as u64) as u32).collect();
            check_all_ops(&seq, k, WtStorage::Rrr);
        }
    }

    #[test]
    fn skewed_distribution_compresses_with_rrr() {
        // Highly skewed cluster sizes -> low H0 per level -> RRR wins.
        let mut rng = Rng::new(16);
        let seq: Vec<u32> = (0..100_000)
            .map(|_| if rng.f64() < 0.95 { 0 } else { 1 + rng.below(255) as u32 })
            .collect();
        let flat = WaveletTree::new(&seq, 256, WtStorage::Flat);
        let rrr = WaveletTree::new(&seq, 256, WtStorage::Rrr);
        assert!(
            (rrr.size_bits() as f64) < 0.5 * flat.size_bits() as f64,
            "rrr={} flat={}",
            rrr.size_bits(),
            flat.size_bits()
        );
    }

    #[test]
    fn uniform_ivf_sequence_sizes() {
        // IVF1024-like: N=20k, K=1024. Flat payload = N * 10 bits.
        let mut rng = Rng::new(17);
        let n = 20_000;
        let seq: Vec<u32> = (0..n).map(|_| rng.below(1024) as u32).collect();
        let wt = WaveletTree::new(&seq, 1024, WtStorage::Flat);
        assert_eq!(wt.payload_bits(), n * 10);
        // Structure overhead (rank samples) should be bounded (~35%).
        assert!(wt.size_bits() < wt.payload_bits() * 14 / 10);
        let wt1 = WaveletTree::new(&seq, 1024, WtStorage::Rrr);
        // Uniform assignment: RRR can't go below ~N log K, but must not
        // blow up either.
        assert!(wt1.size_bits() < wt.size_bits() * 13 / 10);
    }

    #[test]
    fn empty_and_singleton() {
        let wt = WaveletTree::new(&[], 8, WtStorage::Flat);
        assert_eq!(wt.len(), 0);
        assert_eq!(wt.select(3, 0), None);
        let wt = WaveletTree::new(&[5], 8, WtStorage::Flat);
        assert_eq!(wt.access(0), 5);
        assert_eq!(wt.select(5, 0), Some(0));
        assert_eq!(wt.rank(5, 1), 1);
    }
}
