//! Random Order Coding (ROC) — bits-back ANS compression of id *sets*
//! (Severo et al. 2022; paper §3.2, the **ROC** columns of Tables 1/2/4).
//!
//! A list of n distinct ids from `[0, N)` is a set: its ordering carries
//! `log₂(n!)` bits that search never looks at.  ROC recovers them with
//! bits-back coding:
//!
//! * **encode** (per step, i elements remaining): *decode* an index
//!   `j ~ Uniform([0, i))` from the ANS state (this is the bits-back
//!   "sampling" step — it *removes* ~log₂ i bits), select the j-th smallest
//!   remaining element, remove it, and *encode* it under `Uniform([0, N))`
//!   (adds ~log₂ N bits).
//! * **decode** mirrors exactly: decode an element under `Uniform([0, N))`,
//!   insert it, and *encode back* its rank among the i elements decoded so
//!   far, restoring the state the encoder observed.
//!
//! Net rate: `n·log₂N − log₂(n!)` ≈ `log₂ C(N, n)` bits, the set-optimal
//! size, reached within the ANS redundancy (~1e-5 bits/op) plus the 32-bit
//! initial state — the "initial bits" overhead that makes short friend
//! lists (NSG16) *worse* than the Comp. baseline, exactly as in Table 1.
//!
//! The encoder's select-kth runs on a [`Fenwick`] occupancy tree over the
//! sorted list (the structure the paper names as ROC's main search-time
//! cost); the decoder's rank-and-insert runs on a two-level bucket list
//! (`RankSet`), which profiles faster than a universe-sized Fenwick for
//! cluster-sized lists.

use super::{ensure_list_shape, DecodeScratch, Encoded, IdCodec};
use crate::ans::Ans;
use crate::fenwick::Fenwick;
use anyhow::{Context as _, Result};

pub struct Roc;

impl IdCodec for Roc {
    fn name(&self) -> &'static str {
        "roc"
    }

    fn encode(&self, ids: &[u32], universe: u32) -> Encoded {
        let mut sorted = ids.to_vec();
        sorted.sort_unstable();
        debug_assert!(sorted.windows(2).all(|w| w[0] != w[1]), "ids must be distinct");
        let n = sorted.len();
        let mut ans = Ans::new();
        let mut occupancy = Fenwick::ones(n);
        for i in (1..=n as u32).rev() {
            // Bits-back: sample which remaining element goes last.
            let j = ans.decode_uniform(i);
            let p = occupancy.select_kth(j as u64);
            occupancy.add(p, -1);
            ans.encode_uniform(sorted[p], universe);
        }
        let bits = ans.size_bits() as u64;
        Encoded { bytes: ans.to_bytes(), bits }
    }

    fn decode(&self, bytes: &[u8], universe: u32, n: usize, out: &mut Vec<u32>) {
        let mut scratch = DecodeScratch::default();
        self.decode_into(bytes, universe, n, out, &mut scratch);
    }

    /// The hot-path decode: per-cluster state (ANS stream, `RankSet`)
    /// comes from — and returns to — the scratch, so scanning many probed
    /// clusters allocates only on first-touch growth.
    fn decode_into(
        &self,
        bytes: &[u8],
        universe: u32,
        n: usize,
        out: &mut Vec<u32>,
        scratch: &mut DecodeScratch,
    ) {
        let DecodeScratch { ans, ranks, .. } = scratch;
        ans.read_from(bytes).expect("corrupt ROC blob");
        if matches!(ranks, Some(r) if r.covers(universe, n)) {
            ranks.as_mut().expect("checked above").clear();
        } else {
            *ranks = Some(RankSet::new(universe, n));
        }
        let ranks = ranks.as_mut().expect("rank set installed above");
        let start = out.len();
        for i in 1..=n as u32 {
            let x = ans.decode_uniform(universe);
            out.push(x);
            // Re-encode the rank of x among the i decoded elements —
            // restores the bits the encoder borrowed.
            let j = ranks.insert_and_rank(x);
            ans.encode_uniform(j, i);
        }
        debug_assert_eq!(out.len() - start, n);
    }

    fn try_decode_into(
        &self,
        bytes: &[u8],
        universe: u32,
        n: usize,
        out: &mut Vec<u32>,
        scratch: &mut DecodeScratch,
    ) -> Result<()> {
        ensure_list_shape("roc", universe, n)?;
        let DecodeScratch { ans, ranks, .. } = scratch;
        ans.read_from(bytes).context("roc: corrupt blob")?;
        if matches!(ranks, Some(r) if r.covers(universe, n)) {
            ranks.as_mut().expect("checked above").clear();
        } else {
            *ranks = Some(RankSet::new(universe, n));
        }
        let ranks = ranks.as_mut().expect("rank set installed above");
        let start = out.len();
        for i in 1..=n as u32 {
            // Safe on arbitrary state: decode_uniform yields < universe by
            // construction, terminates on any input (stream pops stop at
            // the initial state), and the re-encoded rank j is < i, so the
            // loop body cannot panic or spin — corruption surfaces in the
            // exit checks below instead.
            let x = ans.decode_uniform(universe);
            out.push(x);
            let j = ranks.insert_and_rank(x);
            ans.encode_uniform(j, i);
        }
        // The bits-back loop is a bijection, so decoding a well-formed
        // blob returns the state to exactly the fresh one; a flip or
        // truncation that got this far leaves head/stream off with
        // overwhelming probability.
        if ans.head != 1 << 32 || !ans.stream.is_empty() {
            out.truncate(start);
            anyhow::bail!("roc: ANS state not restored after decode — the blob is corrupt");
        }
        // The ids must form a set; a corrupt stream can still decode
        // in-range duplicates.
        let mut sorted = out[start..].to_vec();
        sorted.sort_unstable();
        if let Some(w) = sorted.windows(2).find(|w| w[0] == w[1]) {
            let dup = w[0];
            out.truncate(start);
            anyhow::bail!("roc: duplicate id {dup} in decoded set");
        }
        Ok(())
    }
}

/// Decode a ROC stream *and* return the fully-restored ANS state, which
/// must equal a fresh state — used by tests and by the stack-of-sets
/// experiments (multiple sets chained on one state).
pub fn decode_with_state(bytes: &[u8], universe: u32, n: usize) -> (Vec<u32>, Ans) {
    let mut ans = Ans::from_bytes(bytes).expect("corrupt ROC blob");
    let mut out = Vec::with_capacity(n);
    let mut ranks = RankSet::new(universe, n);
    for i in 1..=n as u32 {
        let x = ans.decode_uniform(universe);
        out.push(x);
        let j = ranks.insert_and_rank(x);
        ans.encode_uniform(j, i);
    }
    (out, ans)
}

/// Two-level dynamic rank structure over `[0, universe)`:
/// `B = max(universe >> 10, 1)`-ish buckets tracked by a Fenwick tree, plus
/// a sorted vec per bucket.  `insert_and_rank` is
/// O(log B + bucket_len) with tiny constants; bucket_len stays small for
/// cluster-sized lists.
pub struct RankSet {
    universe: u32,
    bucket_shift: u32,
    bucket_counts: Fenwick,
    buckets: Vec<Vec<u32>>,
}

impl RankSet {
    /// Bucket layout for a `(universe, expected_n)` request:
    /// `(shift, n_buckets)`, aiming for ~4 expected elements per bucket.
    fn layout(universe: u32, expected_n: usize) -> (u32, usize) {
        let target_buckets = (expected_n / 4).clamp(1, 1 << 16) as u32;
        let mut shift = 32u32;
        while shift > 0 && (universe as u64 >> (shift - 1)) < target_buckets as u64 {
            shift -= 1;
        }
        (shift, ((universe as u64 >> shift) + 1) as usize)
    }

    pub fn new(universe: u32, expected_n: usize) -> Self {
        let (shift, n_buckets) = Self::layout(universe, expected_n);
        RankSet {
            universe,
            bucket_shift: shift,
            bucket_counts: Fenwick::new(n_buckets),
            buckets: vec![Vec::new(); n_buckets],
        }
    }

    /// Empty the structure in place, keeping every bucket allocation.
    pub fn clear(&mut self) {
        self.bucket_counts.clear();
        for b in &mut self.buckets {
            b.clear();
        }
    }

    /// Whether this instance can serve a `(universe, expected_n)` decode
    /// without rebuilding. Correctness only needs the same universe (any
    /// bucket granularity ranks correctly); a rebuild is worth it solely
    /// when the request wants *more* buckets than we have, so reuse under
    /// this policy makes scratch growth monotone — after one pass over
    /// the clusters the layout is settled and decoding stops allocating.
    pub fn covers(&self, universe: u32, expected_n: usize) -> bool {
        self.universe == universe && Self::layout(universe, expected_n).1 <= self.buckets.len()
    }

    /// Insert `x` (must not be present) and return its 0-based rank.
    #[inline]
    pub fn insert_and_rank(&mut self, x: u32) -> u32 {
        let b = (x >> self.bucket_shift) as usize;
        let before = self.bucket_counts.prefix_sum(b);
        let bucket = &mut self.buckets[b];
        let pos = bucket.partition_point(|&y| y < x);
        bucket.insert(pos, x);
        self.bucket_counts.add(b, 1);
        before as u32 + pos as u32
    }
}

/// Ideal ROC size in bits for an n-subset of [0, N): log2 C(N, n) plus the
/// 64-bit serialized head (the paper's "initial bits" overhead).
pub fn ideal_bits(universe: u32, n: usize) -> f64 {
    crate::util::log2_binomial(universe as u64, n as u64) + 64.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::testutil::check_roundtrip;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        check_roundtrip(&Roc, 8);
    }

    #[test]
    fn state_fully_restored_after_decode() {
        // decode must return the ANS state to exactly the fresh state:
        // the bits-back loop is a bijection.
        let mut rng = Rng::new(9);
        for &(u, n) in &[(1000u32, 100usize), (1 << 20, 2000), (50, 50)] {
            let ids: Vec<u32> = rng.sample_distinct(u as u64, n).iter().map(|&v| v as u32).collect();
            let enc = Roc.encode(&ids, u);
            let (out, ans) = decode_with_state(&enc.bytes, u, n);
            assert_eq!(ans.head, 1 << 32, "u={u} n={n}");
            assert!(ans.stream.is_empty());
            let mut got = out;
            got.sort_unstable();
            let mut want = ids;
            want.sort_unstable();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn rate_beats_compact_and_tracks_binomial() {
        // IVF256-at-1e6 shape: the paper's headline 9.43 bits/id.
        let mut rng = Rng::new(10);
        let (u, n) = (1_000_000u32, 3906usize);
        let ids: Vec<u32> = rng.sample_distinct(u as u64, n).iter().map(|&v| v as u32).collect();
        let enc = Roc.encode(&ids, u);
        let bpe = enc.bits as f64 / n as f64;
        let ideal = ideal_bits(u, n) / n as f64;
        assert!((bpe - ideal).abs() < 0.05, "bpe={bpe} ideal={ideal}");
        assert!(bpe > 9.2 && bpe < 9.7, "paper reports ~9.43, got {bpe}");
        // And far below the 20-bit Comp. baseline.
        assert!(bpe < 10.0);
    }

    #[test]
    fn short_lists_pay_initial_bits() {
        // NSG16-like friend lists: ROC must be *worse* than ceil(log2 N)
        // because of the 32 initial bits (Table 1, NSG16 row).
        let mut rng = Rng::new(11);
        let u = 1_000_000u32;
        let mut total_bits = 0u64;
        let mut total_ids = 0usize;
        for _ in 0..200 {
            let n = 14 + rng.below(4) as usize;
            let ids: Vec<u32> = rng.sample_distinct(u as u64, n).iter().map(|&v| v as u32).collect();
            total_bits += Roc.encode(&ids, u).bits;
            total_ids += n;
        }
        let bpe = total_bits as f64 / total_ids as f64;
        assert!(bpe > 20.0, "short lists should exceed the 20-bit baseline, got {bpe}");
        assert!(bpe < 23.0, "but not by much: {bpe}");
    }

    #[test]
    fn rank_set_matches_naive() {
        let mut rng = Rng::new(12);
        for &u in &[10u32, 1000, 1 << 24] {
            let n = (u as usize).min(500);
            let ids: Vec<u32> = rng.sample_distinct(u as u64, n).iter().map(|&v| v as u32).collect();
            let mut rs = RankSet::new(u, n);
            let mut sorted: Vec<u32> = Vec::new();
            for &x in &ids {
                let want = sorted.partition_point(|&y| y < x) as u32;
                sorted.insert(want as usize, x);
                assert_eq!(rs.insert_and_rank(x), want, "u={u}");
            }
        }
    }

    #[test]
    fn rank_set_reuse_across_shapes_matches_fresh() {
        // One scratch across clusters of varying size and a universe
        // switch: decode_into must agree with the scratch-free decode.
        let mut rng = Rng::new(14);
        let mut scratch = DecodeScratch::default();
        let cases: [(u32, usize); 6] =
            [(1 << 16, 800), (1 << 16, 13), (1 << 16, 2000), (500, 400), (1 << 16, 50), (1 << 20, 1)];
        for &(u, n) in &cases {
            let ids: Vec<u32> =
                rng.sample_distinct(u as u64, n).iter().map(|&v| v as u32).collect();
            let enc = Roc.encode(&ids, u);
            let mut fresh = Vec::new();
            Roc.decode(&enc.bytes, u, n, &mut fresh);
            let mut reused = Vec::new();
            Roc.decode_into(&enc.bytes, u, n, &mut reused, &mut scratch);
            assert_eq!(reused, fresh, "u={u} n={n}");
        }
    }

    #[test]
    fn decode_order_is_deterministic() {
        let mut rng = Rng::new(13);
        let ids: Vec<u32> = rng.sample_distinct(1 << 16, 300).iter().map(|&v| v as u32).collect();
        let enc = Roc.encode(&ids, 1 << 16);
        let mut a = Vec::new();
        let mut b = Vec::new();
        Roc.decode(&enc.bytes, 1 << 16, 300, &mut a);
        Roc.decode(&enc.bytes, 1 << 16, 300, &mut b);
        assert_eq!(a, b);
    }
}
