//! `ans-i2` / `ans-i4` / `ans-i8` — interleaved multi-stream ANS id
//! codecs (the decode-throughput members of the per-list family).
//!
//! Each list is sorted and entropy-coded under `Uniform([0, universe))`
//! with `W` interleaved rANS states over one shared stream
//! ([`crate::ans::interleaved`]). Rate is `n·log₂(universe)` — the
//! `Comp.` baseline's cost without the ⌈·⌉ (so marginally *below*
//! `compact` whenever the universe is not a power of two) plus `W` heads
//! of framing — while decode runs `W` independent dependency chains with
//! no division, which is what the `bench-decode` table quantifies
//! against `roc`/`ef`/`compact`. ROC remains the rate-optimal choice;
//! this family is the speed end of the rate/throughput trade-off.
//!
//! Decode order is ascending (the sorted sequence), identical for every
//! `W` and for the `W = 1` single-stream special case — the cross-decode
//! contract `rust/tests/simd_parity.rs` pins. Streams are read in place
//! from the blob (no scratch state), so `decode_into` is the same
//! allocation-free bulk path as `decode`.

use super::{ensure_list_shape, DecodeScratch, Encoded, IdCodec};
use crate::ans::interleaved;
use anyhow::{Context as _, Result};

/// Interleaved-ANS id codec with a fixed way count (2, 4 or 8).
pub struct AnsInterleaved {
    ways: usize,
    name: &'static str,
}

impl AnsInterleaved {
    /// `ways` must be one of 2/4/8 (the registered spec variants).
    pub fn new(ways: usize) -> AnsInterleaved {
        let name = match ways {
            2 => "ans-i2",
            4 => "ans-i4",
            8 => "ans-i8",
            other => panic!("unregistered interleave width {other} (use 2, 4 or 8)"),
        };
        AnsInterleaved { ways, name }
    }

    pub fn ways(&self) -> usize {
        self.ways
    }
}

impl IdCodec for AnsInterleaved {
    fn name(&self) -> &'static str {
        self.name
    }

    fn encode(&self, ids: &[u32], universe: u32) -> Encoded {
        let mut sorted = ids.to_vec();
        sorted.sort_unstable();
        debug_assert!(sorted.windows(2).all(|w| w[0] != w[1]), "ids must be distinct");
        let m = universe.max(1);
        let bytes = interleaved::encode_uniform(&sorted, m, self.ways);
        // Payload accounting mirrors ROC's: stream words + serialized
        // heads; the u32 length prefix is framing, not payload.
        let words = (bytes.len() - 4 - self.ways * 8) / 4;
        Encoded { bits: interleaved::size_bits(words, self.ways), bytes }
    }

    fn decode(&self, bytes: &[u8], universe: u32, n: usize, out: &mut Vec<u32>) {
        interleaved::decode_uniform_into(bytes, universe.max(1), n, self.ways, out);
    }

    fn try_decode_into(
        &self,
        bytes: &[u8],
        universe: u32,
        n: usize,
        out: &mut Vec<u32>,
        _scratch: &mut DecodeScratch,
    ) -> Result<()> {
        ensure_list_shape(self.name, universe, n)?;
        let start = out.len();
        interleaved::try_decode_uniform_into(bytes, universe.max(1), n, self.ways, out)
            .with_context(|| format!("{}: corrupt blob", self.name))?;
        // Every decoded symbol is < universe by construction (the uniform
        // model cannot emit a slot outside [0, m)), so range needs no
        // re-check. The sorted-distinct contract does: a corrupted stream
        // decodes to in-range garbage that only the ascending-order check
        // can catch.
        if let Some(i) = (start + 1..out.len()).find(|&i| out[i] <= out[i - 1]) {
            let (a, b) = (out[i - 1], out[i]);
            out.truncate(start);
            anyhow::bail!("{}: ids not strictly increasing ({a} then {b})", self.name);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::testutil::check_roundtrip;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_widths() {
        check_roundtrip(&AnsInterleaved::new(2), 0xa152);
        check_roundtrip(&AnsInterleaved::new(4), 0xa154);
        check_roundtrip(&AnsInterleaved::new(8), 0xa158);
    }

    #[test]
    fn decode_is_ascending_and_width_invariant() {
        let mut rng = Rng::new(0xa15a);
        for &(u, n) in &[(1u32 << 20, 1000usize), (100, 100), (1000, 1), (1 << 16, 63)] {
            let ids: Vec<u32> =
                rng.sample_distinct(u as u64, n).into_iter().map(|v| v as u32).collect();
            let mut want = ids.clone();
            want.sort_unstable();
            for ways in [2usize, 4, 8] {
                let codec = AnsInterleaved::new(ways);
                let enc = codec.encode(&ids, u);
                let mut out = Vec::new();
                codec.decode(&enc.bytes, u, n, &mut out);
                assert_eq!(out, want, "u={u} n={n} ways={ways}");
            }
        }
    }

    #[test]
    fn rate_tracks_compact_not_roc() {
        // n·log2(u) + W·64: within a hair of compact on large lists, far
        // from ROC's set-optimal size — the documented trade-off.
        let mut rng = Rng::new(0xa15b);
        let (u, n) = (1_000_000u32, 4096usize);
        let ids: Vec<u32> =
            rng.sample_distinct(u as u64, n).into_iter().map(|v| v as u32).collect();
        let enc = AnsInterleaved::new(4).encode(&ids, u);
        let bpe = enc.bits as f64 / n as f64;
        let log2u = (u as f64).log2(); // ≈ 19.93 < compact's 20
        assert!(bpe > log2u && bpe < log2u + 0.2, "bpe={bpe}");
    }

    #[test]
    fn bits_never_exceed_storage() {
        for ways in [2usize, 4, 8] {
            let codec = AnsInterleaved::new(ways);
            let enc = codec.encode(&[], 1000);
            assert_eq!(enc.bits, ways as u64 * 64, "empty list carries only the heads");
            assert!(enc.bits as usize <= enc.bytes.len() * 8);
        }
    }
}
