//! Lossless codecs for the integer payloads of ANN indexes.
//!
//! Two codec families exist, mirroring the paper's settings (§4):
//!
//! * **Per-list codecs** ([`IdCodec`]) compress one inverted list or friend
//!   list into its own bit stream — the *online* setting. Implementations:
//!   [`fixed::Unc64`]/[`fixed::Unc32`] (uncompressed baselines),
//!   [`fixed::Compact`] (⌈log₂N⌉-bit packing), [`elias_fano::EliasFano`],
//!   [`roc::Roc`] (bits-back ANS, the paper's main contribution) and
//!   [`ansi::AnsInterleaved`] (`ans-i2/i4/i8`: N-way interleaved rANS,
//!   the division-free parallel-decode end of the trade-off).
//! * **Whole-structure codecs** compress an entire index component into one
//!   stream: [`wavelet::WaveletTree`] (full random access over the IVF
//!   assignment sequence), [`rec::Rec`] and [`zuckerli::Zuckerli`]
//!   (offline graph blobs), and [`pcodes::ClusterCodeCodec`]
//!   (cluster-conditioned PQ codes, Fig. 3).
//!
//! Bit accounting: `Encoded::bits` is the *exact* payload size in bits
//! (the paper reports "the sum of bits in all bit streams … without
//! overheads"); `bytes` is the byte-aligned serialized form actually stored.

pub mod fixed;
pub mod elias_fano;
pub mod ansi;
pub mod roc;
pub mod wavelet;
pub mod rec;
pub mod zuckerli;
pub mod pcodes;

use crate::ans::Ans;
use crate::codecs::rec::RecModel;
use crate::codecs::wavelet::WtStorage;
use crate::fenwick::Fenwick;
use anyhow::{bail, ensure, Result};

/// A compressed list plus its exact size in bits.
#[derive(Clone, Debug)]
pub struct Encoded {
    pub bytes: Vec<u8>,
    pub bits: u64,
}

/// Reusable decoder state for the search hot path.
///
/// Lives inside `index::SearchScratch`, so the per-probed-cluster decoders
/// (ROC id lists via [`IdCodec::decode_into`], PQ codes via
/// [`pcodes::ClusterCodeCodec::decode_into`]) stop allocating at steady
/// state: buffers are *reset* between clusters and queries, not rebuilt.
/// Growth is first-touch only — a structure is reallocated solely when a
/// request needs a larger shape than anything seen before.
#[derive(Default)]
pub struct DecodeScratch {
    /// Deserialized ANS state; the stream buffer is reused across blobs.
    pub ans: Ans,
    /// ROC's rank-and-insert structure (see [`roc::RankSet::covers`] for
    /// the reuse-vs-rebuild policy).
    pub ranks: Option<roc::RankSet>,
    /// Pólya-urn weights for the adaptive PQ-code coder.
    pub urn: Option<Fenwick>,
}

/// Codec for one list of distinct ids drawn from `[0, universe)`.
///
/// Implementations may emit the ids in any order on decode (the data is a
/// *set*; that invariance is exactly what ROC monetizes), but the order
/// must be deterministic. `decode` appends exactly `n` ids to `out`.
pub trait IdCodec: Send + Sync {
    fn name(&self) -> &'static str;

    fn encode(&self, ids: &[u32], universe: u32) -> Encoded;

    fn decode(&self, bytes: &[u8], universe: u32, n: usize, out: &mut Vec<u32>);

    /// Like [`IdCodec::decode`] (appends exactly `n` ids in the same
    /// deterministic order) but through a reusable [`DecodeScratch`], so
    /// steady-state decoding performs no heap allocation beyond
    /// first-touch scratch growth. The default implementation ignores the
    /// scratch; codecs with per-decode state (ROC) override it.
    fn decode_into(
        &self,
        bytes: &[u8],
        universe: u32,
        n: usize,
        out: &mut Vec<u32>,
        _scratch: &mut DecodeScratch,
    ) {
        self.decode(bytes, universe, n, out);
    }

    /// Whether `decode_nth` is supported (random access within a list).
    fn supports_random_access(&self) -> bool {
        false
    }

    /// Random access to the k-th id of the *decoded order*.
    fn decode_nth(&self, _bytes: &[u8], _universe: u32, _n: usize, _k: usize) -> Option<u32> {
        None
    }

    /// Fallible decode for **untrusted** bytes — the corruption boundary.
    ///
    /// Same contract as [`IdCodec::decode_into`] (appends exactly `n` ids
    /// in the deterministic decode order) except that every structural
    /// problem — truncated stream, internal length field lying about the
    /// payload, a decoded id outside `[0, universe)`, an impossible
    /// `(universe, n)` shape — is a structured `Err`, never a panic, an
    /// unbounded loop or an attacker-sized allocation. On `Err`, nothing
    /// is appended to `out`.
    ///
    /// The infallible [`IdCodec::decode_into`] remains the hot path for
    /// streams whose container checksum already verified; this method is
    /// what the fault-injection harness, the corrupt-stream property
    /// tests and the legacy-v1 deep validation at open call.
    fn try_decode_into(
        &self,
        bytes: &[u8],
        universe: u32,
        n: usize,
        out: &mut Vec<u32>,
        scratch: &mut DecodeScratch,
    ) -> Result<()>;

    /// Fallible encode: validates the distinct-ids-in-universe
    /// precondition in **release builds too** (the infallible
    /// [`IdCodec::encode`] only `debug_assert`s it), so a duplicate-id
    /// list from a buggy producer yields a structured error instead of
    /// silently encoding garbage. Build paths whose input is distinct by
    /// construction keep calling `encode`.
    fn try_encode(&self, ids: &[u32], universe: u32) -> Result<Encoded> {
        validate_id_list(self.name(), ids, universe)?;
        Ok(self.encode(ids, universe))
    }
}

/// Release-mode validation of the [`IdCodec`] encode precondition: every
/// id in `[0, universe)` and no duplicates.
pub fn validate_id_list(codec: &str, ids: &[u32], universe: u32) -> Result<()> {
    ensure!(
        ids.len() as u64 <= universe as u64,
        "{codec}: {} ids cannot be distinct in a universe of {universe}",
        ids.len()
    );
    if let Some(&bad) = ids.iter().find(|&&id| id as u64 >= universe as u64) {
        bail!("{codec}: id {bad} outside universe [0, {universe})");
    }
    let mut sorted = ids.to_vec();
    sorted.sort_unstable();
    if let Some(w) = sorted.windows(2).find(|w| w[0] == w[1]) {
        bail!("{codec}: duplicate id {} (ids must be distinct)", w[0]);
    }
    Ok(())
}

/// Shared shape guard for [`IdCodec::try_decode_into`] impls: a list of
/// `n` *distinct* ids cannot come from a smaller universe.
pub(crate) fn ensure_list_shape(codec: &str, universe: u32, n: usize) -> Result<()> {
    ensure!(
        n as u64 <= universe as u64,
        "{codec}: claimed {n} distinct ids from a universe of {universe}"
    );
    Ok(())
}

/// A parsed codec specification — the single registry covering both
/// per-list codecs (one stream per inverted/friend list) and
/// whole-structure codecs (wavelet trees over the assignment sequence,
/// whole-graph REC/Zuckerli blobs).
///
/// Parsing is fallible with an actionable error (the valid-name list), so
/// CLI/bench boundaries can report typos instead of panicking; the
/// canonical [`CodecSpec::name`] is what gets persisted in index headers
/// and printed in bench labels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecSpec {
    /// One stream per list (`unc64`, `unc32`, `compact`, `ef`, `roc`,
    /// `ans-i2`, `ans-i4`, `ans-i8`).
    PerList(&'static str),
    /// Wavelet tree over the whole IVF assignment sequence (`wt`, `wt1`).
    Wavelet(WtStorage),
    /// Whole-graph Random Edge Coding (`rec`, `rec-uniform`).
    Rec(RecModel),
    /// Whole-graph Zuckerli-style baseline (`zuckerli`).
    Zuckerli,
}

impl CodecSpec {
    /// Every canonical codec name, for error messages and docs.
    pub const VALID: &'static [&'static str] = &[
        "unc64", "unc32", "compact", "ef", "roc", "ans-i2", "ans-i4", "ans-i8", "wt", "wt1",
        "rec", "rec-uniform", "zuckerli",
    ];

    /// Parse a codec name (canonical or alias) into a spec.
    pub fn parse(name: &str) -> Result<CodecSpec> {
        Ok(match name {
            "unc64" | "unc" => CodecSpec::PerList("unc64"),
            "unc32" => CodecSpec::PerList("unc32"),
            "compact" | "comp" => CodecSpec::PerList("compact"),
            "ef" => CodecSpec::PerList("ef"),
            "roc" => CodecSpec::PerList("roc"),
            "ans-i2" => CodecSpec::PerList("ans-i2"),
            "ans-i4" => CodecSpec::PerList("ans-i4"),
            "ans-i8" => CodecSpec::PerList("ans-i8"),
            "wt" => CodecSpec::Wavelet(WtStorage::Flat),
            "wt1" => CodecSpec::Wavelet(WtStorage::Rrr),
            "rec" => CodecSpec::Rec(RecModel::PolyaUrn),
            "rec-uniform" => CodecSpec::Rec(RecModel::Uniform),
            "zuckerli" | "zuck" => CodecSpec::Zuckerli,
            other => bail!(
                "unknown codec {other:?}; valid names: {}",
                CodecSpec::VALID.join(", ")
            ),
        })
    }

    /// Canonical name (what headers store and tables print).
    pub fn name(&self) -> &'static str {
        match self {
            CodecSpec::PerList(n) => n,
            CodecSpec::Wavelet(WtStorage::Flat) => "wt",
            CodecSpec::Wavelet(WtStorage::Rrr) => "wt1",
            CodecSpec::Rec(RecModel::PolyaUrn) => "rec",
            CodecSpec::Rec(RecModel::Uniform) => "rec-uniform",
            CodecSpec::Zuckerli => "zuckerli",
        }
    }

    /// Whether this spec names a per-list codec (usable for one inverted
    /// list or friend list at a time — the online setting).
    pub fn is_per_list(&self) -> bool {
        matches!(self, CodecSpec::PerList(_))
    }

    /// Instantiate the per-list codec, or explain why this spec cannot be
    /// used where one is required.
    pub fn id_codec(&self) -> Result<Box<dyn IdCodec>> {
        match self {
            CodecSpec::PerList("unc64") => Ok(Box::new(fixed::Unc64)),
            CodecSpec::PerList("unc32") => Ok(Box::new(fixed::Unc32)),
            CodecSpec::PerList("compact") => Ok(Box::new(fixed::Compact)),
            CodecSpec::PerList("ef") => Ok(Box::new(elias_fano::EliasFano)),
            CodecSpec::PerList("roc") => Ok(Box::new(roc::Roc)),
            CodecSpec::PerList("ans-i2") => Ok(Box::new(ansi::AnsInterleaved::new(2))),
            CodecSpec::PerList("ans-i4") => Ok(Box::new(ansi::AnsInterleaved::new(4))),
            CodecSpec::PerList("ans-i8") => Ok(Box::new(ansi::AnsInterleaved::new(8))),
            CodecSpec::PerList(other) => bail!("unregistered per-list codec {other:?}"),
            other => bail!(
                "codec {:?} is a whole-structure codec, not a per-list codec \
                 (per-list names: {})",
                other.name(),
                PER_LIST_CODECS.join(", ")
            ),
        }
    }
}

/// All per-list codec names: the Table-1 columns first, then the
/// interleaved-ANS throughput family (`ans-iW`: `W` round-robin rANS
/// states over one stream — same ids, division-free parallel decode).
pub const PER_LIST_CODECS: [&str; 8] =
    ["unc64", "compact", "ef", "unc32", "roc", "ans-i2", "ans-i4", "ans-i8"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_names_are_rejected_with_the_valid_list() {
        for name in ["", "nope", "ROC", "roc ", "unc6", "elias", "wavelet"] {
            let err = CodecSpec::parse(name).expect_err("should not resolve");
            let msg = format!("{err}");
            assert!(msg.contains("unknown codec"), "{name:?}: {msg}");
            assert!(msg.contains("roc") && msg.contains("zuckerli"), "{name:?}: {msg}");
        }
    }

    #[test]
    fn aliases_resolve_to_canonical_codecs() {
        assert_eq!(CodecSpec::parse("unc").unwrap().name(), "unc64");
        assert_eq!(CodecSpec::parse("comp").unwrap().name(), "compact");
        assert_eq!(CodecSpec::parse("zuck").unwrap().name(), "zuckerli");
    }

    #[test]
    fn every_valid_name_parses_to_itself() {
        for name in CodecSpec::VALID {
            let spec = CodecSpec::parse(name).unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(spec.name(), *name, "canonical name must round-trip");
        }
    }

    #[test]
    fn whole_structure_specs_refuse_per_list_use() {
        for name in ["wt", "wt1", "rec", "rec-uniform", "zuckerli"] {
            let spec = CodecSpec::parse(name).unwrap();
            assert!(!spec.is_per_list());
            let err = spec.id_codec().expect_err("must not build an IdCodec");
            assert!(format!("{err}").contains("per-list"), "{name}: {err}");
        }
    }

    #[test]
    fn per_list_codecs_all_resolve_and_roundtrip() {
        for (i, name) in PER_LIST_CODECS.iter().enumerate() {
            let spec = CodecSpec::parse(name).unwrap_or_else(|e| panic!("{e}"));
            assert!(spec.is_per_list());
            let codec = spec.id_codec().unwrap();
            assert_eq!(codec.name(), *name, "canonical name must match registry key");
            testutil::check_roundtrip(codec.as_ref(), 0xc0dec + i as u64);
        }
    }

    #[test]
    fn decode_nth_agrees_with_full_decode_for_every_per_list_codec() {
        // Property: for every registered per-list codec, random access
        // (`decode_nth`) must agree position-by-position with the full
        // `decode` order — the contract the tombstone-aware dynamic
        // search path and §4.1's deferred id resolution both lean on —
        // and codecs without random access must say so consistently.
        use crate::util::Rng;
        let mut rng = Rng::new(0xdec0de);
        for name in PER_LIST_CODECS.iter() {
            let codec = CodecSpec::parse(name).unwrap().id_codec().unwrap();
            for trial in 0..40 {
                let universe = match trial % 4 {
                    0 => 1 + rng.below(64) as u32,
                    1 => 1 + rng.below(4096) as u32,
                    2 => 1 + rng.below(1 << 20) as u32,
                    _ => u32::MAX - rng.below(1000) as u32,
                };
                let n = (rng.below(200) as usize).min(universe as usize);
                let ids: Vec<u32> = rng
                    .sample_distinct(universe as u64, n)
                    .into_iter()
                    .map(|v| v as u32)
                    .collect();
                let enc = codec.encode(&ids, universe);
                let mut full = Vec::new();
                codec.decode(&enc.bytes, universe, n, &mut full);
                if codec.supports_random_access() {
                    for k in 0..n {
                        assert_eq!(
                            codec.decode_nth(&enc.bytes, universe, n, k),
                            Some(full[k]),
                            "{name}: trial {trial}, nth({k}) of {n} (universe {universe})"
                        );
                    }
                    assert_eq!(
                        codec.decode_nth(&enc.bytes, universe, n, n),
                        None,
                        "{name}: nth past the end must be None"
                    );
                } else {
                    for k in [0usize, n / 2, n.saturating_sub(1)] {
                        assert_eq!(
                            codec.decode_nth(&enc.bytes, universe, n, k),
                            None,
                            "{name}: claims no random access but answered nth({k})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn try_encode_rejects_bad_id_lists_in_release_builds() {
        for name in PER_LIST_CODECS {
            let codec = CodecSpec::parse(name).unwrap().id_codec().unwrap();
            // Valid list passes and matches the infallible encode.
            let enc = codec.try_encode(&[3, 1, 7], 10).unwrap();
            assert_eq!(enc.bytes, codec.encode(&[3, 1, 7], 10).bytes, "{name}");
            // Duplicate ids are a structured error, not silent garbage.
            let err = codec.try_encode(&[3, 1, 3], 10).expect_err(name);
            assert!(format!("{err}").contains("duplicate"), "{name}: {err}");
            // Out-of-universe ids are rejected.
            let err = codec.try_encode(&[3, 10], 10).expect_err(name);
            assert!(format!("{err}").contains("universe"), "{name}: {err}");
            // More ids than the universe can hold.
            assert!(codec.try_encode(&[0, 1, 2], 2).is_err(), "{name}");
        }
    }

    #[test]
    fn try_decode_rejects_impossible_shapes() {
        for name in PER_LIST_CODECS {
            let codec = CodecSpec::parse(name).unwrap().id_codec().unwrap();
            let mut scratch = DecodeScratch::default();
            let mut out = Vec::new();
            // n > universe is impossible for distinct ids, whatever the
            // bytes claim.
            let err = codec
                .try_decode_into(&[0u8; 1024], 8, 9, &mut out, &mut scratch)
                .expect_err(name);
            assert!(format!("{err}").contains("universe"), "{name}: {err}");
            assert!(out.is_empty(), "{name}: out must stay untouched on error");
            // The empty stream can never hold a nonempty list.
            assert!(
                codec.try_decode_into(&[], 100, 5, &mut out, &mut scratch).is_err(),
                "{name}: empty stream decoded 5 ids"
            );
            assert!(out.is_empty(), "{name}");
        }
    }

    #[test]
    fn registry_covers_every_per_list_codec() {
        // Every registered name resolves; the decode of an empty list is a
        // no-op for each of them.
        for name in PER_LIST_CODECS {
            let codec = CodecSpec::parse(name).unwrap().id_codec().unwrap();
            let enc = codec.encode(&[], 1000);
            let mut out = Vec::new();
            codec.decode(&enc.bytes, 1000, 0, &mut out);
            assert!(out.is_empty(), "{name}: empty list must decode to nothing");
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::Rng;

    /// Exhaustive-ish roundtrip property check for a per-list codec.
    pub fn check_roundtrip(codec: &dyn IdCodec, seed: u64) {
        let mut rng = Rng::new(seed);
        let cases: Vec<(u32, usize)> = vec![
            (1, 0),
            (1, 1),
            (2, 1),
            (100, 100), // the full universe
            (1000, 1),
            (1000, 17),
            (1 << 20, 1000),
            (1_000_000, 4096),
            (u32::MAX, 64),
        ];
        // One scratch across every (universe, n) case: decode_into must
        // survive shape changes and match the scratch-free decode exactly.
        let mut scratch = DecodeScratch::default();
        for (universe, n) in cases {
            let ids: Vec<u32> = rng
                .sample_distinct(universe as u64, n)
                .into_iter()
                .map(|v| v as u32)
                .collect();
            let enc = codec.encode(&ids, universe);
            let mut out = Vec::new();
            codec.decode(&enc.bytes, universe, n, &mut out);
            let mut out_scratch = Vec::new();
            codec.decode_into(&enc.bytes, universe, n, &mut out_scratch, &mut scratch);
            assert_eq!(
                out_scratch,
                out,
                "{}: decode_into disagrees with decode (universe={universe} n={n})",
                codec.name()
            );
            let mut out_try = Vec::new();
            codec
                .try_decode_into(&enc.bytes, universe, n, &mut out_try, &mut scratch)
                .unwrap_or_else(|e| {
                    panic!(
                        "{}: try_decode_into rejected a valid stream \
                         (universe={universe} n={n}): {e}",
                        codec.name()
                    )
                });
            assert_eq!(
                out_try,
                out,
                "{}: try_decode_into disagrees with decode (universe={universe} n={n})",
                codec.name()
            );
            let mut got = out.clone();
            got.sort_unstable();
            let mut want = ids.clone();
            want.sort_unstable();
            assert_eq!(got, want, "{} universe={universe} n={n}", codec.name());
            assert!(
                enc.bits as usize <= enc.bytes.len() * 8,
                "bit accounting exceeds storage"
            );
            if codec.supports_random_access() {
                for k in 0..n {
                    let v = codec.decode_nth(&enc.bytes, universe, n, k).unwrap();
                    assert_eq!(v, out[k], "nth({k})");
                }
                assert_eq!(codec.decode_nth(&enc.bytes, universe, n, n), None);
            }
        }
    }
}
