//! Cluster-conditioned entropy coding of PQ codes (paper §5.2, Fig. 3).
//!
//! Vector-quantizer outputs are near max-entropy *marginally*, but within
//! an IVF cluster the sub-quantizer codes concentrate: conditioning on the
//! cluster exposes redundancy.  Each column (sub-quantizer) of each
//! cluster's code matrix is coded with the adaptive Pólya-urn model of
//! eq. (6)–(7) — `P(x) = (1 + #occurrences so far) / (alphabet + i)` — via
//! [`ReverseAdaptiveCoder`], one ANS stream per (cluster, column) so that
//! the online setting's per-cluster random access is preserved.

use crate::ans::{Ans, ReverseAdaptiveCoder};
use crate::codecs::DecodeScratch;
use crate::fenwick::Fenwick;

/// Coder for one cluster's `n × m` code matrix (row-major), alphabet
/// `ksub` (256 for 8-bit PQ, 1024 for 10-bit).
pub struct ClusterCodeCodec {
    pub ksub: u32,
    pub m: usize,
}

/// A compressed cluster: one blob per column + exact bit total.
pub struct EncodedCluster {
    pub columns: Vec<Vec<u8>>,
    pub bits: u64,
}

impl ClusterCodeCodec {
    pub fn new(ksub: u32, m: usize) -> Self {
        ClusterCodeCodec { ksub, m }
    }

    /// Encode `codes` (row-major, `n × m`).
    pub fn encode(&self, codes: &[u16], n: usize) -> EncodedCluster {
        assert_eq!(codes.len(), n * self.m);
        let coder = ReverseAdaptiveCoder::new(self.ksub);
        let mut columns = Vec::with_capacity(self.m);
        let mut bits = 0u64;
        let mut col = Vec::with_capacity(n);
        for j in 0..self.m {
            col.clear();
            col.extend((0..n).map(|i| codes[i * self.m + j] as u32));
            let mut ans = Ans::new();
            coder.encode(&mut ans, &col);
            bits += ans.size_bits() as u64;
            columns.push(ans.to_bytes());
        }
        EncodedCluster { columns, bits }
    }

    /// Decode a cluster of `n` rows back to row-major codes.
    pub fn decode(&self, enc: &EncodedCluster, n: usize) -> Vec<u16> {
        let mut out = Vec::new();
        let mut scratch = DecodeScratch::default();
        self.decode_into(enc, n, &mut out, &mut scratch);
        out
    }

    /// Decode a cluster into a reusable row-major buffer through a
    /// [`DecodeScratch`] — the allocation-free per-probe path of the
    /// PqCompressed scan: the ANS stream buffer and the Pólya urn are
    /// reset between clusters, and symbols are written straight into
    /// `out` at their strided position (no per-column intermediate).
    pub fn decode_into(
        &self,
        enc: &EncodedCluster,
        n: usize,
        out: &mut Vec<u16>,
        scratch: &mut DecodeScratch,
    ) {
        self.decode_columns_into(enc.columns.iter().map(|c| c.as_slice()), n, out, scratch);
    }

    /// Like [`ClusterCodeCodec::decode_into`] but over any source of the
    /// `m` column blobs — the persisted index stores all clusters'
    /// columns end-to-end in one shared buffer ([`crate::util::Blobs`])
    /// and feeds the slices straight from the mapped file region.
    pub fn decode_columns_into<'a, I>(
        &self,
        columns: I,
        n: usize,
        out: &mut Vec<u16>,
        scratch: &mut DecodeScratch,
    ) where
        I: IntoIterator<Item = &'a [u8]>,
    {
        out.clear();
        out.resize(n * self.m, 0);
        let coder = ReverseAdaptiveCoder::new(self.ksub);
        let DecodeScratch { ans, urn, .. } = scratch;
        let a = self.ksub as usize;
        if !matches!(urn, Some(w) if w.len() == a) {
            *urn = Some(Fenwick::new(a));
        }
        let weights = urn.as_mut().expect("urn installed above");
        let m = self.m;
        let mut cols = 0usize;
        for (j, blob) in columns.into_iter().enumerate() {
            ans.read_from(blob).expect("corrupt pcodes blob");
            coder.decode_with(ans, n, weights, |i, v| out[i * m + j] = v as u16);
            cols += 1;
        }
        debug_assert_eq!(cols, m, "expected one blob per sub-quantizer");
    }

    /// Fallible variant of [`ClusterCodeCodec::decode_columns_into`] for
    /// **untrusted** blobs: a truncated or length-lying stream is a
    /// structured error instead of a panic. The decode loop itself is
    /// bounded (`n` symbols per column, every symbol `< ksub` by model
    /// construction), and each well-formed column drains its ANS state
    /// back to exactly the fresh one — the restoration check below is
    /// what catches in-place byte flips. `out` is cleared on `Err`.
    pub fn try_decode_columns_into<'a, I>(
        &self,
        columns: I,
        n: usize,
        out: &mut Vec<u16>,
        scratch: &mut DecodeScratch,
    ) -> anyhow::Result<()>
    where
        I: IntoIterator<Item = &'a [u8]>,
    {
        out.clear();
        out.resize(n * self.m, 0);
        let coder = ReverseAdaptiveCoder::new(self.ksub);
        let DecodeScratch { ans, urn, .. } = scratch;
        let a = self.ksub as usize;
        if !matches!(urn, Some(w) if w.len() == a) {
            *urn = Some(Fenwick::new(a));
        }
        let weights = urn.as_mut().expect("urn installed above");
        let m = self.m;
        let mut cols = 0usize;
        for (j, blob) in columns.into_iter().enumerate() {
            if let Err(e) = ans.read_from(blob) {
                out.clear();
                anyhow::bail!("pcodes: corrupt stream for column {j}: {e}");
            }
            coder.decode_with(ans, n, weights, |i, v| out[i * m + j] = v as u16);
            if ans.head != 1 << 32 || !ans.stream.is_empty() {
                out.clear();
                anyhow::bail!(
                    "pcodes: ANS state not restored after column {j} — the blob is corrupt"
                );
            }
            cols += 1;
        }
        if cols != m {
            out.clear();
            anyhow::bail!("pcodes: {cols} column blobs for {m} sub-quantizers");
        }
        Ok(())
    }

    /// Ideal (model) bits for the cluster — used for rate accounting.
    pub fn ideal_bits(&self, codes: &[u16], n: usize) -> f64 {
        let coder = ReverseAdaptiveCoder::new(self.ksub);
        let mut bits = 0.0;
        let mut col = Vec::with_capacity(n);
        for j in 0..self.m {
            col.clear();
            col.extend((0..n).map(|i| codes[i * self.m + j] as u32));
            bits += coder.ideal_bits(&col);
        }
        bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_random_codes() {
        let mut rng = Rng::new(40);
        for &(ksub, m, n) in &[(256u32, 8usize, 100usize), (1024, 4, 500), (16, 16, 3), (256, 1, 0)] {
            let codec = ClusterCodeCodec::new(ksub, m);
            let codes: Vec<u16> = (0..n * m).map(|_| rng.below(ksub as u64) as u16).collect();
            let enc = codec.encode(&codes, n);
            assert_eq!(codec.decode(&enc, n), codes);
        }
    }

    #[test]
    fn decode_into_scratch_reuse_matches_fresh() {
        // One scratch across clusters of different shapes — including an
        // alphabet switch that forces the urn to be rebuilt — must agree
        // with fresh decodes.
        let mut rng = Rng::new(44);
        let mut scratch = DecodeScratch::default();
        let mut reused = Vec::new();
        for &(ksub, m, n) in &[(256u32, 8usize, 120usize), (256, 8, 7), (1024, 4, 300), (256, 8, 0), (16, 2, 50)]
        {
            let codec = ClusterCodeCodec::new(ksub, m);
            let codes: Vec<u16> = (0..n * m).map(|_| rng.below(ksub as u64) as u16).collect();
            let enc = codec.encode(&codes, n);
            codec.decode_into(&enc, n, &mut reused, &mut scratch);
            assert_eq!(reused, codes, "ksub={ksub} m={m} n={n}");
        }
    }

    #[test]
    fn skewed_columns_compress_below_log_ksub() {
        // Within-cluster concentration: each column uses only 16 of 256
        // values — the Fig. 3 effect.
        let mut rng = Rng::new(41);
        let (m, n) = (16usize, 2000usize);
        let codec = ClusterCodeCodec::new(256, m);
        let palettes: Vec<Vec<u16>> = (0..m)
            .map(|_| (0..16).map(|_| rng.below(256) as u16).collect())
            .collect();
        let codes: Vec<u16> = (0..n * m)
            .map(|i| palettes[i % m][rng.below(16) as usize])
            .collect();
        let enc = codec.encode(&codes, n);
        let bpe = enc.bits as f64 / (n * m) as f64;
        assert!(bpe < 5.0, "expected ~4+eps bits, got {bpe}");
        assert_eq!(codec.decode(&enc, n), codes);
    }

    #[test]
    fn uniform_codes_incompressible() {
        // The paper's negative control (FB-ssnpp): ~8.0 bits/element.
        let mut rng = Rng::new(42);
        let (m, n) = (8usize, 4000usize);
        let codec = ClusterCodeCodec::new(256, m);
        let codes: Vec<u16> = (0..n * m).map(|_| rng.below(256) as u16).collect();
        let enc = codec.encode(&codes, n);
        let bpe = enc.bits as f64 / (n * m) as f64;
        assert!(bpe > 7.9 && bpe < 8.2, "bpe={bpe}");
    }

    #[test]
    fn bits_match_model_ideal() {
        let mut rng = Rng::new(43);
        let (m, n) = (4usize, 1000usize);
        let codec = ClusterCodeCodec::new(256, m);
        let codes: Vec<u16> = (0..n * m).map(|_| rng.below(32) as u16).collect();
        let enc = codec.encode(&codes, n);
        let ideal = codec.ideal_bits(&codes, n) + 64.0 * m as f64; // + initial bits
        assert!(
            (enc.bits as f64 - ideal).abs() < 0.02 * ideal + 64.0,
            "bits={} ideal={ideal}",
            enc.bits
        );
    }
}
