//! Fixed-width id storage: the paper's **Unc.** (64/32-bit machine words)
//! and **Comp.** (⌈log₂N⌉-bit packed) baselines.

use super::{ensure_list_shape, DecodeScratch, Encoded, IdCodec};
use crate::util::bits::{read_bits_at, BitWriter};
use crate::util::bits_for;
use anyhow::{ensure, Result};

/// 64-bit words per id — Faiss's default representation.
pub struct Unc64;

impl IdCodec for Unc64 {
    fn name(&self) -> &'static str {
        "unc64"
    }

    fn encode(&self, ids: &[u32], _universe: u32) -> Encoded {
        let mut bytes = Vec::with_capacity(ids.len() * 8);
        for &id in ids {
            bytes.extend_from_slice(&(id as u64).to_le_bytes());
        }
        Encoded { bits: ids.len() as u64 * 64, bytes }
    }

    fn decode(&self, bytes: &[u8], _universe: u32, n: usize, out: &mut Vec<u32>) {
        for i in 0..n {
            let v = u64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap());
            out.push(v as u32);
        }
    }

    fn supports_random_access(&self) -> bool {
        true
    }

    fn decode_nth(&self, bytes: &[u8], _universe: u32, n: usize, k: usize) -> Option<u32> {
        if k >= n {
            return None;
        }
        Some(u64::from_le_bytes(bytes[k * 8..k * 8 + 8].try_into().unwrap()) as u32)
    }

    fn try_decode_into(
        &self,
        bytes: &[u8],
        universe: u32,
        n: usize,
        out: &mut Vec<u32>,
        _scratch: &mut DecodeScratch,
    ) -> Result<()> {
        ensure_list_shape("unc64", universe, n)?;
        ensure!(
            bytes.len() / 8 >= n,
            "unc64: stream holds {} bytes, need {} for {n} ids",
            bytes.len(),
            n.saturating_mul(8)
        );
        let start = out.len();
        for i in 0..n {
            let v = u64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap());
            if v >= universe as u64 {
                out.truncate(start);
                anyhow::bail!("unc64: id {v} outside universe [0, {universe})");
            }
            out.push(v as u32);
        }
        Ok(())
    }
}

/// 32-bit words per id — the graph-index default.
pub struct Unc32;

impl IdCodec for Unc32 {
    fn name(&self) -> &'static str {
        "unc32"
    }

    fn encode(&self, ids: &[u32], _universe: u32) -> Encoded {
        let mut bytes = Vec::with_capacity(ids.len() * 4);
        for &id in ids {
            bytes.extend_from_slice(&id.to_le_bytes());
        }
        Encoded { bits: ids.len() as u64 * 32, bytes }
    }

    fn decode(&self, bytes: &[u8], _universe: u32, n: usize, out: &mut Vec<u32>) {
        for i in 0..n {
            out.push(u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap()));
        }
    }

    fn supports_random_access(&self) -> bool {
        true
    }

    fn decode_nth(&self, bytes: &[u8], _universe: u32, n: usize, k: usize) -> Option<u32> {
        if k >= n {
            return None;
        }
        Some(u32::from_le_bytes(bytes[k * 4..k * 4 + 4].try_into().unwrap()))
    }

    fn try_decode_into(
        &self,
        bytes: &[u8],
        universe: u32,
        n: usize,
        out: &mut Vec<u32>,
        _scratch: &mut DecodeScratch,
    ) -> Result<()> {
        ensure_list_shape("unc32", universe, n)?;
        ensure!(
            bytes.len() / 4 >= n,
            "unc32: stream holds {} bytes, need {} for {n} ids",
            bytes.len(),
            n.saturating_mul(4)
        );
        let start = out.len();
        for i in 0..n {
            let v = u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
            if v as u64 >= universe as u64 {
                out.truncate(start);
                anyhow::bail!("unc32: id {v} outside universe [0, {universe})");
            }
            out.push(v);
        }
        Ok(())
    }
}

/// ⌈log₂(universe)⌉ bits per id, bit-packed — the **Comp.** baseline
/// ("a basic improvement is to store them as ⌈log N⌉ bits").
pub struct Compact;

impl Compact {
    fn width(universe: u32) -> u32 {
        bits_for(universe as u64).max(1)
    }
}

impl IdCodec for Compact {
    fn name(&self) -> &'static str {
        "compact"
    }

    fn encode(&self, ids: &[u32], universe: u32) -> Encoded {
        let w = Self::width(universe);
        let mut bw = BitWriter::with_capacity(ids.len() * w as usize);
        for &id in ids {
            debug_assert!(id < universe || universe == 0);
            bw.write(id as u64, w);
        }
        let bits = bw.len_bits() as u64;
        let buf = bw.finish();
        let mut bytes = Vec::with_capacity(buf.words.len() * 8);
        for word in &buf.words {
            bytes.extend_from_slice(&word.to_le_bytes());
        }
        Encoded { bytes, bits }
    }

    fn decode(&self, bytes: &[u8], universe: u32, n: usize, out: &mut Vec<u32>) {
        let w = Self::width(universe);
        for i in 0..n {
            out.push(read_bits_at(bytes, i * w as usize, w) as u32);
        }
    }

    fn supports_random_access(&self) -> bool {
        true
    }

    // Reads straight from the serialized blob — no BitBuf rebuild, no
    // allocation — since this runs once per search winner (§4.1's deferred
    // id resolution).
    fn decode_nth(&self, bytes: &[u8], universe: u32, n: usize, k: usize) -> Option<u32> {
        if k >= n {
            return None;
        }
        let w = Self::width(universe);
        Some(read_bits_at(bytes, k * w as usize, w) as u32)
    }

    fn try_decode_into(
        &self,
        bytes: &[u8],
        universe: u32,
        n: usize,
        out: &mut Vec<u32>,
        _scratch: &mut DecodeScratch,
    ) -> Result<()> {
        ensure_list_shape("compact", universe, n)?;
        let w = Self::width(universe);
        // `read_bits_at` zero-fills past the blob end in release builds —
        // a truncated stream would silently decode as id 0 — so the
        // length check here is what turns truncation into an error.
        ensure!(
            (n as u64) * (w as u64) <= (bytes.len() as u64) * 8,
            "compact: stream holds {} bits, need {} for {n} ids of width {w}",
            bytes.len() * 8,
            (n as u64) * (w as u64)
        );
        let start = out.len();
        for i in 0..n {
            let v = read_bits_at(bytes, i * w as usize, w);
            if v >= universe as u64 {
                out.truncate(start);
                anyhow::bail!("compact: id {v} outside universe [0, {universe})");
            }
            out.push(v as u32);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::testutil::check_roundtrip;

    #[test]
    fn unc64_roundtrip() {
        check_roundtrip(&Unc64, 1);
    }

    #[test]
    fn unc32_roundtrip() {
        check_roundtrip(&Unc32, 2);
    }

    #[test]
    fn compact_roundtrip() {
        check_roundtrip(&Compact, 3);
    }

    #[test]
    fn compact_bits_match_formula() {
        // N = 1e6 -> 20 bits/id, the paper's "Comp." reference.
        let ids: Vec<u32> = (0..1000).map(|i| i * 997).collect();
        let enc = Compact.encode(&ids, 1_000_000);
        assert_eq!(enc.bits, 1000 * 20);
        let enc64 = Unc64.encode(&ids, 1_000_000);
        assert_eq!(enc64.bits, 1000 * 64);
    }

    #[test]
    fn compact_preserves_order() {
        // Fixed-width codecs are order-preserving (unlike set codecs).
        let ids = vec![5u32, 1, 9, 3];
        let enc = Compact.encode(&ids, 10);
        let mut out = Vec::new();
        Compact.decode(&enc.bytes, 10, 4, &mut out);
        assert_eq!(out, ids);
    }
}
