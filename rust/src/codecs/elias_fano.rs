//! Elias-Fano encoding of monotone id lists (paper §A.1, **EF** columns).
//!
//! Ids are sorted (the set interpretation), split into `l = ⌊log₂(u/n)⌋`
//! low bits stored verbatim and high bits stored as a unary-coded
//! non-decreasing sequence.  Total ≈ `n(2 + log₂(u/n))` bits — within
//! ~0.56 bits/id of the set-information optimum for large n, which is the
//! gap to ROC visible in Table 1.
//!
//! Supports O(1)-ish random access (`decode_nth`) through select1 on the
//! upper-bits bitvector, which the IVF search path uses to resolve
//! (cluster, offset) pairs without decoding whole lists.

use super::{ensure_list_shape, DecodeScratch, Encoded, IdCodec};
use crate::bitvec::RsBitVec;
use crate::util::bits::{read_bits_at, BitBuf, BitWriter};
use crate::util::{ReadBuf, WriteBuf};
use anyhow::{ensure, Context as _, Result};

pub struct EliasFano;

/// Number of low bits: floor(log2(u / n)) (0 when u <= n).
fn low_bits(universe: u32, n: usize) -> u32 {
    if n == 0 || universe as u64 <= n as u64 {
        return 0;
    }
    let ratio = universe as u64 / n as u64;
    if ratio <= 1 {
        0
    } else {
        63 - ratio.leading_zeros()
    }
}

impl IdCodec for EliasFano {
    fn name(&self) -> &'static str {
        "ef"
    }

    fn encode(&self, ids: &[u32], universe: u32) -> Encoded {
        let n = ids.len();
        let mut sorted = ids.to_vec();
        sorted.sort_unstable();
        let l = low_bits(universe, n);

        let mut lower = BitWriter::with_capacity(n * l as usize);
        let mut upper = BitWriter::with_capacity(2 * n + 64);
        let mut prev_hi = 0u64;
        for &id in &sorted {
            lower.write(id as u64, l);
            let hi = (id as u64) >> l;
            upper.write_unary(hi - prev_hi);
            prev_hi = hi;
        }
        let bits = (lower.len_bits() + upper.len_bits()) as u64;

        let mut w = WriteBuf::new();
        let lower = lower.finish();
        let upper = upper.finish();
        w.put_u32(l);
        w.put_u64(lower.len as u64);
        w.put_u64s(&lower.words);
        w.put_u64(upper.len as u64);
        w.put_u64s(&upper.words);
        Encoded { bytes: w.bytes, bits }
    }

    fn decode(&self, bytes: &[u8], _universe: u32, n: usize, out: &mut Vec<u32>) {
        let (l, lower, upper) = parse(bytes).expect("corrupt EF blob");
        let mut lr = crate::util::BitReader::new(&lower);
        let mut ur = crate::util::BitReader::new(&upper);
        let mut hi = 0u64;
        for _ in 0..n {
            let lo = lr.read(l);
            hi += ur.read_unary();
            out.push(((hi << l) | lo) as u32);
        }
    }

    fn supports_random_access(&self) -> bool {
        true
    }

    // Allocation-free: runs once per search winner on the id-resolve hot
    // path, so no BitBuf/RsBitVec is materialized — the k-th high value is
    // found by a popcount scan over the serialized upper words (≈ n/32
    // words for EF's ~2-bit unary stream) and the low bits are read
    // straight from the blob.
    fn decode_nth(&self, bytes: &[u8], _universe: u32, n: usize, k: usize) -> Option<u32> {
        if k >= n {
            return None;
        }
        let v = EfRawView::new(bytes)?;
        // k-th high value = select1(k) - k on the unary stream.
        let pos = v.select1_upper(k)?;
        let hi = pos - k as u64;
        let lo = read_bits_at(v.lower, k * v.l as usize, v.l);
        Some(((hi << v.l) | lo) as u32)
    }

    fn try_decode_into(
        &self,
        bytes: &[u8],
        universe: u32,
        n: usize,
        out: &mut Vec<u32>,
        _scratch: &mut DecodeScratch,
    ) -> Result<()> {
        ensure_list_shape("ef", universe, n)?;
        if n == 0 {
            return Ok(());
        }
        let (l, lower, upper) = parse(bytes).context("ef: corrupt blob header")?;
        // Internal length fields can lie about the word payloads — every
        // read below must stay inside the deserialized word vectors, so
        // pin the bit lengths to what was actually stored first.
        ensure!(l <= 31, "ef: low-bit width {l} is impossible for u32 ids");
        ensure!(
            lower.len <= lower.words.len() * 64,
            "ef: lower stream claims {} bits but stores {}",
            lower.len,
            lower.words.len() * 64
        );
        ensure!(
            upper.len <= upper.words.len() * 64,
            "ef: upper stream claims {} bits but stores {}",
            upper.len,
            upper.words.len() * 64
        );
        ensure!(
            (n as u64) * (l as u64) <= lower.len as u64,
            "ef: lower stream holds {} bits, need {} for {n} ids",
            lower.len,
            (n as u64) * (l as u64)
        );
        let hi_cap = (universe.saturating_sub(1) as u64) >> l;
        let start = out.len();
        let mut pos = 0usize;
        let mut hi = 0u64;
        let mut prev: Option<u32> = None;
        for i in 0..n {
            // Bounded unary read: a corrupt all-zeros tail can never spin
            // or index past the word vector — the position check fails
            // first.
            let mut delta = 0u64;
            loop {
                if pos >= upper.len {
                    out.truncate(start);
                    anyhow::bail!("ef: upper stream exhausted after {i} of {n} ids");
                }
                let w = upper.words[pos >> 6] >> (pos & 63);
                if w == 0 {
                    delta += (64 - (pos & 63)) as u64;
                    pos += 64 - (pos & 63);
                } else {
                    let tz = w.trailing_zeros() as usize;
                    if pos + tz >= upper.len {
                        out.truncate(start);
                        anyhow::bail!("ef: upper stream exhausted after {i} of {n} ids");
                    }
                    delta += tz as u64;
                    pos += tz + 1;
                    break;
                }
            }
            hi += delta;
            if hi > hi_cap {
                out.truncate(start);
                anyhow::bail!("ef: high bits {hi} exceed universe {universe}");
            }
            let lo = lower.read(i * l as usize, l);
            let v = ((hi << l) | lo) as u32;
            if v as u64 >= universe as u64 {
                out.truncate(start);
                anyhow::bail!("ef: id {v} outside universe [0, {universe})");
            }
            if let Some(p) = prev {
                if v <= p {
                    out.truncate(start);
                    anyhow::bail!("ef: ids not strictly increasing ({p} then {v})");
                }
            }
            prev = Some(v);
            out.push(v);
        }
        Ok(())
    }
}

/// Zero-copy view over a serialized Elias-Fano blob: byte slices of the
/// lower/upper word regions, no parsing into owned buffers.
struct EfRawView<'a> {
    l: u32,
    lower: &'a [u8],
    upper: &'a [u8],
}

impl<'a> EfRawView<'a> {
    fn new(bytes: &'a [u8]) -> Option<Self> {
        // Layout written by `encode`: u32 l | u64 lower_len_bits |
        // u64 n_lower_words | words | u64 upper_len_bits |
        // u64 n_upper_words | words (all little-endian).
        let l = u32::from_le_bytes(bytes.get(0..4)?.try_into().ok()?);
        let nl = u64::from_le_bytes(bytes.get(12..20)?.try_into().ok()?) as usize;
        let lower = bytes.get(20..20 + nl.checked_mul(8)?)?;
        let off = 20 + nl * 8;
        let nu = u64::from_le_bytes(bytes.get(off + 8..off + 16)?.try_into().ok()?) as usize;
        let upper = bytes.get(off + 16..off + 16 + nu.checked_mul(8)?)?;
        Some(EfRawView { l, lower, upper })
    }

    /// Position of the k-th set bit in the upper stream.
    fn select1_upper(&self, k: usize) -> Option<u64> {
        let mut remaining = k as u64;
        for (wi, chunk) in self.upper.chunks_exact(8).enumerate() {
            let word = u64::from_le_bytes(chunk.try_into().unwrap());
            let ones = word.count_ones() as u64;
            if remaining < ones {
                let bit = crate::bitvec::select_in_word(word, remaining as u32);
                return Some(wi as u64 * 64 + bit as u64);
            }
            remaining -= ones;
        }
        None
    }
}

/// Elias-Fano list pre-parsed for repeated random access (IVF hot path).
pub struct EfReader {
    l: u32,
    lower: BitBuf,
    upper: RsBitVec,
}

impl EfReader {
    pub fn new(bytes: &[u8]) -> anyhow::Result<Self> {
        let (l, lower, upper) = parse(bytes)?;
        Ok(EfReader { l, lower, upper: RsBitVec::new(upper) })
    }

    /// k-th smallest id.
    pub fn get(&self, k: usize) -> Option<u32> {
        let pos = self.upper.select1(k as u64)? as u64;
        let hi = pos - k as u64;
        let lo = self.lower.read(k * self.l as usize, self.l);
        Some(((hi << self.l) | lo) as u32)
    }
}

fn parse(bytes: &[u8]) -> anyhow::Result<(u32, BitBuf, BitBuf)> {
    let mut r = ReadBuf::new(bytes);
    let l = r.get_u32()?;
    let lower_len = r.get_u64()? as usize;
    let lower_words = r.get_u64s()?;
    let upper_len = r.get_u64()? as usize;
    let upper_words = r.get_u64s()?;
    Ok((
        l,
        BitBuf { words: lower_words, len: lower_len },
        BitBuf { words: upper_words, len: upper_len },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::testutil::check_roundtrip;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        check_roundtrip(&EliasFano, 4);
    }

    #[test]
    fn decode_is_sorted() {
        let mut rng = Rng::new(5);
        let ids: Vec<u32> = rng.sample_distinct(1 << 22, 500).iter().map(|&v| v as u32).collect();
        let enc = EliasFano.encode(&ids, 1 << 22);
        let mut out = Vec::new();
        EliasFano.decode(&enc.bytes, 1 << 22, 500, &mut out);
        let mut want = ids;
        want.sort_unstable();
        assert_eq!(out, want);
    }

    #[test]
    fn rate_matches_formula() {
        // n ids from [0, u): exact bits must be n*l + n + max_hi where
        // l = floor(log2(u/n)); check the ~2 + log2(u/n) bits/id claim.
        let mut rng = Rng::new(6);
        let (u, n) = (1_000_000u32, 3906usize); // IVF256-like cluster
        let ids: Vec<u32> = rng.sample_distinct(u as u64, n).iter().map(|&v| v as u32).collect();
        let enc = EliasFano.encode(&ids, u);
        let bpe = enc.bits as f64 / n as f64;
        let expect = 2.0 + (u as f64 / n as f64).log2();
        assert!((bpe - expect).abs() < 0.7, "bpe={bpe} expect~{expect}");
        // Table 1 ballpark: ~9.85 bits for IVF256 at N=1e6.
        assert!(bpe > 9.0 && bpe < 10.6, "bpe={bpe}");
    }

    #[test]
    fn ef_reader_random_access() {
        let mut rng = Rng::new(7);
        let ids: Vec<u32> = rng.sample_distinct(1 << 20, 777).iter().map(|&v| v as u32).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        let enc = EliasFano.encode(&ids, 1 << 20);
        let reader = EfReader::new(&enc.bytes).unwrap();
        for (k, &want) in sorted.iter().enumerate() {
            assert_eq!(reader.get(k), Some(want));
        }
        assert_eq!(reader.get(777), None);
    }

    #[test]
    fn dense_universe_all_elements() {
        // n == u: l = 0, ids are 0..n, upper stream is alternating.
        let ids: Vec<u32> = (0..256).collect();
        let enc = EliasFano.encode(&ids, 256);
        let mut out = Vec::new();
        EliasFano.decode(&enc.bytes, 256, 256, &mut out);
        assert_eq!(out, ids);
        // Dense sets are nearly free: ~2 bits/id.
        assert!(enc.bits <= 2 * 256 + 64, "{}", enc.bits);
    }
}
