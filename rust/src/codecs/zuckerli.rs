//! Zuckerli-style graph compression (the offline baseline of Table 3).
//!
//! Zuckerli (Versari et al. 2020) improves WebGraph with entropy coding;
//! this module implements the same algorithmic ingredients at the scale
//! this project needs (DESIGN.md lists the substitution):
//!
//! * adjacency lists encoded in node order, each against an optional
//!   **reference list** chosen from a sliding window of previous nodes
//!   (largest intersection wins);
//! * copied elements signalled with a per-element bitmap over the
//!   reference list (adaptive binary context ≈ WebGraph's copy *blocks*
//!   under an entropy coder);
//! * residuals delta-gap coded with **hybrid integers**: an adaptive
//!   token (bit-length bucket) plus raw trailing bits — Zuckerli's core
//!   integer code;
//! * everything entropy-coded into a **single ANS stream** with adaptive
//!   contexts (degree / reference / copy-bit / gap-token), using the
//!   record-forward-encode-backward trick so the LIFO coder decodes in
//!   natural order.
//!
//! Unlike ROC/REC this codec does *not* exploit the friend-list order
//! invariance (lists are treated as sorted sequences); the comparison
//! between the two is exactly the point of Table 3.

use super::Encoded;
use crate::ans::Ans;

/// Sliding window of candidate reference nodes.
const WINDOW: usize = 8;
/// Number of bit-length tokens for hybrid ints (values < 2^31).
const TOKENS: usize = 32;

/// An adaptive symbol context: counts with periodic halving.
#[derive(Clone)]
struct Ctx {
    counts: Vec<u32>,
    total: u32,
}

impl Ctx {
    fn new(alphabet: usize) -> Self {
        Ctx { counts: vec![1; alphabet], total: alphabet as u32 }
    }

    fn f_c(&self, x: u32) -> (u32, u32) {
        let f = self.counts[x as usize];
        let c = self.counts[..x as usize].iter().sum();
        (f, c)
    }

    fn symbol_of(&self, slot: u32) -> u32 {
        let mut acc = 0u32;
        for (i, &f) in self.counts.iter().enumerate() {
            if slot < acc + f {
                return i as u32;
            }
            acc += f;
        }
        unreachable!("slot {slot} out of total {}", self.total)
    }

    fn bump(&mut self, x: u32) {
        self.counts[x as usize] += 32;
        self.total += 32;
        if self.total > (1 << 24) {
            self.total = 0;
            for c in &mut self.counts {
                *c = (*c >> 1).max(1);
                self.total += *c;
            }
        }
    }
}

/// One recorded coding op: a symbol in an adaptive context or raw bits.
enum Op {
    /// (f, c, m) triple captured at record time.
    Sym { f: u32, c: u32, m: u32 },
    /// Uniform raw bits.
    Raw { x: u32, m: u32 },
}

/// Context ids.
const CTX_DEGREE: usize = 0;
const CTX_REF: usize = 1;
const CTX_COPY: usize = 2;
const CTX_NRES: usize = 3;
const CTX_FIRST: usize = 4;
const CTX_GAP: usize = 5;


fn new_contexts() -> Vec<Ctx> {
    vec![
        Ctx::new(TOKENS),      // degree token
        Ctx::new(WINDOW + 1),  // reference selector (0 = none)
        Ctx::new(2),           // copy bit
        Ctx::new(TOKENS),      // residual-count token
        Ctx::new(TOKENS),      // first-residual token
        Ctx::new(TOKENS),      // gap token
    ]
}

/// Hybrid integer split: token = bit length, payload = trailing bits.
#[inline]
fn int_token(v: u32) -> (u32, u32, u32) {
    // (token, payload, payload_bits): v = 2^(token-1) + payload for v>0.
    if v == 0 {
        (0, 0, 0)
    } else {
        let bits = 32 - v.leading_zeros();
        (bits, v - (1 << (bits - 1)), bits - 1)
    }
}

#[inline]
fn int_from(token: u32, payload: u32) -> u32 {
    if token == 0 {
        0
    } else {
        (1 << (token - 1)) + payload
    }
}

struct Recorder {
    ops: Vec<Op>,
    ctxs: Vec<Ctx>,
}

impl Recorder {
    fn new() -> Self {
        Recorder { ops: Vec::new(), ctxs: new_contexts() }
    }

    fn sym(&mut self, ctx: usize, x: u32) {
        let (f, c) = self.ctxs[ctx].f_c(x);
        let m = self.ctxs[ctx].total;
        self.ops.push(Op::Sym { f, c, m });
        self.ctxs[ctx].bump(x);
    }

    fn hybrid(&mut self, ctx: usize, v: u32) {
        let (token, payload, pbits) = int_token(v);
        self.sym(ctx, token);
        if pbits > 0 {
            self.ops.push(Op::Raw { x: payload, m: 1 << pbits });
        }
    }

    /// Flush to ANS: reverse order so the decoder reads forward.
    fn finish(self) -> Encoded {
        let mut ans = Ans::new();
        for op in self.ops.iter().rev() {
            match *op {
                Op::Sym { f, c, m } => ans.encode(f, c, m),
                Op::Raw { x, m } => ans.encode_uniform(x, m),
            }
        }
        Encoded { bits: ans.size_bits() as u64, bytes: ans.to_bytes() }
    }
}

struct Reader {
    ans: Ans,
    ctxs: Vec<Ctx>,
}

impl Reader {
    fn new(bytes: &[u8]) -> Self {
        Reader { ans: Ans::from_bytes(bytes).expect("corrupt zuckerli blob"), ctxs: new_contexts() }
    }

    fn sym(&mut self, ctx: usize) -> u32 {
        let m = self.ctxs[ctx].total;
        let slot = self.ans.peek(m);
        let x = self.ctxs[ctx].symbol_of(slot);
        let (f, c) = self.ctxs[ctx].f_c(x);
        self.ans.pop(f, c, m);
        self.ctxs[ctx].bump(x);
        x
    }

    fn hybrid(&mut self, ctx: usize) -> u32 {
        let token = self.sym(ctx);
        let pbits = token.saturating_sub(1);
        let payload = if pbits > 0 { self.ans.decode_uniform(1 << pbits) } else { 0 };
        int_from(token, payload)
    }
}

pub struct Zuckerli {
    pub window: usize,
}

impl Default for Zuckerli {
    fn default() -> Self {
        Zuckerli { window: WINDOW }
    }
}

impl Zuckerli {
    /// Encode a directed graph's adjacency lists.
    pub fn encode_graph(&self, adj: &[Vec<u32>]) -> Encoded {
        let mut rec = Recorder::new();
        let sorted: Vec<Vec<u32>> = adj
            .iter()
            .map(|l| {
                let mut l = l.clone();
                l.sort_unstable();
                l
            })
            .collect();
        for i in 0..sorted.len() {
            let list = &sorted[i];
            rec.hybrid(CTX_DEGREE, list.len() as u32);
            if list.is_empty() {
                continue;
            }
            // Reference selection: best intersection in the window.
            let (mut best_r, mut best_gain) = (0usize, 0usize);
            for r in 1..=self.window.min(i) {
                let cand = &sorted[i - r];
                if cand.is_empty() {
                    continue;
                }
                let inter = intersection_size(cand, list);
                // A copied element saves a gap code (~log2(N/deg) bits)
                // and costs ~1 copy bit per reference element; require
                // a material win.
                if inter > cand.len() / 4 && inter > best_gain {
                    best_gain = inter;
                    best_r = r;
                }
            }
            rec.sym(CTX_REF, best_r as u32);
            let mut residuals: Vec<u32> = Vec::with_capacity(list.len());
            if best_r > 0 {
                let reference = &sorted[i - best_r];
                let mut it = list.iter().peekable();
                let mut copied = vec![false; reference.len()];
                for (j, &rv) in reference.iter().enumerate() {
                    while let Some(&&v) = it.peek() {
                        if v < rv {
                            residuals.push(v);
                            it.next();
                        } else {
                            break;
                        }
                    }
                    if it.peek() == Some(&&rv) {
                        copied[j] = true;
                        it.next();
                    }
                }
                residuals.extend(it.copied());
                for &b in &copied {
                    rec.sym(CTX_COPY, b as u32);
                }
            } else {
                residuals.extend_from_slice(list);
            }
            rec.hybrid(CTX_NRES, residuals.len() as u32);
            let mut prev = 0u32;
            for (j, &v) in residuals.iter().enumerate() {
                if j == 0 {
                    rec.hybrid(CTX_FIRST, v);
                } else {
                    rec.hybrid(CTX_GAP, v - prev - 1);
                }
                prev = v;
            }
        }
        rec.finish()
    }

    /// Decode a graph with `n_nodes` nodes.
    pub fn decode_graph(&self, bytes: &[u8], n_nodes: u32) -> Vec<Vec<u32>> {
        let mut rd = Reader::new(bytes);
        let mut out: Vec<Vec<u32>> = Vec::with_capacity(n_nodes as usize);
        for i in 0..n_nodes as usize {
            let deg = rd.hybrid(CTX_DEGREE) as usize;
            if deg == 0 {
                out.push(Vec::new());
                continue;
            }
            let r = rd.sym(CTX_REF) as usize;
            let mut list: Vec<u32> = Vec::with_capacity(deg);
            let mut n_copied = 0usize;
            if r > 0 {
                let reference: Vec<u32> = out[i - r].clone();
                for &rv in &reference {
                    if rd.sym(CTX_COPY) == 1 {
                        list.push(rv);
                        n_copied += 1;
                    }
                }
            }
            let n_res = rd.hybrid(CTX_NRES) as usize;
            debug_assert_eq!(n_copied + n_res, deg);
            let mut prev = 0u32;
            let mut residuals = Vec::with_capacity(n_res);
            for j in 0..n_res {
                let v = if j == 0 {
                    rd.hybrid(CTX_FIRST)
                } else {
                    prev + 1 + rd.hybrid(CTX_GAP)
                };
                residuals.push(v);
                prev = v;
            }
            // Merge copied (sorted) and residuals (sorted).
            let merged = merge_sorted(&list, &residuals);
            out.push(merged);
        }
        out
    }
}

fn intersection_size(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut n) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

fn merge_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_graph(rng: &mut Rng, n: u32, deg: usize) -> Vec<Vec<u32>> {
        (0..n)
            .map(|_| {
                let d = rng.below(2 * deg as u64 + 1) as usize;
                rng.sample_distinct(n as u64, d.min(n as usize))
                    .into_iter()
                    .map(|v| v as u32)
                    .collect()
            })
            .collect()
    }

    fn sorted(adj: &[Vec<u32>]) -> Vec<Vec<u32>> {
        adj.iter()
            .map(|l| {
                let mut l = l.clone();
                l.sort_unstable();
                l
            })
            .collect()
    }

    #[test]
    fn hybrid_int_split_roundtrip() {
        for v in (0..1000).chain([1 << 20, u32::MAX / 2]) {
            let (t, p, _) = int_token(v);
            assert_eq!(int_from(t, p), v);
        }
    }

    #[test]
    fn roundtrip_random_graphs() {
        let mut rng = Rng::new(30);
        for &(n, d) in &[(1u32, 0usize), (50, 4), (1000, 12), (300, 64)] {
            let adj = random_graph(&mut rng, n, d);
            let z = Zuckerli::default();
            let enc = z.encode_graph(&adj);
            let got = z.decode_graph(&enc.bytes, n);
            assert_eq!(got, sorted(&adj), "n={n} d={d}");
        }
    }

    #[test]
    fn copies_exploited_on_overlapping_lists() {
        // Consecutive nodes share most neighbors: reference coding must
        // beat the no-overlap rate substantially.
        let mut rng = Rng::new(31);
        let n = 2000u32;
        let base: Vec<Vec<u32>> = (0..n / 10)
            .map(|_| rng.sample_distinct(n as u64, 32).into_iter().map(|v| v as u32).collect())
            .collect();
        let overlapping: Vec<Vec<u32>> = (0..n)
            .map(|i| {
                let mut l = base[(i / 10) as usize].clone();
                // mutate 4 of 32 entries
                for _ in 0..4 {
                    let p = rng.below(l.len() as u64) as usize;
                    l[p] = rng.below(n as u64) as u32;
                }
                l.sort_unstable();
                l.dedup();
                l
            })
            .collect();
        let disjoint = random_graph(&mut rng, n, 16);
        let z = Zuckerli::default();
        let e_o: u64 = overlapping.iter().map(|l| l.len() as u64).sum();
        let e_d: u64 = disjoint.iter().map(|l| l.len() as u64).sum();
        let bpe_o = z.encode_graph(&overlapping).bits as f64 / e_o as f64;
        let bpe_d = z.encode_graph(&disjoint).bits as f64 / e_d as f64;
        assert!(bpe_o < 0.6 * bpe_d, "overlap={bpe_o} disjoint={bpe_d}");
        // Roundtrip of the overlapping graph too.
        assert_eq!(z.decode_graph(&z.encode_graph(&overlapping).bytes, n), sorted(&overlapping));
    }

    #[test]
    fn rate_close_to_gap_entropy_for_random_lists() {
        // Sorted random m-subsets of [0,N): gap coding should land near
        // m*(log2(N/m) + ~2.3) bits + tokens overhead.
        let mut rng = Rng::new(32);
        let n = 100_000u32;
        let adj: Vec<Vec<u32>> = (0..1000)
            .map(|_| rng.sample_distinct(n as u64, 64).into_iter().map(|v| v as u32).collect())
            .collect();
        let e: u64 = adj.iter().map(|l| l.len() as u64).sum();
        let bpe = Zuckerli::default().encode_graph(&adj).bits as f64 / e as f64;
        let gap_est = (n as f64 / 64.0).log2() + 2.3;
        assert!((bpe - gap_est).abs() < 1.5, "bpe={bpe} est={gap_est}");
    }
}
