//! The unified public API of the ANN system: one trait ([`AnnIndex`]),
//! one on-disk container format ([`persist`]), one serving path.
//!
//! Every backend — the IVF index with any id codec, and the graph indexes
//! wrapped by [`GraphIndex`] — implements [`AnnIndex`], so the batching
//! coordinator, the QPS bench and the CLI `build`/`serve` subcommands are
//! written once against `dyn AnnIndex` instead of one ad-hoc API per
//! index family. The paper's storage claim (compressed ids cut index
//! size, §4) only pays off if an index can be saved, reopened and served
//! without re-building or re-expanding its compressed payloads; that is
//! what [`AnnIndex::save`]/[`persist::open`] provide: the already-encoded
//! streams are written verbatim and reopened as slices into the file
//! buffer.

pub mod graph_index;
pub mod persist;

pub use graph_index::{GraphFamily, GraphIndex};

use crate::graph::VisitedSet;
use crate::index::{IvfIndex, SearchParams, SearchScratch};
use anyhow::Result;
use std::path::Path;

/// Which index family a backend belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexKind {
    Ivf,
    Nsg,
    Hnsw,
    /// Mutable multi-segment IVF ([`crate::dynamic::DynamicIvf`]).
    DynamicIvf,
    /// Multi-shard container served by [`crate::serve::ShardedIndex`]:
    /// N embedded shard containers behind one router + merge.
    Sharded,
}

impl IndexKind {
    pub fn name(self) -> &'static str {
        match self {
            IndexKind::Ivf => "ivf",
            IndexKind::Nsg => "nsg",
            IndexKind::Hnsw => "hnsw",
            IndexKind::DynamicIvf => "dynamic-ivf",
            IndexKind::Sharded => "sharded",
        }
    }
}

/// Storage accounting of one immutable segment of a (dynamic) index —
/// the per-segment view that makes compression under churn observable:
/// a segment sealed from the write buffer reports its own bits/id, and
/// compaction visibly collapses the list back to one entry at the
/// static build's rate.
#[derive(Clone, Debug)]
pub struct SegmentStats {
    /// Rows physically stored (including not-yet-compacted tombstoned
    /// ones).
    pub rows: usize,
    /// Exact compressed id-stream payload in bits.
    pub id_bits: u64,
    /// Rank→external-id map bits (0 for identity-mapped and static
    /// segments).
    pub map_bits: u64,
}

impl SegmentStats {
    pub fn bits_per_id(&self) -> f64 {
        self.id_bits as f64 / self.rows.max(1) as f64
    }
}

/// Storage accounting for one index, split the way the paper reports it:
/// vector-id payload (`id_bits`, the Table-1 numerator), vector payload
/// (`code_bits`: raw floats or PQ codes, possibly entropy-coded) and
/// graph adjacency payload (`link_bits`, the NSG/HNSW rows).
#[derive(Clone, Debug)]
pub struct IndexStats {
    pub kind: IndexKind,
    pub n: usize,
    pub dim: usize,
    /// Graph edge count (0 for IVF) — the denominator of the paper's
    /// NSG bits/id rows.
    pub edges: u64,
    /// Canonical codec spec of the compressed payload (id store for IVF,
    /// adjacency store for graphs).
    pub codec: String,
    pub id_bits: u64,
    pub code_bits: u64,
    pub link_bits: u64,
    /// Searchable vectors (equals `n` for static indexes; for dynamic
    /// indexes, assigned ids minus deletes).
    pub live: usize,
    /// Tombstoned rows still physically stored (0 for static indexes
    /// and right after a full compaction).
    pub deleted: usize,
    /// Uncompressed rows in the mutable write buffer (0 for static
    /// indexes).
    pub buffer_rows: usize,
    /// Deletion metadata (tombstone bitmap) in bits — reported next to,
    /// not inside, `id_bits`, mirroring how the paper excludes overheads
    /// from its bit counts.
    pub aux_bits: u64,
    /// Whether the index payload is covered by per-section CRC-32C
    /// checksums: true for indexes built in memory or opened from a v2
    /// container (checksums verified at open), false for indexes opened
    /// from a legacy v1 container (no checksums on disk; a deep decode
    /// validation ran at open instead).
    pub checksummed: bool,
    /// Per-segment breakdown (one entry for a static IVF index, empty
    /// for graphs).
    pub segments: Vec<SegmentStats>,
}

impl IndexStats {
    pub fn total_bits(&self) -> u64 {
        self.id_bits + self.code_bits + self.link_bits
    }

    /// Total payload size in bytes (what the container file should weigh,
    /// within header overhead).
    pub fn payload_bytes(&self) -> u64 {
        self.total_bits().div_ceil(8)
    }

    /// Bits per vector id (Table-1 metric): `id_bits / n` for the IVF
    /// families (static and dynamic); for graphs, bits per *edge* id
    /// (`link_bits / edges`), following the paper's NSG rows.
    pub fn bits_per_id(&self) -> f64 {
        match self.kind {
            IndexKind::Nsg | IndexKind::Hnsw => {
                self.link_bits as f64 / (self.edges.max(1)) as f64
            }
            IndexKind::Ivf | IndexKind::DynamicIvf | IndexKind::Sharded => {
                self.id_bits as f64 / (self.n.max(1)) as f64
            }
        }
    }
}

/// Backend-generic query parameters. IVF backends read `nprobe`, graph
/// backends read `ef`; both honor `k`. Carrying the union keeps the
/// serving config one struct for every backend behind `dyn AnnIndex`.
#[derive(Clone, Debug)]
pub struct QueryParams {
    /// Number of results to return.
    pub k: usize,
    /// IVF: how many inverted lists to probe.
    pub nprobe: usize,
    /// Graphs: beam width of the best-first search.
    pub ef: usize,
}

impl Default for QueryParams {
    fn default() -> Self {
        QueryParams { k: 10, nprobe: 16, ef: 64 }
    }
}

impl QueryParams {
    /// The IVF view of these parameters.
    pub fn ivf(&self) -> SearchParams {
        SearchParams { nprobe: self.nprobe, k: self.k }
    }
}

/// Reusable per-worker scratch covering every backend: the IVF search
/// scratch (coarse buffer, LUT, top-k, decode state) and the graph-search
/// state (epoch visited-set + neighbor decode buffer). Both halves are
/// cheap when unused, so one `AnnScratch` per serving worker handles any
/// `dyn AnnIndex` without downcasting.
#[derive(Default)]
pub struct AnnScratch {
    pub ivf: SearchScratch,
    pub visited: VisitedSet,
    pub neighbors: Vec<u32>,
    /// Cached `zann_beam_searches_total{family}` handle (graph backends).
    pub(crate) graph_obs: crate::obs::LabeledCounter,
}

/// Coarse-stage description a backend exposes to batched engines: the
/// coordinator ships `‖q − c‖²` for a whole batch through PJRT (or the
/// fused rust fallback) and hands each query its row. Backends without a
/// coarse stage (graphs) return `None` and are served query-at-a-time.
pub struct CoarseInfo<'a> {
    pub centroids: &'a [f32],
    pub norms: &'a [f32],
    pub k: usize,
}

/// The one index trait every backend implements and every serving path
/// consumes.
///
/// Contract: `search_into` replaces `out` with up to `params.k`
/// `(distance, id)` pairs in ascending distance order, and with a warmed
/// `scratch` performs no allocation beyond first-touch scratch growth
/// (IVF backends; graph backends currently allocate inside beam search).
pub trait AnnIndex: Send + Sync {
    fn kind(&self) -> IndexKind;

    /// Vector dimensionality.
    fn dim(&self) -> usize;

    /// Number of indexed vectors.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage accounting (id/code/link bits).
    fn stats(&self) -> IndexStats;

    /// Search `query`, replacing `out` with the results.
    fn search_into(
        &self,
        query: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    );

    /// Batched-coarse hook; `None` for backends without a coarse stage.
    fn coarse_info(&self) -> Option<CoarseInfo<'_>> {
        None
    }

    /// Search with externally computed coarse distances (the batched
    /// serving path). Backends without a coarse stage ignore `coarse`.
    fn search_with_coarse_into(
        &self,
        query: &[f32],
        _coarse: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        self.search_into(query, params, scratch, out);
    }

    /// Serialize to the zann container format ([`persist`]): compressed
    /// payloads verbatim, reopenable zero-copy.
    fn to_bytes(&self) -> Result<Vec<u8>>;

    /// Save to `path`; returns the number of bytes written.
    fn save(&self, path: &Path) -> Result<u64> {
        persist::save(self, path)
    }
}

impl AnnIndex for IvfIndex {
    fn kind(&self) -> IndexKind {
        IndexKind::Ivf
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.n
    }

    fn stats(&self) -> IndexStats {
        IndexStats {
            kind: IndexKind::Ivf,
            n: self.n,
            dim: self.dim,
            edges: 0,
            codec: self.id_codec_name().to_string(),
            id_bits: self.id_bits(),
            code_bits: self.code_bits(),
            link_bits: 0,
            live: self.n,
            deleted: 0,
            buffer_rows: 0,
            aux_bits: 0,
            checksummed: self.checksummed(),
            segments: vec![SegmentStats { rows: self.n, id_bits: self.id_bits(), map_bits: 0 }],
        }
    }

    fn search_into(
        &self,
        query: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        IvfIndex::search_into(self, query, &params.ivf(), &mut scratch.ivf, out);
    }

    fn coarse_info(&self) -> Option<CoarseInfo<'_>> {
        Some(CoarseInfo { centroids: &self.centroids, norms: &self.centroid_norms, k: self.k })
    }

    fn search_with_coarse_into(
        &self,
        query: &[f32],
        coarse: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        IvfIndex::search_with_coarse_into(self, query, coarse, &params.ivf(), &mut scratch.ivf, out);
    }

    fn to_bytes(&self) -> Result<Vec<u8>> {
        self.to_container_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, Kind};
    use crate::index::IvfBuildParams;

    #[test]
    fn ivf_trait_search_matches_inherent() {
        let ds = generate(Kind::DeepLike, 2000, 20, 8, 51);
        let idx = IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 16, id_codec: "roc".into(), threads: 2, ..Default::default() },
        );
        let p = QueryParams { k: 5, nprobe: 4, ef: 0 };
        let dyn_idx: &dyn AnnIndex = &idx;
        let mut scratch = AnnScratch::default();
        let mut got = Vec::new();
        let mut inherent_scratch = SearchScratch::default();
        for qi in 0..ds.nq {
            dyn_idx.search_into(ds.query(qi), &p, &mut scratch, &mut got);
            let want = idx.search(ds.query(qi), &p.ivf(), &mut inherent_scratch);
            assert_eq!(got, want, "query {qi}");
        }
        assert_eq!(dyn_idx.kind(), IndexKind::Ivf);
        assert_eq!(dyn_idx.len(), 2000);
        assert_eq!(dyn_idx.dim(), 8);
        assert!(dyn_idx.coarse_info().is_some());
    }

    #[test]
    fn stats_accounting_is_consistent() {
        let ds = generate(Kind::DeepLike, 1500, 1, 8, 52);
        let idx = IvfIndex::build(
            &ds.data,
            ds.dim,
            &IvfBuildParams { k: 8, id_codec: "ef".into(), threads: 2, ..Default::default() },
        );
        let s = AnnIndex::stats(&idx);
        assert_eq!(s.codec, "ef");
        assert_eq!(s.id_bits, idx.id_bits());
        assert_eq!(s.code_bits, idx.code_bits());
        assert_eq!(s.link_bits, 0);
        assert_eq!(s.total_bits(), s.id_bits + s.code_bits);
        assert!((s.bits_per_id() - idx.bits_per_id()).abs() < 1e-12);
    }
}
