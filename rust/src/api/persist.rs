//! The zann on-disk container: a versioned, section-tagged binary format
//! shared by every [`AnnIndex`] backend.
//!
//! ```text
//! byte 0..4   magic  b"ZANN"
//! byte 4..6   format version (u16 LE, currently 3)
//! byte 6      index kind (1 = IVF, 2 = graph, 3 = dynamic IVF, 4 = sharded)
//! byte 7      reserved (0)
//! then until EOF, sections:
//!   v1: [tag: 4 ascii bytes] [payload length: u64 LE] [payload]
//!   v2+: [tag: 4 ascii bytes] [payload length: u64 LE] [payload] [CRC-32C: u32 LE]
//! v3 only: the final section is the terminator ZEND, whose 8-byte payload
//!   is the u64 LE length of everything before it (see [`finish_container`]).
//! ```
//!
//! The v2 trailer is the CRC-32C of `tag ‖ payload`, verified during
//! [`Container::parse`] — a bit flip anywhere in a section (including its
//! tag, so swapping tags between two sections is also caught) fails the
//! open with a structured checksum error instead of reaching a decoder.
//! The v3 terminator closes the one hole section CRCs leave: a file
//! truncated exactly at a section boundary. [`Container::parse`] checks the
//! declared length against the physical length *before* slicing any section
//! and reports a structured [`TruncatedContainer`] error on mismatch.
//! Version-1 files (written before the checksum existed) still open; they
//! carry no per-section CRC, are reported `checksummed=false` in
//! [`crate::api::IndexStats`], and get a one-time deep decode validation
//! at open (see the backend `from_container` impls) as a substitute.
//!
//! Design rule: **compressed payloads are stored verbatim**. The id
//! streams (and entropy-coded PQ columns / adjacency streams) produced at
//! build time are written byte-for-byte, and `open` turns the sections
//! back into [`crate::util::Blobs`] over the borrowed file buffer — no
//! stream is decoded, re-encoded or even copied blob-by-blob. Only
//! derived acceleration data (centroid norms) is recomputed, so file size
//! ≈ `id_bits/8 + code_bits/8 + link_bits/8` plus header/offset-table
//! overhead, and reopening is O(file read), not O(re-encode).
//!
//! Unknown sections are skipped on read (forward-compatible additions);
//! unknown versions and kinds are hard errors.

use crate::api::{AnnIndex, GraphIndex};
use crate::index::IvfIndex;
use crate::util::bits::read_bits_at;
use crate::util::bytes::Bytes;
use crate::util::crc32c::Crc32c;
use anyhow::{bail, ensure, Context as _, Result};
use std::path::Path;

/// File magic.
pub const MAGIC: [u8; 4] = *b"ZANN";
/// Container format version this build writes (v2 added per-section
/// CRC-32C; v3 added the mandatory `ZEND` length terminator).
pub const VERSION: u16 = 3;
/// Oldest container format version this build still reads (v1: no
/// per-section checksums, v2: no terminator).
pub const MIN_VERSION: u16 = 1;
/// Tag of the v3 terminator section. Its 8-byte payload is the u64 LE byte
/// length of everything before the terminator, so a file truncated at a
/// section boundary — which parses as perfectly valid v2 framing — is
/// detected *before* any section is sliced.
pub const TERMINATOR: [u8; 4] = *b"ZEND";
/// Total on-disk size of the terminator section: tag (4) + length field (8)
/// + payload (8) + CRC trailer (4).
pub const TERMINATOR_BYTES: u64 = 24;
/// Kind tag: IVF index.
pub const KIND_IVF: u8 = 1;
/// Kind tag: graph index (NSG/HNSW; family is in the HEAD section).
pub const KIND_GRAPH: u8 = 2;
/// Kind tag: dynamic (multi-segment) IVF index. The section layout is
/// versioned inside its `DHDR` section (see [`crate::dynamic::persist`]);
/// pre-existing single-segment `KIND_IVF` containers are unaffected and
/// keep opening byte-for-byte.
pub const KIND_DYNAMIC: u8 = 3;
/// Kind tag: sharded multi-index container — a routing table plus N
/// embedded shard containers, each stored verbatim (see
/// [`crate::serve::persist`]). The embedded containers keep their own
/// per-section CRCs, so shard payloads are covered twice: once inside
/// the embedded container and once by the enclosing section CRC.
pub const KIND_SHARDED: u8 = 4;

/// Start a container file: magic + version + kind + reserved byte.
pub fn file_header(kind: u8) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(kind);
    out.push(0);
    out
}

/// Append one tagged section (v2: with the CRC-32C trailer over
/// `tag ‖ payload`).
pub fn push_section(out: &mut Vec<u8>, tag: &[u8; 4], payload: &[u8]) {
    out.extend_from_slice(tag);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    let mut h = Crc32c::new();
    h.update(tag);
    h.update(payload);
    out.extend_from_slice(&h.finalize().to_le_bytes());
}

/// Finish a v3 container: append the `ZEND` terminator section recording
/// the byte length of everything before it. Every writer must call this
/// exactly once, after its last real section.
pub fn finish_container(out: &mut Vec<u8>) {
    let content_len = out.len() as u64;
    push_section(out, &TERMINATOR, &content_len.to_le_bytes());
}

fn tag_str(tag: &[u8; 4]) -> String {
    String::from_utf8_lossy(tag).into_owned()
}

/// Structured error for a container whose physical file length disagrees
/// with its declared length — the signature of a file truncated (or
/// extended) at a section boundary, where per-section CRCs alone cannot
/// tell. Raised by [`Container::parse`] for v3 files *before* any section
/// is sliced.
///
/// Note: the vendored `anyhow` shim flattens error types into strings, so
/// downstream code matches this via [`is_truncated`] rather than downcast.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TruncatedContainer {
    /// Expected total file length, when the terminator was readable.
    pub expected: Option<u64>,
    /// Actual file length.
    pub actual: u64,
}

impl std::fmt::Display for TruncatedContainer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.expected {
            Some(e) => write!(
                f,
                "TruncatedContainer: file is {} bytes but the section table \
                 declares {e} — truncated or torn at a section boundary",
                self.actual
            ),
            None => write!(
                f,
                "TruncatedContainer: file is {} bytes and does not end in a \
                 valid ZEND terminator",
                self.actual
            ),
        }
    }
}

impl std::error::Error for TruncatedContainer {}

/// Whether `err`'s chain reports a [`TruncatedContainer`] (string match —
/// see the note on the struct).
pub fn is_truncated(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.contains("TruncatedContainer"))
}

/// A parsed container: kind byte + format version + tagged sections, each
/// a [`Bytes`] sub-region of the one file buffer.
pub struct Container {
    pub kind: u8,
    /// Container format version the file was written at.
    pub version: u16,
    sections: Vec<([u8; 4], Bytes)>,
}

impl Container {
    /// Whether every section carried (and passed) a CRC-32C check — true
    /// for v2 files, false for legacy v1 files.
    pub fn checksummed(&self) -> bool {
        self.version >= 2
    }

    /// Parse the header and section table. Every framing problem — short
    /// file, bad magic, unsupported version, truncated section, checksum
    /// mismatch — is a structured error, never a panic. For v2 files the
    /// CRC-32C of every section is verified here, so corruption anywhere
    /// in the payload is rejected before any decoder sees it.
    pub fn parse(region: &Bytes) -> Result<Container> {
        let s = region.as_slice();
        ensure!(s.len() >= 8, "file too short ({} bytes) for the zann header", s.len());
        ensure!(
            s[0..4] == MAGIC,
            "bad magic {:02x?} (not a zann index file)",
            &s[0..4]
        );
        let version = u16::from_le_bytes([s[4], s[5]]);
        ensure!(
            (MIN_VERSION..=VERSION).contains(&version),
            "unsupported container version {version} \
             (this build reads versions {MIN_VERSION}..={VERSION})"
        );
        let trailer: u64 = if version >= 2 { 4 } else { 0 };
        // v3: verify the terminator *before* slicing any section. A file cut
        // exactly at a section boundary has flawless v2 framing (every CRC
        // present passes), so physical length must be checked against the
        // declared length first.
        if version >= 3 {
            let actual = s.len() as u64;
            if actual < 8 + TERMINATOR_BYTES {
                return Err(TruncatedContainer { expected: None, actual }.into());
            }
            let term_at = s.len() - TERMINATOR_BYTES as usize;
            let tag: [u8; 4] = s[term_at..term_at + 4].try_into().unwrap();
            let len = u64::from_le_bytes(s[term_at + 4..term_at + 12].try_into().unwrap());
            if tag != TERMINATOR || len != 8 {
                return Err(TruncatedContainer { expected: None, actual }.into());
            }
            let declared =
                u64::from_le_bytes(s[term_at + 12..term_at + 20].try_into().unwrap());
            if declared != term_at as u64 {
                return Err(TruncatedContainer {
                    expected: Some(declared + TERMINATOR_BYTES),
                    actual,
                }
                .into());
            }
        }
        let kind = s[6];
        let mut sections = Vec::new();
        let mut pos = 8usize;
        while pos < s.len() {
            ensure!(
                s.len() - pos >= 12,
                "truncated section header at byte {pos} of {}",
                s.len()
            );
            let tag: [u8; 4] = s[pos..pos + 4].try_into().unwrap();
            let len = u64::from_le_bytes(s[pos + 4..pos + 12].try_into().unwrap());
            let remaining = (s.len() - pos - 12) as u64;
            ensure!(
                len <= remaining && trailer <= remaining - len,
                "section {} claims {len} bytes but only {remaining} remain",
                tag_str(&tag),
            );
            pos += 12;
            let body = region.slice(pos, len as usize)?;
            pos += len as usize;
            if version >= 2 {
                let stored = u32::from_le_bytes(s[pos..pos + 4].try_into().unwrap());
                let mut h = Crc32c::new();
                h.update(&tag);
                h.update(body.as_slice());
                let computed = h.finalize();
                ensure!(
                    stored == computed,
                    "checksum mismatch in section {} (stored {stored:08x}, computed \
                     {computed:08x}) — the file is corrupt",
                    tag_str(&tag),
                );
                pos += 4;
            }
            sections.push((tag, body));
        }
        Ok(Container { kind, version, sections })
    }

    /// Look up a section by tag (first match; later duplicates are
    /// ignored, like unknown tags).
    pub fn section(&self, tag: &[u8; 4]) -> Result<Bytes> {
        self.sections
            .iter()
            .find(|(t, _)| t == tag)
            .map(|(_, b)| b.clone())
            .with_context(|| format!("missing section {:?}", tag_str(tag)))
    }
}

/// Pack PQ codes at exactly `width` bits each (LSB-first, matching
/// [`read_bits_at`]) — the file stores `code_bits/8` bytes, not padded
/// u16 words.
pub fn pack_codes(codes: &[u16], width: u32) -> Vec<u8> {
    debug_assert!((1..=16).contains(&width));
    let mut out = Vec::with_capacity((codes.len() * width as usize).div_ceil(8));
    let mut acc: u64 = 0;
    let mut nb: u32 = 0;
    for &c in codes {
        acc |= (c as u64) << nb;
        nb += width;
        while nb >= 8 {
            out.push((acc & 0xff) as u8);
            acc >>= 8;
            nb -= 8;
        }
    }
    if nb > 0 {
        out.push((acc & 0xff) as u8);
    }
    out
}

/// Inverse of [`pack_codes`]. Validates the buffer length up front so a
/// truncated section is an error, not an out-of-bounds read.
pub fn unpack_codes(bytes: &[u8], width: u32, count: usize) -> Result<Vec<u16>> {
    ensure!((1..=16).contains(&width), "bad packed-code width {width}");
    let need = (count * width as usize).div_ceil(8);
    ensure!(
        bytes.len() >= need,
        "packed code section holds {} bytes, need {need} for {count} codes",
        bytes.len()
    );
    Ok((0..count).map(|i| read_bits_at(bytes, i * width as usize, width) as u16).collect())
}

/// Serialize `index` and write it to `path`; returns bytes written.
/// Generic over `?Sized` so the [`AnnIndex::save`] default method works
/// for concrete backends and `dyn AnnIndex` alike.
///
/// The write is atomic (temp file → fsync → rename → fsync dir, via
/// [`crate::durable::atomic::commit_bytes`]): a crash mid-save leaves the
/// previous file intact, never a torn container.
pub fn save<T: AnnIndex + ?Sized>(index: &T, path: &Path) -> Result<u64> {
    let bytes = index.to_bytes()?;
    crate::durable::atomic::commit_bytes(path, &bytes)
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(bytes.len() as u64)
}

/// Open a saved index of any kind from `path`.
pub fn open(path: &Path) -> Result<Box<dyn AnnIndex>> {
    let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    open_bytes(buf).with_context(|| format!("opening {}", path.display()))
}

/// Open a saved index of any kind from an in-memory buffer. The buffer
/// becomes the backing store of every compressed section (zero-copy).
pub fn open_bytes(buf: Vec<u8>) -> Result<Box<dyn AnnIndex>> {
    let region = Bytes::from_vec(buf);
    let c = Container::parse(&region)?;
    match c.kind {
        KIND_IVF => Ok(Box::new(IvfIndex::from_container(&c)?)),
        KIND_GRAPH => Ok(Box::new(GraphIndex::from_container(&c)?)),
        KIND_DYNAMIC => Ok(Box::new(crate::dynamic::persist::from_container(&c)?)),
        KIND_SHARDED => Ok(Box::new(crate::serve::persist::from_container(&c)?)),
        other => bail!("unknown index kind tag {other}"),
    }
}

/// Typed open for sharded multi-index containers (`zann info`, the serve
/// node and tests need the concrete shard list back).
pub fn open_sharded_bytes(buf: Vec<u8>) -> Result<crate::serve::ShardedIndex> {
    let region = Bytes::from_vec(buf);
    let c = Container::parse(&region)?;
    ensure!(
        c.kind == KIND_SHARDED,
        "container holds kind {} (expected a sharded index)",
        c.kind
    );
    crate::serve::persist::from_container(&c)
}

/// Open a saved sharded index from `path`.
pub fn open_sharded(path: &Path) -> Result<crate::serve::ShardedIndex> {
    let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    open_sharded_bytes(buf).with_context(|| format!("opening {}", path.display()))
}

/// Typed open for IVF containers (tests, tooling that needs the concrete
/// index API).
pub fn open_ivf_bytes(buf: Vec<u8>) -> Result<IvfIndex> {
    let region = Bytes::from_vec(buf);
    let c = Container::parse(&region)?;
    ensure!(c.kind == KIND_IVF, "container holds kind {} (expected an IVF index)", c.kind);
    IvfIndex::from_container(&c)
}

/// Typed open for graph containers.
pub fn open_graph_bytes(buf: Vec<u8>) -> Result<GraphIndex> {
    let region = Bytes::from_vec(buf);
    let c = Container::parse(&region)?;
    ensure!(c.kind == KIND_GRAPH, "container holds kind {} (expected a graph index)", c.kind);
    GraphIndex::from_container(&c)
}

/// Typed open for dynamic (multi-segment) IVF containers — the CLI
/// mutation subcommands need the concrete mutable index back.
pub fn open_dynamic_bytes(buf: Vec<u8>) -> Result<crate::dynamic::DynamicIvf> {
    let region = Bytes::from_vec(buf);
    let c = Container::parse(&region)?;
    ensure!(
        c.kind == KIND_DYNAMIC,
        "container holds kind {} (expected a dynamic IVF index)",
        c.kind
    );
    crate::dynamic::persist::from_container(&c)
}

/// Open a saved dynamic index from `path`.
pub fn open_dynamic(path: &Path) -> Result<crate::dynamic::DynamicIvf> {
    let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    open_dynamic_bytes(buf).with_context(|| format!("opening {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_and_sections_roundtrip() {
        let mut f = file_header(KIND_IVF);
        push_section(&mut f, b"AAAA", b"hello");
        push_section(&mut f, b"BBBB", b"");
        push_section(&mut f, b"CCCC", &[1, 2, 3]);
        finish_container(&mut f);
        let c = Container::parse(&Bytes::from_vec(f)).unwrap();
        assert_eq!(c.kind, KIND_IVF);
        assert_eq!(c.section(b"AAAA").unwrap().as_slice(), b"hello");
        assert_eq!(c.section(b"BBBB").unwrap().len(), 0);
        assert_eq!(c.section(b"CCCC").unwrap().as_slice(), &[1, 2, 3]);
        let err = c.section(b"DDDD").expect_err("missing tag");
        assert!(format!("{err:?}").contains("missing section"), "{err:?}");
    }

    #[test]
    fn framing_corruption_is_an_error_not_a_panic() {
        let mut good = file_header(KIND_GRAPH);
        push_section(&mut good, b"HEAD", &[7; 40]);
        finish_container(&mut good);

        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(Container::parse(&Bytes::from_vec(bad)).is_err());
        // Future version.
        let mut bad = good.clone();
        bad[4] = 99;
        let err = Container::parse(&Bytes::from_vec(bad)).unwrap_err();
        assert!(format!("{err}").contains("version"), "{err}");
        // v3: truncation at *every* prefix length — including exact section
        // boundaries, which v2 framing alone cannot see — must error.
        for cut in 0..good.len() {
            assert!(
                Container::parse(&Bytes::from_vec(good[..cut].to_vec())).is_err(),
                "truncation at byte {cut} of {} went undetected",
                good.len()
            );
        }
        // Section length pointing past EOF.
        let mut bad = good.clone();
        let len_at = 8 + 4;
        bad[len_at] = 0xff;
        assert!(Container::parse(&Bytes::from_vec(bad)).is_err());
    }

    /// Build a legacy v1 container (no section CRCs) by hand.
    fn v1_container(kind: u8, sections: &[(&[u8; 4], &[u8])]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&1u16.to_le_bytes());
        out.push(kind);
        out.push(0);
        for (tag, payload) in sections {
            out.extend_from_slice(*tag);
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(payload);
        }
        out
    }

    #[test]
    fn v2_checksum_catches_every_single_byte_flip() {
        let mut f = file_header(KIND_IVF);
        push_section(&mut f, b"AAAA", &[0x11; 24]);
        push_section(&mut f, b"BBBB", &[0x22; 9]);
        finish_container(&mut f);
        assert!(Container::parse(&Bytes::from_vec(f.clone())).is_ok());
        // Every byte past the 8-byte header participates in a section's
        // tag, length, payload or CRC — flipping any one must fail parse.
        for i in 8..f.len() {
            let mut bad = f.clone();
            bad[i] ^= 0x40;
            assert!(
                Container::parse(&Bytes::from_vec(bad)).is_err(),
                "flip at byte {i} went undetected"
            );
        }
    }

    #[test]
    fn v2_checksum_catches_tag_swaps() {
        // Swapping the tags of two sections leaves both payloads and CRCs
        // byte-identical — only the tag under the CRC changes. The CRC
        // covers the tag precisely so this mutation is caught.
        let mut f = file_header(KIND_IVF);
        push_section(&mut f, b"AAAA", &[0x11; 16]);
        push_section(&mut f, b"BBBB", &[0x22; 16]);
        finish_container(&mut f);
        let first_tag = 8;
        let second_tag = 8 + 12 + 16 + 4;
        let mut bad = f.clone();
        for j in 0..4 {
            bad.swap(first_tag + j, second_tag + j);
        }
        let err = Container::parse(&Bytes::from_vec(bad)).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");
    }

    #[test]
    fn v1_containers_still_parse_and_are_flagged_unchecksummed() {
        let f = v1_container(KIND_IVF, &[(b"AAAA", b"hello"), (b"BBBB", &[1, 2, 3])]);
        let c = Container::parse(&Bytes::from_vec(f.clone())).unwrap();
        assert_eq!(c.version, 1);
        assert!(!c.checksummed());
        assert_eq!(c.section(b"AAAA").unwrap().as_slice(), b"hello");
        let c2 = {
            let mut f2 = file_header(KIND_IVF);
            push_section(&mut f2, b"AAAA", b"hello");
            finish_container(&mut f2);
            Container::parse(&Bytes::from_vec(f2)).unwrap()
        };
        assert_eq!(c2.version, VERSION);
        assert!(c2.checksummed());
        // A v1 file re-labeled v2 fails: its sections carry no CRC.
        let mut relabeled = f;
        relabeled[4] = 2;
        assert!(Container::parse(&Bytes::from_vec(relabeled)).is_err());
    }

    #[test]
    fn boundary_truncation_yields_structured_truncated_error() {
        let mut f = file_header(KIND_IVF);
        push_section(&mut f, b"AAAA", &[0x11; 24]);
        push_section(&mut f, b"BBBB", &[0x22; 16]);
        finish_container(&mut f);
        let full = f.len();

        // Cut exactly at each section boundary: flawless v2 framing, but the
        // terminator is gone (or mis-placed) — must be TruncatedContainer.
        for boundary in [8, 8 + 12 + 24 + 4, 8 + 12 + 24 + 4 + 12 + 16 + 4] {
            let err =
                Container::parse(&Bytes::from_vec(f[..boundary].to_vec())).unwrap_err();
            assert!(is_truncated(&err), "boundary cut at {boundary}: {err}");
        }
        // Cut inside the terminator's declared-length payload: readable tag,
        // but short — still structured.
        let err = Container::parse(&Bytes::from_vec(f[..full - 4].to_vec())).unwrap_err();
        assert!(is_truncated(&err), "{err}");
        // Appending trailing garbage shifts the terminator off EOF.
        let mut longer = f.clone();
        longer.extend_from_slice(&[0u8; 9]);
        let err = Container::parse(&Bytes::from_vec(longer)).unwrap_err();
        assert!(is_truncated(&err), "{err}");
        // A checksum failure is NOT classified as truncation.
        let mut flipped = f.clone();
        flipped[20] ^= 0x40;
        let err = Container::parse(&Bytes::from_vec(flipped)).unwrap_err();
        assert!(!is_truncated(&err), "{err}");
        // And the intact file still opens.
        assert!(Container::parse(&Bytes::from_vec(f)).is_ok());
    }

    #[test]
    fn packed_codes_roundtrip_at_every_width() {
        for width in 1..=16u32 {
            let mask = if width == 16 { u16::MAX } else { (1u16 << width) - 1 };
            let codes: Vec<u16> =
                (0..257u32).map(|i| (i.wrapping_mul(2654435761) as u16) & mask).collect();
            let packed = pack_codes(&codes, width);
            assert_eq!(packed.len(), (codes.len() * width as usize).div_ceil(8));
            let back = unpack_codes(&packed, width, codes.len()).unwrap();
            assert_eq!(back, codes, "width {width}");
            assert!(unpack_codes(&packed[..packed.len() - 1], width, codes.len()).is_err());
        }
    }
}
