//! [`GraphIndex`] — the serving wrapper that turns a built NSG or HNSW
//! graph into an [`AnnIndex`] backend: compressed adjacency
//! ([`GraphStore`]) + owned vectors + entry points + the shared
//! best-first [`beam_search`], with container persistence.
//!
//! The raw builders ([`Nsg`], [`Hnsw`]) stay construction-only types;
//! everything the serving path and the persistence layer need is fused
//! here, which is what lets the coordinator and the QPS bench treat graph
//! backends exactly like IVF ones.

use crate::api::{persist, AnnIndex, AnnScratch, IndexKind, IndexStats, QueryParams};
use crate::codecs::CodecSpec;
use crate::graph::hnsw::Hnsw;
use crate::graph::nsg::Nsg;
use crate::graph::{beam_search, GraphStore};
use crate::util::bytes::Blobs;
use crate::util::{ReadBuf, WriteBuf};
use anyhow::{bail, ensure, Context as _, Result};

/// Which graph construction produced the adjacency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphFamily {
    Nsg,
    Hnsw,
}

impl GraphFamily {
    fn tag(self) -> u8 {
        match self {
            GraphFamily::Nsg => 0,
            GraphFamily::Hnsw => 1,
        }
    }

    /// Metric-label name for the family.
    pub fn name(self) -> &'static str {
        match self {
            GraphFamily::Nsg => "nsg",
            GraphFamily::Hnsw => "hnsw",
        }
    }

    fn from_tag(t: u8) -> Result<GraphFamily> {
        match t {
            0 => Ok(GraphFamily::Nsg),
            1 => Ok(GraphFamily::Hnsw),
            other => bail!("unknown graph family tag {other}"),
        }
    }
}

/// A self-contained, servable graph index: compressed friend lists,
/// vectors, and the entry set the beam search starts from.
pub struct GraphIndex {
    family: GraphFamily,
    store: GraphStore,
    data: Vec<f32>,
    dim: usize,
    entries: Vec<u32>,
    codec: CodecSpec,
    /// False only when opened from a legacy v1 container (no per-section
    /// CRCs on disk); surfaced through [`IndexStats::checksummed`].
    checksummed: bool,
}

impl GraphIndex {
    /// Wrap a built NSG: friend lists are re-encoded once with `codec`
    /// (any per-list name: unc64|unc32|compact|ef|roc), vectors copied in.
    pub fn from_nsg(nsg: &Nsg, data: &[f32], codec: &str) -> Result<GraphIndex> {
        let spec = CodecSpec::parse(codec)?;
        let n = nsg.adj.len();
        ensure!(
            data.len() == n * nsg.dim,
            "data holds {} floats for {n} vectors of dim {}",
            data.len(),
            nsg.dim
        );
        let store = GraphStore::try_compress(&nsg.adj, &spec)?;
        Ok(GraphIndex {
            family: GraphFamily::Nsg,
            store,
            data: data.to_vec(),
            dim: nsg.dim,
            entries: nsg.entries.clone(),
            codec: spec,
            checksummed: true,
        })
    }

    /// Wrap a built HNSW base layer (the upper layers only steer toward
    /// an entry point, which is captured in `entries`; Table 3: "other
    /// levels occupy negligible storage").
    pub fn from_hnsw(h: &Hnsw, data: &[f32], codec: &str) -> Result<GraphIndex> {
        let spec = CodecSpec::parse(codec)?;
        let n = h.base_adj().len();
        ensure!(
            data.len() == n * h.dim,
            "data holds {} floats for {n} vectors of dim {}",
            data.len(),
            h.dim
        );
        let store = GraphStore::try_compress(h.base_adj(), &spec)?;
        Ok(GraphIndex {
            family: GraphFamily::Hnsw,
            store,
            data: data.to_vec(),
            dim: h.dim,
            entries: vec![h.entry],
            codec: spec,
            checksummed: true,
        })
    }

    pub fn family(&self) -> GraphFamily {
        self.family
    }

    /// The adjacency store (for direct [`beam_search`] comparisons).
    pub fn store(&self) -> &GraphStore {
        &self.store
    }

    /// The beam-search entry set.
    pub fn entries(&self) -> &[u32] {
        &self.entries
    }

    /// The owned vector data (row-major `n × dim`).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub(crate) fn to_container_bytes(&self) -> Result<Vec<u8>> {
        let (blobs, lens, bits) = match &self.store {
            GraphStore::Compressed { blobs, lens, bits, .. } => (blobs, lens, *bits),
            GraphStore::Raw(_) => bail!(
                "raw adjacency is not persisted; construct the GraphIndex with a per-list codec"
            ),
        };
        let mut head = WriteBuf::new();
        head.put_u8(self.family.tag());
        head.put_u64(self.dim as u64);
        head.put_u64((self.data.len() / self.dim) as u64);
        head.put_str(self.codec.name());
        head.put_u32s(&self.entries);
        head.put_u64(bits);

        let mut file = persist::file_header(persist::KIND_GRAPH);
        persist::push_section(&mut file, b"HEAD", &head.bytes);
        let mut vecs = WriteBuf::new();
        vecs.put_f32s(&self.data);
        persist::push_section(&mut file, b"VECS", &vecs.bytes);
        let mut glen = WriteBuf::new();
        glen.put_u32s(lens);
        persist::push_section(&mut file, b"GLEN", &glen.bytes);
        let mut goff = WriteBuf::new();
        goff.put_u64s(blobs.offsets());
        persist::push_section(&mut file, b"GOFF", &goff.bytes);
        persist::push_section(&mut file, b"GBLB", blobs.payload());
        persist::finish_container(&mut file);
        Ok(file)
    }

    pub(crate) fn from_container(c: &persist::Container) -> Result<GraphIndex> {
        let head = c.section(b"HEAD")?;
        let mut r = ReadBuf::new(head.as_slice());
        let family = GraphFamily::from_tag(r.get_u8()?)?;
        let dim = r.get_u64()? as usize;
        let n = r.get_u64()? as usize;
        let codec_name = r.get_str()?;
        let entries = r.get_u32s()?;
        let bits = r.get_u64()?;
        ensure!(dim >= 1, "degenerate header (dim=0)");
        ensure!(!entries.is_empty(), "graph index has no entry points");
        ensure!(
            entries.iter().all(|&e| (e as usize) < n),
            "entry point out of range (n={n})"
        );
        let spec = CodecSpec::parse(&codec_name).context("graph header names its codec")?;

        let sec = c.section(b"VECS")?;
        let data = ReadBuf::new(sec.as_slice()).get_f32s()?;
        ensure!(data.len() == n * dim, "vector section holds {} floats", data.len());
        let sec = c.section(b"GLEN")?;
        let lens = ReadBuf::new(sec.as_slice()).get_u32s()?;
        ensure!(lens.len() == n, "length table holds {} entries for n={n}", lens.len());
        // A friend list can reference at most every other node; a larger
        // length is structural corruption and would otherwise surface as
        // a decode panic mid-query instead of an open-time error.
        ensure!(
            lens.iter().all(|&l| (l as usize) < n.max(1)),
            "length table contains a degree >= n={n}"
        );
        let sec = c.section(b"GOFF")?;
        let goff = ReadBuf::new(sec.as_slice()).get_u64s()?;
        let blobs = Blobs::from_parts(c.section(b"GBLB")?, goff)?;
        let store = GraphStore::from_compressed_parts(&spec, blobs, lens, n as u32, bits)?;
        if !c.checksummed() {
            // Legacy v1 file: no per-section CRC protected the adjacency
            // streams, so decode every friend list once now — corruption
            // surfaces as an open error instead of a panic mid-query.
            store.validate_decode().context("v1 graph container failed decode validation")?;
        }
        Ok(GraphIndex {
            family,
            store,
            data,
            dim,
            entries,
            codec: spec,
            checksummed: c.checksummed(),
        })
    }
}

impl AnnIndex for GraphIndex {
    fn kind(&self) -> IndexKind {
        match self.family {
            GraphFamily::Nsg => IndexKind::Nsg,
            GraphFamily::Hnsw => IndexKind::Hnsw,
        }
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    fn stats(&self) -> IndexStats {
        IndexStats {
            kind: self.kind(),
            n: self.len(),
            dim: self.dim,
            edges: self.store.num_edges(),
            codec: self.codec.name().to_string(),
            id_bits: 0,
            code_bits: self.data.len() as u64 * 32,
            link_bits: self.store.id_bits(),
            live: self.len(),
            deleted: 0,
            buffer_rows: 0,
            aux_bits: 0,
            checksummed: self.checksummed,
            segments: Vec::new(),
        }
    }

    fn search_into(
        &self,
        query: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        if crate::obs::enabled() {
            scratch.graph_obs.get("zann_beam_searches_total", "family", self.family.name()).inc();
        }
        let span = crate::obs::trace::span(crate::obs::trace::Stage::BeamSearch);
        let res = beam_search(
            &self.store,
            &self.data,
            self.dim,
            &self.entries,
            query,
            params.ef.max(params.k),
            params.k,
            &mut scratch.visited,
            &mut scratch.neighbors,
        );
        drop(span);
        out.clear();
        out.extend(res);
    }

    fn to_bytes(&self) -> Result<Vec<u8>> {
        self.to_container_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, Kind};
    use crate::graph::nsg::NsgParams;
    use crate::graph::VisitedSet;

    #[test]
    fn graph_index_search_is_exactly_beam_search() {
        let ds = generate(Kind::DeepLike, 1200, 10, 8, 61);
        let nsg = Nsg::build(
            &ds.data,
            ds.dim,
            &NsgParams { r: 16, knn_k: 24, threads: 2, seed: 5, ..Default::default() },
        );
        let gi = GraphIndex::from_nsg(&nsg, &ds.data, "roc").unwrap();
        let p = QueryParams { k: 5, nprobe: 0, ef: 32 };
        let mut scratch = AnnScratch::default();
        let mut out = Vec::new();
        let mut visited = VisitedSet::default();
        let mut neigh = Vec::new();
        for qi in 0..ds.nq {
            gi.search_into(ds.query(qi), &p, &mut scratch, &mut out);
            let want = beam_search(
                gi.store(),
                &ds.data,
                ds.dim,
                gi.entries(),
                ds.query(qi),
                32,
                5,
                &mut visited,
                &mut neigh,
            );
            assert_eq!(out, want, "query {qi}");
        }
        let s = gi.stats();
        assert_eq!(s.kind, IndexKind::Nsg);
        assert_eq!(s.link_bits, gi.store().id_bits());
        assert!(s.link_bits > 0);
        assert_eq!(s.edges, gi.store().num_edges());
        // bits_per_id for a graph is the paper's bits-per-edge-id.
        assert!((s.bits_per_id() - gi.store().bits_per_edge()).abs() < 1e-9);
    }

    #[test]
    fn whole_structure_codec_is_rejected_for_adjacency() {
        let ds = generate(Kind::DeepLike, 300, 1, 8, 62);
        let nsg = Nsg::build(
            &ds.data,
            ds.dim,
            &NsgParams { r: 8, knn_k: 16, threads: 2, seed: 5, ..Default::default() },
        );
        let err = GraphIndex::from_nsg(&nsg, &ds.data, "zuckerli").expect_err("not per-list");
        assert!(format!("{err}").contains("per-list"), "{err}");
        let err = GraphIndex::from_nsg(&nsg, &ds.data, "rocc").expect_err("typo");
        assert!(format!("{err}").contains("valid names"), "{err}");
    }
}
