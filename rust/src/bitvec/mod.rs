//! Succinct bitvectors: plain rank/select ([`RsBitVec`]) and an
//! RRR-compressed variant ([`rrr::RrrVec`]).
//!
//! These back the Elias-Fano codec (select1 over the unary upper-bits
//! stream) and the wavelet tree (rank0/rank1 per level; WT1 swaps the flat
//! bitmaps for RRR ones, trading select speed for space exactly as the
//! paper describes).

pub mod rrr;

use crate::util::bits::BitBuf;

/// Plain bitvector with o(n) rank and sampled select.
///
/// Layout: one absolute 64-bit rank sample per 512-bit superblock, plus the
/// raw words; select1/select0 binary-search the samples then scan words.
#[derive(Clone, Debug)]
pub struct RsBitVec {
    buf: BitBuf,
    /// rank1 at the start of each 512-bit superblock.
    rank_samples: Vec<u64>,
    ones: u64,
}

const SUPER: usize = 512; // bits per superblock (8 words)

impl RsBitVec {
    pub fn new(buf: BitBuf) -> Self {
        let n_super = buf.len.div_ceil(SUPER);
        let mut rank_samples = Vec::with_capacity(n_super + 1);
        let mut acc = 0u64;
        for sb in 0..=n_super {
            rank_samples.push(acc);
            if sb == n_super {
                break;
            }
            let w0 = sb * (SUPER / 64);
            for w in w0..(w0 + SUPER / 64).min(buf.words.len()) {
                let mut word = buf.words[w];
                // Mask tail bits beyond len in the last word.
                let bit0 = w * 64;
                if bit0 + 64 > buf.len {
                    let valid = buf.len - bit0;
                    word &= if valid == 0 { 0 } else { u64::MAX >> (64 - valid) };
                }
                acc += word.count_ones() as u64;
            }
        }
        RsBitVec { ones: acc, buf, rank_samples }
    }

    pub fn len(&self) -> usize {
        self.buf.len
    }

    pub fn is_empty(&self) -> bool {
        self.buf.len == 0
    }

    pub fn count_ones(&self) -> u64 {
        self.ones
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        self.buf.get_bit(i)
    }

    /// Number of ones in `[0, i)`.
    #[inline]
    pub fn rank1(&self, i: usize) -> u64 {
        debug_assert!(i <= self.buf.len);
        let sb = i / SUPER;
        let mut r = self.rank_samples[sb];
        let w0 = sb * (SUPER / 64);
        let wi = i / 64;
        for w in w0..wi {
            r += self.buf.words[w].count_ones() as u64;
        }
        let bit = i & 63;
        if bit != 0 {
            r += (self.buf.words[wi] & ((1u64 << bit) - 1)).count_ones() as u64;
        }
        r
    }

    /// Number of zeros in `[0, i)`.
    #[inline]
    pub fn rank0(&self, i: usize) -> u64 {
        i as u64 - self.rank1(i)
    }

    /// Position of the k-th one (0-based); `None` if k >= count_ones.
    pub fn select1(&self, k: u64) -> Option<usize> {
        if k >= self.ones {
            return None;
        }
        // Binary search superblock samples.
        let mut lo = 0usize;
        let mut hi = self.rank_samples.len() - 1;
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if self.rank_samples[mid] <= k {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        let mut rem = k - self.rank_samples[lo];
        let w0 = lo * (SUPER / 64);
        for w in w0..self.buf.words.len() {
            let mut word = self.buf.words[w];
            let bit0 = w * 64;
            if bit0 + 64 > self.buf.len {
                let valid = self.buf.len - bit0;
                word &= if valid == 0 { 0 } else { u64::MAX >> (64 - valid) };
            }
            let c = word.count_ones() as u64;
            if rem < c {
                return Some(bit0 + select_in_word(word, rem as u32) as usize);
            }
            rem -= c;
        }
        None
    }

    /// Position of the k-th zero (0-based).
    pub fn select0(&self, k: u64) -> Option<usize> {
        let zeros = self.buf.len as u64 - self.ones;
        if k >= zeros {
            return None;
        }
        let mut lo = 0usize;
        let mut hi = self.rank_samples.len() - 1;
        // rank0 at superblock s = s*SUPER - rank_samples[s].
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            let r0 = (mid * SUPER).min(self.buf.len) as u64 - self.rank_samples[mid];
            if r0 <= k {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        let mut rem = k - ((lo * SUPER).min(self.buf.len) as u64 - self.rank_samples[lo]);
        let w0 = lo * (SUPER / 64);
        for w in w0..self.buf.words.len() {
            let bit0 = w * 64;
            let valid = (self.buf.len - bit0).min(64);
            let mask = if valid == 64 { u64::MAX } else { (1u64 << valid) - 1 };
            let word = !self.buf.words[w] & mask;
            let c = word.count_ones() as u64;
            if rem < c {
                return Some(bit0 + select_in_word(word, rem as u32) as usize);
            }
            rem -= c;
        }
        None
    }

    /// Size of the structure in bits (payload + rank samples).
    pub fn size_bits(&self) -> usize {
        self.buf.words.len() * 64 + self.rank_samples.len() * 64
    }

    /// The raw bitmap words (LSB-first), for serialization; rank
    /// samples are rebuilt by [`RsBitVec::new`] on the way back in.
    pub fn words(&self) -> &[u64] {
        &self.buf.words
    }

    /// Payload-only size in bits.
    pub fn payload_bits(&self) -> usize {
        self.buf.len
    }
}

/// Position (0..64) of the k-th set bit of `word` (k < popcount).
#[inline]
pub fn select_in_word(mut word: u64, mut k: u32) -> u32 {
    // Clear the k lowest set bits, then count trailing zeros.
    while k > 0 {
        word &= word - 1;
        k -= 1;
    }
    word.trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bits::BitWriter;
    use crate::util::Rng;

    fn make(bits: &[bool]) -> RsBitVec {
        let mut w = BitWriter::new();
        for &b in bits {
            w.push_bit(b);
        }
        RsBitVec::new(w.finish())
    }

    fn naive_rank1(bits: &[bool], i: usize) -> u64 {
        bits[..i].iter().filter(|&&b| b).count() as u64
    }

    #[test]
    fn rank_select_small() {
        let bits = vec![true, false, true, true, false, false, true];
        let v = make(&bits);
        assert_eq!(v.count_ones(), 4);
        for i in 0..=bits.len() {
            assert_eq!(v.rank1(i), naive_rank1(&bits, i), "rank1({i})");
            assert_eq!(v.rank0(i), i as u64 - naive_rank1(&bits, i));
        }
        assert_eq!(v.select1(0), Some(0));
        assert_eq!(v.select1(1), Some(2));
        assert_eq!(v.select1(3), Some(6));
        assert_eq!(v.select1(4), None);
        assert_eq!(v.select0(0), Some(1));
        assert_eq!(v.select0(2), Some(5));
        assert_eq!(v.select0(3), None);
    }

    #[test]
    fn rank_select_random_property() {
        let mut rng = Rng::new(5);
        for &density in &[0.02, 0.5, 0.93] {
            for &n in &[1usize, 63, 64, 65, 511, 512, 513, 5000] {
                let bits: Vec<bool> = (0..n).map(|_| rng.f64() < density).collect();
                let v = make(&bits);
                // rank at every position
                let mut ones = 0u64;
                for i in 0..n {
                    assert_eq!(v.rank1(i), ones);
                    if bits[i] {
                        // select of this one must return i
                        assert_eq!(v.select1(ones), Some(i));
                        ones += 1;
                    } else {
                        assert_eq!(v.select0(i as u64 - v.rank1(i)), Some(i));
                    }
                }
                assert_eq!(v.rank1(n), ones);
                assert_eq!(v.count_ones(), ones);
            }
        }
    }

    #[test]
    fn select_in_word_all_positions() {
        let w = 0b1011_0100_1000u64;
        let positions: Vec<u32> = (0..64).filter(|i| (w >> i) & 1 == 1).collect();
        for (k, &p) in positions.iter().enumerate() {
            assert_eq!(select_in_word(w, k as u32), p);
        }
    }

    #[test]
    fn empty_and_all_ones() {
        let v = make(&[]);
        assert_eq!(v.count_ones(), 0);
        assert_eq!(v.select1(0), None);
        let v = make(&vec![true; 1000]);
        assert_eq!(v.count_ones(), 1000);
        for k in 0..1000 {
            assert_eq!(v.select1(k as u64), Some(k));
        }
    }
}
