//! RRR compressed bitvector (Raman–Raman–Rao).
//!
//! Bits are grouped into 63-bit blocks; each block is stored as a
//! (class, offset) pair where `class` is the popcount (6 bits) and `offset`
//! is the block's index in the enumeration of all `C(63, class)` patterns
//! (`ceil(log2 C(63, class))` bits — the combinatorial number system).
//! Every `SAMPLE` blocks we store an absolute rank and a pointer into the
//! offset stream, giving O(SAMPLE) rank/select with the usual
//! entropy-compressed payload: `n H0 + o(n)` bits.
//!
//! This is the structure behind the paper's **WT1** variant: swapping the
//! wavelet tree's flat bitmaps for RRR ones buys compression below
//! `log2 K` bits/id at the cost of slower select (Table 1 / Table 2).

use crate::util::bits::{BitBuf, BitWriter};

pub const BLOCK: usize = 63;
const SAMPLE: usize = 32; // blocks per rank/pointer sample

/// Pascal's triangle up to n=63, C(n,k) as u64 (C(63,31) < 2^63).
fn binomials() -> &'static [[u64; BLOCK + 1]; BLOCK + 1] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<Box<[[u64; BLOCK + 1]; BLOCK + 1]>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = Box::new([[0u64; BLOCK + 1]; BLOCK + 1]);
        for n in 0..=BLOCK {
            t[n][0] = 1;
            for k in 1..=n {
                t[n][k] = t[n - 1][k - 1] + if k <= n - 1 { t[n - 1][k] } else { 0 };
            }
        }
        t
    })
}

/// Bits needed for the offset of a class-k block (precomputed — this is
/// on the rank/select hot path of WT1).
#[inline]
fn offset_bits(k: usize) -> u32 {
    static BITS: std::sync::OnceLock<[u32; BLOCK + 1]> = std::sync::OnceLock::new();
    BITS.get_or_init(|| {
        let bin = binomials();
        let mut t = [0u32; BLOCK + 1];
        for (k, slot) in t.iter_mut().enumerate() {
            let c = bin[BLOCK][k];
            *slot = if c <= 1 { 0 } else { 64 - (c - 1).leading_zeros() };
        }
        t
    })[k]
}

/// Enumerative encode: 63-bit pattern -> offset within its class.
/// offset = sum over set bits (in increasing position p, 1-based index i)
/// of C(p, i).
fn encode_block(word: u64) -> (usize, u64) {
    let k = word.count_ones() as usize;
    let bin = binomials();
    let mut offset = 0u64;
    let mut i = 0usize; // how many set bits seen so far
    let mut w = word;
    while w != 0 {
        let p = w.trailing_zeros() as usize;
        i += 1;
        offset += bin[p][i];
        w &= w - 1;
    }
    (k, offset)
}

/// Enumerative decode: (class, offset) -> 63-bit pattern.
fn decode_block(k: usize, mut offset: u64) -> u64 {
    let bin = binomials();
    let mut word = 0u64;
    let mut rem = k;
    // Choose set-bit positions from highest to lowest.
    let mut p = BLOCK;
    while rem > 0 {
        p -= 1;
        let c = bin[p][rem];
        if offset >= c {
            offset -= c;
            word |= 1u64 << p;
            rem -= 1;
        }
    }
    word
}

/// RRR-compressed bitvector with rank/select.
#[derive(Clone, Debug)]
pub struct RrrVec {
    len: usize,
    ones: u64,
    /// 6-bit class per block, packed.
    classes: BitBuf,
    /// Variable-width offsets, concatenated.
    offsets: BitBuf,
    /// Every SAMPLE blocks: (rank1 so far, bit position in `offsets`).
    samples: Vec<(u64, u64)>,
}

impl RrrVec {
    pub fn new(buf: &BitBuf) -> Self {
        let n_blocks = buf.len.div_ceil(BLOCK);
        let mut classes = BitWriter::with_capacity(n_blocks * 6);
        let mut offsets = BitWriter::new();
        let mut samples = Vec::with_capacity(n_blocks / SAMPLE + 1);
        let mut ones = 0u64;
        for b in 0..n_blocks {
            if b % SAMPLE == 0 {
                samples.push((ones, offsets.len_bits() as u64));
            }
            let word = read_block(buf, b);
            let (k, off) = encode_block(word);
            classes.write(k as u64, 6);
            offsets.write(off, offset_bits(k));
            ones += k as u64;
        }
        samples.push((ones, offsets.len_bits() as u64));
        RrrVec {
            len: buf.len,
            ones,
            classes: classes.finish(),
            offsets: offsets.finish(),
            samples,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn count_ones(&self) -> u64 {
        self.ones
    }

    #[inline]
    fn class_of(&self, block: usize) -> usize {
        self.classes.read(block * 6, 6) as usize
    }

    /// Decode block `b`, given the offset-stream bit position of its sample
    /// predecessor; returns (word, updated stream pos after this block).
    fn walk_to_block(&self, block: usize) -> (u64, u64) {
        let s = block / SAMPLE;
        let (mut rank, mut pos) = self.samples[s];
        for b in (s * SAMPLE)..block {
            let k = self.class_of(b);
            rank += k as u64;
            pos += offset_bits(k) as u64;
        }
        (rank, pos)
    }

    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        let block = i / BLOCK;
        let (_, pos) = self.walk_to_block(block);
        let k = self.class_of(block);
        let off = self.offsets.read(pos as usize, offset_bits(k));
        let word = decode_block(k, off);
        (word >> (i % BLOCK)) & 1 == 1
    }

    /// Number of ones in `[0, i)`.
    pub fn rank1(&self, i: usize) -> u64 {
        debug_assert!(i <= self.len);
        if i == 0 {
            return 0;
        }
        let block = i / BLOCK;
        let (rank, pos) = self.walk_to_block(block.min(self.blocks() - 1));
        if block >= self.blocks() {
            return self.ones;
        }
        let k = self.class_of(block);
        let off = self.offsets.read(pos as usize, offset_bits(k));
        let word = decode_block(k, off);
        let bit = i % BLOCK;
        let mask = if bit == 0 { 0 } else { (1u64 << bit) - 1 };
        rank + (word & mask).count_ones() as u64
    }

    pub fn rank0(&self, i: usize) -> u64 {
        i as u64 - self.rank1(i)
    }

    /// Position of the k-th one (0-based).
    pub fn select1(&self, k: u64) -> Option<usize> {
        if k >= self.ones {
            return None;
        }
        // Binary search rank samples.
        let mut lo = 0usize;
        let mut hi = self.samples.len() - 1;
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if self.samples[mid].0 <= k {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        let (mut rank, mut pos) = self.samples[lo];
        for b in (lo * SAMPLE)..self.blocks() {
            let kc = self.class_of(b);
            if rank + kc as u64 > k {
                let off = self.offsets.read(pos as usize, offset_bits(kc));
                let word = decode_block(kc, off);
                let j = super::select_in_word(word, (k - rank) as u32);
                return Some(b * BLOCK + j as usize);
            }
            rank += kc as u64;
            pos += offset_bits(kc) as u64;
        }
        None
    }

    /// Position of the k-th zero (0-based).
    pub fn select0(&self, k: u64) -> Option<usize> {
        let zeros = self.len as u64 - self.ones;
        if k >= zeros {
            return None;
        }
        let mut lo = 0usize;
        let mut hi = self.samples.len() - 1;
        // rank0 before sample s = s*SAMPLE*BLOCK - rank1 (clamped to len).
        let r0 = |s: usize| -> u64 {
            let bits = ((s * SAMPLE * BLOCK) as u64).min(self.len as u64);
            bits - self.samples[s].0
        };
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if r0(mid) <= k {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        let (mut rank1, mut pos) = self.samples[lo];
        for b in (lo * SAMPLE)..self.blocks() {
            let kc = self.class_of(b);
            let block_bits = (self.len - b * BLOCK).min(BLOCK) as u64;
            let zeros_before = (b * BLOCK) as u64 - rank1;
            let zeros_in = block_bits - kc as u64;
            if zeros_before + zeros_in > k {
                let off = self.offsets.read(pos as usize, offset_bits(kc));
                let word = decode_block(kc, off);
                // block_bits <= 63 so the mask below never shifts by 64.
                let inv = !word & ((1u64 << block_bits) - 1);
                let j = super::select_in_word(inv, (k - zeros_before) as u32);
                return Some(b * BLOCK + j as usize);
            }
            rank1 += kc as u64;
            pos += offset_bits(kc) as u64;
        }
        None
    }

    fn blocks(&self) -> usize {
        self.len.div_ceil(BLOCK)
    }

    /// Total structure size in bits (classes + offsets + samples).
    pub fn size_bits(&self) -> usize {
        self.classes.size_bits() + self.offsets.size_bits() + self.samples.len() * 128
    }
}

fn read_block(buf: &BitBuf, block: usize) -> u64 {
    let start = block * BLOCK;
    let n = (buf.len - start).min(BLOCK) as u32;
    buf.read(start, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bits::BitWriter;
    use crate::util::Rng;

    fn bitbuf(bits: &[bool]) -> BitBuf {
        let mut w = BitWriter::new();
        for &b in bits {
            w.push_bit(b);
        }
        w.finish()
    }

    #[test]
    fn block_codec_roundtrip_exhaustive_small_classes() {
        // All 0/1/2-bit patterns plus random dense words.
        for p in 0..BLOCK {
            let w = 1u64 << p;
            let (k, off) = encode_block(w);
            assert_eq!(k, 1);
            assert_eq!(decode_block(k, off), w);
            for q in (p + 1)..BLOCK {
                let w2 = w | (1u64 << q);
                let (k2, off2) = encode_block(w2);
                assert_eq!(k2, 2);
                assert_eq!(decode_block(k2, off2), w2);
            }
        }
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            let w = rng.next_u64() & (u64::MAX >> 1); // 63 bits
            let (k, off) = encode_block(w);
            assert!(off < binomials()[BLOCK][k]);
            assert_eq!(decode_block(k, off), w);
        }
    }

    #[test]
    fn offset_is_dense_enumeration() {
        // For class 1 the offsets must be a permutation of 0..63.
        let mut seen = vec![false; BLOCK];
        for p in 0..BLOCK {
            let (_, off) = encode_block(1u64 << p);
            assert!(!seen[off as usize]);
            seen[off as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn rank_select_matches_plain() {
        let mut rng = Rng::new(2);
        for &density in &[0.05, 0.5, 0.95] {
            for &n in &[1usize, 62, 63, 64, 200, 63 * 33, 10_000] {
                let bits: Vec<bool> = (0..n).map(|_| rng.f64() < density).collect();
                let buf = bitbuf(&bits);
                let rrr = RrrVec::new(&buf);
                assert_eq!(rrr.len(), n);
                let mut ones = 0u64;
                for i in 0..n {
                    assert_eq!(rrr.rank1(i), ones, "rank1({i}) n={n}");
                    assert_eq!(rrr.get(i), bits[i]);
                    if bits[i] {
                        assert_eq!(rrr.select1(ones), Some(i));
                        ones += 1;
                    } else {
                        assert_eq!(rrr.select0(i as u64 - ones), Some(i));
                    }
                }
                assert_eq!(rrr.rank1(n), ones);
                assert_eq!(rrr.count_ones(), ones);
                assert_eq!(rrr.select1(ones), None);
            }
        }
    }

    #[test]
    fn compresses_sparse_bitmaps() {
        // 1% density: RRR must be far below the plain 1 bit/bit payload.
        let mut rng = Rng::new(3);
        let n = 200_000;
        let bits: Vec<bool> = (0..n).map(|_| rng.f64() < 0.01).collect();
        let rrr = RrrVec::new(&bitbuf(&bits));
        let plain_bits = n as f64;
        let rrr_bits = rrr.size_bits() as f64;
        // H0(0.01) ~ 0.081 bits; allow generous structural overhead.
        assert!(
            rrr_bits < 0.35 * plain_bits,
            "rrr {rrr_bits} vs plain {plain_bits}"
        );
    }
}
