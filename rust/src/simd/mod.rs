//! Runtime-dispatched SIMD kernels for the search read path.
//!
//! Three kernels live here, each with a scalar reference and (on
//! `x86_64`) hand-written SSE4.1/AVX2 variants selected once per process
//! by [`level`]:
//!
//! * [`coarse`] — the fused coarse distance kernel
//!   `‖q‖² − 2·q·c + ‖c‖²` (consumed through
//!   [`crate::quant::coarse::dists_into`], so IVF, the runtime fallback
//!   and the coordinator pick it up without signature churn);
//! * [`adc`] — a blocked PQ ADC scan (the per-query LUT gathered for
//!   8 codes at a time, accumulated in registers);
//! * [`filter`] — batched tombstone filtering for the dynamic index
//!   (bitmap tests for 8 ids per gather).
//!
//! **Determinism contract:** every SIMD variant performs *exactly* the
//! same per-lane operations in the same order as its scalar reference —
//! same 4-lane accumulators, same left-associated reductions, multiply
//! then add (no FMA contraction) — so dispatched and scalar results are
//! **bit-identical**, not merely close. `rust/tests/simd_parity.rs`
//! asserts exact equality on random inputs for every level the host
//! supports, and `ci.sh` runs the build→save→open→serve smoke under
//! `ZANN_SIMD=scalar` and under the default dispatch and `cmp`s the
//! result dumps. This is what lets every existing `assert_eq!`-style
//! parity test (serving, churn, persistence fixtures) hold regardless of
//! the host's instruction set.
//!
//! **Forcing a level:** set `ZANN_SIMD` to `scalar`, `sse4.1`, `avx2` or
//! `auto` (default). Requests above what the host supports clamp down;
//! unknown values warn once and fall back to `auto`. On non-x86_64
//! targets every request resolves to `scalar` (NEON variants are a
//! roadmap item; the scalar reference is the portable path).

pub mod adc;
pub mod coarse;
pub mod filter;

use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction-set tier of the dispatched kernels, ordered by capability.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Scalar,
    Sse41,
    Avx2,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Sse41 => "sse4.1",
            Level::Avx2 => "avx2",
        }
    }

    /// Every level this build knows, weakest first (test sweeps iterate
    /// the prefix supported by the host).
    pub const ALL: [Level; 3] = [Level::Scalar, Level::Sse41, Level::Avx2];
}

/// Cached dispatch decision: 0 = undecided, else `level as u8 + 1`.
static LEVEL: AtomicU8 = AtomicU8::new(0);

fn from_tag(tag: u8) -> Level {
    match tag {
        1 => Level::Scalar,
        2 => Level::Sse41,
        _ => Level::Avx2,
    }
}

/// Highest tier the host CPU supports (ignores the env override).
pub fn detected() -> Level {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Level::Avx2;
        }
        if is_x86_feature_detected!("sse4.1") {
            return Level::Sse41;
        }
        Level::Scalar
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        Level::Scalar
    }
}

fn decide() -> Level {
    let hw = detected();
    match std::env::var("ZANN_SIMD") {
        Ok(v) => match v.to_ascii_lowercase().as_str() {
            "scalar" => Level::Scalar,
            "sse4.1" | "sse41" => hw.min(Level::Sse41),
            "avx2" => hw.min(Level::Avx2),
            "" | "auto" => hw,
            other => {
                eprintln!(
                    "ZANN_SIMD={other:?} not recognized (scalar|sse4.1|avx2|auto); using auto"
                );
                hw
            }
        },
        Err(_) => hw,
    }
}

/// The active dispatch level: hardware detection clamped by the
/// `ZANN_SIMD` override, decided once and cached for the process.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => {
            let l = decide();
            // A racing thread computes the same value; last store wins.
            LEVEL.store(l as u8 + 1, Ordering::Relaxed);
            l
        }
        tag => from_tag(tag),
    }
}

/// Prefetch the cache line at `ptr` into L1 (read intent). No-op on
/// targets without a prefetch intrinsic; never a correctness concern —
/// the address does not need to be valid to prefetch.
#[inline(always)]
pub fn prefetch_read<T>(ptr: *const T) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(ptr as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = ptr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_is_cached_and_within_detection() {
        let l = level();
        assert!(l <= detected());
        assert_eq!(level(), l, "second call must return the cached decision");
        assert!(["scalar", "sse4.1", "avx2"].contains(&l.name()));
    }

    #[test]
    fn prefetch_accepts_any_pointer() {
        let v = [1f32, 2.0, 3.0];
        prefetch_read(v.as_ptr());
        prefetch_read(std::ptr::null::<f32>());
    }
}
