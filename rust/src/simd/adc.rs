//! Blocked PQ ADC scan: distances for whole code lists through the
//! per-query LUT, 8 rows per step on AVX2.
//!
//! The scalar reference is one row at a time, `m` table adds in
//! sub-quantizer order `j = 0 … m−1` (exactly [`crate::quant::pq::Pq::adc`]).
//! The AVX2 variant keeps 8 *rows* in flight instead of widening a
//! single row's sum: for each `j` it gathers
//! `lut[j·ksub + code(r, j)]` for rows `r … r+7` with `vgatherdps` and
//! accumulates per lane — so each row's sum performs the same additions
//! in the same order as the scalar loop and the results are
//! bit-identical. (An SSE4.1 tier would be a scalar gather with vector
//! adds — no win — so dispatch is AVX2-or-scalar here.)
//!
//! Safety invariant: every code must be `< ksub`. All code sources
//! uphold it structurally (the encoder emits `nearest` indices, the
//! packed container masks to the code width, the entropy decoder's
//! alphabet is `ksub`), and the entry points `debug_assert` it.

use super::Level;

/// Fill `out[r]` with the ADC distance of row `r` at the given level.
/// `codes` is row-major `n × m`; `lut` is `m × ksub`.
pub fn adc_scan_level(
    level: Level,
    lut: &[f32],
    ksub: usize,
    m: usize,
    codes: &[u16],
    out: &mut [f32],
) {
    debug_assert!(m > 0 && codes.len() % m == 0);
    debug_assert_eq!(lut.len(), m * ksub);
    debug_assert_eq!(out.len(), codes.len() / m);
    debug_assert!(codes.iter().all(|&c| (c as usize) < ksub), "code out of alphabet");
    #[cfg(target_arch = "x86_64")]
    {
        if level == Level::Avx2 {
            let n = out.len();
            let full = n - n % 8;
            unsafe {
                x86::adc_rows_avx2(lut, ksub, m, &codes[..full * m], &mut out[..full]);
            }
            adc_rows_scalar(lut, ksub, m, &codes[full * m..], &mut out[full..]);
            return;
        }
    }
    let _ = level;
    adc_rows_scalar(lut, ksub, m, codes, out);
}

/// Dispatched blocked scan into a reusable buffer (replaces `out`).
pub fn adc_scan_into(lut: &[f32], ksub: usize, m: usize, codes: &[u16], out: &mut Vec<f32>) {
    let n = codes.len() / m.max(1);
    out.clear();
    out.resize(n, 0.0);
    adc_scan_level(super::level(), lut, ksub, m, codes, out);
}

/// The scalar reference: per row, `m` adds in `j` order.
pub fn adc_rows_scalar(lut: &[f32], ksub: usize, m: usize, codes: &[u16], out: &mut [f32]) {
    for (r, row) in codes.chunks_exact(m).enumerate() {
        let mut s = 0f32;
        for (j, &c) in row.iter().enumerate() {
            s += lut[j * ksub + c as usize];
        }
        out[r] = s;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    /// 8 rows per iteration; caller guarantees `out.len() % 8 == 0`,
    /// `codes.len() == out.len() * m` and every code `< ksub`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn adc_rows_avx2(
        lut: &[f32],
        ksub: usize,
        m: usize,
        codes: &[u16],
        out: &mut [f32],
    ) {
        let lut_ptr = lut.as_ptr();
        for (blk, o) in out.chunks_exact_mut(8).enumerate() {
            let rows = codes.as_ptr().add(blk * 8 * m);
            let mut acc = _mm256_setzero_ps();
            for j in 0..m {
                let base = (j * ksub) as i32;
                let idx = _mm256_setr_epi32(
                    *rows.add(j) as i32 + base,
                    *rows.add(m + j) as i32 + base,
                    *rows.add(2 * m + j) as i32 + base,
                    *rows.add(3 * m + j) as i32 + base,
                    *rows.add(4 * m + j) as i32 + base,
                    *rows.add(5 * m + j) as i32 + base,
                    *rows.add(6 * m + j) as i32 + base,
                    *rows.add(7 * m + j) as i32 + base,
                );
                let g = _mm256_i32gather_ps::<4>(lut_ptr, idx);
                acc = _mm256_add_ps(acc, g);
            }
            _mm256_storeu_ps(o.as_mut_ptr(), acc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn avx2_scan_is_bit_identical_to_scalar() {
        let mut rng = Rng::new(0xadc5);
        let hw = super::super::detected();
        for &(ksub, m) in &[(16usize, 1usize), (256, 4), (256, 8), (1024, 8), (64, 9)] {
            for &n in &[0usize, 1, 7, 8, 9, 40, 257] {
                let lut: Vec<f32> = (0..m * ksub).map(|_| rng.normal()).collect();
                let codes: Vec<u16> =
                    (0..n * m).map(|_| rng.below(ksub as u64) as u16).collect();
                let mut want = vec![0f32; n];
                adc_scan_level(Level::Scalar, &lut, ksub, m, &codes, &mut want);
                for level in Level::ALL {
                    if level > hw {
                        continue;
                    }
                    let mut got = vec![0f32; n];
                    adc_scan_level(level, &lut, ksub, m, &codes, &mut got);
                    for (r, (&g, &w)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "{}: ksub={ksub} m={m} n={n} row {r}",
                            level.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn scan_matches_per_row_adc_order() {
        // The scalar reference must be exactly the Pq::adc summation.
        let mut rng = Rng::new(0xadc6);
        let (ksub, m, n) = (256usize, 8usize, 33usize);
        let lut: Vec<f32> = (0..m * ksub).map(|_| rng.normal()).collect();
        let codes: Vec<u16> = (0..n * m).map(|_| rng.below(ksub as u64) as u16).collect();
        let mut out = Vec::new();
        adc_scan_into(&lut, ksub, m, &codes, &mut out);
        for (r, row) in codes.chunks_exact(m).enumerate() {
            let mut s = 0f32;
            for (j, &c) in row.iter().enumerate() {
                s += lut[j * ksub + c as usize];
            }
            assert_eq!(out[r].to_bits(), s.to_bits(), "row {r}");
        }
    }
}
