//! SIMD variants of the fused coarse kernel
//! `d(q, c) = ‖q‖² − 2·q·c + ‖c‖²`.
//!
//! The scalar reference is [`crate::quant::coarse::dists_into_scalar`]:
//! blocks of 4 centroids, 4 f32 lanes per dim-chunk, left-associated
//! lane reduction, scalar remainders. Each variant here replays those
//! operations with vector registers of the *same lane layout* — SSE4.1
//! holds one centroid's 4 lanes per `__m128`, AVX2 packs two centroids'
//! lane quads into one `__m256` — multiply-then-add (no FMA), so every
//! intermediate equals the scalar one bit-for-bit and the final
//! distances are identical. That bit-exactness is load-bearing: the
//! serving/churn/persistence suites compare full result lists with
//! `assert_eq!` across paths that may run on different dispatch levels.

use super::Level;
use crate::quant::coarse::dists_into_scalar;
#[cfg(target_arch = "x86_64")]
use crate::quant::coarse::dot;

/// Fused distances from one query to every centroid row at the given
/// dispatch level (`out.len() == norms.len()`). Bit-identical across
/// levels.
pub fn dists_into_level(
    level: Level,
    query: &[f32],
    centroids: &[f32],
    dim: usize,
    norms: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(centroids.len(), norms.len() * dim);
    debug_assert_eq!(out.len(), norms.len());
    debug_assert_eq!(query.len(), dim);
    #[cfg(target_arch = "x86_64")]
    {
        match level {
            Level::Avx2 => unsafe { x86::dists_into_avx2(query, centroids, dim, norms, out) },
            Level::Sse41 => unsafe { x86::dists_into_sse41(query, centroids, dim, norms, out) },
            Level::Scalar => dists_into_scalar(query, centroids, dim, norms, out),
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = level;
        dists_into_scalar(query, centroids, dim, norms, out);
    }
}

/// Dispatched entry point (the body of
/// [`crate::quant::coarse::dists_into`]).
#[inline]
pub fn dists_into(query: &[f32], centroids: &[f32], dim: usize, norms: &[f32], out: &mut [f32]) {
    dists_into_level(super::level(), query, centroids, dim, norms, out);
}

/// Scalar epilogue shared by every level: the centroids left over after
/// the 4-wide blocks, scored with the same [`dot`] the scalar path uses.
#[cfg(target_arch = "x86_64")]
#[inline]
fn tail(
    query: &[f32],
    centroids: &[f32],
    dim: usize,
    norms: &[f32],
    out: &mut [f32],
    q_norm: f32,
    from: usize,
) {
    for c in from..norms.len() {
        let d = dot(query, &centroids[c * dim..(c + 1) * dim]);
        out[c] = (q_norm - 2.0 * d + norms[c]).max(0.0);
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{dot, tail};
    use core::arch::x86_64::*;

    /// Left-associated horizontal sum — the scalar reduction
    /// `acc[0] + acc[1] + acc[2] + acc[3]`, performed in that order.
    #[inline(always)]
    unsafe fn hsum_ordered(v: __m128) -> f32 {
        let a: [f32; 4] = core::mem::transmute(v);
        a[0] + a[1] + a[2] + a[3]
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn dists_into_sse41(
        query: &[f32],
        centroids: &[f32],
        dim: usize,
        norms: &[f32],
        out: &mut [f32],
    ) {
        let k = norms.len();
        let q_norm = dot(query, query);
        let chunks = dim / 4;
        let blocks = k / 4;
        let q = query.as_ptr();
        for b in 0..blocks {
            let base = centroids.as_ptr().add(b * 4 * dim);
            let mut acc = [_mm_setzero_ps(); 4];
            for i in 0..chunks {
                let qv = _mm_loadu_ps(q.add(i * 4));
                for (j, a) in acc.iter_mut().enumerate() {
                    let cv = _mm_loadu_ps(base.add(j * dim + i * 4));
                    *a = _mm_add_ps(*a, _mm_mul_ps(qv, cv));
                }
            }
            let mut d = [
                hsum_ordered(acc[0]),
                hsum_ordered(acc[1]),
                hsum_ordered(acc[2]),
                hsum_ordered(acc[3]),
            ];
            for i in chunks * 4..dim {
                let qi = *query.get_unchecked(i);
                for (j, dj) in d.iter_mut().enumerate() {
                    *dj += qi * *base.add(j * dim + i);
                }
            }
            for (j, &dj) in d.iter().enumerate() {
                out[b * 4 + j] = (q_norm - 2.0 * dj + norms[b * 4 + j]).max(0.0);
            }
        }
        tail(query, centroids, dim, norms, out, q_norm, blocks * 4);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dists_into_avx2(
        query: &[f32],
        centroids: &[f32],
        dim: usize,
        norms: &[f32],
        out: &mut [f32],
    ) {
        let k = norms.len();
        let q_norm = dot(query, query);
        let chunks = dim / 4;
        let blocks = k / 4;
        let q = query.as_ptr();
        for b in 0..blocks {
            let base = centroids.as_ptr().add(b * 4 * dim);
            // Two centroids' lane quads per 256-bit accumulator: low half
            // tracks centroid 2j, high half 2j+1 — per lane exactly the
            // scalar acc[c][l] sequence.
            let mut acc01 = _mm256_setzero_ps();
            let mut acc23 = _mm256_setzero_ps();
            for i in 0..chunks {
                let qv = _mm_loadu_ps(q.add(i * 4));
                let q2 = _mm256_insertf128_ps::<1>(_mm256_castps128_ps256(qv), qv);
                let c01 = _mm256_insertf128_ps::<1>(
                    _mm256_castps128_ps256(_mm_loadu_ps(base.add(i * 4))),
                    _mm_loadu_ps(base.add(dim + i * 4)),
                );
                let c23 = _mm256_insertf128_ps::<1>(
                    _mm256_castps128_ps256(_mm_loadu_ps(base.add(2 * dim + i * 4))),
                    _mm_loadu_ps(base.add(3 * dim + i * 4)),
                );
                acc01 = _mm256_add_ps(acc01, _mm256_mul_ps(q2, c01));
                acc23 = _mm256_add_ps(acc23, _mm256_mul_ps(q2, c23));
            }
            let mut d = [
                hsum_ordered(_mm256_castps256_ps128(acc01)),
                hsum_ordered(_mm256_extractf128_ps::<1>(acc01)),
                hsum_ordered(_mm256_castps256_ps128(acc23)),
                hsum_ordered(_mm256_extractf128_ps::<1>(acc23)),
            ];
            for i in chunks * 4..dim {
                let qi = *query.get_unchecked(i);
                for (j, dj) in d.iter_mut().enumerate() {
                    *dj += qi * *base.add(j * dim + i);
                }
            }
            for (j, &dj) in d.iter().enumerate() {
                out[b * 4 + j] = (q_norm - 2.0 * dj + norms[b * 4 + j]).max(0.0);
            }
        }
        tail(query, centroids, dim, norms, out, q_norm, blocks * 4);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::coarse::centroid_norms;
    use crate::util::Rng;

    #[test]
    fn every_supported_level_is_bit_identical_to_scalar() {
        let mut rng = Rng::new(0x51bd);
        let hw = super::super::detected();
        for &dim in &[1usize, 3, 4, 5, 8, 19, 32, 33, 96] {
            for &k in &[0usize, 1, 3, 4, 5, 17, 64] {
                let q: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
                let cents: Vec<f32> = (0..k * dim).map(|_| rng.normal()).collect();
                let norms = centroid_norms(&cents, dim);
                let mut want = vec![0f32; k];
                dists_into_level(Level::Scalar, &q, &cents, dim, &norms, &mut want);
                for level in Level::ALL {
                    if level > hw {
                        continue;
                    }
                    let mut got = vec![0f32; k];
                    dists_into_level(level, &q, &cents, dim, &norms, &mut got);
                    for (c, (&g, &w)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "{}: dim={dim} k={k} c={c}: {g} vs {w}",
                            level.name()
                        );
                    }
                }
            }
        }
    }
}
