//! Batched tombstone filtering for the dynamic index's segment scan.
//!
//! The scan used to interleave a bitmap test with every distance
//! computation; this module separates the phases: one pass classifies a
//! whole decoded list against the tombstone bitmap (8 ids per AVX2
//! gather), emitting the surviving positions, and the caller then runs a
//! dense, branch-light distance loop over the survivors. Survivor order
//! is the decode order, so downstream results are identical to the
//! fused loop's.
//!
//! Bitmap layout: the tombstone words are `u64` (bit `id % 64` of word
//! `id / 64`); on little-endian x86 the same memory read as `u32` words
//! indexes as bit `id % 32` of word `id / 32`, which is what the gather
//! uses. Ids at or beyond the bitmap's end are live (the bitmap only
//! grows on delete).

use super::Level;

/// Append to `keep` (after clearing it) the positions `o` of every id in
/// `exts` whose tombstone bit is unset. `words` is the delete bitmap.
pub fn live_positions_into(words: &[u64], exts: &[u32], keep: &mut Vec<u32>) {
    live_positions_level(super::level(), words, exts, keep);
}

/// Level-explicit variant (parity tests sweep it).
pub fn live_positions_level(level: Level, words: &[u64], exts: &[u32], keep: &mut Vec<u32>) {
    keep.clear();
    keep.reserve(exts.len());
    #[cfg(target_arch = "x86_64")]
    {
        if level == Level::Avx2 {
            let full = exts.len() - exts.len() % 8;
            let mut o = 0usize;
            while o < full {
                let dead = unsafe { x86::dead_mask8_avx2(words, &exts[o..o + 8]) };
                if dead == 0 {
                    for lane in 0..8u32 {
                        keep.push(o as u32 + lane);
                    }
                } else {
                    let mut live = (!dead) & 0xff;
                    while live != 0 {
                        keep.push(o as u32 + live.trailing_zeros());
                        live &= live - 1;
                    }
                }
                o += 8;
            }
            scalar_tail(words, exts, keep, full);
            return;
        }
    }
    let _ = level;
    scalar_tail(words, exts, keep, 0);
}

#[inline]
fn is_dead(words: &[u64], id: u32) -> bool {
    words.get(id as usize / 64).is_some_and(|w| (w >> (id % 64)) & 1 == 1)
}

#[inline]
fn scalar_tail(words: &[u64], exts: &[u32], keep: &mut Vec<u32>, from: usize) {
    for (o, &e) in exts.iter().enumerate().skip(from) {
        if !is_dead(words, e) {
            keep.push(o as u32);
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    /// Bitmask (low 8 bits) of the lanes of `exts` whose tombstone bit is
    /// set. `exts.len() == 8`; out-of-bitmap ids report live (gather
    /// lanes outside the word range are masked to 0).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dead_mask8_avx2(words: &[u64], exts: &[u32]) -> u32 {
        let words32 = words.as_ptr() as *const i32;
        let n32 = (words.len() * 2) as i32;
        let e = _mm256_loadu_si256(exts.as_ptr() as *const __m256i);
        // Word index (id / 32) fits 27 bits, so signed compares are safe.
        let widx = _mm256_srli_epi32::<5>(e);
        let inb = _mm256_cmpgt_epi32(_mm256_set1_epi32(n32), widx);
        let w = _mm256_mask_i32gather_epi32::<4>(_mm256_setzero_si256(), words32, widx, inb);
        let bit = _mm256_and_si256(
            _mm256_srlv_epi32(w, _mm256_and_si256(e, _mm256_set1_epi32(31))),
            _mm256_set1_epi32(1),
        );
        let dead = _mm256_cmpeq_epi32(bit, _mm256_set1_epi32(1));
        _mm256_movemask_ps(_mm256_castsi256_ps(dead)) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn every_level_matches_the_scalar_filter() {
        let mut rng = Rng::new(0xf11e);
        let hw = super::super::detected();
        for trial in 0..30 {
            // Bitmap covering [0, 4096) with random deletes; ids probe
            // inside, at the boundary, and far beyond the bitmap.
            let mut words = vec![0u64; 64];
            for _ in 0..(trial * 37) % 2000 {
                let id = rng.below(4096) as usize;
                words[id / 64] |= 1 << (id % 64);
            }
            let n = (rng.below(200)) as usize;
            let exts: Vec<u32> = (0..n)
                .map(|i| match i % 5 {
                    0 => rng.below(4096) as u32,
                    1 => 4095,
                    2 => 4096 + rng.below(1000) as u32,
                    3 => u32::MAX - rng.below(100) as u32,
                    _ => rng.below(64) as u32,
                })
                .collect();
            let mut want = Vec::new();
            live_positions_level(Level::Scalar, &words, &exts, &mut want);
            for level in Level::ALL {
                if level > hw {
                    continue;
                }
                let mut got = Vec::new();
                live_positions_level(level, &words, &exts, &mut got);
                assert_eq!(got, want, "{}: trial {trial} n={n}", level.name());
            }
            // Cross-check against the bitmap definition directly.
            for &o in &want {
                assert!(!is_dead(&words, exts[o as usize]));
            }
            assert_eq!(
                want.len(),
                exts.iter().filter(|&&e| !is_dead(&words, e)).count(),
                "trial {trial}"
            );
        }
    }

    #[test]
    fn empty_bitmap_keeps_everything() {
        let exts: Vec<u32> = (0..100).map(|i| i * 7919).collect();
        let mut keep = Vec::new();
        live_positions_into(&[], &exts, &mut keep);
        assert_eq!(keep, (0..100u32).collect::<Vec<u32>>());
    }
}
