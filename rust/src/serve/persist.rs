//! Multi-shard container (kind 4): one routing-table section plus each
//! shard's own container embedded verbatim.
//!
//! Layout after the 8-byte file header:
//!
//! ```text
//! [SHRD] layout:u32  dim:u32  nshards:u32  router:u8
//!        router=0 (hash)   → seed:u64
//!        router=1 (kmeans) → centroids:f32s (nshards × dim)
//! [XC\xss\xss] shard s's complete container bytes, verbatim
//! [XM\xss\xss] shard s's id map (local row id → global ext id), u32s
//! ```
//!
//! Embedding each shard's container unchanged means a shard can be
//! carved out of (or swapped into) a node without re-encoding, and the
//! outer section CRCs cover every embedded payload *in addition to* the
//! inner container's own per-section CRCs — corruption is caught at the
//! outer parse before any shard decoder runs.

use crate::api::persist::{file_header, finish_container, push_section, Container, KIND_SHARDED};
use crate::api::AnnIndex;
use crate::serve::sharded::{Router, ShardedIndex};
use crate::util::serialize::{ReadBuf, WriteBuf};
use anyhow::{bail, ensure, Result};
use std::sync::Arc;

/// Bumped only on incompatible changes to the SHRD section layout.
const LAYOUT_VERSION: u32 = 1;

/// Shard section tags encode the part (`C` container, `M` id map) and
/// the shard ordinal big-endian in the last two bytes.
fn shard_tag(part: u8, s: usize) -> [u8; 4] {
    debug_assert!(s <= u16::MAX as usize);
    [b'X', part, (s >> 8) as u8, (s & 0xff) as u8]
}

/// Encode a router (kind byte + parameters) — shared by the SHRD header
/// and the durable node directory's ROUTER file.
pub(crate) fn write_router(w: &mut WriteBuf, router: &Router) {
    match router {
        Router::Hash { seed } => {
            w.put_u8(0);
            w.put_u64(*seed);
        }
        Router::Kmeans { centroids, .. } => {
            w.put_u8(1);
            w.put_f32s(centroids);
        }
    }
}

/// Decode a router written by [`write_router`].
pub(crate) fn read_router(rb: &mut ReadBuf, dim: usize) -> Result<Router> {
    match rb.get_u8()? {
        0 => Ok(Router::Hash { seed: rb.get_u64()? }),
        1 => {
            let centroids = rb.get_f32s()?;
            ensure!(
                dim > 0 && centroids.len() % dim == 0,
                "kmeans router holds {} floats, not a multiple of dim {dim}",
                centroids.len()
            );
            Ok(Router::Kmeans { centroids, dim })
        }
        other => bail!("unknown router kind byte {other}"),
    }
}

/// Serialize a sharded index: routing table, then each shard's container
/// bytes and id map.
pub fn to_bytes(idx: &ShardedIndex) -> Result<Vec<u8>> {
    ensure!(
        idx.num_shards() <= u16::MAX as usize + 1,
        "cannot persist {} shards (tag encoding holds 65536)",
        idx.num_shards()
    );
    let mut out = file_header(KIND_SHARDED);
    let mut hdr = WriteBuf::new();
    hdr.put_u32(LAYOUT_VERSION);
    hdr.put_u32(idx.dim() as u32);
    hdr.put_u32(idx.num_shards() as u32);
    write_router(&mut hdr, idx.router());
    push_section(&mut out, b"SHRD", &hdr.bytes);
    for s in 0..idx.num_shards() {
        let shard_bytes = idx.shard(s).to_bytes()?;
        push_section(&mut out, &shard_tag(b'C', s), &shard_bytes);
        let mut map = WriteBuf::new();
        map.put_u32s(idx.id_map(s));
        push_section(&mut out, &shard_tag(b'M', s), &map.bytes);
    }
    finish_container(&mut out);
    Ok(out)
}

/// Reassemble a [`ShardedIndex`] from a parsed kind-4 container. Every
/// embedded shard container goes back through the regular kind dispatch,
/// so a sharded file may mix static IVF, graph and dynamic shards.
pub fn from_container(c: &Container) -> Result<ShardedIndex> {
    ensure!(
        c.kind == KIND_SHARDED,
        "container holds kind {} (expected a sharded index)",
        c.kind
    );
    let hdr = c.section(b"SHRD")?;
    let mut rb = ReadBuf::new(hdr.as_slice());
    let layout = rb.get_u32()?;
    ensure!(
        layout == LAYOUT_VERSION,
        "unsupported sharded layout version {layout} (this build reads {LAYOUT_VERSION})"
    );
    let dim = rb.get_u32()? as usize;
    let nshards = rb.get_u32()? as usize;
    ensure!(dim > 0, "sharded header declares dim 0");
    ensure!(
        (1..=u16::MAX as usize + 1).contains(&nshards),
        "sharded header declares {nshards} shards"
    );
    let router = read_router(&mut rb, dim)?;
    ensure!(rb.remaining() == 0, "trailing bytes after the sharded header");

    let mut shards: Vec<Arc<dyn AnnIndex>> = Vec::with_capacity(nshards);
    let mut id_maps: Vec<Vec<u32>> = Vec::with_capacity(nshards);
    for s in 0..nshards {
        let cbytes = c.section(&shard_tag(b'C', s))?;
        let raw = cbytes.as_slice();
        ensure!(
            raw.len() < 7 || raw[6] != KIND_SHARDED,
            "shard {s} embeds another sharded container (nesting is not supported)"
        );
        let shard = crate::api::persist::open_bytes(raw.to_vec())
            .map_err(|e| e.context(format!("opening embedded container for shard {s}")))?;
        let mbytes = c.section(&shard_tag(b'M', s))?;
        let mut mb = ReadBuf::new(mbytes.as_slice());
        let map = mb.get_u32s()?;
        ensure!(mb.remaining() == 0, "trailing bytes after shard {s}'s id map");
        shards.push(Arc::from(shard));
        id_maps.push(map);
    }
    ShardedIndex::from_parts(router, shards, id_maps, dim, c.checksummed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{AnnScratch, QueryParams};
    use crate::datasets::{generate, Kind};
    use crate::index::IvfBuildParams;
    use crate::serve::sharded::{RouterKind, ShardedBuildParams};

    fn build(router: RouterKind) -> (ShardedIndex, Vec<f32>, usize) {
        let ds = generate(Kind::DeepLike, 2000, 8, 8, 77);
        let params = ShardedBuildParams {
            shards: 3,
            router,
            ivf: IvfBuildParams { k: 16, threads: 2, id_codec: "roc".into(), ..Default::default() },
        };
        let idx = ShardedIndex::build(&ds.data, ds.dim, &params).unwrap();
        (idx, ds.queries, ds.dim)
    }

    fn search_all(idx: &dyn AnnIndex, queries: &[f32], dim: usize) -> Vec<Vec<(f32, u32)>> {
        let sp = QueryParams { k: 10, nprobe: 8, ef: 32 };
        let mut scratch = AnnScratch::default();
        let mut out = Vec::new();
        queries
            .chunks_exact(dim)
            .map(|q| {
                idx.search_into(q, &sp, &mut scratch, &mut out);
                out.clone()
            })
            .collect()
    }

    #[test]
    fn roundtrip_preserves_search_results_exactly() {
        for router in [RouterKind::Hash, RouterKind::Kmeans] {
            let (idx, queries, dim) = build(router);
            let before = search_all(&idx, &queries, dim);
            let bytes = idx.to_bytes().unwrap();
            let back = crate::api::persist::open_sharded_bytes(bytes.clone()).unwrap();
            assert_eq!(back.num_shards(), 3);
            assert_eq!(AnnIndex::len(&back), AnnIndex::len(&idx));
            assert_eq!(search_all(&back, &queries, dim), before, "router {router:?}");
            // The generic open dispatches on the kind byte too.
            let generic = crate::api::persist::open_bytes(bytes).unwrap();
            assert_eq!(generic.kind(), crate::api::IndexKind::Sharded);
            assert_eq!(search_all(&*generic, &queries, dim), before);
        }
    }

    #[test]
    fn stats_survive_roundtrip() {
        let (idx, _, _) = build(RouterKind::Hash);
        let bytes = idx.to_bytes().unwrap();
        let back = crate::api::persist::open_sharded_bytes(bytes).unwrap();
        let a = AnnIndex::stats(&idx);
        let b = AnnIndex::stats(&back);
        assert_eq!(a.n, b.n);
        assert_eq!(a.codec, b.codec);
        assert_eq!(a.segments.len(), b.segments.len());
        assert!(b.checksummed, "v2 sharded container must report checksummed stats");
    }

    #[test]
    fn every_flipped_byte_is_rejected() {
        let (idx, _, _) = build(RouterKind::Hash);
        let bytes = idx.to_bytes().unwrap();
        // Flip one byte at a stride across the whole file; the outer CRCs
        // must reject every corruption (the header bytes fail the magic /
        // version / kind checks instead).
        for pos in (0..bytes.len()).step_by(37) {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(
                crate::api::persist::open_sharded_bytes(bad).is_err(),
                "flip at byte {pos} of {} was not detected",
                bytes.len()
            );
        }
    }

    #[test]
    fn truncation_is_rejected() {
        let (idx, _, _) = build(RouterKind::Kmeans);
        let bytes = idx.to_bytes().unwrap();
        for cut in [1usize, 7, 12, bytes.len() / 2, bytes.len() - 1] {
            let bad = bytes[..cut].to_vec();
            assert!(crate::api::persist::open_sharded_bytes(bad).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn nested_sharded_containers_are_refused() {
        let (idx, _, dim) = build(RouterKind::Hash);
        let inner = idx.to_bytes().unwrap();
        // Hand-roll a kind-4 container whose shard 0 is itself kind 4.
        let mut out = file_header(KIND_SHARDED);
        let mut hdr = WriteBuf::new();
        hdr.put_u32(LAYOUT_VERSION);
        hdr.put_u32(dim as u32);
        hdr.put_u32(1);
        hdr.put_u8(0);
        hdr.put_u64(7);
        push_section(&mut out, b"SHRD", &hdr.bytes);
        push_section(&mut out, &shard_tag(b'C', 0), &inner);
        let mut map = WriteBuf::new();
        map.put_u32s(&(0..AnnIndex::len(&idx) as u32).collect::<Vec<u32>>());
        push_section(&mut out, &shard_tag(b'M', 0), &map.bytes);
        finish_container(&mut out);
        let err = crate::api::persist::open_sharded_bytes(out).unwrap_err();
        assert!(format!("{err:#}").contains("nesting"), "{err:#}");
    }
}
