//! Per-tenant admission control: token buckets in front of the shard
//! queues.
//!
//! The coordinator's bounded queue (PR 7) protects the *node* — it sheds
//! load when the whole box is behind. The token buckets here protect
//! *tenants from each other*: a greedy tenant that floods the node burns
//! through its own budget and starts seeing `Overloaded` while
//! well-behaved tenants keep their full rate. Buckets refill
//! continuously at `rate` tokens/second up to a cap of `burst`; a
//! request is admitted iff its tenant has ≥ 1 token. `rate = 0` makes a
//! bucket a fixed budget of `burst` admits (what the CI gate uses — it
//! needs a deterministic rejection count, not a wall-clock race).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::{self, Counter};

/// Budget applied to every tenant (per-tenant overrides are not needed
/// yet — the bench and CI exercise symmetric policies with asymmetric
/// traffic).
#[derive(Clone, Copy, Debug)]
pub struct TenantPolicy {
    /// Bucket capacity: how many requests a tenant may burst back-to-back.
    pub burst: u64,
    /// Refill rate in tokens per second. 0 = never refills (fixed budget).
    pub rate: f64,
}

impl Default for TenantPolicy {
    fn default() -> Self {
        TenantPolicy { burst: 256, rate: 512.0 }
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Upper bound on distinct tenant buckets held at once. A long-lived
/// node fed ever-new tenant names (an attack or a naming bug) would
/// otherwise grow the map without bound. At the cap, buckets that have
/// refilled back to full burst (idle long enough) are swept first; if
/// every bucket is mid-budget the least-recently-used one is evicted.
/// Eviction forgets that tenant's counters and restores its budget on
/// return — the accepted trade for bounded memory.
const MAX_TENANTS: usize = 4096;

/// Per-tenant request counters, surfaced by the node and the serve bench.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantCounters {
    pub admitted: u64,
    pub rejected: u64,
}

struct TenantEntry {
    bucket: Bucket,
    counters: TenantCounters,
    /// Registry series (`zann_tenant_{admitted,rejected}_total{tenant}`),
    /// registered when the bucket is created. Survive bucket eviction on
    /// the registry (monotone totals), while the bucket-local counters
    /// reset with the bucket; the registry's own per-name cardinality cap
    /// bounds growth under unique-name floods.
    admitted_h: Arc<Counter>,
    rejected_h: Arc<Counter>,
}

pub struct Admission {
    policy: TenantPolicy,
    tenants: Mutex<HashMap<String, TenantEntry>>,
}

impl Admission {
    pub fn new(policy: TenantPolicy) -> Admission {
        Admission { policy, tenants: Mutex::new(HashMap::new()) }
    }

    pub fn policy(&self) -> TenantPolicy {
        self.policy
    }

    /// Try to admit one request for `tenant`. Debits a token on success;
    /// counts a rejection otherwise.
    pub fn try_admit(&self, tenant: &str) -> bool {
        let now = Instant::now();
        let mut map = self.tenants.lock().unwrap_or_else(|e| e.into_inner());
        if map.len() >= MAX_TENANTS && !map.contains_key(tenant) {
            let burst = self.policy.burst as f64;
            let rate = self.policy.rate;
            map.retain(|_, e| {
                e.bucket.tokens
                    + now.saturating_duration_since(e.bucket.last).as_secs_f64() * rate
                    < burst
            });
            if map.len() >= MAX_TENANTS {
                if let Some(lru) =
                    map.iter().min_by_key(|(_, e)| e.bucket.last).map(|(t, _)| t.clone())
                {
                    map.remove(&lru);
                }
            }
        }
        let entry = map.entry(tenant.to_string()).or_insert_with(|| TenantEntry {
            bucket: Bucket { tokens: self.policy.burst as f64, last: now },
            counters: TenantCounters::default(),
            admitted_h: obs::counter("zann_tenant_admitted_total", &[("tenant", tenant)]),
            rejected_h: obs::counter("zann_tenant_rejected_total", &[("tenant", tenant)]),
        });
        let bucket = &mut entry.bucket;
        let dt = now.saturating_duration_since(bucket.last).as_secs_f64();
        bucket.last = now;
        bucket.tokens = (bucket.tokens + dt * self.policy.rate).min(self.policy.burst as f64);
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            entry.counters.admitted += 1;
            entry.admitted_h.inc();
            true
        } else {
            entry.counters.rejected += 1;
            entry.rejected_h.inc();
            false
        }
    }

    /// Counters for one tenant (zeros if it never sent a request).
    pub fn counters(&self, tenant: &str) -> TenantCounters {
        let map = self.tenants.lock().unwrap_or_else(|e| e.into_inner());
        map.get(tenant).map(|e| e.counters).unwrap_or_default()
    }

    /// All tenants with their counters, sorted by tenant name so output
    /// is deterministic.
    pub fn all_counters(&self) -> Vec<(String, TenantCounters)> {
        let map = self.tenants.lock().unwrap_or_else(|e| e.into_inner());
        let mut v: Vec<(String, TenantCounters)> =
            map.iter().map(|(t, e)| (t.clone(), e.counters)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Refill every bucket to `burst` and clear counters — the serve
    /// bench calls this between measured passes so each pass sees the
    /// same admission state.
    pub fn reset(&self) {
        let mut map = self.tenants.lock().unwrap_or_else(|e| e.into_inner());
        map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_budget_admits_exactly_burst() {
        let a = Admission::new(TenantPolicy { burst: 5, rate: 0.0 });
        let admitted = (0..20).filter(|_| a.try_admit("t0")).count();
        assert_eq!(admitted, 5, "rate=0 bucket is a fixed budget");
        let c = a.counters("t0");
        assert_eq!(c.admitted, 5);
        assert_eq!(c.rejected, 15);
    }

    #[test]
    fn tenants_have_independent_budgets() {
        let a = Admission::new(TenantPolicy { burst: 3, rate: 0.0 });
        for _ in 0..10 {
            a.try_admit("greedy");
        }
        // The greedy tenant exhausted its own bucket, not anyone else's.
        assert!(a.try_admit("quiet"));
        assert_eq!(a.counters("greedy").rejected, 7);
        assert_eq!(a.counters("quiet").rejected, 0);
        let all = a.all_counters();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].0, "greedy", "counters are sorted by tenant");
    }

    #[test]
    fn reset_restores_full_budgets() {
        let a = Admission::new(TenantPolicy { burst: 2, rate: 0.0 });
        assert!(a.try_admit("t"));
        assert!(a.try_admit("t"));
        assert!(!a.try_admit("t"));
        a.reset();
        assert!(a.try_admit("t"));
        assert_eq!(a.counters("t").rejected, 0, "reset clears counters");
    }

    #[test]
    fn refill_restores_tokens_over_time() {
        let a = Admission::new(TenantPolicy { burst: 1, rate: 1000.0 });
        assert!(a.try_admit("t"));
        // Bucket is empty now; at 1000 tokens/s a few ms restores it.
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(a.try_admit("t"), "bucket must refill at the configured rate");
        assert_eq!(a.counters("t").admitted, 2);
    }

    #[test]
    fn tenant_map_is_bounded_under_unique_names() {
        // rate = 0 buckets never refill to full burst, so the idle sweep
        // keeps everything and the LRU fallback must do the bounding.
        let a = Admission::new(TenantPolicy { burst: 2, rate: 0.0 });
        for i in 0..(MAX_TENANTS + 50) {
            a.try_admit(&format!("t{i}"));
        }
        assert!(a.all_counters().len() <= MAX_TENANTS);
        // An evicted tenant that returns is re-admitted at full budget.
        assert!(a.try_admit("t0"));
    }

    #[test]
    fn idle_refilled_buckets_are_swept_at_cap() {
        // With a huge refill rate every bucket is back at full burst by
        // the time the cap is hit, so the sweep (not the LRU fallback)
        // reclaims them; either way the map stays bounded.
        let a = Admission::new(TenantPolicy { burst: 1, rate: 1e12 });
        for i in 0..(MAX_TENANTS + 10) {
            assert!(a.try_admit(&format!("u{i}")), "fresh bucket admits");
        }
        assert!(a.all_counters().len() <= MAX_TENANTS);
    }

    #[test]
    fn tenant_counters_are_mirrored_on_the_registry() {
        let a = Admission::new(TenantPolicy { burst: 1, rate: 0.0 });
        assert!(a.try_admit("mirror-tenant"));
        assert!(!a.try_admit("mirror-tenant"));
        if crate::obs::enabled() {
            let adm =
                crate::obs::counter("zann_tenant_admitted_total", &[("tenant", "mirror-tenant")]);
            let rej =
                crate::obs::counter("zann_tenant_rejected_total", &[("tenant", "mirror-tenant")]);
            assert!(adm.get() >= 1, "admitted must reach the registry");
            assert!(rej.get() >= 1, "rejected must reach the registry");
        }
    }

    #[test]
    fn unknown_tenant_reads_as_zero() {
        let a = Admission::new(TenantPolicy::default());
        let c = a.counters("nobody");
        assert_eq!(c.admitted, 0);
        assert_eq!(c.rejected, 0);
    }
}
