//! Passive sharded index: N shards behind one [`AnnIndex`] endpoint with
//! an exact scatter-gather top-k merge.
//!
//! The bit-identity contract — a sharded search returns exactly what a
//! single index built over the union would — rests on three invariants:
//!
//! 1. **One global coarse quantizer.** [`ShardedIndex::build`] trains
//!    k-means over the *whole* dataset with the same configuration as
//!    [`IvfIndex::build`], then hands every shard the full centroid set
//!    via [`IvfIndex::build_preassigned`] (a shard's absent clusters are
//!    just empty lists, skipped by the scan). Probe selection is
//!    therefore identical in every shard, so the union of per-shard
//!    candidates equals the single-index candidate set at any `nprobe`.
//! 2. **Monotone id maps.** Rows are appended to their shard in
//!    ascending global-id order, so shard-local id order equals global id
//!    order and per-shard tie handling agrees with the single index.
//! 3. **Exact k-way merge.** Per-shard top-k results are merged through
//!    [`TopK`], whose ordering is `(distance, payload)` — with global
//!    external ids as payloads the final tie order is pinned to
//!    `(distance, ext_id)` regardless of shard count or merge order.

use crate::api::{AnnIndex, AnnScratch, IndexKind, IndexStats, QueryParams, SegmentStats};
use crate::index::{IvfBuildParams, IvfIndex};
use crate::quant::{kmeans, l2_sq, TopK};
use anyhow::{bail, ensure, Result};
use std::sync::Arc;

/// How ingest assigns a row to a shard.
#[derive(Clone, Debug)]
pub enum Router {
    /// Hash of the global external id (splitmix64 finalizer, seeded) —
    /// uniform placement, vector-independent.
    Hash { seed: u64 },
    /// Nearest router centroid of the row vector — locality-preserving
    /// placement. The `shards × dim` centroid matrix is its own tiny
    /// clustering, separate from the shared coarse quantizer.
    Kmeans { centroids: Vec<f32>, dim: usize },
}

fn splitmix64_fin(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Router {
    /// Number of shards this router addresses.
    pub fn num_shards(&self, configured: usize) -> usize {
        match self {
            Router::Hash { .. } => configured,
            Router::Kmeans { centroids, dim } => centroids.len() / (*dim).max(1),
        }
    }

    /// Shard for a row, given its global external id and vector. Hash
    /// routers read the id, k-means routers read the vector.
    pub fn route(&self, ext_id: u32, vector: &[f32], nshards: usize) -> usize {
        match self {
            Router::Hash { seed } => {
                (splitmix64_fin(ext_id as u64 ^ seed) % nshards.max(1) as u64) as usize
            }
            Router::Kmeans { centroids, dim } => {
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for (s, c) in centroids.chunks_exact(*dim).enumerate() {
                    let d = l2_sq(vector, c);
                    if d < best_d {
                        best_d = d;
                        best = s;
                    }
                }
                best
            }
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            Router::Hash { .. } => "hash",
            Router::Kmeans { .. } => "kmeans",
        }
    }
}

/// Which [`Router`] family [`ShardedIndex::build`] should construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterKind {
    Hash,
    Kmeans,
}

impl RouterKind {
    pub fn parse(s: &str) -> Result<RouterKind> {
        match s {
            "hash" => Ok(RouterKind::Hash),
            "kmeans" => Ok(RouterKind::Kmeans),
            other => bail!("unknown router {other:?} (valid: hash, kmeans)"),
        }
    }
}

/// Build configuration: shard count, router family and the per-shard IVF
/// parameters. `ivf.k` is the *global* coarse cluster count (every shard
/// carries the full centroid set).
#[derive(Clone)]
pub struct ShardedBuildParams {
    pub shards: usize,
    pub router: RouterKind,
    pub ivf: IvfBuildParams,
}

impl Default for ShardedBuildParams {
    fn default() -> Self {
        ShardedBuildParams { shards: 4, router: RouterKind::Hash, ivf: IvfBuildParams::default() }
    }
}

/// N shards behind one [`AnnIndex`] endpoint. Searches scatter to every
/// shard and merge exactly; shard-local result ids are translated to
/// global external ids through per-shard monotone id maps.
pub struct ShardedIndex {
    dim: usize,
    router: Router,
    shards: Vec<Arc<dyn AnnIndex>>,
    /// Shard-local row id → global external id (ascending at build time).
    id_maps: Vec<Vec<u32>>,
    /// Whether the enclosing container carried per-section CRCs (true
    /// for in-memory builds).
    pub(crate) checksummed: bool,
}

impl ShardedIndex {
    /// Partition `data` and build one [`IvfIndex`] per shard over the
    /// shared global clustering. Returns the concrete parts so callers
    /// that need mutable shards (the serve node wraps each in a
    /// [`crate::dynamic::DynamicIvf`]) can reuse the same partitioning.
    pub fn build_parts(
        data: &[f32],
        dim: usize,
        params: &ShardedBuildParams,
    ) -> Result<(Router, Vec<IvfIndex>, Vec<Vec<u32>>)> {
        ensure!(dim > 0 && data.len() % dim == 0, "data is not row-major n × {dim}");
        let n = data.len() / dim;
        ensure!(params.shards >= 1, "need at least one shard");
        ensure!(
            n >= params.shards,
            "cannot split {n} rows across {} shards",
            params.shards
        );
        // The shared coarse quantizer — the exact same training call as
        // `IvfIndex::build`, so a 1-shard build (or the union reference in
        // tests) produces bit-identical centroids and assignments.
        let cfg = kmeans::KmeansConfig {
            k: params.ivf.k,
            iters: params.ivf.train_iters,
            seed: params.ivf.seed,
            threads: params.ivf.threads,
            ..Default::default()
        };
        let centroids = kmeans::train(data, dim, &cfg);
        let kk = centroids.len() / dim;
        let assign = kmeans::assign(data, dim, &centroids, params.ivf.threads);

        let router = match params.router {
            RouterKind::Hash => Router::Hash { seed: params.ivf.seed },
            RouterKind::Kmeans => {
                let rc = kmeans::train(
                    data,
                    dim,
                    &kmeans::KmeansConfig {
                        k: params.shards,
                        iters: params.ivf.train_iters,
                        // Decorrelated from the coarse quantizer's seed.
                        seed: params.ivf.seed ^ 0x51a2_9d1e,
                        threads: params.ivf.threads,
                        ..Default::default()
                    },
                );
                Router::Kmeans { centroids: rc, dim }
            }
        };

        // Partition rows in ascending global-id order so each shard's
        // local id order is a monotone restriction of the global order.
        let nshards = params.shards;
        let mut shard_data: Vec<Vec<f32>> = vec![Vec::new(); nshards];
        let mut shard_assign: Vec<Vec<u32>> = vec![Vec::new(); nshards];
        let mut id_maps: Vec<Vec<u32>> = vec![Vec::new(); nshards];
        for i in 0..n {
            let row = &data[i * dim..(i + 1) * dim];
            let s = router.route(i as u32, row, nshards);
            shard_data[s].extend_from_slice(row);
            shard_assign[s].push(assign[i]);
            id_maps[s].push(i as u32);
        }
        for (s, m) in id_maps.iter().enumerate() {
            ensure!(
                !m.is_empty(),
                "shard {s} received no rows (n={n}, shards={nshards}); use fewer shards"
            );
        }
        let shards: Vec<IvfIndex> = (0..nshards)
            .map(|s| {
                IvfIndex::build_preassigned(
                    &shard_data[s],
                    dim,
                    &centroids,
                    &shard_assign[s],
                    &params.ivf,
                    kk,
                )
            })
            .collect();
        Ok((router, shards, id_maps))
    }

    /// Build a static sharded index over `data`.
    pub fn build(data: &[f32], dim: usize, params: &ShardedBuildParams) -> Result<ShardedIndex> {
        let (router, shards, id_maps) = Self::build_parts(data, dim, params)?;
        Self::from_parts(
            router,
            shards.into_iter().map(|i| Arc::new(i) as Arc<dyn AnnIndex>).collect(),
            id_maps,
            dim,
            true,
        )
    }

    /// Assemble from already-built shards (container open, serve node,
    /// tests). Validates shapes; `checksummed` records whether the source
    /// container carried CRCs.
    pub fn from_parts(
        router: Router,
        shards: Vec<Arc<dyn AnnIndex>>,
        id_maps: Vec<Vec<u32>>,
        dim: usize,
        checksummed: bool,
    ) -> Result<ShardedIndex> {
        ensure!(!shards.is_empty(), "a sharded index needs at least one shard");
        ensure!(shards.len() == id_maps.len(), "shard/id-map count mismatch");
        for (s, (shard, map)) in shards.iter().zip(&id_maps).enumerate() {
            ensure!(
                shard.dim() == dim,
                "shard {s} has dim {} (container says {dim})",
                shard.dim()
            );
            // Static shards map every stored row; mutable shards may have
            // assigned more local ids than live rows, never fewer.
            ensure!(
                map.len() >= shard.len(),
                "shard {s} id map covers {} local ids but the shard stores {} rows",
                map.len(),
                shard.len()
            );
        }
        if let Router::Kmeans { centroids, dim: rdim } = &router {
            ensure!(
                *rdim == dim && centroids.len() == shards.len() * dim,
                "router centroid matrix is {}×{rdim}, expected {}×{dim}",
                centroids.len() / (*rdim).max(1),
                shards.len()
            );
        }
        Ok(ShardedIndex { dim, router, shards, id_maps, checksummed })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn shard(&self, s: usize) -> &Arc<dyn AnnIndex> {
        &self.shards[s]
    }

    pub fn id_map(&self, s: usize) -> &[u32] {
        &self.id_maps[s]
    }

    /// Per-shard stats, in shard order (`zann info` prints one line per
    /// shard from this).
    pub fn shard_stats(&self) -> Vec<IndexStats> {
        self.shards.iter().map(|s| s.stats()).collect()
    }

    /// Decompose into (router, shards, id maps, dim) — the serve node
    /// takes ownership of the slots this way.
    pub fn into_parts(self) -> (Router, Vec<Arc<dyn AnnIndex>>, Vec<Vec<u32>>, usize) {
        (self.router, self.shards, self.id_maps, self.dim)
    }

    /// Merge pre-translated `(distance, global_id)` candidates from many
    /// shards into the final top-k, tie order pinned to
    /// `(distance, ext_id)`. Shared by the passive index and the serve
    /// node's scatter-gather path so both merge identically.
    pub fn merge_topk(
        per_shard: impl IntoIterator<Item = (f32, u32)>,
        k: usize,
    ) -> Vec<(f32, u32)> {
        let mut merged = TopK::new(k);
        for (d, gid) in per_shard {
            merged.push(d, gid as u64);
        }
        merged.into_sorted()
    }
}

impl AnnIndex for ShardedIndex {
    fn kind(&self) -> IndexKind {
        IndexKind::Sharded
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    fn stats(&self) -> IndexStats {
        let per: Vec<IndexStats> = self.shard_stats();
        let mut codecs: Vec<String> = Vec::new();
        for s in &per {
            if !codecs.contains(&s.codec) {
                codecs.push(s.codec.clone());
            }
        }
        IndexStats {
            kind: IndexKind::Sharded,
            n: per.iter().map(|s| s.n).sum(),
            dim: self.dim,
            edges: per.iter().map(|s| s.edges).sum(),
            codec: codecs.join("+"),
            id_bits: per.iter().map(|s| s.id_bits).sum(),
            code_bits: per.iter().map(|s| s.code_bits).sum(),
            link_bits: per.iter().map(|s| s.link_bits).sum(),
            live: per.iter().map(|s| s.live).sum(),
            deleted: per.iter().map(|s| s.deleted).sum(),
            buffer_rows: per.iter().map(|s| s.buffer_rows).sum(),
            aux_bits: per.iter().map(|s| s.aux_bits).sum(),
            checksummed: self.checksummed && per.iter().all(|s| s.checksummed),
            segments: per
                .iter()
                .zip(&self.id_maps)
                .map(|(s, m)| SegmentStats {
                    rows: s.n,
                    id_bits: s.id_bits,
                    map_bits: 32 * m.len() as u64,
                })
                .collect(),
        }
    }

    /// Serial scatter-gather: each shard searches with the shared
    /// scratch, results are translated to global ids and merged exactly.
    /// (The serve node runs the same merge over per-shard worker pools;
    /// this path is the single-threaded reference and what `zann serve`
    /// verification compares against.)
    fn search_into(
        &self,
        query: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        let mut merged = TopK::new(params.k);
        let mut tmp: Vec<(f32, u32)> = Vec::with_capacity(params.k);
        for (s, shard) in self.shards.iter().enumerate() {
            shard.search_into(query, params, scratch, &mut tmp);
            let map = &self.id_maps[s];
            for &(d, local) in &tmp {
                merged.push(d, map[local as usize] as u64);
            }
        }
        *out = merged.into_sorted();
    }

    // No `coarse_info`: shards run their own coarse stage inside the
    // scatter, so the sharded endpoint is served query-at-a-time (like
    // graphs) when put behind a single coordinator.

    fn to_bytes(&self) -> Result<Vec<u8>> {
        super::persist::to_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, Kind};

    fn params(codec: &str, shards: usize, router: RouterKind) -> ShardedBuildParams {
        ShardedBuildParams {
            shards,
            router,
            ivf: IvfBuildParams {
                k: 16,
                id_codec: codec.into(),
                threads: 2,
                seed: 0x5eed,
                ..Default::default()
            },
        }
    }

    #[test]
    fn hash_router_spreads_and_is_deterministic() {
        let r = Router::Hash { seed: 7 };
        let mut counts = [0usize; 4];
        for id in 0..4000u32 {
            let s = r.route(id, &[], 4);
            assert_eq!(s, r.route(id, &[], 4));
            counts[s] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn kmeans_router_routes_to_nearest_centroid() {
        let r = Router::Kmeans {
            centroids: vec![0.0, 0.0, 10.0, 10.0, -10.0, 10.0],
            dim: 2,
        };
        assert_eq!(r.route(0, &[0.1, -0.2], 3), 0);
        assert_eq!(r.route(1, &[9.0, 11.0], 3), 1);
        assert_eq!(r.route(2, &[-11.0, 9.5], 3), 2);
    }

    #[test]
    fn sharded_build_partitions_every_row_once() {
        let ds = generate(Kind::DeepLike, 3000, 4, 8, 31);
        for router in [RouterKind::Hash, RouterKind::Kmeans] {
            let idx = ShardedIndex::build(&ds.data, ds.dim, &params("roc", 4, router)).unwrap();
            assert_eq!(idx.num_shards(), 4);
            assert_eq!(AnnIndex::len(&idx), 3000);
            let mut seen = vec![false; 3000];
            for s in 0..4 {
                let map = idx.id_map(s);
                assert_eq!(map.len(), idx.shard(s).len());
                assert!(map.windows(2).all(|w| w[0] < w[1]), "id map must be monotone");
                for &g in map {
                    assert!(!seen[g as usize], "row {g} in two shards");
                    seen[g as usize] = true;
                }
            }
            assert!(seen.iter().all(|&b| b), "router {router:?} dropped rows");
            let st = AnnIndex::stats(&idx);
            assert_eq!(st.kind, IndexKind::Sharded);
            assert_eq!(st.n, 3000);
            assert_eq!(st.segments.len(), 4);
            assert!(st.checksummed);
            assert!(st.bits_per_id() > 0.0);
        }
    }

    #[test]
    fn merge_topk_pins_ties_to_distance_then_id() {
        // Three shards emit overlapping tie groups; the merge must keep
        // the k smallest (distance, id) pairs regardless of input order.
        let cands = vec![
            (2.0, 9u32),
            (1.0, 7),
            (1.0, 3),
            (3.0, 1),
            (1.0, 5),
            (2.0, 2),
        ];
        let got = ShardedIndex::merge_topk(cands, 4);
        assert_eq!(got, vec![(1.0, 3), (1.0, 5), (1.0, 7), (2.0, 2)]);
    }

    #[test]
    fn empty_shard_is_rejected_at_build() {
        let ds = generate(Kind::DeepLike, 64, 1, 4, 9);
        // 64 rows into 64 hash shards will leave some shard empty with
        // near certainty; the build must say so instead of producing a
        // shard whose codecs choke on an empty universe.
        let err = ShardedIndex::build(&ds.data, ds.dim, &params("roc", 64, RouterKind::Hash));
        assert!(err.is_err());
    }
}
