//! The sharded serving node: per-shard worker pools, scatter-gather
//! top-k, per-tenant admission, live shard swap and snapshot/restore.
//!
//! ```text
//!             ┌────────────── ServeNode ──────────────┐
//!  tenant ──▶ │ admission │ router │  scatter-gather  │
//!             └─────┬─────────┬──────────┬────────────┘
//!                   ▼         ▼          ▼
//!              [Coordinator] [Coordinator] [Coordinator]   one bounded
//!                 shard 0       shard 1       shard 2      queue + pool
//!                   │             │             │          per shard
//!              EpochShard    EpochShard    EpochShard      (RCU swap)
//! ```
//!
//! Each shard sits behind its own [`Coordinator`] — its own bounded
//! admission queue and scan-worker pool — so a hot shard saturates only
//! its own pool and the other shards keep answering (the pool itself
//! steals work internally via the oversplit chunking in
//! [`crate::util::pool::parallel_chunks`]). A query is *submitted* to
//! every shard before any reply is awaited, so the slowest shard bounds
//! latency but never serializes the scatter.
//!
//! Degradation composes across layers: a shard's own queue may answer
//! `Overloaded`, its deadline check `Timeout`, a caught panic `Failed` —
//! the node takes the worst status across shards and, per
//! [`DegradePolicy`], either fails the query or returns the merged
//! results from the healthy shards.

use crate::api::{AnnIndex, AnnScratch, IndexKind, IndexStats, QueryParams};
use crate::coordinator::{Coordinator, ResponseStatus, ServeConfig};
use crate::dynamic::{CompactionPolicy, DynamicHandle, DynamicIvf};
use crate::serve::admission::{Admission, TenantCounters, TenantPolicy};
use crate::serve::sharded::{Router, ShardedBuildParams, ShardedIndex};
use anyhow::{bail, ensure, Result};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// What a query returns when at least one shard degrades.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradePolicy {
    /// Propagate the worst shard status with empty results — all or
    /// nothing.
    Fail,
    /// Return the merged top-k from the shards that answered `Ok`,
    /// still carrying the worst status so callers can see the response
    /// is partial.
    Partial,
}

/// Node configuration: the per-shard coordinator config plus node-level
/// policies.
pub struct NodeConfig {
    /// Applied to every shard's coordinator (queue depth, deadline,
    /// batch size, scan threads, search params — `search.k` is also the
    /// merge k).
    pub serve: ServeConfig,
    pub policy: DegradePolicy,
    /// Per-tenant token buckets; `None` admits everything.
    pub tenants: Option<TenantPolicy>,
}

impl Default for NodeConfig {
    fn default() -> Self {
        NodeConfig { serve: ServeConfig::default(), policy: DegradePolicy::Partial, tenants: None }
    }
}

/// One scatter-gather answer. `results` hold *global* external ids.
#[derive(Clone, Debug)]
pub struct NodeResponse {
    pub results: Vec<(f32, u32)>,
    pub status: ResponseStatus,
    pub latency: Duration,
}

impl NodeResponse {
    pub fn is_ok(&self) -> bool {
        self.status == ResponseStatus::Ok
    }
}

/// Worst-of ordering across shard statuses: a `Failed` shard outranks an
/// `Overloaded` one outranks a `Timeout` outranks `Ok`.
fn severity(s: ResponseStatus) -> u8 {
    match s {
        ResponseStatus::Ok => 0,
        ResponseStatus::Timeout => 1,
        ResponseStatus::Overloaded => 2,
        ResponseStatus::Failed => 3,
    }
}

/// RCU slot for one shard's index: queries clone the current `Arc` and
/// search it lock-free for the rest of the query; a swap replaces the
/// `Arc` and in-flight queries finish on the epoch they started with.
struct EpochShard {
    current: Mutex<Arc<dyn AnnIndex>>,
    dim: usize,
}

impl EpochShard {
    fn load(&self) -> Arc<dyn AnnIndex> {
        self.current.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    fn store(&self, new: Arc<dyn AnnIndex>) {
        *self.current.lock().unwrap_or_else(|e| e.into_inner()) = new;
    }
}

impl AnnIndex for EpochShard {
    fn kind(&self) -> IndexKind {
        self.load().kind()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.load().len()
    }

    fn stats(&self) -> IndexStats {
        self.load().stats()
    }

    // No coarse stage: the epoch under this slot can change between
    // batches, so the coordinator must not cache centroids across the
    // swap. Every query takes the direct per-query path and reads the
    // epoch current at its own start.
    fn coarse_info(&self) -> Option<crate::api::CoarseInfo<'_>> {
        None
    }

    fn search_into(
        &self,
        query: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        self.load().search_into(query, params, scratch, out)
    }

    fn to_bytes(&self) -> Result<Vec<u8>> {
        self.load().to_bytes()
    }
}

struct ShardSlot {
    epoch: Arc<EpochShard>,
    coord: Coordinator,
    /// Shard-local row id → global external id. Extended on ingest,
    /// replaced wholesale on swap.
    id_map: RwLock<Vec<u32>>,
    /// Typed write handle for mutable (dynamic) shards; `None` for
    /// read-only shards (static builds, restored snapshots without a
    /// fresh writer).
    writer: RwLock<Option<Arc<DynamicHandle>>>,
    /// Registry series `zann_shard_queries_total{shard}` /
    /// `zann_shard_swaps_total{shard}` (cached handles).
    queries_h: Arc<crate::obs::Counter>,
    swaps_h: Arc<crate::obs::Counter>,
}

pub struct ServeNode {
    dim: usize,
    router: Router,
    slots: Vec<ShardSlot>,
    policy: DegradePolicy,
    admission: Option<Admission>,
    /// `zann_stage_us{stage="admission"}` — admission happens on the
    /// client thread before submit, so it is recorded here as an
    /// aggregate histogram rather than inside the per-query trace.
    admission_us: Arc<crate::obs::Histogram>,
    /// Next global external id handed to ingest.
    next_id: AtomicU32,
    search: QueryParams,
}

impl ServeNode {
    /// Serve an already-built (read-only) sharded index: each shard goes
    /// behind its own coordinator; `add` is rejected.
    pub fn start_static(index: ShardedIndex, cfg: NodeConfig) -> Result<ServeNode> {
        let (router, shards, id_maps, dim) = index.into_parts();
        let next = id_maps.iter().flat_map(|m| m.iter().copied()).max().map_or(0, |m| m + 1);
        Self::assemble(router, shards, id_maps, Vec::new(), dim, next, cfg)
    }

    /// Build a mutable node over `data`: shards are partitioned with the
    /// shared global clustering, then each is wrapped in a
    /// [`DynamicIvf`] behind a [`DynamicHandle`] so ingest and compaction
    /// run per shard without pausing reads.
    pub fn start_mutable(
        data: &[f32],
        dim: usize,
        params: &ShardedBuildParams,
        policy: CompactionPolicy,
        cfg: NodeConfig,
    ) -> Result<ServeNode> {
        let (router, static_shards, id_maps) = ShardedIndex::build_parts(data, dim, params)?;
        let n = (data.len() / dim) as u32;
        let mut shards: Vec<Arc<dyn AnnIndex>> = Vec::with_capacity(static_shards.len());
        let mut writers: Vec<Arc<DynamicHandle>> = Vec::with_capacity(static_shards.len());
        for s in static_shards {
            let dynamic = DynamicIvf::from_static(s, policy, params.ivf.threads)?;
            let handle = Arc::new(DynamicHandle::new(dynamic));
            shards.push(handle.clone());
            writers.push(handle);
        }
        Self::assemble(router, shards, id_maps, writers, dim, n, cfg)
    }

    fn assemble(
        router: Router,
        shards: Vec<Arc<dyn AnnIndex>>,
        id_maps: Vec<Vec<u32>>,
        writers: Vec<Arc<DynamicHandle>>,
        dim: usize,
        next_id: u32,
        cfg: NodeConfig,
    ) -> Result<ServeNode> {
        ensure!(!shards.is_empty(), "a serve node needs at least one shard");
        ensure!(shards.len() == id_maps.len(), "shard/id-map count mismatch");
        ensure!(
            writers.is_empty() || writers.len() == shards.len(),
            "writer handles must cover every shard or none"
        );
        let mut writers: Vec<Option<Arc<DynamicHandle>>> = if writers.is_empty() {
            (0..shards.len()).map(|_| None).collect()
        } else {
            writers.into_iter().map(Some).collect()
        };
        let slots: Vec<ShardSlot> = shards
            .into_iter()
            .zip(id_maps)
            .enumerate()
            .map(|(s, (shard, map))| {
                let epoch = Arc::new(EpochShard { current: Mutex::new(shard), dim });
                let coord = Coordinator::start(
                    epoch.clone() as Arc<dyn AnnIndex>,
                    None,
                    clone_serve_config(&cfg.serve),
                );
                let shard_label = s.to_string();
                let l: [(&'static str, &str); 1] = [("shard", &shard_label)];
                ShardSlot {
                    epoch,
                    coord,
                    id_map: RwLock::new(map),
                    writer: RwLock::new(writers[s].take()),
                    queries_h: crate::obs::counter("zann_shard_queries_total", &l),
                    swaps_h: crate::obs::counter("zann_shard_swaps_total", &l),
                }
            })
            .collect();
        Ok(ServeNode {
            dim,
            router,
            slots,
            policy: cfg.policy,
            admission: cfg.tenants.map(Admission::new),
            admission_us: crate::obs::histogram("zann_stage_us", &[("stage", "admission")]),
            next_id: AtomicU32::new(next_id),
            search: cfg.serve.search,
        })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn num_shards(&self) -> usize {
        self.slots.len()
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Live row count per shard (the imbalance metric in the bench).
    pub fn shard_rows(&self) -> Vec<usize> {
        self.slots.iter().map(|s| AnnIndex::len(&*s.epoch)).collect()
    }

    /// Tenant-facing search: admission first (a debited or empty bucket
    /// answers `Overloaded` without touching any shard queue), then
    /// scatter-gather.
    pub fn search(&self, tenant: &str, query: &[f32]) -> Result<NodeResponse> {
        if let Some(adm) = &self.admission {
            let t0 = Instant::now();
            let admitted = adm.try_admit(tenant);
            if crate::obs::enabled() {
                self.admission_us.observe(t0.elapsed().as_micros() as u64);
            }
            if !admitted {
                return Ok(NodeResponse {
                    results: Vec::new(),
                    status: ResponseStatus::Overloaded,
                    latency: Duration::ZERO,
                });
            }
        }
        self.search_raw(query)
    }

    /// Scatter-gather without admission accounting — warmup, parity
    /// checks and the post-overload liveness probe use this.
    pub fn search_raw(&self, query: &[f32]) -> Result<NodeResponse> {
        ensure!(query.len() == self.dim, "query dim {} != index dim {}", query.len(), self.dim);
        let start = Instant::now();
        // Submit to every shard before awaiting any reply.
        let mut pending = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            if crate::obs::enabled() {
                slot.queries_h.inc();
            }
            pending.push(slot.coord.client.submit(query.to_vec())?);
        }
        let mut worst = ResponseStatus::Ok;
        let mut translated: Vec<(f32, u32)> = Vec::with_capacity(self.search.k * 2);
        for (s, p) in pending.into_iter().enumerate() {
            match p.wait() {
                Ok(resp) => {
                    if severity(resp.status) > severity(worst) {
                        worst = resp.status;
                    }
                    if resp.status == ResponseStatus::Ok {
                        let map = self.slots[s].id_map.read().unwrap_or_else(|e| e.into_inner());
                        for &(d, local) in &resp.results {
                            // A query racing a live swap can carry locals
                            // from the epoch it started on, which the
                            // freshly-installed (possibly shorter) map no
                            // longer covers. Drop those rows instead of
                            // indexing out of bounds — the next query
                            // runs entirely on the new epoch.
                            if let Some(&ext) = map.get(local as usize) {
                                translated.push((d, ext));
                            }
                        }
                    }
                }
                // A dead shard coordinator (reply channel dropped
                // mid-panic) is a failed shard, not a node error.
                Err(_) => worst = ResponseStatus::Failed,
            }
        }
        let results = if worst == ResponseStatus::Ok || self.policy == DegradePolicy::Partial {
            ShardedIndex::merge_topk(translated, self.search.k)
        } else {
            Vec::new()
        };
        Ok(NodeResponse { results, status: worst, latency: start.elapsed() })
    }

    /// Ingest rows: each is assigned the next global id, routed to its
    /// shard and appended through that shard's write handle. Returns the
    /// global id range. Requires a mutable node (every target shard must
    /// have a writer).
    pub fn add(&self, rows: &[f32]) -> Result<std::ops::Range<u32>> {
        ensure!(!rows.is_empty() && rows.len() % self.dim == 0, "rows are not n × {}", self.dim);
        let n = rows.len() / self.dim;
        let base = self.next_id.fetch_add(n as u32, Ordering::SeqCst);
        // Group by target shard, preserving ascending global-id order
        // within each group (keeps every id map monotone).
        let mut groups: Vec<(Vec<f32>, Vec<u32>)> =
            (0..self.slots.len()).map(|_| (Vec::new(), Vec::new())).collect();
        for i in 0..n {
            let gid = base + i as u32;
            let row = &rows[i * self.dim..(i + 1) * self.dim];
            let s = self.router.route(gid, row, self.slots.len());
            groups[s].0.extend_from_slice(row);
            groups[s].1.push(gid);
        }
        for (s, (flat, gids)) in groups.into_iter().enumerate() {
            if gids.is_empty() {
                continue;
            }
            // Hold the slot's id-map write lock across the whole ingest:
            // DynamicHandle::add publishes the new epoch (rows become
            // searchable) before it returns, so a reader translating
            // those locals blocks on this lock until the map covers
            // them, and concurrent adds to the same shard are serialized
            // so `local.start == map.len()` is an invariant rather than
            // a race that could strand published rows unmapped.
            let slot = &self.slots[s];
            let mut map = slot.id_map.write().unwrap_or_else(|e| e.into_inner());
            let writer = slot.writer.read().unwrap_or_else(|e| e.into_inner()).clone();
            let Some(writer) = writer else {
                bail!("shard {s} is read-only (static build or restored snapshot)");
            };
            let local = writer.add(&flat)?;
            ensure!(
                local.start as usize == map.len(),
                "shard {s} local ids ({}..) diverged from its id map ({} entries)",
                local.start,
                map.len()
            );
            map.extend_from_slice(&gids);
        }
        Ok(base..base + n as u32)
    }

    /// Swap a shard's index live (RCU): queries in flight finish on the
    /// old epoch; new queries see `new`. `writer` supplies the write
    /// handle for the new epoch (`None` leaves the shard read-only).
    pub fn swap_shard(
        &self,
        s: usize,
        new: Arc<dyn AnnIndex>,
        id_map: Vec<u32>,
        writer: Option<Arc<DynamicHandle>>,
    ) -> Result<()> {
        ensure!(s < self.slots.len(), "no shard {s} (node has {})", self.slots.len());
        ensure!(new.dim() == self.dim, "swap dim {} != node dim {}", new.dim(), self.dim);
        ensure!(
            id_map.len() >= new.len(),
            "swap id map covers {} ids but the shard stores {} rows",
            id_map.len(),
            new.len()
        );
        let slot = &self.slots[s];
        // Hold the id-map write lock across the whole swap so it cannot
        // interleave with an in-flight `add` on this slot (which holds
        // the same lock across its ingest): writer, map and epoch change
        // as one unit. A query racing the swap may still finish on the
        // old epoch and translate through the new map — search_raw
        // bounds-checks that lookup, so a shorter map drops those rows
        // instead of panicking.
        let mut map = slot.id_map.write().unwrap_or_else(|e| e.into_inner());
        *slot.writer.write().unwrap_or_else(|e| e.into_inner()) = writer;
        *map = id_map;
        slot.epoch.store(new);
        if crate::obs::enabled() {
            slot.swaps_h.inc();
        }
        Ok(())
    }

    /// Snapshot one shard as a complete 1-shard sharded container
    /// (compact first when mutable, so the replica receives a single
    /// clean segment). The container's per-section CRCs are the
    /// transport integrity check.
    pub fn snapshot_shard(&self, s: usize) -> Result<Vec<u8>> {
        ensure!(s < self.slots.len(), "no shard {s} (node has {})", self.slots.len());
        let slot = &self.slots[s];
        let writer = slot.writer.read().unwrap_or_else(|e| e.into_inner()).clone();
        if let Some(w) = writer {
            w.compact()?;
        }
        let index = slot.epoch.load();
        let id_map = slot.id_map.read().unwrap_or_else(|e| e.into_inner()).clone();
        let single = ShardedIndex::from_parts(
            // The embedded router is irrelevant for a 1-shard snapshot;
            // hash keeps the header tiny.
            Router::Hash { seed: 0 },
            vec![index],
            vec![id_map],
            self.dim,
            true,
        )?;
        single.to_bytes()
    }

    /// Restore a snapshot into shard `s`: parse (every section CRC is
    /// verified), check search parity query-by-query against the
    /// currently-serving shard, then swap. A parity mismatch leaves the
    /// current shard serving. Returns the number of parity queries run.
    pub fn restore_shard(&self, s: usize, snapshot: &[u8], parity_queries: &[f32]) -> Result<usize> {
        ensure!(s < self.slots.len(), "no shard {s} (node has {})", self.slots.len());
        let restored = crate::api::persist::open_sharded_bytes(snapshot.to_vec())?;
        ensure!(
            restored.num_shards() == 1,
            "shard snapshot holds {} shards (expected 1)",
            restored.num_shards()
        );
        let (_, mut shards, mut maps, rdim) = restored.into_parts();
        ensure!(rdim == self.dim, "snapshot dim {rdim} != node dim {}", self.dim);
        let new = shards.pop().expect("1-shard snapshot");
        let new_map = maps.pop().expect("1-shard snapshot");
        ensure!(
            new_map.len() >= new.len(),
            "snapshot id map covers {} ids but its shard stores {} rows",
            new_map.len(),
            new.len()
        );

        let slot = &self.slots[s];
        let current = slot.epoch.load();
        let cur_map = slot.id_map.read().unwrap_or_else(|e| e.into_inner()).clone();
        // Local → global translation that refuses to read past the map:
        // a mutable shard can grow between the epoch load and the map
        // clone above (the handle is the epoch), so a parity query may
        // surface a row the snapshot of the map does not cover yet.
        let translate = |pairs: &[(f32, u32)], map: &[u32]| -> Result<Vec<(u32, u32)>> {
            pairs
                .iter()
                .map(|&(d, l)| match map.get(l as usize) {
                    Some(&ext) => Ok((d.to_bits(), ext)),
                    None => bail!(
                        "parity hit local id {l} past the id map ({} entries) — \
                         concurrent ingest on shard {s}? retry the restore",
                        map.len()
                    ),
                })
                .collect()
        };
        let mut scratch = AnnScratch::default();
        let mut got = Vec::new();
        let mut want = Vec::new();
        let nq = parity_queries.len() / self.dim;
        for (qi, q) in parity_queries.chunks_exact(self.dim).enumerate() {
            current.search_into(q, &self.search, &mut scratch, &mut want);
            new.search_into(q, &self.search, &mut scratch, &mut got);
            let a = translate(&want, &cur_map)?;
            let b = translate(&got, &new_map)?;
            ensure!(
                a == b,
                "restore parity mismatch on query {qi}/{nq} for shard {s}: \
                 snapshot disagrees with the serving index"
            );
        }
        self.swap_shard(s, new, new_map, None)?;
        Ok(nq)
    }

    /// Persist the whole node into `dir` as generation 0 of a durable node
    /// directory (router file + one snapshot container per shard + manifest;
    /// see [`crate::durable::node`]). Mutable shards are compacted by the
    /// per-shard snapshot. The directory must not already hold a manifest.
    pub fn save_dir(&self, dir: &std::path::Path) -> Result<()> {
        let snaps: Vec<Vec<u8>> =
            (0..self.slots.len()).map(|s| self.snapshot_shard(s)).collect::<Result<_>>()?;
        crate::durable::node::init_node_dir(dir, &self.router, self.dim, &snaps)
    }

    /// Snapshot shard `s` and commit it into the durable node directory
    /// `dir` under the next manifest generation — the on-disk half of a
    /// shard swap. Crash-safe: until the manifest flip, the directory's
    /// previous generation stays reachable. Returns the new generation.
    pub fn commit_shard(&self, dir: &std::path::Path, s: usize) -> Result<u64> {
        let snap = self.snapshot_shard(s)?;
        crate::durable::node::commit_shard(dir, s, &snap)
    }

    /// Restart a node from a durable directory written by [`Self::save_dir`]
    /// / [`Self::commit_shard`]: reopen the manifest's current generation
    /// and serve it read-only (matching `restore_shard` semantics — a
    /// restarted replica serves snapshots; ingest resumes on the primary).
    pub fn start_from_dir(dir: &std::path::Path, cfg: NodeConfig) -> Result<ServeNode> {
        let (index, _generation) = crate::durable::node::open_node_dir(dir)?;
        Self::start_static(index, cfg)
    }

    /// Refill every tenant bucket (bench passes start from a clean slate).
    pub fn reset_admission(&self) {
        if let Some(a) = &self.admission {
            a.reset();
        }
    }

    /// Per-tenant admission counters, sorted by tenant.
    pub fn tenant_counters(&self) -> Vec<(String, TenantCounters)> {
        self.admission.as_ref().map(|a| a.all_counters()).unwrap_or_default()
    }

    /// Deepest any shard's admission queue ever got.
    pub fn queue_hwm(&self) -> u64 {
        self.slots.iter().map(|s| s.coord.metrics.queue_depth_hwm()).max().unwrap_or(0)
    }

    /// One human-readable metrics line per shard.
    pub fn metrics_summary(&self) -> String {
        self.slots
            .iter()
            .enumerate()
            .map(|(s, slot)| format!("shard {s}: {}", slot.coord.metrics.summary()))
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// All shard coordinators' metrics as one JSON object
    /// (`{"shards": [...]}`), same per-shard schema as
    /// [`crate::coordinator::metrics::Metrics::metrics_json`].
    pub fn metrics_json(&self) -> String {
        let shards: Vec<String> =
            self.slots.iter().map(|s| s.coord.metrics.metrics_json()).collect();
        format!("{{\"shards\": [{}]}}", shards.join(", "))
    }

    /// Stop every shard coordinator (drains and joins the batchers).
    pub fn stop(self) {
        for slot in self.slots {
            slot.coord.stop();
        }
    }
}

fn clone_serve_config(c: &ServeConfig) -> ServeConfig {
    ServeConfig {
        batch_size: c.batch_size,
        max_wait: c.max_wait,
        search: c.search.clone(),
        scan_threads: c.scan_threads,
        queue_depth: c.queue_depth,
        deadline: c.deadline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, Kind};
    use crate::index::IvfBuildParams;
    use crate::serve::sharded::RouterKind;

    fn build_params(shards: usize, router: RouterKind) -> ShardedBuildParams {
        ShardedBuildParams {
            shards,
            router,
            ivf: IvfBuildParams { k: 16, threads: 2, id_codec: "roc".into(), ..Default::default() },
        }
    }

    fn node_cfg(k: usize, nprobe: usize) -> NodeConfig {
        NodeConfig {
            serve: ServeConfig {
                batch_size: 8,
                max_wait: Duration::from_millis(1),
                search: QueryParams { k, nprobe, ef: 32 },
                scan_threads: 2,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn static_node_matches_passive_sharded_index_bit_for_bit() {
        let ds = generate(Kind::DeepLike, 2000, 16, 8, 41);
        let params = build_params(3, RouterKind::Hash);
        let passive = ShardedIndex::build(&ds.data, ds.dim, &params).unwrap();
        let node = ServeNode::start_static(
            ShardedIndex::build(&ds.data, ds.dim, &params).unwrap(),
            node_cfg(10, 8),
        )
        .unwrap();
        let sp = QueryParams { k: 10, nprobe: 8, ef: 32 };
        let mut scratch = AnnScratch::default();
        let mut want = Vec::new();
        for (qi, q) in ds.queries.chunks_exact(ds.dim).enumerate() {
            passive.search_into(q, &sp, &mut scratch, &mut want);
            let got = node.search("t0", q).unwrap();
            assert_eq!(got.status, ResponseStatus::Ok);
            assert_eq!(got.results, want, "query {qi}");
        }
        node.stop();
    }

    #[test]
    fn durable_dir_restart_is_bit_identical_across_commits() {
        let dir = std::env::temp_dir()
            .join(format!("zann-node-dir-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let ds = generate(Kind::DeepLike, 1500, 8, 8, 43);
        let params = build_params(2, RouterKind::Hash);
        let node = ServeNode::start_mutable(
            &ds.data[..1000 * ds.dim],
            ds.dim,
            &params,
            CompactionPolicy::default(),
            node_cfg(8, 6),
        )
        .unwrap();
        node.save_dir(&dir).unwrap();

        // Restart from disk and compare every query bit-for-bit.
        let check = |node: &ServeNode, label: &str| {
            let reopened = ServeNode::start_from_dir(&dir, node_cfg(8, 6)).unwrap();
            for (qi, q) in ds.queries.chunks_exact(ds.dim).enumerate() {
                let live = node.search_raw(q).unwrap();
                let back = reopened.search_raw(q).unwrap();
                assert_eq!(live.results, back.results, "{label}: query {qi}");
            }
            reopened.stop();
        };
        check(&node, "generation 0");

        // Ingest, then roll each shard to a new generation; the directory
        // must track the live node after every commit.
        node.add(&ds.data[1000 * ds.dim..1300 * ds.dim]).unwrap();
        let g1 = node.commit_shard(&dir, 0).unwrap();
        assert_eq!(g1, 1);
        node.add(&ds.data[1300 * ds.dim..]).unwrap();
        let g2 = node.commit_shard(&dir, 1).unwrap();
        assert_eq!(g2, 2);
        // Shard 0's generation-1 snapshot predates the second ingest, so
        // re-commit it before comparing against the live node.
        node.commit_shard(&dir, 0).unwrap();
        check(&node, "after commits");

        node.stop();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn admission_sheds_greedy_tenant_but_not_quiet_one() {
        let ds = generate(Kind::DeepLike, 1200, 4, 8, 42);
        let params = build_params(2, RouterKind::Hash);
        let mut cfg = node_cfg(5, 4);
        cfg.tenants = Some(TenantPolicy { burst: 10, rate: 0.0 });
        let node = ServeNode::start_static(
            ShardedIndex::build(&ds.data, ds.dim, &params).unwrap(),
            cfg,
        )
        .unwrap();
        let q = &ds.queries[..ds.dim];
        let mut shed = 0;
        for _ in 0..30 {
            let r = node.search("greedy", q).unwrap();
            if r.status == ResponseStatus::Overloaded {
                shed += 1;
                assert!(r.results.is_empty());
            }
        }
        assert_eq!(shed, 20, "rate=0 bucket admits exactly burst");
        // The quiet tenant's bucket is untouched.
        assert_eq!(node.search("quiet", q).unwrap().status, ResponseStatus::Ok);
        let counters = node.tenant_counters();
        let greedy = counters.iter().find(|(t, _)| t == "greedy").unwrap().1;
        assert_eq!(greedy.rejected, 20);
        assert_eq!(counters.iter().find(|(t, _)| t == "quiet").unwrap().1.rejected, 0);
        // Post-overload liveness: the serving loop still answers.
        assert_eq!(node.search_raw(q).unwrap().status, ResponseStatus::Ok);
        node.stop();
    }

    #[test]
    fn mutable_node_ingests_and_finds_new_rows() {
        let ds = generate(Kind::DeepLike, 1500, 4, 8, 43);
        for router in [RouterKind::Hash, RouterKind::Kmeans] {
            let node = ServeNode::start_mutable(
                &ds.data,
                ds.dim,
                &build_params(3, router),
                CompactionPolicy::default(),
                node_cfg(5, 16),
            )
            .unwrap();
            let row: Vec<f32> = (0..ds.dim).map(|j| 40.0 + j as f32).collect();
            let ids = node.add(&row).unwrap();
            assert_eq!(ids, 1500..1501);
            let got = node.search("t", &row).unwrap();
            assert_eq!(got.status, ResponseStatus::Ok);
            assert_eq!(got.results[0].1, 1500, "the planted row is its own nearest neighbor");
            assert_eq!(got.results[0].0, 0.0);
            assert_eq!(node.shard_rows().iter().sum::<usize>(), 1501);
            node.stop();
        }
    }

    #[test]
    fn static_node_rejects_ingest() {
        let ds = generate(Kind::DeepLike, 600, 2, 8, 44);
        let node = ServeNode::start_static(
            ShardedIndex::build(&ds.data, ds.dim, &build_params(2, RouterKind::Hash)).unwrap(),
            node_cfg(5, 4),
        )
        .unwrap();
        assert!(node.add(&vec![0.5; ds.dim]).is_err());
        node.stop();
    }

    #[test]
    fn snapshot_restore_roundtrip_verifies_parity_and_swaps() {
        let ds = generate(Kind::DeepLike, 1500, 8, 8, 45);
        let node = ServeNode::start_mutable(
            &ds.data,
            ds.dim,
            &build_params(2, RouterKind::Hash),
            CompactionPolicy::default(),
            node_cfg(10, 8),
        )
        .unwrap();
        let before: Vec<NodeResponse> = ds
            .queries
            .chunks_exact(ds.dim)
            .map(|q| node.search_raw(q).unwrap())
            .collect();
        let snap = node.snapshot_shard(0).unwrap();
        let nq = node.restore_shard(0, &snap, &ds.queries).unwrap();
        assert_eq!(nq, 8);
        // The restored epoch serves bit-identical answers.
        for (q, b) in ds.queries.chunks_exact(ds.dim).zip(&before) {
            let after = node.search_raw(q).unwrap();
            assert_eq!(after.results, b.results);
        }
        // The restored shard is read-only now; the other still writes.
        assert!(node.snapshot_shard(0).is_ok());
        node.stop();
    }

    #[test]
    fn restore_rejects_corrupt_and_mismatched_snapshots() {
        let ds = generate(Kind::DeepLike, 1000, 4, 8, 46);
        let node = ServeNode::start_mutable(
            &ds.data,
            ds.dim,
            &build_params(2, RouterKind::Hash),
            CompactionPolicy::default(),
            node_cfg(5, 8),
        )
        .unwrap();
        let snap = node.snapshot_shard(0).unwrap();
        // Bit rot in transit: CRC catches it, shard keeps serving.
        let mut bad = snap.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(node.restore_shard(0, &bad, &ds.queries).is_err());
        // Wrong shard's snapshot: parity check refuses the swap.
        let other = node.snapshot_shard(1).unwrap();
        let err = node.restore_shard(0, &other, &ds.queries).unwrap_err();
        assert!(format!("{err:#}").contains("parity"), "{err:#}");
        // Either way the node still answers.
        assert_eq!(node.search_raw(&ds.queries[..ds.dim]).unwrap().status, ResponseStatus::Ok);
        node.stop();
    }

    #[test]
    fn concurrent_ingest_and_search_never_hits_an_unmapped_row() {
        // Regression for the add/search race: DynamicHandle::add
        // publishes rows before the id map used to be extended, so a
        // concurrent search could translate a fresh local id out of
        // bounds and panic. With the map lock held across the ingest,
        // every published row is mapped by the time a reader looks.
        let ds = generate(Kind::DeepLike, 800, 4, 8, 48);
        let node = Arc::new(
            ServeNode::start_mutable(
                &ds.data,
                ds.dim,
                &build_params(2, RouterKind::Hash),
                CompactionPolicy::default(),
                node_cfg(5, 8),
            )
            .unwrap(),
        );
        let writer = {
            let node = node.clone();
            let dim = ds.dim;
            std::thread::spawn(move || {
                for i in 0..500u32 {
                    let row: Vec<f32> = (0..dim).map(|j| (i as f32) * 0.01 + j as f32).collect();
                    node.add(&row).unwrap();
                }
            })
        };
        while !writer.is_finished() {
            for q in ds.queries.chunks_exact(ds.dim) {
                let r = node.search_raw(q).unwrap();
                assert_ne!(r.status, ResponseStatus::Failed, "no panic may escape a shard");
            }
        }
        writer.join().unwrap();
        assert_eq!(node.shard_rows().iter().sum::<usize>(), 800 + 500);
        assert!(node.search_raw(&ds.queries[..ds.dim]).unwrap().is_ok());
        if let Ok(n) = Arc::try_unwrap(node) {
            n.stop();
        }
    }

    #[test]
    fn concurrent_swap_and_search_stays_in_bounds() {
        // Regression for the swap/search race: a query in flight on the
        // old (large) epoch can translate its locals through a freshly
        // installed 1-entry map. The bounds-checked translation drops
        // those rows instead of panicking.
        let ds = generate(Kind::DeepLike, 1200, 4, 8, 49);
        let params = build_params(2, RouterKind::Hash);
        let node = Arc::new(
            ServeNode::start_static(
                ShardedIndex::build(&ds.data, ds.dim, &params).unwrap(),
                node_cfg(5, 8),
            )
            .unwrap(),
        );
        let (_, shards, maps, _) =
            ShardedIndex::build(&ds.data, ds.dim, &params).unwrap().into_parts();
        let big = shards[0].clone();
        let big_map = maps[0].clone();
        let swapper = {
            let node = node.clone();
            let dim = ds.dim;
            std::thread::spawn(move || {
                for i in 0..300 {
                    if i % 2 == 0 {
                        let tiny: Arc<dyn AnnIndex> = Arc::new(PanickyShard { dim });
                        node.swap_shard(0, tiny, vec![0], None).unwrap();
                    } else {
                        node.swap_shard(0, big.clone(), big_map.clone(), None).unwrap();
                    }
                    // Keep the swapper alive long enough for searches to
                    // interleave with the swaps.
                    std::thread::sleep(Duration::from_micros(50));
                }
            })
        };
        while !swapper.is_finished() {
            for q in ds.queries.chunks_exact(ds.dim) {
                let r = node.search_raw(q).unwrap();
                assert_ne!(r.status, ResponseStatus::Failed);
            }
        }
        swapper.join().unwrap();
        assert!(node.search_raw(&ds.queries[..ds.dim]).unwrap().is_ok());
        if let Ok(n) = Arc::try_unwrap(node) {
            n.stop();
        }
    }

    /// Chaos shard: panics whenever the query's first component is NaN.
    struct PanickyShard {
        dim: usize,
    }

    impl AnnIndex for PanickyShard {
        fn kind(&self) -> IndexKind {
            IndexKind::Ivf
        }

        fn dim(&self) -> usize {
            self.dim
        }

        fn len(&self) -> usize {
            1
        }

        fn stats(&self) -> IndexStats {
            IndexStats {
                kind: IndexKind::Ivf,
                n: 1,
                dim: self.dim,
                edges: 0,
                codec: "chaos".into(),
                id_bits: 0,
                code_bits: 0,
                link_bits: 0,
                live: 1,
                deleted: 0,
                buffer_rows: 0,
                aux_bits: 0,
                checksummed: false,
                segments: Vec::new(),
            }
        }

        fn search_into(
            &self,
            query: &[f32],
            _params: &QueryParams,
            _scratch: &mut AnnScratch,
            out: &mut Vec<(f32, u32)>,
        ) {
            if query[0].is_nan() {
                panic!("injected shard panic");
            }
            out.clear();
            out.push((1e30, 0));
        }

        fn to_bytes(&self) -> Result<Vec<u8>> {
            bail!("not serializable")
        }
    }

    #[test]
    fn shard_panic_degrades_per_policy_without_hanging() {
        let ds = generate(Kind::DeepLike, 1000, 4, 8, 47);
        for (policy, expect_results) in [(DegradePolicy::Partial, true), (DegradePolicy::Fail, false)]
        {
            let mut cfg = node_cfg(5, 8);
            cfg.policy = policy;
            let node = ServeNode::start_static(
                ShardedIndex::build(&ds.data, ds.dim, &build_params(2, RouterKind::Hash))
                    .unwrap(),
                cfg,
            )
            .unwrap();
            // Swap a chaos index into shard 1, live.
            node.swap_shard(1, Arc::new(PanickyShard { dim: ds.dim }), vec![0], None).unwrap();
            let mut bad = ds.queries[..ds.dim].to_vec();
            bad[0] = f32::NAN;
            let r = node.search_raw(&bad).unwrap();
            assert_eq!(r.status, ResponseStatus::Failed, "policy {policy:?}");
            // NaN distances still come back from the healthy shard (NaN
            // query ⇒ NaN distances are pushed but TopK's total_cmp
            // handles them); what matters is the policy split on whether
            // any results surface at all.
            if !expect_results {
                assert!(r.results.is_empty(), "Fail policy returns nothing");
            }
            // The panicked shard's pool survived: clean queries are Ok on
            // the healthy shard and the node answers — no hang.
            let clean = node.search_raw(&ds.queries[..ds.dim]).unwrap();
            assert!(
                matches!(clean.status, ResponseStatus::Ok),
                "node must keep serving after a shard panic, got {:?}",
                clean.status
            );
            node.stop();
        }
    }
}
