//! L4 sharded serving: one endpoint over N shards.
//!
//! The compression layers below keep each shard's posting lists small;
//! this layer is about operating *many* of them as one index:
//!
//! - [`sharded`] — the passive [`ShardedIndex`]: ingest routers
//!   (hash-by-id, kmeans-by-vector), a build that shares one global
//!   coarse quantizer across shards, and an exact scatter-gather top-k
//!   merge whose tie order is pinned to `(distance, ext_id)`. Searching
//!   a sharded index is bit-identical to searching a single index built
//!   over the union of its rows.
//! - [`persist`] — the kind-4 multi-shard container: a routing-table
//!   section plus each shard's own container embedded verbatim, every
//!   payload CRC-covered at the outer framing *and* inside the embedded
//!   container.
//! - [`admission`] — per-tenant token buckets so one greedy tenant
//!   sheds its own traffic (`Overloaded`) instead of starving everyone.
//! - [`node`] — the live [`ServeNode`]: a coordinator (bounded queue +
//!   worker pool) per shard, RCU epoch handles for live shard swap,
//!   partitioned ingest through dynamic shards, and snapshot/restore
//!   with CRC + search-parity verification before the swap.

pub mod admission;
pub mod node;
pub mod persist;
pub mod sharded;

pub use admission::{Admission, TenantCounters, TenantPolicy};
pub use node::{DegradePolicy, NodeConfig, NodeResponse, ServeNode};
pub use sharded::{Router, RouterKind, ShardedBuildParams, ShardedIndex};
