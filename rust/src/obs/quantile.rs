//! Nearest-rank quantiles — the single shared implementation.
//!
//! Three copies of "percentile of a sorted slice" grew up independently
//! (coordinator latency percentiles, the workload aggregator, histogram
//! quantiles) with subtly different index formulas. This module pins one
//! convention and everything routes through it:
//!
//! > the quantile `q ∈ [0, 1]` of `n` sorted samples is the element at
//! > index `round(q · (n − 1))`, with `round` half-away-from-zero
//! > (Rust's `f64::round`).
//!
//! So `q=0.5` over `[1, 2, 3, 4]` is index `round(1.5) = 2` → `3`, and
//! `q=1.0` is always the max. This matches the historical behaviour of
//! `eval/workload.rs::percentile` and `Metrics::latency_percentile_us`,
//! which tests in both modules pin.

/// Index of the nearest-rank quantile `q` in a sorted collection of
/// `len` elements. Returns 0 for empty input; `q` is clamped to [0, 1].
pub fn nearest_rank_index(len: usize, q: f64) -> usize {
    if len == 0 {
        return 0;
    }
    let q = q.clamp(0.0, 1.0);
    let idx = ((len - 1) as f64 * q).round() as usize;
    idx.min(len - 1)
}

/// Nearest-rank quantile of an **ascending-sorted** f64 slice.
/// Returns 0.0 for an empty slice.
pub fn quantile_sorted_f64(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        0.0
    } else {
        sorted[nearest_rank_index(sorted.len(), q)]
    }
}

/// Nearest-rank quantile of an **ascending-sorted** u64 slice.
/// Returns 0 for an empty slice.
pub fn quantile_sorted_u64(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        0
    } else {
        sorted[nearest_rank_index(sorted.len(), q)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_input_is_zero() {
        assert_eq!(nearest_rank_index(0, 0.5), 0);
        assert_eq!(quantile_sorted_f64(&[], 0.5), 0.0);
        assert_eq!(quantile_sorted_u64(&[], 0.99), 0);
    }

    #[test]
    fn single_element_is_every_quantile() {
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(quantile_sorted_u64(&[7], q), 7);
        }
    }

    #[test]
    fn matches_workload_percentile_convention() {
        // Pinned from eval/workload.rs: percentile(&[1,2,3,4], 50) == 3.0
        // because round(0.5 * 3) = round(1.5) = 2 (half away from zero).
        assert_eq!(quantile_sorted_f64(&[1.0, 2.0, 3.0, 4.0], 0.5), 3.0);
        assert_eq!(quantile_sorted_u64(&[1, 2, 3, 4], 0.5), 3);
    }

    #[test]
    fn extremes_are_min_and_max() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(quantile_sorted_u64(&v, 0.0), 1);
        assert_eq!(quantile_sorted_u64(&v, 1.0), 100);
        // Out-of-range q clamps rather than panicking.
        assert_eq!(quantile_sorted_u64(&v, -3.0), 1);
        assert_eq!(quantile_sorted_u64(&v, 2.0), 100);
    }

    #[test]
    fn property_monotone_in_q() {
        // Quantiles must be non-decreasing in q for any sorted input.
        let mut v: Vec<u64> = (0..257).map(|i| (i * i * 31 + i) % 1009).collect();
        v.sort_unstable();
        let mut prev = quantile_sorted_u64(&v, 0.0);
        let mut q = 0.0;
        while q <= 1.0 {
            let cur = quantile_sorted_u64(&v, q);
            assert!(cur >= prev, "quantile decreased at q={q}: {cur} < {prev}");
            prev = cur;
            q += 0.01;
        }
    }

    #[test]
    fn property_result_is_always_a_sample() {
        let mut v: Vec<u64> = (0..53).map(|i| (i * 7919) % 997).collect();
        v.sort_unstable();
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let r = quantile_sorted_u64(&v, q);
            assert!(v.contains(&r), "quantile {q} returned non-sample {r}");
        }
    }

    #[test]
    fn property_rank_error_is_at_most_half_step() {
        // For n samples, the chosen index must be the closest integer to
        // q*(n-1): |idx - q*(n-1)| <= 0.5.
        for n in [1usize, 2, 3, 10, 101] {
            for i in 0..=40 {
                let q = i as f64 / 40.0;
                let idx = nearest_rank_index(n, q);
                let exact = q * (n - 1) as f64;
                assert!(
                    (idx as f64 - exact).abs() <= 0.5 + 1e-9,
                    "n={n} q={q}: idx={idx} exact={exact}"
                );
            }
        }
    }
}
