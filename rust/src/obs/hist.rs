//! Fixed-bucket log₂ latency histogram.
//!
//! Values land in power-of-two buckets: bucket 0 holds exactly `0`,
//! bucket `i ≥ 1` holds `2^(i-1) ..= 2^i - 1` (i.e. values whose bit
//! length is `i`). 42 buckets cover `0 ..= 2^40 - 1` with the last
//! bucket absorbing everything larger — at microsecond resolution that
//! is ~12.7 days, far beyond any latency we record. Observing is two
//! relaxed `fetch_add`s and a `leading_zeros`; there is no lock to
//! poison, which is the point (a caught worker panic used to poison the
//! coordinator's `Mutex<Vec<u64>>` and silently zero its percentiles).
//!
//! Quantiles are nearest-rank (see [`crate::obs::quantile`]) over the
//! cumulative bucket counts and return the *upper bound* of the selected
//! bucket (`2^i − 1`), a conservative ≤2× overestimate. Tests pin the
//! exact values so the contract can't drift silently.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use super::quantile::nearest_rank_index;

/// Number of buckets: one for zero plus one per bit length 1..=40, plus
/// a final catch-all for values ≥ 2^40.
pub const BUCKETS: usize = 42;

/// Lock-free log₂ histogram. Const-constructible so it can back both
/// `static` registries and `Arc`-shared handles.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Self {
        // [const-init; BUCKETS] requires the element expression be const.
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            buckets: [ZERO; BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Bucket index for a value: 0 for 0, else its bit length, clamped
    /// to the catch-all bucket.
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        let bits = (64 - v.leading_zeros()) as usize;
        bits.min(BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` (`2^i − 1`); `u64::MAX` for
    /// the catch-all.
    pub fn bucket_upper_bound(i: usize) -> u64 {
        if i >= BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    /// Snapshot of the raw bucket counts.
    pub fn snapshot(&self) -> [u64; BUCKETS] {
        let mut out = [0u64; BUCKETS];
        for (o, b) in out.iter_mut().zip(self.buckets.iter()) {
            *o = b.load(Relaxed);
        }
        out
    }

    /// Nearest-rank quantile, reported as the upper bound of the bucket
    /// holding the selected sample. 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let snap = self.snapshot();
        let total: u64 = snap.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = nearest_rank_index(total as usize, q) as u64;
        let mut seen = 0u64;
        for (i, &c) in snap.iter().enumerate() {
            seen += c;
            // rank is a 0-based index; bucket i covers indices
            // [seen-c, seen).
            if c > 0 && rank < seen {
                return Self::bucket_upper_bound(i);
            }
        }
        Self::bucket_upper_bound(BUCKETS - 1)
    }

    /// Reset all cells to zero (tests and bench warmup only; not atomic
    /// as a whole).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Relaxed);
        }
        self.count.store(0, Relaxed);
        self.sum.store(0, Relaxed);
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_exact_powers_of_two() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(7), 3);
        assert_eq!(Histogram::bucket_index(8), 4);
        assert_eq!(Histogram::bucket_index(100), 7); // 64..=127
        assert_eq!(Histogram::bucket_index(1 << 40), BUCKETS - 1);
        assert_eq!(Histogram::bucket_index(u64::MAX), BUCKETS - 1);
        // Every bucket's upper bound maps back into that bucket.
        for i in 1..BUCKETS - 1 {
            assert_eq!(Histogram::bucket_index(Histogram::bucket_upper_bound(i)), i);
            assert_eq!(Histogram::bucket_index(Histogram::bucket_upper_bound(i) + 1), i + 1);
        }
    }

    #[test]
    fn quantiles_return_bucket_upper_bounds() {
        let h = Histogram::new();
        for v in 1..=100u64 {
            h.observe(v);
        }
        // Cumulative counts: b1=1, b2=3, b3=7, b4=15, b5=31, b6=63, b7=100.
        // rank(q=0.5) = round(0.5*99) = 50 → bucket 6 → upper bound 63.
        assert_eq!(h.quantile(0.5), 63);
        // rank(0.95) = round(94.05) = 94 → bucket 7 → 127.
        assert_eq!(h.quantile(0.95), 127);
        assert_eq!(h.quantile(1.0), 127);
        // rank(0.0) = 0 → bucket 1 → 1.
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum(), 5050);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.quantile(1.0), 0);
    }

    #[test]
    fn zero_values_land_in_bucket_zero() {
        let h = Histogram::new();
        h.observe(0);
        h.observe(0);
        assert_eq!(h.snapshot()[0], 2);
        assert_eq!(h.quantile(0.5), 0);
    }

    #[test]
    fn concurrent_observes_are_all_counted() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.observe(t * 10_000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
        assert_eq!(h.snapshot().iter().sum::<u64>(), 40_000);
    }

    #[test]
    fn reset_clears_everything() {
        let h = Histogram::new();
        h.observe(42);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0);
        assert_eq!(h.snapshot().iter().sum::<u64>(), 0);
    }
}
