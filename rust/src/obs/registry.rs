//! The lock-free metrics registry.
//!
//! Recording sites hold `Arc` handles (or `&'static` cells) and touch a
//! single relaxed atomic — the registry's mutex guards only the *series
//! table* used at registration and exposition time, both rare. Series
//! are keyed by `(name, labels)`; `name` is always a `&'static str`
//! (metric names are part of the code contract, not data), label values
//! are owned strings (tenant names, shard indices).
//!
//! Cardinality is capped per name ([`MAX_SERIES_PER_NAME`]): once a name
//! has that many label combinations, further registrations return
//! functional *orphan* handles that count but are never exposed, so an
//! attacker spraying unique tenant names cannot grow the registry
//! without bound (mirroring the bounded tenant map in serve/admission).

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex, MutexGuard};

use super::hist::Histogram;

/// Per-name label-combination cap; beyond it, handles become orphans.
pub const MAX_SERIES_PER_NAME: usize = 4096;

/// Monotone counter: one relaxed `fetch_add` per record.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Signed gauge (queue depths, high-water marks, build info).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicI64::new(0))
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Relaxed);
    }

    /// Add `delta` and return the post-update value.
    #[inline]
    pub fn add(&self, delta: i64) -> i64 {
        self.0.fetch_add(delta, Relaxed) + delta
    }

    /// Subtract with a floor of zero (for depth gauges where a stray
    /// extra decrement must not wrap negative).
    #[inline]
    pub fn sub_floor0(&self, delta: i64) {
        let _ = self.0.fetch_update(Relaxed, Relaxed, |v| Some((v - delta).max(0)));
    }

    /// Raise to `v` if larger (high-water marks).
    #[inline]
    pub fn max_of(&self, v: i64) {
        self.0.fetch_max(v, Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Relaxed)
    }
}

/// A label-free counter that can live in a `static` and registers itself
/// on the global registry at first use. The steady-state cost is one
/// relaxed flag load plus the `fetch_add`; with the `obs` feature off it
/// is a pure no-op and never registers.
#[derive(Debug)]
pub struct StaticCounter {
    name: &'static str,
    cell: Counter,
    registered: AtomicBool,
}

impl StaticCounter {
    pub const fn new(name: &'static str) -> Self {
        StaticCounter { name, cell: Counter::new(), registered: AtomicBool::new(false) }
    }

    #[inline]
    pub fn add(&'static self, n: u64) {
        if !super::enabled() {
            return;
        }
        if !self.registered.load(Relaxed) {
            super::global().register_static(self);
            self.registered.store(true, Relaxed);
        }
        self.cell.add(n);
    }

    #[inline]
    pub fn inc(&'static self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.cell.get()
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// One registered series: a metric cell plus its identity.
pub(crate) struct Entry {
    pub(crate) name: &'static str,
    pub(crate) labels: Vec<(&'static str, String)>,
    pub(crate) metric: Metric,
}

pub(crate) enum Metric {
    Counter(Arc<Counter>),
    CounterRef(&'static StaticCounter),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Const-constructible series table. All mutation goes through
/// [`Registry::entries`], which recovers from poisoning — a panicking
/// exposition caller must not be able to wedge every recording site.
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub const fn new() -> Self {
        Registry { entries: Mutex::new(Vec::new()) }
    }

    pub(crate) fn entries(&self) -> MutexGuard<'_, Vec<Entry>> {
        self.entries.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn labels_match(have: &[(&'static str, String)], want: &[(&'static str, &str)]) -> bool {
        have.len() == want.len()
            && have.iter().zip(want.iter()).all(|((hk, hv), (wk, wv))| hk == wk && hv == wv)
    }

    fn series_count(entries: &[Entry], name: &str) -> usize {
        entries.iter().filter(|e| e.name == name).count()
    }

    /// Get-or-register the counter `(name, labels)`. Returns an orphan
    /// (unregistered but functional) handle if the name is over its
    /// cardinality cap or already registered with a different type.
    pub fn counter(&self, name: &'static str, labels: &[(&'static str, &str)]) -> Arc<Counter> {
        let mut entries = self.entries();
        for e in entries.iter() {
            if e.name == name && Self::labels_match(&e.labels, labels) {
                if let Metric::Counter(c) = &e.metric {
                    return Arc::clone(c);
                }
                return Arc::new(Counter::new()); // type clash: orphan
            }
        }
        let c = Arc::new(Counter::new());
        if Self::series_count(&entries, name) < MAX_SERIES_PER_NAME {
            entries.push(Entry {
                name,
                labels: labels.iter().map(|(k, v)| (*k, v.to_string())).collect(),
                metric: Metric::Counter(Arc::clone(&c)),
            });
        }
        c
    }

    /// Get-or-register the gauge `(name, labels)` (orphan rules as
    /// [`Registry::counter`]).
    pub fn gauge(&self, name: &'static str, labels: &[(&'static str, &str)]) -> Arc<Gauge> {
        let mut entries = self.entries();
        for e in entries.iter() {
            if e.name == name && Self::labels_match(&e.labels, labels) {
                if let Metric::Gauge(g) = &e.metric {
                    return Arc::clone(g);
                }
                return Arc::new(Gauge::new());
            }
        }
        let g = Arc::new(Gauge::new());
        if Self::series_count(&entries, name) < MAX_SERIES_PER_NAME {
            entries.push(Entry {
                name,
                labels: labels.iter().map(|(k, v)| (*k, v.to_string())).collect(),
                metric: Metric::Gauge(Arc::clone(&g)),
            });
        }
        g
    }

    /// Get-or-register the histogram `(name, labels)` (orphan rules as
    /// [`Registry::counter`]).
    pub fn histogram(&self, name: &'static str, labels: &[(&'static str, &str)]) -> Arc<Histogram> {
        let mut entries = self.entries();
        for e in entries.iter() {
            if e.name == name && Self::labels_match(&e.labels, labels) {
                if let Metric::Histogram(h) = &e.metric {
                    return Arc::clone(h);
                }
                return Arc::new(Histogram::new());
            }
        }
        let h = Arc::new(Histogram::new());
        if Self::series_count(&entries, name) < MAX_SERIES_PER_NAME {
            entries.push(Entry {
                name,
                labels: labels.iter().map(|(k, v)| (*k, v.to_string())).collect(),
                metric: Metric::Histogram(Arc::clone(&h)),
            });
        }
        h
    }

    /// Register a [`StaticCounter`] by reference (idempotent by pointer
    /// identity — a benign first-use race registers it once).
    pub fn register_static(&self, sc: &'static StaticCounter) {
        let mut entries = self.entries();
        let already = entries.iter().any(|e| match &e.metric {
            Metric::CounterRef(r) => std::ptr::eq(*r, sc),
            _ => false,
        });
        if !already && Self::series_count(&entries, sc.name()) < MAX_SERIES_PER_NAME {
            entries.push(Entry { name: sc.name(), labels: Vec::new(), metric: Metric::CounterRef(sc) });
        }
    }

    /// Number of registered series (tests / diagnostics).
    pub fn series_len(&self) -> usize {
        self.entries().len()
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// A one-slot per-call-site cache for a labeled counter, for hot paths
/// whose label value (codec name, graph family) is a `&str` that rarely
/// changes. Stays on a scratch struct, so lookups hit the registry only
/// when the label actually differs from the cached one.
#[derive(Default)]
pub struct LabeledCounter {
    cached: Option<(String, Arc<Counter>)>,
}

impl LabeledCounter {
    pub const fn new() -> Self {
        LabeledCounter { cached: None }
    }

    /// Handle for `name{key=val}`, re-resolving only on label change.
    #[inline]
    pub fn get(&mut self, name: &'static str, key: &'static str, val: &str) -> &Counter {
        let stale = match &self.cached {
            Some((v, _)) => v != val,
            None => true,
        };
        if stale {
            self.cached = Some((val.to_string(), super::counter(name, &[(key, val)])));
        }
        &self.cached.as_ref().unwrap().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_or_register_returns_same_cell() {
        let r = Registry::new();
        let a = r.counter("c_total", &[("k", "v")]);
        let b = r.counter("c_total", &[("k", "v")]);
        a.add(2);
        b.inc();
        assert_eq!(a.get(), 3);
        assert_eq!(r.series_len(), 1);
    }

    #[test]
    fn label_order_and_values_distinguish_series() {
        let r = Registry::new();
        let _ = r.counter("c_total", &[("a", "1"), ("b", "2")]);
        let _ = r.counter("c_total", &[("a", "1"), ("b", "3")]);
        let _ = r.counter("c_total", &[("a", "1")]);
        assert_eq!(r.series_len(), 3);
    }

    #[test]
    fn type_clash_yields_orphan_not_panic() {
        let r = Registry::new();
        let c = r.counter("mixed", &[]);
        c.inc();
        let g = r.gauge("mixed", &[]);
        g.set(99);
        // The counter keeps its value; the gauge is a detached orphan.
        assert_eq!(c.get(), 1);
        assert_eq!(r.series_len(), 1);
    }

    #[test]
    fn cardinality_cap_stops_registration_but_not_counting() {
        let r = Registry::new();
        for i in 0..MAX_SERIES_PER_NAME + 10 {
            let v = i.to_string();
            let c = r.counter("spray_total", &[("tenant", &v)]);
            c.inc();
            assert_eq!(c.get(), 1, "orphan handles must still count");
        }
        assert_eq!(r.series_len(), MAX_SERIES_PER_NAME);
    }

    #[test]
    fn concurrent_writers_lose_no_increments() {
        let r = Registry::new();
        let c = r.counter("hot_total", &[]);
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..25_000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 200_000);
    }

    #[test]
    fn gauge_floor_and_max() {
        let g = Gauge::new();
        assert_eq!(g.add(3), 3);
        g.sub_floor0(10);
        assert_eq!(g.get(), 0, "depth gauges must not wrap negative");
        g.max_of(7);
        g.max_of(5);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn static_counter_registers_once() {
        static SC: StaticCounter = StaticCounter::new("static_demo_total");
        SC.inc();
        SC.add(2);
        if crate::obs::enabled() {
            assert_eq!(SC.get(), 3);
            // Registered exactly once on the global registry.
            let n = crate::obs::global()
                .entries()
                .iter()
                .filter(|e| e.name == "static_demo_total")
                .count();
            assert_eq!(n, 1);
        } else {
            assert_eq!(SC.get(), 0, "obs-off StaticCounter must be a no-op");
        }
    }

    #[test]
    fn labeled_counter_cache_follows_label_changes() {
        let mut lc = LabeledCounter::new();
        lc.get("cache_total", "codec", "bitpack").inc();
        lc.get("cache_total", "codec", "bitpack").inc();
        lc.get("cache_total", "codec", "elias-fano").inc();
        if crate::obs::enabled() {
            let a = crate::obs::counter("cache_total", &[("codec", "bitpack")]);
            let b = crate::obs::counter("cache_total", &[("codec", "elias-fano")]);
            assert_eq!(a.get(), 2);
            assert_eq!(b.get(), 1);
        }
    }
}
