//! Sampled per-query pipeline-stage tracing.
//!
//! A query's life is split into named stages (admission → queue wait →
//! coarse quantize → per-list decode → ADC scan / beam search → top-k
//! merge → reply). For a sampled subset of queries — every Nth, set by
//! `ZANN_TRACE_SAMPLE=1/N` (or just `N`; unset/0 disables) — the worker
//! thread accumulates per-stage nanoseconds in thread-local storage and,
//! at reply time, publishes a [`QueryTrace`] into a bounded ring buffer
//! and the `zann_stage_us{stage=...}` histograms. Unsampled queries pay
//! one relaxed atomic load and one `fetch_add` on the sequence counter;
//! with the `obs` feature off the tracer never activates at all.
//!
//! The whole trace is assembled on the worker thread that serves the
//! query (the batcher hands each request to exactly one worker), so no
//! cross-thread stitching is needed: queue wait is derived from the
//! request's submit timestamp, and the residue between the end-to-end
//! time and the instrumented stages is attributed to [`Stage::Other`] so
//! the per-stage sum tracks the measured latency.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Pipeline stages, in pipeline order. `Other` absorbs un-attributed
/// time inside the serve path so stage sums stay close to end-to-end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Admission,
    QueueWait,
    CoarseQuantize,
    ListDecode,
    AdcScan,
    BeamSearch,
    TopkMerge,
    Other,
    Reply,
}

impl Stage {
    pub const COUNT: usize = 9;

    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::Admission,
        Stage::QueueWait,
        Stage::CoarseQuantize,
        Stage::ListDecode,
        Stage::AdcScan,
        Stage::BeamSearch,
        Stage::TopkMerge,
        Stage::Other,
        Stage::Reply,
    ];

    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::CoarseQuantize => "coarse_quantize",
            Stage::ListDecode => "list_decode",
            Stage::AdcScan => "adc_scan",
            Stage::BeamSearch => "beam_search",
            Stage::TopkMerge => "topk_merge",
            Stage::Other => "other",
            Stage::Reply => "reply",
        }
    }
}

/// One sampled query's per-stage timeline.
#[derive(Debug, Clone)]
pub struct QueryTrace {
    pub seq: u64,
    pub stage_ns: [u64; Stage::COUNT],
    pub total_ns: u64,
}

impl QueryTrace {
    /// Sum of all attributed stage time (excludes [`Stage::Admission`],
    /// which happens before the request's submit timestamp and so is
    /// also excluded from `total_ns`).
    pub fn stage_sum_ns(&self) -> u64 {
        Stage::ALL
            .iter()
            .filter(|s| !matches!(s, Stage::Admission))
            .map(|s| self.stage_ns[s.idx()])
            .sum()
    }
}

/// Sampling divisor. `u64::MAX` is the "env not read yet" sentinel; 0
/// disables tracing; N means every Nth query is sampled.
static SAMPLE: AtomicU64 = AtomicU64::new(u64::MAX);
/// Global query sequence (advances for every query while sampling is on).
static SEQ: AtomicU64 = AtomicU64::new(0);

const RING_CAP: usize = 1024;

struct RingInner {
    buf: Vec<QueryTrace>,
    next: usize,
    recorded: u64,
}

static RING: Mutex<RingInner> = Mutex::new(RingInner { buf: Vec::new(), next: 0, recorded: 0 });

thread_local! {
    static ACTIVE: Cell<bool> = const { Cell::new(false) };
    static CUR_SEQ: Cell<u64> = const { Cell::new(0) };
    static STAGE_NS: RefCell<[u64; Stage::COUNT]> = const { RefCell::new([0; Stage::COUNT]) };
}

/// Parse a `ZANN_TRACE_SAMPLE` value: `1/N` or `N` → N; anything else
/// (including 0 and malformed input) disables sampling.
pub fn parse_sample(s: &str) -> u64 {
    let s = s.trim();
    let n = match s.split_once('/') {
        Some((num, den)) => {
            if num.trim() != "1" {
                return 0;
            }
            den.trim().parse::<u64>().unwrap_or(0)
        }
        None => s.parse::<u64>().unwrap_or(0),
    };
    if n == u64::MAX {
        0
    } else {
        n
    }
}

fn sample_n() -> u64 {
    let n = SAMPLE.load(Relaxed);
    if n != u64::MAX {
        return n;
    }
    let parsed = match std::env::var("ZANN_TRACE_SAMPLE") {
        Ok(v) => parse_sample(&v),
        Err(_) => 0,
    };
    SAMPLE.store(parsed, Relaxed);
    parsed
}

/// Override the sampling divisor (0 disables). Takes precedence over the
/// environment; used by the self-measurement bench and tests.
pub fn set_sample(n: u64) {
    SAMPLE.store(if n == u64::MAX { 0 } else { n }, Relaxed);
}

/// Current sampling divisor (after env resolution).
pub fn sample() -> u64 {
    if !super::enabled() {
        return 0;
    }
    sample_n()
}

/// Begin a query on this thread. Returns true when the query is sampled;
/// the caller must then finish with [`end_query`] or [`discard`].
#[inline]
pub fn begin_query() -> bool {
    if !super::enabled() {
        return false;
    }
    let n = sample_n();
    if n == 0 {
        return false;
    }
    let seq = SEQ.fetch_add(1, Relaxed);
    if seq % n != 0 {
        return false;
    }
    ACTIVE.with(|a| a.set(true));
    CUR_SEQ.with(|c| c.set(seq));
    STAGE_NS.with(|s| *s.borrow_mut() = [0; Stage::COUNT]);
    true
}

/// True when the current thread is recording a sampled query.
#[inline]
pub fn active() -> bool {
    super::enabled() && ACTIVE.with(|a| a.get())
}

/// Attribute `ns` nanoseconds to `stage` for the active query (no-op
/// when not sampled).
#[inline]
pub fn add_ns(stage: Stage, ns: u64) {
    if active() {
        STAGE_NS.with(|s| s.borrow_mut()[stage.idx()] += ns);
    }
}

/// RAII span: measures from construction to drop and attributes the
/// elapsed time to its stage. Inert (no clock read) when not sampled.
pub struct SpanGuard {
    live: Option<(Stage, Instant)>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((stage, start)) = self.live.take() {
            add_ns(stage, start.elapsed().as_nanos() as u64);
        }
    }
}

/// Open a span for `stage` on the active query.
#[inline]
pub fn span(stage: Stage) -> SpanGuard {
    if active() {
        SpanGuard { live: Some((stage, Instant::now())) }
    } else {
        SpanGuard { live: None }
    }
}

/// Total nanoseconds attributed so far on this thread's active query.
pub fn thread_ns() -> u64 {
    STAGE_NS.with(|s| s.borrow().iter().sum())
}

/// Abandon the active query without recording (panic/timeout paths).
pub fn discard() {
    ACTIVE.with(|a| a.set(false));
}

/// Finish the active query: attribute the unexplained remainder of
/// `total` to [`Stage::Other`], publish the trace to the ring buffer and
/// the per-stage histograms. No-op when this thread is not sampling.
pub fn end_query(total: Duration) {
    if !active() {
        return;
    }
    ACTIVE.with(|a| a.set(false));
    let total_ns = total.as_nanos() as u64;
    let mut stage_ns = STAGE_NS.with(|s| *s.borrow());
    let attributed: u64 =
        Stage::ALL.iter().filter(|s| !matches!(s, Stage::Admission)).map(|s| stage_ns[s.idx()]).sum();
    stage_ns[Stage::Other.idx()] += total_ns.saturating_sub(attributed);
    let trace =
        QueryTrace { seq: CUR_SEQ.with(|c| c.get()), stage_ns, total_ns: total_ns.max(attributed) };
    for s in Stage::ALL {
        let ns = trace.stage_ns[s.idx()];
        if ns > 0 {
            super::histogram("zann_stage_us", &[("stage", s.name())]).observe(ns / 1_000);
        }
    }
    let mut ring = RING.lock().unwrap_or_else(|e| e.into_inner());
    if ring.buf.len() < RING_CAP {
        ring.buf.push(trace);
    } else {
        let at = ring.next;
        ring.buf[at] = trace;
    }
    ring.next = (ring.next + 1) % RING_CAP;
    ring.recorded += 1;
}

/// Total traces ever recorded (including ones evicted from the ring).
pub fn recorded() -> u64 {
    RING.lock().unwrap_or_else(|e| e.into_inner()).recorded
}

/// Drain the ring buffer, oldest trace first.
pub fn take_spans() -> Vec<QueryTrace> {
    let mut ring = RING.lock().unwrap_or_else(|e| e.into_inner());
    let next = ring.next;
    let full = ring.buf.len() == RING_CAP;
    let mut buf = std::mem::take(&mut ring.buf);
    ring.next = 0;
    if full {
        buf.rotate_left(next);
    }
    buf
}

/// Render traces as a JSON array of per-stage timelines (nanoseconds);
/// zero-valued stages are omitted.
pub fn spans_json(traces: &[QueryTrace]) -> String {
    let mut out = String::from("[");
    for (i, t) in traces.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!(
            "{{\"seq\": {}, \"total_ns\": {}, \"stage_sum_ns\": {}, \"stages\": {{",
            t.seq,
            t.total_ns,
            t.stage_sum_ns()
        ));
        let mut first = true;
        for s in Stage::ALL {
            let ns = t.stage_ns[s.idx()];
            if ns > 0 {
                if !first {
                    out.push_str(", ");
                }
                out.push_str(&format!("\"{}\": {}", s.name(), ns));
                first = false;
            }
        }
        out.push_str("}}");
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // The tracer is global state (sampling divisor, sequence, ring), and
    // unit tests in this binary run concurrently — coordinator tests
    // serve real queries that would be sampled too once the divisor is
    // set. So: one combined test, marker values to recognise our own
    // traces, and >= assertions where other tests may interleave.
    #[test]
    fn tracer_lifecycle_sampling_ring_and_json() {
        // -- parse_sample contract --
        assert_eq!(parse_sample("1/8"), 8);
        assert_eq!(parse_sample("16"), 16);
        assert_eq!(parse_sample(" 1 / 4 "), 4);
        assert_eq!(parse_sample("0"), 0);
        assert_eq!(parse_sample("1/0"), 0);
        assert_eq!(parse_sample("2/4"), 0, "only 1/N numerators are accepted");
        assert_eq!(parse_sample("banana"), 0);
        assert_eq!(parse_sample(""), 0);

        // -- disabled: begin_query must refuse --
        set_sample(0);
        assert!(!begin_query());
        assert!(!active());
        add_ns(Stage::AdcScan, 999); // must be a no-op
        end_query(Duration::from_micros(5)); // must be a no-op

        if !crate::obs::enabled() {
            // obs-off: sampling can never activate, even at 1/1.
            set_sample(1);
            assert!(!begin_query());
            assert_eq!(sample(), 0);
            return;
        }

        // -- sample everything, record one marked trace --
        set_sample(1);
        assert_eq!(sample(), 1);
        assert!(begin_query());
        assert!(active());
        const MARK: u64 = 123_456_789_321;
        add_ns(Stage::CoarseQuantize, MARK);
        {
            let _g = span(Stage::AdcScan);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(thread_ns() >= MARK);
        end_query(Duration::from_nanos(MARK + 10_000_000));
        assert!(!active());

        let spans = take_spans();
        let mine = spans
            .iter()
            .find(|t| t.stage_ns[Stage::CoarseQuantize.idx()] == MARK)
            .expect("sampled trace must reach the ring");
        assert!(mine.stage_ns[Stage::AdcScan.idx()] > 0, "span guard must attribute time");
        // `Other` absorbs the remainder, so the stage sum matches e2e.
        assert_eq!(mine.stage_sum_ns(), mine.total_ns);
        assert!(recorded() >= 1);

        // -- discard drops the active query --
        assert!(begin_query());
        add_ns(Stage::CoarseQuantize, MARK);
        discard();
        end_query(Duration::from_micros(1)); // inert after discard
        assert!(
            !take_spans().iter().any(|t| t.stage_ns[Stage::CoarseQuantize.idx()] == MARK),
            "discarded trace must not be recorded"
        );

        // -- 1/N sampling thins the stream --
        set_sample(1_000_000_000);
        let picked = (0..64).filter(|_| begin_query()).count();
        for _ in 0..picked {
            discard();
        }
        assert!(picked <= 1, "divisor 1e9 must sample at most one of 64");

        // -- spans_json is well-formed and omits zero stages --
        let t = QueryTrace {
            seq: 7,
            stage_ns: {
                let mut a = [0u64; Stage::COUNT];
                a[Stage::QueueWait.idx()] = 100;
                a[Stage::AdcScan.idx()] = 250;
                a
            },
            total_ns: 350,
        };
        let js = spans_json(&[t]);
        assert!(js.contains("\"queue_wait\": 100"));
        assert!(js.contains("\"adc_scan\": 250"));
        assert!(!js.contains("beam_search"));
        assert!(js.contains("\"stage_sum_ns\": 350"));
        crate::obs::expo::check_json_shape(&js).expect("spans_json must be well-formed");
        assert_eq!(spans_json(&[]), "[]");

        // -- ring wraps at capacity, oldest evicted first --
        for i in 0..(RING_CAP as u64 + 5) {
            assert!(begin_query());
            add_ns(Stage::Reply, MARK + i);
            end_query(Duration::from_nanos(MARK + i));
        }
        let spans = take_spans();
        assert!(spans.len() <= RING_CAP);
        let ours: Vec<u64> = spans
            .iter()
            .map(|t| t.stage_ns[Stage::Reply.idx()])
            .filter(|&v| v >= MARK)
            .collect();
        // Oldest-first order within our own traces, and the first five
        // (evicted) markers are gone.
        assert!(ours.windows(2).all(|w| w[0] < w[1]), "drain must be oldest-first");
        assert!(*ours.first().unwrap() >= MARK + 5);

        set_sample(0); // restore: don't perturb concurrently-running tests
    }

    #[test]
    fn stage_names_are_unique_and_snake_case() {
        let mut seen = std::collections::HashSet::new();
        for s in Stage::ALL {
            assert!(seen.insert(s.name()), "duplicate stage name {}", s.name());
            assert!(
                s.name().chars().all(|c| c.is_ascii_lowercase() || c == '_'),
                "stage name {} must be snake_case",
                s.name()
            );
            assert_eq!(Stage::ALL[s.idx()].name(), s.name(), "idx() must match ALL order");
        }
    }
}
