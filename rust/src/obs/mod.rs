//! Observability: a lock-free metrics registry, a sampled pipeline-stage
//! tracer, and a Prometheus/JSON exposition layer.
//!
//! The paper's headline claims are quantitative ("no impact on accuracy
//! or search runtime"), so the serving stack must be able to say *where*
//! a query's time goes — coarse quantize vs. per-list decode vs. ADC
//! scan vs. top-k merge — and which codec/shard/tenant is responsible
//! for a regression, without perturbing the numbers it reports. Three
//! pieces, all cheap enough for the hot path:
//!
//! * [`registry`] — a process-global [`Registry`] of relaxed-atomic
//!   [`Counter`]s, [`Gauge`]s and log₂-bucket [`Histogram`]s, registered
//!   by static name with labels (`codec`, `shard`, `tenant`, ...).
//!   Recording is a single relaxed atomic op; registration (rare) takes
//!   a mutex. Hot paths cache their handles per thread/struct so the
//!   steady state never touches the registry lock.
//! * [`trace`] — per-query pipeline-stage spans (queue wait → coarse
//!   quantize → list decode → ADC scan / beam search → top-k merge →
//!   reply) recorded into a bounded ring buffer for a sampled subset of
//!   queries (`ZANN_TRACE_SAMPLE=1/N`), dumpable as per-stage JSON
//!   timelines. Unsampled queries pay one atomic load.
//! * [`expo`] — `Registry::render_prometheus()` / `render_json()`
//!   text exposition, plus the serde-free JSON shape check shared with
//!   the bench emitters.
//!
//! [`quantile`] holds the one nearest-rank percentile implementation the
//! coordinator metrics, the workload aggregator and the histogram
//! quantiles all share.
//!
//! With the `obs` cargo feature off (`--no-default-features`), nothing
//! registers on the global registry and the tracer never samples, so the
//! exposition renders empty, span dumps are never produced, and the
//! instrumentation sites compile down to no-ops (they gate on the const
//! [`enabled`]). Search results are bit-identical either way — the
//! instrumentation only *reads* timing and counts, never the data path.

pub mod expo;
pub mod hist;
pub mod quantile;
pub mod registry;
pub mod trace;

pub use hist::Histogram;
pub use registry::{Counter, Gauge, LabeledCounter, Registry, StaticCounter};

use std::sync::Arc;

/// True when the `obs` cargo feature is compiled in. A `const fn`, so
/// `if obs::enabled() { ... }` blocks fold away entirely in `obs`-off
/// builds — the promised "compiled to no-ops".
pub const fn enabled() -> bool {
    cfg!(feature = "obs")
}

/// The process-global registry behind [`counter`]/[`gauge`]/[`histogram`]
/// and the `zann metrics` / `zann serve` exposition.
pub fn global() -> &'static Registry {
    static GLOBAL: Registry = Registry::new();
    &GLOBAL
}

/// Get-or-register a counter on the global registry. With the `obs`
/// feature off this returns a functional but *unregistered* handle, so
/// callers that depend on their counters for correctness (coordinator
/// metrics) keep working while the exposition stays empty.
pub fn counter(name: &'static str, labels: &[(&'static str, &str)]) -> Arc<Counter> {
    if enabled() {
        global().counter(name, labels)
    } else {
        Arc::new(Counter::new())
    }
}

/// Get-or-register a gauge on the global registry (orphan when `obs` is
/// off, like [`counter`]).
pub fn gauge(name: &'static str, labels: &[(&'static str, &str)]) -> Arc<Gauge> {
    if enabled() {
        global().gauge(name, labels)
    } else {
        Arc::new(Gauge::new())
    }
}

/// Get-or-register a histogram on the global registry (orphan when `obs`
/// is off, like [`counter`]).
pub fn histogram(name: &'static str, labels: &[(&'static str, &str)]) -> Arc<Histogram> {
    if enabled() {
        global().histogram(name, labels)
    } else {
        Arc::new(Histogram::new())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_helpers_return_shared_handles_when_enabled() {
        let a = counter("zann_obs_mod_test_total", &[("case", "shared")]);
        let b = counter("zann_obs_mod_test_total", &[("case", "shared")]);
        a.add(3);
        b.add(4);
        if enabled() {
            assert_eq!(a.get(), b.get(), "same (name, labels) must share one cell");
            assert_eq!(a.get(), 7);
        } else {
            assert_eq!(a.get(), 3);
            assert_eq!(b.get(), 4);
        }
    }

    #[test]
    fn distinct_labels_are_distinct_series() {
        let a = counter("zann_obs_mod_test_total", &[("case", "x")]);
        let b = counter("zann_obs_mod_test_total", &[("case", "y")]);
        a.inc();
        assert_eq!(b.get(), 0, "different label values must not alias");
    }
}
