//! Exposition: Prometheus text format and JSON rendering for the
//! registry, plus the serde-free JSON shape check shared with the bench
//! emitters.

use super::hist::{Histogram, BUCKETS};
use super::registry::{Metric, Registry};

/// Escape a label value for the Prometheus text format: backslash,
/// double quote and newline must be escaped, nothing else.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Escape a string for embedding in a JSON string literal.
pub fn escape_json(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            _ => out.push(c),
        }
    }
    out
}

fn prom_labels(labels: &[(&'static str, String)], extra: Option<(&str, &str)>) -> String {
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{}=\"{}\"", k, escape_label(v))).collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{}=\"{}\"", k, escape_label(v)));
    }
    format!("{{{}}}", parts.join(","))
}

/// Flattened snapshot of one series, decoupled from the registry lock.
enum Snap {
    Counter(u64),
    Gauge(i64),
    Histogram { buckets: [u64; BUCKETS], count: u64, sum: u64 },
}

impl Snap {
    fn type_name(&self) -> &'static str {
        match self {
            Snap::Counter(_) => "counter",
            Snap::Gauge(_) => "gauge",
            Snap::Histogram { .. } => "histogram",
        }
    }
}

fn snapshot(reg: &Registry) -> Vec<(&'static str, Vec<(&'static str, String)>, Snap)> {
    let entries = reg.entries();
    let mut out: Vec<(&'static str, Vec<(&'static str, String)>, Snap)> = entries
        .iter()
        .map(|e| {
            let snap = match &e.metric {
                Metric::Counter(c) => Snap::Counter(c.get()),
                Metric::CounterRef(c) => Snap::Counter(c.get()),
                Metric::Gauge(g) => Snap::Gauge(g.get()),
                Metric::Histogram(h) => {
                    Snap::Histogram { buckets: h.snapshot(), count: h.count(), sum: h.sum() }
                }
            };
            (e.name, e.labels.clone(), snap)
        })
        .collect();
    drop(entries);
    // Deterministic output: sort by name then label values; registration
    // order is load-dependent.
    out.sort_by(|a, b| a.0.cmp(b.0).then_with(|| a.1.cmp(&b.1)));
    out
}

impl Registry {
    /// Render every registered series in the Prometheus text exposition
    /// format: one `# TYPE` line per metric name, then its samples.
    /// Histograms emit cumulative `_bucket{le=...}` samples for occupied
    /// buckets plus `le="+Inf"`, `_sum`, and `_count`.
    pub fn render_prometheus(&self) -> String {
        let snaps = snapshot(self);
        let mut out = String::new();
        let mut last_name = "";
        for (name, labels, snap) in &snaps {
            if *name != last_name {
                out.push_str(&format!("# TYPE {} {}\n", name, snap.type_name()));
                last_name = name;
            }
            match snap {
                Snap::Counter(v) => {
                    out.push_str(&format!("{}{} {}\n", name, prom_labels(labels, None), v));
                }
                Snap::Gauge(v) => {
                    out.push_str(&format!("{}{} {}\n", name, prom_labels(labels, None), v));
                }
                Snap::Histogram { buckets, count, sum } => {
                    let mut cum = 0u64;
                    for (i, &b) in buckets.iter().enumerate() {
                        cum += b;
                        // The catch-all bucket is covered by the
                        // explicit `+Inf` sample below.
                        if b == 0 || i == BUCKETS - 1 {
                            continue;
                        }
                        let le = Histogram::bucket_upper_bound(i).to_string();
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            name,
                            prom_labels(labels, Some(("le", &le))),
                            cum
                        ));
                    }
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        name,
                        prom_labels(labels, Some(("le", "+Inf"))),
                        count
                    ));
                    out.push_str(&format!("{}_sum{} {}\n", name, prom_labels(labels, None), sum));
                    out.push_str(&format!("{}_count{} {}\n", name, prom_labels(labels, None), count));
                }
            }
        }
        out
    }

    /// Render every registered series as a JSON object:
    /// `{"series": [{"name": ..., "labels": {...}, "type": ...,
    /// "value"|"count"/"sum"/"p50"/"p95"/"p99": ...}, ...]}`.
    pub fn render_json(&self) -> String {
        let snaps = snapshot(self);
        let mut out = String::from("{\"series\": [");
        for (i, (name, labels, snap)) in snaps.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("{{\"name\": \"{}\"", escape_json(name)));
            if !labels.is_empty() {
                out.push_str(", \"labels\": {");
                for (j, (k, v)) in labels.iter().enumerate() {
                    if j > 0 {
                        out.push_str(", ");
                    }
                    out.push_str(&format!("\"{}\": \"{}\"", escape_json(k), escape_json(v)));
                }
                out.push('}');
            }
            out.push_str(&format!(", \"type\": \"{}\"", snap.type_name()));
            match snap {
                Snap::Counter(v) => out.push_str(&format!(", \"value\": {}", v)),
                Snap::Gauge(v) => out.push_str(&format!(", \"value\": {}", v)),
                Snap::Histogram { buckets, count, sum } => {
                    let q = |qv: f64| quantile_of(buckets, qv);
                    out.push_str(&format!(
                        ", \"count\": {}, \"sum\": {}, \"p50\": {}, \"p95\": {}, \"p99\": {}",
                        count,
                        sum,
                        q(0.50),
                        q(0.95),
                        q(0.99)
                    ));
                }
            }
            out.push('}');
        }
        out.push_str("]}");
        out
    }
}

/// Nearest-rank quantile over a raw bucket snapshot (upper-bound
/// convention, matching [`Histogram::quantile`]).
fn quantile_of(buckets: &[u64; BUCKETS], q: f64) -> u64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0;
    }
    let rank = super::quantile::nearest_rank_index(total as usize, q) as u64;
    let mut seen = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        seen += c;
        if c > 0 && rank < seen {
            return Histogram::bucket_upper_bound(i);
        }
    }
    Histogram::bucket_upper_bound(BUCKETS - 1)
}

/// Serde-free JSON well-formedness check: balanced braces/brackets
/// outside string literals, valid string escapes tracked, and no
/// trailing commas before a closer. Shared by the bench emitters' tests
/// and the exposition tests — it catches the classes of bug hand-rolled
/// JSON writers actually have, without needing a parser dependency.
pub fn check_json_shape(s: &str) -> Result<(), String> {
    let mut stack: Vec<char> = Vec::new();
    let mut in_string = false;
    let mut escaped = false;
    let mut last_significant = ' ';
    for (i, c) in s.char_indices() {
        if in_string {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_string = false;
            }
            continue;
        }
        match c {
            '"' => in_string = true,
            '{' => stack.push('}'),
            '[' => stack.push(']'),
            '}' | ']' => {
                if last_significant == ',' {
                    return Err(format!("trailing comma before `{}` at byte {}", c, i));
                }
                match stack.pop() {
                    Some(want) if want == c => {}
                    Some(want) => return Err(format!("expected `{}` but found `{}` at byte {}", want, c, i)),
                    None => return Err(format!("unmatched `{}` at byte {}", c, i)),
                }
            }
            _ => {}
        }
        if !c.is_whitespace() {
            last_significant = c;
        }
    }
    if in_string {
        return Err("unterminated string".to_string());
    }
    if !stack.is_empty() {
        return Err(format!("{} unclosed bracket(s)", stack.len()));
    }
    if s.trim().is_empty() {
        return Err("empty document".to_string());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prometheus_type_line_precedes_samples_and_labels_escape() {
        let r = Registry::new();
        r.counter("t_requests_total", &[("tenant", "a\"b\\c\nd")]).add(5);
        r.gauge("t_depth", &[]).set(-2);
        let text = r.render_prometheus();
        let lines: Vec<&str> = text.lines().collect();
        let type_idx = lines
            .iter()
            .position(|l| *l == "# TYPE t_requests_total counter")
            .expect("TYPE line present");
        let sample_idx = lines
            .iter()
            .position(|l| l.starts_with("t_requests_total{"))
            .expect("sample line present");
        assert!(type_idx < sample_idx, "# TYPE must precede its samples");
        assert!(
            text.contains("t_requests_total{tenant=\"a\\\"b\\\\c\\nd\"} 5"),
            "label escaping: got {text}"
        );
        assert!(text.contains("# TYPE t_depth gauge"));
        assert!(text.contains("t_depth -2"));
    }

    #[test]
    fn prometheus_histogram_buckets_are_cumulative_with_inf() {
        let r = Registry::new();
        let h = r.histogram("t_lat_us", &[("shard", "0")]);
        for v in [1u64, 2, 3, 100] {
            h.observe(v);
        }
        let text = r.render_prometheus();
        // buckets: b1 (v=1) cum 1; b2 (2,3) cum 3; b7 (100) cum 4.
        assert!(text.contains("t_lat_us_bucket{shard=\"0\",le=\"1\"} 1"), "{text}");
        assert!(text.contains("t_lat_us_bucket{shard=\"0\",le=\"3\"} 3"), "{text}");
        assert!(text.contains("t_lat_us_bucket{shard=\"0\",le=\"127\"} 4"), "{text}");
        assert!(text.contains("t_lat_us_bucket{shard=\"0\",le=\"+Inf\"} 4"), "{text}");
        assert!(text.contains("t_lat_us_sum{shard=\"0\"} 106"), "{text}");
        assert!(text.contains("t_lat_us_count{shard=\"0\"} 4"), "{text}");
        // Cumulative counts must be monotone in emission order.
        let mut prev = 0u64;
        for l in text.lines().filter(|l| l.starts_with("t_lat_us_bucket")) {
            let v: u64 = l.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "bucket counts must be cumulative: {l}");
            prev = v;
        }
    }

    #[test]
    fn render_json_is_wellformed_and_quantiles_match_live() {
        let r = Registry::new();
        r.counter("j_total", &[("codec", "elias-fano")]).add(7);
        let h = r.histogram("j_us", &[]);
        for v in 1..=100u64 {
            h.observe(v);
        }
        let js = r.render_json();
        check_json_shape(&js).expect("render_json must be well-formed");
        assert!(js.contains("\"name\": \"j_total\""));
        assert!(js.contains("\"labels\": {\"codec\": \"elias-fano\"}"));
        assert!(js.contains("\"value\": 7"));
        assert!(js.contains("\"p50\": 63"), "JSON quantiles must match Histogram::quantile: {js}");
        assert!(js.contains("\"p95\": 127"));
        assert!(js.contains("\"count\": 100"));
        assert_eq!(h.quantile(0.5), 63);
    }

    #[test]
    fn empty_registry_renders_empty_exposition() {
        let r = Registry::new();
        assert_eq!(r.render_prometheus(), "");
        let js = r.render_json();
        check_json_shape(&js).unwrap();
        assert_eq!(js, "{\"series\": []}");
    }

    #[test]
    fn json_shape_checker_accepts_good_and_rejects_bad() {
        check_json_shape("{\"a\": [1, 2, {\"b\": \"}]\"}]}").expect("braces in strings are fine");
        check_json_shape("{\"esc\": \"a\\\"b\"}").expect("escaped quotes are fine");
        assert!(check_json_shape("{\"a\": 1,}").is_err(), "trailing comma");
        assert!(check_json_shape("[1, 2,\n]").is_err(), "trailing comma before newline-]");
        assert!(check_json_shape("{\"a\": [1}").is_err(), "mismatched closer");
        assert!(check_json_shape("{\"a\": 1").is_err(), "unclosed");
        assert!(check_json_shape("{\"a\": \"oops").is_err(), "unterminated string");
        assert!(check_json_shape("   ").is_err(), "empty document");
    }

    #[test]
    fn escape_json_handles_control_chars() {
        assert_eq!(escape_json("a\tb\nc\"d\\e"), "a\\tb\\nc\\\"d\\\\e");
        assert_eq!(escape_json("\u{1}"), "\\u0001");
    }
}
