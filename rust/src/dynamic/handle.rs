//! Epoch-swapped publication of a [`DynamicIvf`] — mutate-while-serving
//! without reader locks.
//!
//! The coordinator holds an `Arc<dyn AnnIndex>` and searches through
//! `&self`; a mutable index therefore needs a publication layer. A
//! reader/writer lock around the whole index would stall every
//! in-flight query for the duration of a compaction. [`DynamicHandle`]
//! avoids that with RCU-style epochs:
//!
//! * the **writer side** owns the canonical [`DynamicIvf`] behind a
//!   writer-only mutex; `update` applies a mutation, then publishes a
//!   snapshot. Snapshots are cheap — segments are `Arc`-shared, only
//!   the write buffer and tombstone bitmap are copied;
//! * the **reader side** grabs the current epoch `Arc` (a mutex held
//!   for one pointer clone, never across a search) and runs the whole
//!   query against that immutable snapshot. A compaction publishing a
//!   new epoch never blocks or disturbs queries running on the old one;
//!   the old epoch is freed when its last query drops it.
//!
//! The handle implements [`AnnIndex`] itself, so
//! `Coordinator::start(Arc<DynamicHandle>, …)` serves a mutating index
//! through the exact same batcher/worker path as the static backends.
//! The coarse stage (centroids never change across epochs) is answered
//! from the handle's own copy, which keeps [`AnnIndex::coarse_info`]
//! borrowable without touching an epoch.

use super::DynamicIvf;
use crate::api::{AnnIndex, AnnScratch, CoarseInfo, IndexKind, IndexStats, QueryParams};
use anyhow::Result;
use std::sync::{Arc, Mutex};

pub struct DynamicHandle {
    /// Canonical mutable state; writers serialize here. Compaction runs
    /// inside this lock — readers never take it.
    writer: Mutex<DynamicIvf>,
    /// The published epoch; the lock is held only to clone/replace the
    /// `Arc`, never across a search.
    epoch: Mutex<Arc<DynamicIvf>>,
    /// Coarse stage, immutable across epochs.
    centroids: Arc<Vec<f32>>,
    centroid_norms: Arc<Vec<f32>>,
    dim: usize,
    k: usize,
}

impl DynamicHandle {
    pub fn new(index: DynamicIvf) -> DynamicHandle {
        let centroids = index.centroids_arc();
        let centroid_norms = index.centroid_norms_arc();
        let dim = index.dim();
        let k = index.num_clusters();
        let epoch = Mutex::new(Arc::new(index.clone()));
        DynamicHandle { writer: Mutex::new(index), epoch, centroids, centroid_norms, dim, k }
    }

    /// The current published snapshot (what queries see).
    pub fn load(&self) -> Arc<DynamicIvf> {
        self.epoch.lock().unwrap().clone()
    }

    /// Apply a mutation to the canonical index, then publish a fresh
    /// epoch. Concurrent `update` calls serialize; concurrent queries
    /// keep running on the previous epoch until the swap.
    pub fn update<R>(&self, f: impl FnOnce(&mut DynamicIvf) -> R) -> R {
        let mut w = self.writer.lock().unwrap();
        let r = f(&mut w);
        let snap = Arc::new(w.clone());
        *self.epoch.lock().unwrap() = snap;
        r
    }

    /// Convenience wrappers over [`DynamicHandle::update`]. Each
    /// `update` publishes one snapshot (cloning the write buffer and
    /// tombstone bitmap), so batch mutations should go through one call
    /// — `add` already takes a whole batch of rows, and bulk deletes
    /// should use [`DynamicHandle::delete_many`], not `delete` in a
    /// loop.
    pub fn add(&self, rows: &[f32]) -> Result<std::ops::Range<u32>> {
        self.update(|idx| idx.add(rows))
    }

    pub fn delete(&self, id: u32) -> Result<bool> {
        self.update(|idx| idx.delete(id))
    }

    /// Tombstone a batch of ids under one writer lock and publish a
    /// single epoch. Returns how many were live (unknown/already-dead
    /// ids are skipped, like [`DynamicIvf::delete`]).
    pub fn delete_many(&self, ids: impl IntoIterator<Item = u32>) -> Result<usize> {
        self.update(|idx| {
            let mut deleted = 0usize;
            for id in ids {
                if idx.delete(id)? {
                    deleted += 1;
                }
            }
            Ok(deleted)
        })
    }

    pub fn compact(&self) -> Result<()> {
        self.update(|idx| idx.compact())
    }
}

impl AnnIndex for DynamicHandle {
    fn kind(&self) -> IndexKind {
        IndexKind::DynamicIvf
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.load().live()
    }

    fn stats(&self) -> IndexStats {
        self.load().stats()
    }

    fn search_into(
        &self,
        query: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        let epoch = self.load();
        DynamicIvf::search_into(epoch.as_ref(), query, &params.ivf(), &mut scratch.ivf, out);
    }

    fn coarse_info(&self) -> Option<CoarseInfo<'_>> {
        Some(CoarseInfo { centroids: &self.centroids, norms: &self.centroid_norms, k: self.k })
    }

    fn search_with_coarse_into(
        &self,
        query: &[f32],
        coarse: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        let epoch = self.load();
        DynamicIvf::search_with_coarse_into(
            epoch.as_ref(),
            query,
            coarse,
            &params.ivf(),
            &mut scratch.ivf,
            out,
        );
    }

    fn to_bytes(&self) -> Result<Vec<u8>> {
        self.load().to_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, ServeConfig};
    use crate::datasets::{generate, Kind};
    use crate::dynamic::{CompactionPolicy, DynamicBuildParams};
    use crate::index::{IvfBuildParams, SearchParams, SearchScratch};
    use std::time::Duration;

    #[test]
    fn updates_publish_and_readers_see_snapshots() {
        let ds = generate(Kind::DeepLike, 1500, 10, 8, 55);
        let idx = DynamicIvf::build(
            &ds.data[..1000 * ds.dim],
            ds.dim,
            &DynamicBuildParams {
                ivf: IvfBuildParams {
                    k: 16,
                    id_codec: "roc".into(),
                    threads: 2,
                    ..Default::default()
                },
                policy: CompactionPolicy { auto: false, ..Default::default() },
            },
        )
        .unwrap();
        let handle = DynamicHandle::new(idx);
        let before = handle.load();
        assert_eq!(before.live(), 1000);
        let range = handle.add(&ds.data[1000 * ds.dim..1200 * ds.dim]).unwrap();
        assert_eq!(range, 1000..1200);
        // The old epoch is genuinely frozen; the new one sees the adds.
        assert_eq!(before.live(), 1000);
        assert_eq!(handle.load().live(), 1200);
        assert!(handle.delete(3).unwrap());
        handle.compact().unwrap();
        assert_eq!(handle.load().live(), 1199);
        assert_eq!(handle.load().num_segments(), 1);
        // Search on the retained pre-add epoch still works (no ABA, no
        // torn state) and returns only pre-add ids.
        let mut s = SearchScratch::default();
        let hits = before.search(ds.query(0), &SearchParams { nprobe: 8, k: 5 }, &mut s);
        assert!(hits.iter().all(|&(_, id)| id < 1000));
    }

    #[test]
    fn coordinator_serves_a_mutating_dynamic_index() {
        let ds = generate(Kind::DeepLike, 1600, 30, 8, 56);
        let idx = DynamicIvf::build(
            &ds.data[..1200 * ds.dim],
            ds.dim,
            &DynamicBuildParams {
                ivf: IvfBuildParams {
                    k: 16,
                    id_codec: "roc".into(),
                    threads: 2,
                    ..Default::default()
                },
                policy: CompactionPolicy { flush_rows: 100, auto: true, ..Default::default() },
            },
        )
        .unwrap();
        let handle = Arc::new(DynamicHandle::new(idx));
        let cfg = ServeConfig {
            batch_size: 8,
            max_wait: Duration::from_millis(1),
            search: QueryParams { nprobe: 8, k: 5, ..Default::default() },
            scan_threads: 2,
            ..Default::default()
        };
        let coord = Coordinator::start(handle.clone(), None, cfg);
        // Interleave serving with mutations (including a compaction).
        let queries: Vec<Vec<f32>> = (0..ds.nq).map(|qi| ds.query(qi).to_vec()).collect();
        let r1 = coord.client.search_many(queries[..10].to_vec()).unwrap();
        handle.add(&ds.data[1200 * ds.dim..1600 * ds.dim]).unwrap();
        assert_eq!(handle.delete_many(0..100u32).unwrap(), 100);
        assert_eq!(handle.delete_many(0..100u32).unwrap(), 0, "already dead");
        handle.compact().unwrap();
        let r2 = coord.client.search_many(queries[10..].to_vec()).unwrap();
        assert_eq!(r1.len() + r2.len(), ds.nq);
        // Post-compaction responses must match a direct search on the
        // current epoch and never serve a tombstoned id.
        let epoch = handle.load();
        let sp = SearchParams { nprobe: 8, k: 5 };
        let mut s = SearchScratch::default();
        for (i, resp) in r2.iter().enumerate() {
            let qi = 10 + i;
            let want = epoch.search(ds.query(qi), &sp, &mut s);
            assert_eq!(resp.results, want, "query {qi}");
            assert!(resp.results.iter().all(|&(_, id)| id >= 100));
        }
        coord.stop();
    }
}
