//! Mutable IVF: LSM-style segmented ingestion over compressed id
//! storage.
//!
//! The paper's codecs assume a frozen set of ids per inverted list; a
//! serving system sees inserts and deletes. [`DynamicIvf`] keeps both
//! properties by wrapping the static [`IvfIndex`] layout in an LSM-like
//! structure:
//!
//! * the bulk of every inverted list lives in immutable **compressed
//!   [`Segment`]s** (any registered per-list [`CodecSpec`] — the initial
//!   segment adopts a static build's streams verbatim);
//! * fresh inserts land in a small uncompressed **[`WriteBuffer`]**,
//!   sealed into a new segment once it exceeds the
//!   [`CompactionPolicy::flush_rows`] threshold;
//! * deletes set a bit in a **[`Tombstones`]** bitmap; search filters
//!   them out, so a delete is O(1) and never touches a compressed
//!   stream;
//! * the **compaction engine** ([`DynamicIvf::compact`]) merges segments
//!   + buffer, drops tombstoned rows, and re-encodes each cluster on the
//!   `util::pool` workers. Re-encoding happens in a *rank space* with
//!   the dead ids squeezed out (see [`segment::IdMap`]), so
//!   post-compaction bits/id matches a from-scratch static build over
//!   the live set — compression does not decay under churn.
//!
//! `DynamicIvf` implements [`AnnIndex`], so persistence, the CLI and the
//! batching coordinator serve it unchanged; [`DynamicHandle`] adds
//! epoch-swapped publication so compaction never blocks in-flight
//! queries.

pub mod handle;
pub mod persist;
pub mod segment;

pub use handle::DynamicHandle;
pub use segment::{IdMap, Segment, Tombstones, WriteBuffer};

use crate::api::{
    AnnIndex, AnnScratch, CoarseInfo, IndexKind, IndexStats, QueryParams, SegmentStats,
};
use crate::bitvec::RsBitVec;
use crate::codecs::{CodecSpec, DecodeScratch, PER_LIST_CODECS};
use crate::index::{IvfBuildParams, IvfIndex, SearchParams, SearchScratch, VectorMode};
use crate::obs::trace::{self, Stage};
use crate::quant::{coarse, kmeans, l2_sq};
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Maintenance thresholds for the LSM structure. `auto` maintenance
/// runs after every `add`/`delete`; an explicit [`DynamicIvf::flush`] /
/// [`DynamicIvf::compact`] is always available regardless.
#[derive(Clone, Copy, Debug)]
pub struct CompactionPolicy {
    /// Seal the write buffer into a compressed segment at this many rows.
    pub flush_rows: usize,
    /// Fully compact when the segment count exceeds this.
    pub max_segments: usize,
    /// Fully compact when tombstoned rows exceed this fraction of
    /// stored rows.
    pub max_dead_frac: f64,
    /// Whether `add`/`delete` trigger maintenance automatically.
    pub auto: bool,
}

impl Default for CompactionPolicy {
    fn default() -> Self {
        CompactionPolicy { flush_rows: 8192, max_segments: 8, max_dead_frac: 0.25, auto: true }
    }
}

/// Build parameters: the wrapped static build plus the LSM policy.
#[derive(Default)]
pub struct DynamicBuildParams {
    pub ivf: IvfBuildParams,
    pub policy: CompactionPolicy,
}

/// Result of a parity audit against a from-scratch static rebuild over
/// the same live id set ([`DynamicIvf::check_parity`]).
#[derive(Clone, Debug)]
pub struct Parity {
    pub queries: usize,
    /// Queries whose (distance, id) results matched the static build
    /// exactly (ids mapped through the live-set numbering).
    pub identical: usize,
    /// Compressed id payload per live id of the dynamic index.
    pub dynamic_bits_per_id: f64,
    /// `bits_per_id` of the freshly built static index.
    pub static_bits_per_id: f64,
}

/// A mutable IVF index: immutable compressed segments + write buffer +
/// tombstones, sharing the coarse quantizer (and search semantics) of
/// the static [`IvfIndex`] it wraps.
///
/// Snapshots are cheap ([`Clone`]): segments are `Arc`-shared, only the
/// write buffer and tombstone bitmap are copied — the substrate of
/// [`DynamicHandle`]'s epoch swapping.
#[derive(Clone)]
pub struct DynamicIvf {
    dim: usize,
    k: usize,
    centroids: Arc<Vec<f32>>,
    centroid_norms: Arc<Vec<f32>>,
    spec: CodecSpec,
    threads: usize,
    policy: CompactionPolicy,
    segments: Vec<Arc<Segment>>,
    buffer: WriteBuffer,
    tombs: Tombstones,
    /// Next external id to assign; ids are never reused.
    next_id: u32,
    /// Tombstoned rows still physically present in segments/buffer.
    dead_stored: usize,
    /// False only when opened from a legacy v1 container (no per-section
    /// CRCs on disk); surfaced through `IndexStats::checksummed`.
    pub(crate) checksummed: bool,
}

impl DynamicIvf {
    /// Build from row-major `data`: a static build whose compressed
    /// streams become the first segment verbatim.
    pub fn build(data: &[f32], dim: usize, params: &DynamicBuildParams) -> Result<DynamicIvf> {
        let spec = CodecSpec::parse(&params.ivf.id_codec)?;
        ensure!(
            spec.is_per_list(),
            "dynamic indexes need a per-list id codec ({})",
            PER_LIST_CODECS.join("|")
        );
        ensure!(
            matches!(params.ivf.vectors, VectorMode::Flat),
            "dynamic indexes currently store Flat vectors"
        );
        let idx = IvfIndex::build(data, dim, &params.ivf);
        Self::from_static(idx, params.policy, params.ivf.threads)
    }

    /// Wrap an existing static index (Flat vectors, per-list codec): its
    /// id streams and vector rows are adopted as the initial segment
    /// without re-encoding. `threads` sizes the insert-assignment and
    /// compaction worker pools.
    pub fn from_static(
        idx: IvfIndex,
        policy: CompactionPolicy,
        threads: usize,
    ) -> Result<DynamicIvf> {
        let parts = idx.into_parts()?;
        let k = parts.k;
        let n = parts.n;
        let seg = Segment::from_parts(
            parts.blobs,
            parts.offsets,
            parts.vectors,
            parts.spec,
            n as u32,
            IdMap::Identity,
            parts.id_bits,
            parts.dim,
        )?;
        Ok(DynamicIvf {
            dim: parts.dim,
            k,
            centroids: Arc::new(parts.centroids),
            centroid_norms: Arc::new(parts.centroid_norms),
            spec: parts.spec,
            threads: threads.max(1),
            policy,
            segments: vec![Arc::new(seg)],
            buffer: WriteBuffer::new(k),
            tombs: Tombstones::default(),
            next_id: n as u32,
            dead_stored: 0,
            checksummed: true,
        })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn num_clusters(&self) -> usize {
        self.k
    }

    /// Live (searchable) vectors: assigned ids minus deletes.
    pub fn live(&self) -> usize {
        (self.next_id as u64 - self.tombs.count()) as usize
    }

    /// Rows physically stored (segments + buffer), including tombstoned
    /// ones not yet compacted away.
    pub fn stored_rows(&self) -> usize {
        self.segments.iter().map(|s| s.rows()).sum::<usize>() + self.buffer.rows
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    pub fn buffer_rows(&self) -> usize {
        self.buffer.rows
    }

    /// Tombstoned rows still stored (removed at the next compaction).
    pub fn dead_stored(&self) -> usize {
        self.dead_stored
    }

    pub fn next_id(&self) -> u32 {
        self.next_id
    }

    pub fn id_codec_name(&self) -> &str {
        self.spec.name()
    }

    pub fn policy(&self) -> CompactionPolicy {
        self.policy
    }

    pub fn set_policy(&mut self, policy: CompactionPolicy) {
        self.policy = policy;
    }

    /// Compressed + buffered id payload in bits.
    pub fn id_bits(&self) -> u64 {
        self.segments.iter().map(|s| s.id_bits()).sum::<u64>() + self.buffer.id_bits()
    }

    /// Id payload per live id.
    pub fn bits_per_id(&self) -> f64 {
        self.id_bits() as f64 / self.live().max(1) as f64
    }

    /// Insert row-major vectors; returns the external ids assigned
    /// (consecutive, never reused). May trigger a flush/compaction per
    /// the policy.
    pub fn add(&mut self, rows: &[f32]) -> Result<std::ops::Range<u32>> {
        ensure!(
            self.dim > 0 && rows.len() % self.dim == 0,
            "row buffer of {} floats is not a multiple of dim {}",
            rows.len(),
            self.dim
        );
        let n = rows.len() / self.dim;
        ensure!(
            self.next_id as u64 + n as u64 <= u32::MAX as u64,
            "id space exhausted ({} + {n} ids)",
            self.next_id
        );
        let assign = kmeans::assign(rows, self.dim, &self.centroids, self.threads);
        for (i, &c) in assign.iter().enumerate() {
            self.buffer.push(
                c as usize,
                self.next_id + i as u32,
                &rows[i * self.dim..(i + 1) * self.dim],
            );
        }
        let range = self.next_id..self.next_id + n as u32;
        self.next_id += n as u32;
        self.maintain()?;
        Ok(range)
    }

    /// Tombstone one id. Returns false (and changes nothing) when the
    /// id was never assigned or is already deleted.
    pub fn delete(&mut self, id: u32) -> Result<bool> {
        if id >= self.next_id || !self.tombs.set(id) {
            return Ok(false);
        }
        self.dead_stored += 1;
        self.maintain()?;
        Ok(true)
    }

    /// Whether `id` is currently searchable.
    pub fn is_live(&self, id: u32) -> bool {
        id < self.next_id && !self.tombs.get(id)
    }

    /// Every currently-searchable external id, ascending — the exact id
    /// universe a search can return, which is what the recall harness
    /// builds its post-churn groundtruth over.
    pub fn live_ids(&self) -> Vec<u32> {
        (0..self.next_id).filter(|&id| self.is_live(id)).collect()
    }

    fn maintain(&mut self) -> Result<()> {
        if !self.policy.auto {
            return Ok(());
        }
        if self.buffer.rows >= self.policy.flush_rows.max(1) {
            self.flush()?;
        }
        let stored = self.stored_rows();
        let dead_frac =
            if stored == 0 { 0.0 } else { self.dead_stored as f64 / stored as f64 };
        if self.segments.len() > self.policy.max_segments
            || dead_frac > self.policy.max_dead_frac
        {
            self.compact()?;
        }
        Ok(())
    }

    /// Seal the write buffer into a compressed segment (minor
    /// compaction). Tombstoned buffer rows are dropped on the way.
    pub fn flush(&mut self) -> Result<()> {
        if self.buffer.rows == 0 {
            return Ok(());
        }
        let dim = self.dim;
        let mut lists: Vec<Vec<u32>> = Vec::with_capacity(self.k);
        let mut vecs: Vec<Vec<f32>> = Vec::with_capacity(self.k);
        let mut dropped = 0usize;
        for c in 0..self.k {
            let bl = &self.buffer.lists[c];
            let bv = &self.buffer.vecs[c];
            let mut l = Vec::with_capacity(bl.len());
            let mut v = Vec::with_capacity(bv.len());
            for (o, &ext) in bl.iter().enumerate() {
                if self.tombs.get(ext) {
                    dropped += 1;
                    continue;
                }
                l.push(ext);
                v.extend_from_slice(&bv[o * dim..(o + 1) * dim]);
            }
            lists.push(l);
            vecs.push(v);
        }
        if lists.iter().any(|l| !l.is_empty()) {
            // Buffer ids are a subset of [0, next_id) with no holes to
            // squeeze (the streams are small and short-lived); encode
            // them directly under the identity map.
            let seg = Segment::build(
                &lists,
                self.next_id,
                dim,
                self.spec,
                IdMap::Identity,
                |c, pos| &vecs[c][pos * dim..(pos + 1) * dim],
                self.threads,
            )?;
            self.segments.push(Arc::new(seg));
        }
        self.dead_stored -= dropped;
        self.buffer.clear();
        Ok(())
    }

    /// Gather every live row in external-id order: per-cluster rank
    /// lists (sorted), rank-major vector rows, the external id of each
    /// rank, and the live bitvector (None when the id space has no
    /// holes, i.e. nothing was ever deleted).
    fn gather_live(&self) -> (Vec<Vec<u32>>, Vec<f32>, Vec<u32>, Option<RsBitVec>) {
        let dim = self.dim;
        let live_n = self.live();
        let live_bv = (self.tombs.count() > 0).then(|| self.tombs.live_bitvec(self.next_id));
        let rank = |ext: u32| -> usize {
            match &live_bv {
                Some(bv) => bv.rank1(ext as usize) as usize,
                None => ext as usize,
            }
        };
        let mut lists: Vec<Vec<u32>> = vec![Vec::new(); self.k];
        let mut rows = vec![0f32; live_n * dim];
        let mut ext_of = vec![0u32; live_n];
        let mut ids = Vec::new();
        let mut scratch = DecodeScratch::default();
        for seg in &self.segments {
            for c in 0..self.k {
                if seg.list_len(c) == 0 {
                    continue;
                }
                seg.decode_list_into(c, &mut ids, &mut scratch);
                let crows = seg.cluster_rows(c);
                for (o, &r) in ids.iter().enumerate() {
                    let ext = seg.ext_id(r);
                    if self.tombs.get(ext) {
                        continue;
                    }
                    let rk = rank(ext);
                    lists[c].push(rk as u32);
                    rows[rk * dim..(rk + 1) * dim]
                        .copy_from_slice(&crows[o * dim..(o + 1) * dim]);
                    ext_of[rk] = ext;
                }
            }
        }
        for c in 0..self.k {
            for (o, &ext) in self.buffer.lists[c].iter().enumerate() {
                if self.tombs.get(ext) {
                    continue;
                }
                let rk = rank(ext);
                lists[c].push(rk as u32);
                rows[rk * dim..(rk + 1) * dim]
                    .copy_from_slice(&self.buffer.vecs[c][o * dim..(o + 1) * dim]);
                ext_of[rk] = ext;
            }
        }
        for l in &mut lists {
            l.sort_unstable();
        }
        (lists, rows, ext_of, live_bv)
    }

    /// Major compaction: merge every segment and the write buffer into
    /// one segment holding only live rows, re-encoded through the codec
    /// registry over the squeezed rank universe. Runs the per-cluster
    /// re-encode data-parallel on the `util::pool` workers.
    ///
    /// The rank lists it encodes are exactly the lists a from-scratch
    /// static build over the live vectors would produce (same centroids,
    /// same assignment, live rows numbered in external-id order), so
    /// post-compaction `bits_per_id` matches the static build.
    pub fn compact(&mut self) -> Result<()> {
        let dim = self.dim;
        let (lists, rows, _ext_of, live_bv) = self.gather_live();
        let universe = match &live_bv {
            Some(bv) => bv.count_ones() as u32,
            None => self.next_id,
        };
        let map = match live_bv {
            Some(bv) => IdMap::Live(bv),
            None => IdMap::Identity,
        };
        let seg = Segment::build(
            &lists,
            universe,
            dim,
            self.spec,
            map,
            |c, pos| {
                let rk = lists[c][pos] as usize;
                &rows[rk * dim..(rk + 1) * dim]
            },
            self.threads,
        )?;
        self.segments = vec![Arc::new(seg)];
        self.buffer.clear();
        self.dead_stored = 0;
        Ok(())
    }

    /// Build a fresh static [`IvfIndex`] over the live vectors (same
    /// centroids, same codec). Returns the index plus the external id of
    /// each of its rows (`row i` ↔ `ext_of[i]`) — the audit baseline for
    /// [`DynamicIvf::check_parity`] and the churn bench.
    pub fn rebuild_static(&self) -> Result<(IvfIndex, Vec<u32>)> {
        let (_, rows, ext_of, _) = self.gather_live();
        let assign = kmeans::assign(&rows, self.dim, &self.centroids, self.threads);
        let params = IvfBuildParams {
            k: self.k,
            id_codec: self.spec.name().into(),
            vectors: VectorMode::Flat,
            threads: self.threads,
            ..Default::default()
        };
        let idx = IvfIndex::build_preassigned(
            &rows,
            self.dim,
            &self.centroids,
            &assign,
            &params,
            self.k,
        );
        Ok((idx, ext_of))
    }

    /// Audit search parity against a from-scratch static build over the
    /// same live set: for each query, dynamic results must equal the
    /// static results with row ids mapped back to external ids.
    pub fn check_parity(&self, queries: &[f32], sp: &SearchParams) -> Result<Parity> {
        ensure!(
            self.dim > 0 && queries.len() % self.dim == 0,
            "query buffer of {} floats is not a multiple of dim {}",
            queries.len(),
            self.dim
        );
        let (stat, ext_of) = self.rebuild_static()?;
        let nq = queries.len() / self.dim;
        let mut s_dyn = SearchScratch::default();
        let mut s_stat = SearchScratch::default();
        let (mut got, mut raw) = (Vec::new(), Vec::new());
        let mut identical = 0usize;
        for qi in 0..nq {
            let q = &queries[qi * self.dim..(qi + 1) * self.dim];
            self.search_into(q, sp, &mut s_dyn, &mut got);
            stat.search_into(q, sp, &mut s_stat, &mut raw);
            let want: Vec<(f32, u32)> =
                raw.iter().map(|&(d, id)| (d, ext_of[id as usize])).collect();
            if got == want {
                identical += 1;
            }
        }
        Ok(Parity {
            queries: nq,
            identical,
            dynamic_bits_per_id: self.bits_per_id(),
            static_bits_per_id: stat.bits_per_id(),
        })
    }

    /// Search with coarse distances computed internally.
    pub fn search(
        &self,
        query: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> Vec<(f32, u32)> {
        let mut out = Vec::with_capacity(p.k);
        self.search_into(query, p, scratch, &mut out);
        out
    }

    /// Buffer-reusing search (replaces `out`): scans the write buffer
    /// and every segment of each probed cluster, translating rank ids
    /// through the segment map and filtering tombstones in a batched
    /// pass ([`crate::simd::filter`]) ahead of the dense distance loop.
    pub fn search_into(
        &self,
        query: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        scratch.coarse.clear();
        scratch.coarse.resize(self.k, 0.0);
        coarse::dists_into(
            query,
            &self.centroids,
            self.dim,
            &self.centroid_norms,
            &mut scratch.coarse,
        );
        self.search_with_coarse_inner(query, p, scratch, out);
    }

    /// Search with externally supplied coarse distances (the batched
    /// coordinator path).
    pub fn search_with_coarse_into(
        &self,
        query: &[f32],
        coarse: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        assert_eq!(coarse.len(), self.k);
        scratch.coarse.clear();
        scratch.coarse.extend_from_slice(coarse);
        self.search_with_coarse_inner(query, p, scratch, out);
    }

    fn search_with_coarse_inner(
        &self,
        query: &[f32],
        p: &SearchParams,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        let dim = self.dim;
        let nprobe = p.nprobe.min(self.k);
        let SearchScratch { coarse, probe_order, ids, exts, keep, topk, winners, decode, .. } =
            scratch;
        // Best-first probe ordering, exactly as the static index does it
        // (same centroids ⇒ same probe set and order).
        probe_order.clear();
        probe_order.extend(0..self.k as u32);
        if nprobe > 0 && nprobe < self.k {
            probe_order.select_nth_unstable_by(nprobe - 1, |&a, &b| {
                coarse[a as usize].total_cmp(&coarse[b as usize])
            });
        }
        let probes = &mut probe_order[..nprobe];
        probes.sort_unstable_by(|&a, &b| coarse[a as usize].total_cmp(&coarse[b as usize]));

        topk.reset(p.k);
        // With no deletes ever, the tombstone bitmap is empty: skip the
        // filter phase outright. Otherwise each list is filtered in a
        // batch (8 bitmap tests per AVX2 gather, scalar elsewhere) and
        // the distance loop runs dense over the survivors — same
        // survivor order, identical results to the fused test-per-row
        // loop.
        let no_deletes = self.tombs.count() == 0;
        // Label-free decode-path counters: statics self-register on the
        // global registry at first use and are no-ops with obs off.
        static BUFFER_SCANS: crate::obs::StaticCounter =
            crate::obs::StaticCounter::new("zann_dynamic_buffer_scans_total");
        static SEGMENT_SEARCHES: crate::obs::StaticCounter =
            crate::obs::StaticCounter::new("zann_dynamic_segment_searches_total");
        for &c in probes.iter() {
            let c = c as usize;
            // Write buffer: uncompressed external ids.
            let bl = &self.buffer.lists[c];
            if !bl.is_empty() {
                BUFFER_SCANS.inc();
                let _span = trace::span(Stage::AdcScan);
                let bv = &self.buffer.vecs[c];
                if no_deletes {
                    for (o, &ext) in bl.iter().enumerate() {
                        let d = l2_sq(query, &bv[o * dim..(o + 1) * dim]);
                        if d < topk.threshold() {
                            topk.push(d, ext);
                        }
                    }
                } else {
                    crate::simd::filter::live_positions_into(self.tombs.words(), bl, keep);
                    for &o in keep.iter() {
                        let o = o as usize;
                        let d = l2_sq(query, &bv[o * dim..(o + 1) * dim]);
                        if d < topk.threshold() {
                            topk.push(d, bl[o]);
                        }
                    }
                }
            }
            // Immutable segments: bulk-decode the rank stream (tombstone
            // filtering needs every row's id anyway), batch-translate
            // through the segment map, batch-filter, then scan dense.
            for seg in &self.segments {
                let len = seg.list_len(c);
                if len == 0 {
                    continue;
                }
                SEGMENT_SEARCHES.inc();
                {
                    let _span = trace::span(Stage::ListDecode);
                    seg.decode_list_into(c, ids, decode);
                }
                let _span = trace::span(Stage::AdcScan);
                let rows = seg.cluster_rows(c);
                if no_deletes {
                    for (o, &r) in ids.iter().enumerate() {
                        let ext = seg.ext_id(r);
                        let d = l2_sq(query, &rows[o * dim..(o + 1) * dim]);
                        if d < topk.threshold() {
                            topk.push(d, ext);
                        }
                    }
                } else {
                    exts.clear();
                    match seg.map() {
                        IdMap::Identity => exts.extend_from_slice(ids),
                        IdMap::Live(_) => exts.extend(ids.iter().map(|&r| seg.ext_id(r))),
                    }
                    crate::simd::filter::live_positions_into(self.tombs.words(), exts, keep);
                    for &o in keep.iter() {
                        let o = o as usize;
                        let d = l2_sq(query, &rows[o * dim..(o + 1) * dim]);
                        if d < topk.threshold() {
                            topk.push(d, exts[o]);
                        }
                    }
                }
            }
        }
        let _span = trace::span(Stage::TopkMerge);
        topk.drain_sorted_into(winners);
        out.clear();
        out.extend(winners.iter().map(|&(d, pl)| (d, pl as u32)));
    }

    pub(crate) fn centroids_arc(&self) -> Arc<Vec<f32>> {
        self.centroids.clone()
    }

    pub(crate) fn centroid_norms_arc(&self) -> Arc<Vec<f32>> {
        self.centroid_norms.clone()
    }

    pub(crate) fn segments(&self) -> &[Arc<Segment>] {
        &self.segments
    }

    pub(crate) fn parts(
        &self,
    ) -> (&Arc<Vec<f32>>, &WriteBuffer, &Tombstones, CompactionPolicy, u32, usize) {
        (&self.centroids, &self.buffer, &self.tombs, self.policy, self.next_id, self.dead_stored)
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_open_parts(
        dim: usize,
        k: usize,
        centroids: Vec<f32>,
        spec: CodecSpec,
        policy: CompactionPolicy,
        segments: Vec<Arc<Segment>>,
        buffer: WriteBuffer,
        tombs: Tombstones,
        next_id: u32,
        dead_stored: usize,
    ) -> DynamicIvf {
        let centroid_norms = coarse::centroid_norms(&centroids, dim);
        DynamicIvf {
            dim,
            k,
            centroids: Arc::new(centroids),
            centroid_norms: Arc::new(centroid_norms),
            spec,
            threads: crate::util::pool::default_threads(),
            policy,
            segments,
            buffer,
            tombs,
            next_id,
            dead_stored,
            checksummed: true,
        }
    }
}

impl AnnIndex for DynamicIvf {
    fn kind(&self) -> IndexKind {
        IndexKind::DynamicIvf
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.live()
    }

    fn stats(&self) -> IndexStats {
        let segments: Vec<SegmentStats> = self
            .segments
            .iter()
            .map(|s| SegmentStats { rows: s.rows(), id_bits: s.id_bits(), map_bits: s.map_bits() })
            .collect();
        IndexStats {
            kind: IndexKind::DynamicIvf,
            n: self.live(),
            dim: self.dim,
            edges: 0,
            codec: self.spec.name().to_string(),
            id_bits: self.id_bits(),
            code_bits: self.stored_rows() as u64 * self.dim as u64 * 32,
            link_bits: 0,
            live: self.live(),
            deleted: self.dead_stored,
            buffer_rows: self.buffer.rows,
            aux_bits: self.tombs.size_bits(),
            checksummed: self.checksummed,
            segments,
        }
    }

    fn search_into(
        &self,
        query: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        DynamicIvf::search_into(self, query, &params.ivf(), &mut scratch.ivf, out);
    }

    fn coarse_info(&self) -> Option<CoarseInfo<'_>> {
        Some(CoarseInfo { centroids: &self.centroids, norms: &self.centroid_norms, k: self.k })
    }

    fn search_with_coarse_into(
        &self,
        query: &[f32],
        coarse: &[f32],
        params: &QueryParams,
        scratch: &mut AnnScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        DynamicIvf::search_with_coarse_into(
            self,
            query,
            coarse,
            &params.ivf(),
            &mut scratch.ivf,
            out,
        );
    }

    fn to_bytes(&self) -> Result<Vec<u8>> {
        persist::to_container_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{generate, Kind};
    use crate::util::Rng;

    fn build_dyn(n: usize, codec: &str, auto: bool) -> (crate::datasets::Dataset, DynamicIvf) {
        let ds = generate(Kind::DeepLike, n + n / 2, 30, 8, 97);
        let params = DynamicBuildParams {
            ivf: IvfBuildParams { k: 16, id_codec: codec.into(), threads: 2, ..Default::default() },
            policy: CompactionPolicy {
                flush_rows: 200,
                max_segments: 4,
                auto,
                ..Default::default()
            },
        };
        let idx = DynamicIvf::build(&ds.data[..n * ds.dim], ds.dim, &params).unwrap();
        (ds, idx)
    }

    #[test]
    fn fresh_dynamic_matches_static_exactly() {
        let (ds, idx) = build_dyn(2000, "roc", false);
        let stat = IvfIndex::build(
            &ds.data[..2000 * ds.dim],
            ds.dim,
            &IvfBuildParams { k: 16, id_codec: "roc".into(), threads: 2, ..Default::default() },
        );
        assert_eq!(idx.live(), 2000);
        assert_eq!(idx.num_segments(), 1);
        assert_eq!(idx.id_bits(), stat.id_bits(), "wrapped streams must be adopted verbatim");
        let sp = SearchParams { nprobe: 8, k: 10 };
        let mut s1 = SearchScratch::default();
        let mut s2 = SearchScratch::default();
        for qi in 0..ds.nq {
            assert_eq!(
                idx.search(ds.query(qi), &sp, &mut s1),
                stat.search(ds.query(qi), &sp, &mut s2),
                "query {qi}"
            );
        }
    }

    #[test]
    fn add_delete_search_filters_and_finds() {
        let (ds, mut idx) = build_dyn(1000, "roc", false);
        let sp = SearchParams { nprobe: 16, k: 5 };
        let mut scratch = SearchScratch::default();
        // A brand-new vector must be findable immediately (from the
        // write buffer), and gone right after delete.
        let probe: Vec<f32> = ds.data[7 * ds.dim..8 * ds.dim].to_vec();
        let range = idx.add(&probe).unwrap();
        let new_id = range.start;
        assert_eq!(new_id, 1000);
        assert_eq!(idx.live(), 1001);
        let hits = idx.search(&probe, &sp, &mut scratch);
        assert!(hits.iter().any(|&(_, id)| id == new_id), "fresh insert not found: {hits:?}");
        assert!(idx.delete(new_id).unwrap());
        assert!(!idx.delete(new_id).unwrap(), "double delete must be a no-op");
        assert!(!idx.delete(50_000).unwrap(), "unknown id must be a no-op");
        let hits = idx.search(&probe, &sp, &mut scratch);
        assert!(hits.iter().all(|&(_, id)| id != new_id), "tombstoned id served: {hits:?}");
        // The original near-duplicate (id 7) is still served.
        assert!(hits.iter().any(|&(_, id)| id == 7));
        assert_eq!(idx.live(), 1000);
    }

    #[test]
    fn flush_and_compact_preserve_results_for_every_codec() {
        for codec in PER_LIST_CODECS {
            let (ds, mut idx) = build_dyn(1200, codec, false);
            let extra = &ds.data[1200 * ds.dim..1500 * ds.dim];
            idx.add(extra).unwrap();
            let mut rng = Rng::new(4);
            for id in rng.sample_distinct(1200, 150) {
                assert!(idx.delete(id as u32).unwrap());
            }
            let sp = SearchParams { nprobe: 8, k: 10 };
            let mut s = SearchScratch::default();
            let before: Vec<_> =
                (0..ds.nq).map(|qi| idx.search(ds.query(qi), &sp, &mut s)).collect();
            idx.flush().unwrap();
            assert_eq!(idx.buffer_rows(), 0);
            assert_eq!(idx.num_segments(), 2);
            let after_flush: Vec<_> =
                (0..ds.nq).map(|qi| idx.search(ds.query(qi), &sp, &mut s)).collect();
            assert_eq!(before, after_flush, "{codec}: flush changed results");
            idx.compact().unwrap();
            assert_eq!(idx.num_segments(), 1);
            assert_eq!(idx.dead_stored(), 0);
            assert_eq!(idx.stored_rows(), idx.live());
            let after_compact: Vec<_> =
                (0..ds.nq).map(|qi| idx.search(ds.query(qi), &sp, &mut s)).collect();
            assert_eq!(before, after_compact, "{codec}: compaction changed results");
        }
    }

    #[test]
    fn auto_policy_flushes_and_compacts() {
        let (ds, mut idx) = build_dyn(1000, "roc", true);
        // 450 inserts at flush_rows=200 → at least two sealed segments.
        idx.add(&ds.data[1000 * ds.dim..1450 * ds.dim]).unwrap();
        assert!(idx.num_segments() >= 2, "segments={}", idx.num_segments());
        assert!(idx.buffer_rows() < 200);
        // Deleting well past max_dead_frac=0.25 must trigger compaction
        // (without it, all 500 tombstoned rows would still be stored).
        for id in 0..500u32 {
            idx.delete(id).unwrap();
        }
        assert_eq!(idx.num_segments(), 1, "compaction should have fired");
        assert!(idx.dead_stored() < 250, "dead_stored={}", idx.dead_stored());
        assert_eq!(idx.live(), 950);
    }

    #[test]
    fn acceptance_churn_parity_and_bits_per_id() {
        // The PR acceptance criterion: after 20% random deletes + 20%
        // inserts and a full compaction, search results are identical to
        // a fresh static build over the live set, and roc bits/id is
        // within 2% of the static build.
        let n = 4000usize;
        let ds = generate(Kind::DeepLike, n + n / 5, 40, 16, 31);
        let params = DynamicBuildParams {
            ivf: IvfBuildParams { k: 64, id_codec: "roc".into(), threads: 2, ..Default::default() },
            policy: CompactionPolicy { flush_rows: 300, auto: true, ..Default::default() },
        };
        let mut idx = DynamicIvf::build(&ds.data[..n * ds.dim], ds.dim, &params).unwrap();
        let mut rng = Rng::new(77);
        for id in rng.sample_distinct(n as u64, n / 5) {
            assert!(idx.delete(id as u32).unwrap());
        }
        idx.add(&ds.data[n * ds.dim..]).unwrap();
        idx.compact().unwrap();
        assert_eq!(idx.live(), n, "20% out, 20% in");
        let parity = idx
            .check_parity(&ds.queries, &SearchParams { nprobe: 16, k: 10 })
            .unwrap();
        assert_eq!(
            parity.identical, parity.queries,
            "{}/{} queries diverged from the static rebuild",
            parity.queries - parity.identical,
            parity.queries
        );
        let ratio = parity.dynamic_bits_per_id / parity.static_bits_per_id;
        assert!(
            (ratio - 1.0).abs() < 0.02,
            "post-compaction bits/id {} vs static {} (ratio {ratio})",
            parity.dynamic_bits_per_id,
            parity.static_bits_per_id
        );
    }

    #[test]
    fn trait_serving_matches_inherent_search() {
        let (ds, mut idx) = build_dyn(1500, "ef", false);
        idx.add(&ds.data[1500 * ds.dim..1800 * ds.dim]).unwrap();
        for id in 0..200u32 {
            idx.delete(id).unwrap();
        }
        let p = QueryParams { k: 10, nprobe: 8, ef: 0 };
        let dyn_idx: &dyn AnnIndex = &idx;
        assert_eq!(dyn_idx.len(), 1600);
        assert!(dyn_idx.coarse_info().is_some());
        let mut s = AnnScratch::default();
        let mut s2 = SearchScratch::default();
        let mut got = Vec::new();
        for qi in 0..ds.nq {
            dyn_idx.search_into(ds.query(qi), &p, &mut s, &mut got);
            let want = idx.search(ds.query(qi), &p.ivf(), &mut s2);
            assert_eq!(got, want, "query {qi}");
        }
        let stats = dyn_idx.stats();
        assert_eq!(stats.live, 1600);
        assert_eq!(stats.deleted, 200);
        assert_eq!(stats.segments.len() + usize::from(stats.buffer_rows > 0), 2);
        assert_eq!(stats.n, 1600);
    }
}
