//! Storage cells of the LSM-style mutable IVF: immutable compressed
//! [`Segment`]s, the uncompressed [`WriteBuffer`] that absorbs fresh
//! inserts, and the [`Tombstones`] bitmap that records deletes.
//!
//! A segment stores, per cluster, one compressed id stream (any per-list
//! [`IdCodec`] from the registry) plus the vector rows in the codec's
//! decode order — the same reordering invariance the static
//! [`crate::index::IvfIndex`] relies on. Ids inside a stream live in a
//! segment-local **rank space** translated to external ids by an
//! [`IdMap`]: the identity for segments sealed from a dense id prefix,
//! or `select1` over a frozen liveness bitmap for segments produced by
//! compaction after deletes. The rank indirection is what keeps the
//! compressed size at the static build's level — lists are re-encoded
//! over a universe of exactly the live ids, not the ever-growing
//! external id space with tombstone holes in it.

use crate::bitvec::RsBitVec;
use crate::codecs::{CodecSpec, DecodeScratch, IdCodec};
use crate::util::bits::BitBuf;
use crate::util::bytes::{Blobs, BlobsBuilder};
use crate::util::pool::parallel_map;
use anyhow::{ensure, Result};

/// Frozen rank → external-id translation of one segment.
pub enum IdMap {
    /// Rank space == external-id space (no holes at seal time).
    Identity,
    /// `ext = select1(rank)` over the liveness bitmap frozen at seal
    /// time (bit i set ⇔ external id i was live when the segment was
    /// encoded).
    Live(RsBitVec),
}

impl IdMap {
    /// Translate a decoded rank id to an external id.
    #[inline]
    pub fn ext(&self, rank: u32) -> u32 {
        match self {
            IdMap::Identity => rank,
            IdMap::Live(bv) => bv.select1(rank as u64).expect("rank within live universe") as u32,
        }
    }

    /// Auxiliary bits this map occupies (0 for the identity).
    pub fn size_bits(&self) -> u64 {
        match self {
            IdMap::Identity => 0,
            IdMap::Live(bv) => bv.size_bits() as u64,
        }
    }
}

/// One immutable compressed segment: per-cluster id streams + vector
/// rows in decode order.
pub struct Segment {
    /// One compressed rank-id stream per cluster (`k` blobs).
    blobs: Blobs,
    /// Cluster row boundaries (`k + 1` entries).
    offsets: Vec<usize>,
    /// Vector rows, cluster-major, in each stream's decode order.
    vectors: Vec<f32>,
    codec: Box<dyn IdCodec>,
    /// Rank-space size the streams were encoded against.
    universe: u32,
    map: IdMap,
    /// Exact compressed id payload in bits (sum over streams).
    id_bits: u64,
    dim: usize,
}

impl Segment {
    /// Encode per-cluster rank-id `lists` (each strictly ascending) into
    /// a sealed segment. `rows_for(c, pos)` returns the vector row of
    /// `lists[c][pos]`; rows are laid out in the codec's decode order,
    /// resolved back to list positions by binary search (the lists are
    /// sorted). Encoding is data-parallel over clusters on the
    /// `util::pool` workers — this is the compaction hot loop.
    pub fn build<'a, F>(
        lists: &[Vec<u32>],
        universe: u32,
        dim: usize,
        spec: CodecSpec,
        map: IdMap,
        rows_for: F,
        threads: usize,
    ) -> Result<Segment>
    where
        F: Fn(usize, usize) -> &'a [f32] + Sync,
    {
        let codec = spec.id_codec()?;
        let k = lists.len();
        let encoded: Vec<(crate::codecs::Encoded, Vec<f32>)> = parallel_map(k, threads, |c| {
            let l = &lists[c];
            debug_assert!(l.windows(2).all(|w| w[0] < w[1]), "cluster {c}: list not ascending");
            let enc = codec.encode(l, universe);
            let mut order = Vec::with_capacity(l.len());
            codec.decode(&enc.bytes, universe, l.len(), &mut order);
            let mut rows = Vec::with_capacity(l.len() * dim);
            for &v in &order {
                let pos = l.binary_search(&v).expect("decoded id not in encoded list");
                rows.extend_from_slice(rows_for(c, pos));
            }
            (enc, rows)
        });
        let mut blobs = BlobsBuilder::new();
        let mut offsets = Vec::with_capacity(k + 1);
        let mut vectors = Vec::with_capacity(lists.iter().map(|l| l.len()).sum::<usize>() * dim);
        let mut id_bits = 0u64;
        let mut acc = 0usize;
        for (c, (enc, rows)) in encoded.into_iter().enumerate() {
            offsets.push(acc);
            acc += lists[c].len();
            id_bits += enc.bits;
            blobs.push(&enc.bytes);
            vectors.extend_from_slice(&rows);
        }
        offsets.push(acc);
        Ok(Segment { blobs: blobs.finish(), offsets, vectors, codec, universe, map, id_bits, dim })
    }

    /// Reassemble a segment from already-encoded parts (the static-index
    /// wrap and the container-open paths: streams are adopted verbatim,
    /// never re-encoded).
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        blobs: Blobs,
        offsets: Vec<usize>,
        vectors: Vec<f32>,
        spec: CodecSpec,
        universe: u32,
        map: IdMap,
        id_bits: u64,
        dim: usize,
    ) -> Result<Segment> {
        let codec = spec.id_codec()?;
        ensure!(!offsets.is_empty(), "segment offset table is empty");
        ensure!(blobs.count() == offsets.len() - 1, "segment blob/offset count mismatch");
        let rows = *offsets.last().unwrap();
        ensure!(
            vectors.len() == rows * dim,
            "segment holds {} floats for {rows} rows of dim {dim}",
            vectors.len()
        );
        if let IdMap::Live(bv) = &map {
            ensure!(
                bv.count_ones() == universe as u64,
                "live map covers {} ids but the streams use universe {universe}",
                bv.count_ones()
            );
        }
        Ok(Segment { blobs, offsets, vectors, codec, universe, map, id_bits, dim })
    }

    pub fn num_clusters(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn rows(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    pub fn list_len(&self, c: usize) -> usize {
        self.offsets[c + 1] - self.offsets[c]
    }

    pub fn id_bits(&self) -> u64 {
        self.id_bits
    }

    pub fn map_bits(&self) -> u64 {
        self.map.size_bits()
    }

    pub fn universe(&self) -> u32 {
        self.universe
    }

    pub fn map(&self) -> &IdMap {
        &self.map
    }

    /// Translate a decoded rank to an external id.
    #[inline]
    pub fn ext_id(&self, rank: u32) -> u32 {
        self.map.ext(rank)
    }

    /// The vector rows of cluster `c` (decode order).
    #[inline]
    pub fn cluster_rows(&self, c: usize) -> &[f32] {
        &self.vectors[self.offsets[c] * self.dim..self.offsets[c + 1] * self.dim]
    }

    /// Decode cluster `c`'s rank ids into `out` (replacing its contents)
    /// through a reusable scratch — the search-path bulk decode.
    pub fn decode_list_into(&self, c: usize, out: &mut Vec<u32>, scratch: &mut DecodeScratch) {
        out.clear();
        self.codec.decode_into(self.blobs.get(c), self.universe, self.list_len(c), out, scratch);
    }

    /// Decode every cluster's id stream once through the fallible codec
    /// path, so structural corruption surfaces as an open-time error
    /// instead of a panic mid-query. Called when a legacy (unchecksummed)
    /// container is opened — checksummed containers already verified
    /// their bytes. A clean decode also proves every rank is inside the
    /// segment universe, which is exactly the [`IdMap::ext`] precondition.
    pub fn validate_decode(&self) -> Result<()> {
        use anyhow::Context as _;
        let mut scratch = DecodeScratch::default();
        let mut out = Vec::new();
        for c in 0..self.num_clusters() {
            out.clear();
            self.codec
                .try_decode_into(self.blobs.get(c), self.universe, self.list_len(c), &mut out, &mut scratch)
                .with_context(|| format!("cluster {c} id stream failed to decode"))?;
        }
        Ok(())
    }

    /// Serialization accessors (streams are written verbatim).
    pub fn blob_offsets(&self) -> &[u64] {
        self.blobs.offsets()
    }

    pub fn blob_payload(&self) -> &[u8] {
        self.blobs.payload()
    }

    pub fn row_offsets(&self) -> &[usize] {
        &self.offsets
    }

    pub fn vectors(&self) -> &[f32] {
        &self.vectors
    }
}

/// The mutable head of the LSM structure: per-cluster uncompressed id
/// lists + vector rows, appended on insert, sealed into a [`Segment`]
/// by `flush`.
#[derive(Clone, Default)]
pub struct WriteBuffer {
    /// External ids per cluster, in insertion (= ascending) order.
    pub lists: Vec<Vec<u32>>,
    /// Vector rows parallel to `lists`, per cluster.
    pub vecs: Vec<Vec<f32>>,
    pub rows: usize,
}

impl WriteBuffer {
    pub fn new(k: usize) -> WriteBuffer {
        WriteBuffer { lists: vec![Vec::new(); k], vecs: vec![Vec::new(); k], rows: 0 }
    }

    pub fn push(&mut self, cluster: usize, ext: u32, row: &[f32]) {
        self.lists[cluster].push(ext);
        self.vecs[cluster].extend_from_slice(row);
        self.rows += 1;
    }

    pub fn clear(&mut self) {
        for l in &mut self.lists {
            l.clear();
        }
        for v in &mut self.vecs {
            v.clear();
        }
        self.rows = 0;
    }

    /// Uncompressed id payload of the buffer in bits (32 per id — the
    /// honest cost of the mutable head, reported in the index stats).
    pub fn id_bits(&self) -> u64 {
        self.rows as u64 * 32
    }
}

/// Growable delete bitmap over the external id space. Bits are never
/// cleared: an id, once deleted, is dead forever (external ids are not
/// reused), which is what makes `get` a complete liveness test and
/// double-deletes detectable after the rows themselves were compacted
/// away.
#[derive(Clone, Default)]
pub struct Tombstones {
    words: Vec<u64>,
    count: u64,
}

impl Tombstones {
    pub fn from_parts(words: Vec<u64>, count: u64) -> Tombstones {
        Tombstones { words, count }
    }

    #[inline]
    pub fn get(&self, id: u32) -> bool {
        self.words.get(id as usize / 64).is_some_and(|w| (w >> (id % 64)) & 1 == 1)
    }

    /// Mark `id` deleted; returns false if it already was.
    pub fn set(&mut self, id: u32) -> bool {
        let w = id as usize / 64;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        let mask = 1u64 << (id % 64);
        if self.words[w] & mask != 0 {
            return false;
        }
        self.words[w] |= mask;
        self.count += 1;
        true
    }

    /// Total ids ever deleted (whether or not their rows still exist).
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn size_bits(&self) -> u64 {
        self.words.len() as u64 * 64
    }

    /// The complement bitvector over `[0, next_id)` with rank/select —
    /// the compaction-time rank map (rank(ext) = number of live ids
    /// below ext).
    pub fn live_bitvec(&self, next_id: u32) -> RsBitVec {
        let n = next_id as usize;
        let n_words = n.div_ceil(64);
        let mut words: Vec<u64> = (0..n_words)
            .map(|i| !self.words.get(i).copied().unwrap_or(0))
            .collect();
        if n % 64 != 0 {
            if let Some(last) = words.last_mut() {
                *last &= u64::MAX >> (64 - (n % 64));
            }
        }
        RsBitVec::new(BitBuf { words, len: n })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tombstones_set_get_count() {
        let mut t = Tombstones::default();
        assert!(!t.get(0));
        assert!(!t.get(1000));
        assert!(t.set(5));
        assert!(!t.set(5), "double delete must report false");
        assert!(t.set(200));
        assert_eq!(t.count(), 2);
        assert!(t.get(5) && t.get(200));
        assert!(!t.get(6));
    }

    #[test]
    fn live_bitvec_ranks_and_selects_around_holes() {
        let mut t = Tombstones::default();
        for id in [1u32, 3, 64, 65, 130] {
            assert!(t.set(id));
        }
        let next_id = 131u32;
        let bv = t.live_bitvec(next_id);
        assert_eq!(bv.len(), 131);
        assert_eq!(bv.count_ones(), 131 - 5);
        // rank(ext) skips the dead; select1(rank) inverts it.
        let mut rank = 0u64;
        for ext in 0..next_id {
            if t.get(ext) {
                assert!(!bv.get(ext as usize), "dead id {ext} marked live");
                continue;
            }
            assert_eq!(bv.rank1(ext as usize), rank, "rank of ext {ext}");
            assert_eq!(bv.select1(rank), Some(ext as usize), "select of rank {rank}");
            rank += 1;
        }
        assert_eq!(bv.select1(rank), None);
    }

    #[test]
    fn segment_build_roundtrips_ids_and_rows() {
        // Two clusters, rank universe 10, dim 2; rows keyed by rank value
        // so decode-order placement is checkable.
        let lists = vec![vec![0u32, 3, 7], vec![1u32, 9]];
        let dim = 2;
        let rows: Vec<f32> = (0..10 * dim).map(|i| i as f32).collect();
        for codec in ["unc64", "compact", "ef", "roc"] {
            let spec = CodecSpec::parse(codec).unwrap();
            let seg = Segment::build(
                &lists,
                10,
                dim,
                spec,
                IdMap::Identity,
                |c, pos| {
                    let r = lists[c][pos] as usize;
                    &rows[r * dim..(r + 1) * dim]
                },
                2,
            )
            .unwrap();
            assert_eq!(seg.num_clusters(), 2);
            assert_eq!(seg.rows(), 5);
            assert!(seg.id_bits() > 0);
            let mut out = Vec::new();
            let mut scratch = DecodeScratch::default();
            for c in 0..2 {
                seg.decode_list_into(c, &mut out, &mut scratch);
                let mut sorted = out.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, lists[c], "{codec}: cluster {c} id set");
                // Rows must follow decode order exactly.
                let crows = seg.cluster_rows(c);
                for (o, &r) in out.iter().enumerate() {
                    assert_eq!(
                        &crows[o * dim..(o + 1) * dim],
                        &rows[r as usize * dim..(r as usize + 1) * dim],
                        "{codec}: cluster {c} row {o}"
                    );
                }
            }
        }
    }
}
