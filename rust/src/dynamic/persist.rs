//! Container serialization for [`DynamicIvf`]: the multi-segment
//! extension of the zann format.
//!
//! A dynamic container is a `ZANN` file with kind
//! [`crate::api::persist::KIND_DYNAMIC`] and these sections:
//!
//! ```text
//! DHDR   dynamic-layout version, dim/k/next_id/dead_stored, codec
//!        spec, compaction policy, segment count
//! CENT   coarse centroids (norms are recomputed on open)
//! TOMB   tombstone bitmap (count + words)
//! WBUF   write buffer: per cluster, external ids + vector rows
//! S Hni  per segment i: universe, id bits, id map, row offsets,
//!        blob offsets
//! S Ini  per segment i: compressed id streams, written VERBATIM and
//!        reopened zero-copy as `Blobs` over the file buffer
//! S Vni  per segment i: vector rows in decode order
//! ```
//!
//! (`n`/`i` above are the two raw bytes of the segment index.) The
//! single-segment static containers (kind `KIND_IVF`, written by
//! `IvfIndex::save` before this module existed) are untouched: they
//! keep their layout, keep opening, and a static build is still saved
//! in that format. `DHDR` carries its own layout version so the
//! multi-segment section can evolve without breaking the outer
//! container framing.

use super::segment::{IdMap, Segment, Tombstones, WriteBuffer};
use super::{CompactionPolicy, DynamicIvf};
use crate::api::persist::{self, Container};
use crate::bitvec::RsBitVec;
use crate::codecs::CodecSpec;
use crate::util::bits::BitBuf;
use crate::util::bytes::Blobs;
use crate::util::{ReadBuf, WriteBuf};
use anyhow::{ensure, Context as _, Result};
use std::sync::Arc;

/// Version of the dynamic section layout (independent of the outer
/// container version, which only covers the magic/section framing).
pub const DYN_LAYOUT_VERSION: u32 = 1;

/// Section tag of segment `i`, part `b'H'` (header), `b'I'` (id
/// streams) or `b'V'` (vectors).
fn seg_tag(part: u8, i: usize) -> [u8; 4] {
    [b'S', part, (i >> 8) as u8, (i & 0xff) as u8]
}

pub(crate) fn to_container_bytes(idx: &DynamicIvf) -> Result<Vec<u8>> {
    let (centroids, buffer, tombs, policy, next_id, dead_stored) = idx.parts();
    let segments = idx.segments();
    ensure!(segments.len() <= u16::MAX as usize, "too many segments ({})", segments.len());

    let mut head = WriteBuf::new();
    head.put_u32(DYN_LAYOUT_VERSION);
    head.put_u64(idx.dim() as u64);
    head.put_u64(idx.num_clusters() as u64);
    head.put_u64(next_id as u64);
    head.put_u64(dead_stored as u64);
    head.put_u64(segments.len() as u64);
    head.put_str(idx.id_codec_name());
    head.put_u64(policy.flush_rows as u64);
    head.put_u64(policy.max_segments as u64);
    // f64 bit pattern, so the policy round-trips exactly.
    head.put_u64(policy.max_dead_frac.to_bits());
    head.put_u8(policy.auto as u8);

    let mut file = persist::file_header(persist::KIND_DYNAMIC);
    persist::push_section(&mut file, b"DHDR", &head.bytes);

    let mut cent = WriteBuf::new();
    cent.put_f32s(centroids);
    persist::push_section(&mut file, b"CENT", &cent.bytes);

    let mut tw = WriteBuf::new();
    tw.put_u64(tombs.count());
    tw.put_u64s(tombs.words());
    persist::push_section(&mut file, b"TOMB", &tw.bytes);

    let mut bw = WriteBuf::new();
    for c in 0..idx.num_clusters() {
        bw.put_u32s(&buffer.lists[c]);
        bw.put_f32s(&buffer.vecs[c]);
    }
    persist::push_section(&mut file, b"WBUF", &bw.bytes);

    for (i, seg) in segments.iter().enumerate() {
        let mut sh = WriteBuf::new();
        sh.put_u32(seg.universe());
        sh.put_u64(seg.id_bits());
        match seg.map() {
            IdMap::Identity => sh.put_u8(0),
            IdMap::Live(bv) => {
                sh.put_u8(1);
                sh.put_u64(bv.len() as u64);
                sh.put_u64s(bv.words());
            }
        }
        sh.put_u64s(&seg.row_offsets().iter().map(|&o| o as u64).collect::<Vec<u64>>());
        sh.put_u64s(seg.blob_offsets());
        persist::push_section(&mut file, &seg_tag(b'H', i), &sh.bytes);
        persist::push_section(&mut file, &seg_tag(b'I', i), seg.blob_payload());
        let mut sv = WriteBuf::new();
        sv.put_f32s(seg.vectors());
        persist::push_section(&mut file, &seg_tag(b'V', i), &sv.bytes);
    }
    persist::finish_container(&mut file);
    Ok(file)
}

pub(crate) fn from_container(c: &Container) -> Result<DynamicIvf> {
    let head = c.section(b"DHDR")?;
    let mut r = ReadBuf::new(head.as_slice());
    let version = r.get_u32()?;
    ensure!(
        version == DYN_LAYOUT_VERSION,
        "unsupported dynamic-section layout version {version} (this build reads \
         {DYN_LAYOUT_VERSION})"
    );
    let dim = r.get_u64()? as usize;
    let k = r.get_u64()? as usize;
    let next_id64 = r.get_u64()?;
    let dead_stored = r.get_u64()? as usize;
    let nseg = r.get_u64()? as usize;
    let codec_name = r.get_str()?;
    let flush_rows = r.get_u64()? as usize;
    let max_segments = r.get_u64()? as usize;
    let max_dead_frac = f64::from_bits(r.get_u64()?);
    let auto = r.get_u8()? != 0;
    ensure!(dim >= 1 && k >= 1, "degenerate dynamic header (dim={dim}, k={k})");
    ensure!(next_id64 <= u32::MAX as u64, "next_id {next_id64} exceeds the id space");
    let next_id = next_id64 as u32;
    let spec = CodecSpec::parse(&codec_name).context("dynamic header names its id codec")?;
    ensure!(spec.is_per_list(), "dynamic containers store per-list streams, not {codec_name:?}");
    let policy = CompactionPolicy { flush_rows, max_segments, max_dead_frac, auto };

    let sec = c.section(b"CENT")?;
    let centroids = ReadBuf::new(sec.as_slice()).get_f32s()?;
    ensure!(
        centroids.len() == k * dim,
        "centroid section holds {} floats for k={k}, dim={dim}",
        centroids.len()
    );

    let sec = c.section(b"TOMB")?;
    let mut r = ReadBuf::new(sec.as_slice());
    let tomb_count = r.get_u64()?;
    let tomb_words = r.get_u64s()?;
    ensure!(tomb_count <= next_id as u64, "tombstone count {tomb_count} exceeds next_id");
    let popcount: u64 = tomb_words.iter().map(|w| w.count_ones() as u64).sum();
    ensure!(
        popcount == tomb_count,
        "tombstone bitmap holds {popcount} set bits, header says {tomb_count}"
    );
    let tombs = Tombstones::from_parts(tomb_words, tomb_count);

    let sec = c.section(b"WBUF")?;
    let mut r = ReadBuf::new(sec.as_slice());
    let mut buffer = WriteBuffer::new(k);
    for c_idx in 0..k {
        let ids = r.get_u32s()?;
        let vecs = r.get_f32s()?;
        ensure!(
            vecs.len() == ids.len() * dim,
            "write buffer cluster {c_idx}: {} floats for {} ids",
            vecs.len(),
            ids.len()
        );
        ensure!(
            ids.iter().all(|&id| id < next_id),
            "write buffer cluster {c_idx} holds an id past next_id {next_id}"
        );
        buffer.rows += ids.len();
        buffer.lists[c_idx] = ids;
        buffer.vecs[c_idx] = vecs;
    }

    let mut segments = Vec::with_capacity(nseg);
    for i in 0..nseg {
        let sec = c.section(&seg_tag(b'H', i)).with_context(|| format!("segment {i} header"))?;
        let mut r = ReadBuf::new(sec.as_slice());
        let universe = r.get_u32()?;
        let id_bits = r.get_u64()?;
        let map = match r.get_u8()? {
            0 => IdMap::Identity,
            1 => {
                let len = r.get_u64()? as usize;
                let words = r.get_u64s()?;
                ensure!(
                    words.len() == len.div_ceil(64),
                    "segment {i}: live map holds {} words for {len} bits",
                    words.len()
                );
                IdMap::Live(RsBitVec::new(BitBuf { words, len }))
            }
            other => anyhow::bail!("segment {i}: unknown id-map tag {other}"),
        };
        let offsets_u64 = r.get_u64s()?;
        ensure!(offsets_u64.len() == k + 1, "segment {i}: expected {} row offsets", k + 1);
        ensure!(
            offsets_u64[0] == 0 && offsets_u64.windows(2).all(|w| w[0] <= w[1]),
            "segment {i}: row offsets are not a monotone partition"
        );
        let offsets: Vec<usize> = offsets_u64.iter().map(|&o| o as usize).collect();
        let blob_offsets = r.get_u64s()?;
        let blobs = Blobs::from_parts(
            c.section(&seg_tag(b'I', i)).with_context(|| format!("segment {i} id streams"))?,
            blob_offsets,
        )?;
        ensure!(blobs.count() == k, "segment {i}: {} blobs for k={k}", blobs.count());
        let sec =
            c.section(&seg_tag(b'V', i)).with_context(|| format!("segment {i} vectors"))?;
        let vectors = ReadBuf::new(sec.as_slice()).get_f32s()?;
        let seg = Segment::from_parts(blobs, offsets, vectors, spec, universe, map, id_bits, dim)
            .with_context(|| format!("segment {i}"))?;
        segments.push(Arc::new(seg));
    }

    let mut idx = DynamicIvf::from_open_parts(
        dim,
        k,
        centroids,
        spec,
        policy,
        segments,
        buffer,
        tombs,
        next_id,
        dead_stored,
    );
    idx.checksummed = c.checksummed();
    ensure!(
        idx.stored_rows() as u64 + tomb_count == next_id as u64 + idx.dead_stored() as u64,
        "row accounting is inconsistent: {} stored + {tomb_count} tombstoned vs {next_id} \
         assigned + {} dead-but-stored",
        idx.stored_rows(),
        idx.dead_stored()
    );
    if !c.checksummed() {
        for (i, seg) in idx.segments().iter().enumerate() {
            seg.validate_decode()
                .with_context(|| format!("v1 dynamic container: segment {i} failed decode validation"))?;
        }
    }
    Ok(idx)
}

#[cfg(test)]
mod tests {
    use super::super::{DynamicBuildParams, DynamicIvf};
    use super::*;
    use crate::api::AnnIndex;
    use crate::codecs::PER_LIST_CODECS;
    use crate::datasets::{generate, Kind};
    use crate::index::{IvfBuildParams, SearchParams, SearchScratch};
    use crate::util::Rng;

    fn churned(codec: &str) -> (crate::datasets::Dataset, DynamicIvf) {
        let ds = generate(Kind::DeepLike, 1500, 20, 8, 61);
        let mut idx = DynamicIvf::build(
            &ds.data[..1000 * ds.dim],
            ds.dim,
            &DynamicBuildParams {
                ivf: IvfBuildParams {
                    k: 16,
                    id_codec: codec.into(),
                    threads: 2,
                    ..Default::default()
                },
                policy: CompactionPolicy { flush_rows: 150, auto: true, ..Default::default() },
            },
        )
        .unwrap();
        let mut rng = Rng::new(9);
        for id in rng.sample_distinct(1000, 120) {
            assert!(idx.delete(id as u32).unwrap());
        }
        idx.add(&ds.data[1000 * ds.dim..1500 * ds.dim]).unwrap();
        (ds, idx)
    }

    #[test]
    fn multi_segment_roundtrip_bit_identical_for_every_codec() {
        for codec in PER_LIST_CODECS {
            let (ds, idx) = churned(codec);
            assert!(
                idx.num_segments() >= 2 || idx.buffer_rows() > 0,
                "{codec}: churn should leave a multi-part index"
            );
            let bytes = idx.to_bytes().unwrap();
            let back = persist::open_dynamic_bytes(bytes.clone()).unwrap();
            assert_eq!(back.live(), idx.live(), "{codec}");
            assert_eq!(back.num_segments(), idx.num_segments(), "{codec}");
            assert_eq!(back.buffer_rows(), idx.buffer_rows(), "{codec}");
            assert_eq!(back.dead_stored(), idx.dead_stored(), "{codec}");
            assert_eq!(back.id_bits(), idx.id_bits(), "{codec}: streams must survive verbatim");
            let (bp, ip) = (back.policy(), idx.policy());
            assert_eq!(
                (bp.flush_rows, bp.max_segments, bp.auto, bp.max_dead_frac.to_bits()),
                (ip.flush_rows, ip.max_segments, ip.auto, ip.max_dead_frac.to_bits()),
                "{codec}: compaction policy must round-trip exactly"
            );
            let sp = SearchParams { nprobe: 8, k: 10 };
            let mut s1 = SearchScratch::default();
            let mut s2 = SearchScratch::default();
            for qi in 0..ds.nq {
                assert_eq!(
                    back.search(ds.query(qi), &sp, &mut s1),
                    idx.search(ds.query(qi), &sp, &mut s2),
                    "{codec}: query {qi}"
                );
            }
            // And the generic open dispatches on the kind byte.
            let dyn_back = persist::open_bytes(bytes).unwrap();
            assert_eq!(dyn_back.len(), idx.live(), "{codec}");
        }
    }

    #[test]
    fn reopened_index_stays_mutable() {
        let (ds, idx) = churned("roc");
        let live_before = idx.live();
        let mut back = persist::open_dynamic_bytes(idx.to_bytes().unwrap()).unwrap();
        let range = back.add(&ds.data[..3 * ds.dim]).unwrap();
        assert_eq!(range.len(), 3);
        assert!(back.delete(range.start).unwrap());
        back.compact().unwrap();
        assert_eq!(back.live(), live_before + 2);
        assert_eq!(back.num_segments(), 1);
        // Deleted-then-compacted ids stay dead after another round-trip.
        let again = persist::open_dynamic_bytes(back.to_bytes().unwrap()).unwrap();
        assert!(!again.is_live(range.start));
        assert_eq!(again.live(), live_before + 2);
    }

    #[test]
    fn corrupt_dynamic_sections_error_cleanly() {
        let (_, idx) = churned("roc");
        let good = idx.to_bytes().unwrap();
        assert!(persist::open_bytes(good.clone()).is_ok());
        // Unknown id-map tag inside a segment header → error, not panic.
        for cut in [9usize, good.len() / 4, good.len() / 2, good.len() - 1] {
            assert!(
                persist::open_bytes(good[..cut].to_vec()).is_err(),
                "truncation at {cut} must fail"
            );
        }
        // A dynamic file is not a static IVF file.
        let err = persist::open_ivf_bytes(good).expect_err("kind mismatch");
        assert!(format!("{err}").contains("kind"), "{err}");
    }
}
