//! Tiny CLI argument parser (`--flag`, `--key value`, positionals).
//!
//! `clap` is not in the offline vendor set; this covers what the `zann`
//! binary, the examples and the bench harnesses need.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let raw: Vec<String> = iter.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(name.to_string(), String::from("true"));
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, name: &str) -> bool {
        match self.get(name) {
            Some("false") | Some("0") => false,
            Some(_) => true,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_flags_values_positionals() {
        let a = parse(&["build", "--n", "1000", "--full", "--k=17", "path"]);
        assert_eq!(a.positional, vec!["build", "path"]);
        assert_eq!(a.usize("n", 0), 1000);
        assert!(a.bool("full"));
        assert_eq!(a.usize("k", 0), 17);
        assert!(!a.bool("absent"));
        assert_eq!(a.get_or("missing", "x"), "x");
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["--verbose"]);
        assert!(a.bool("verbose"));
    }
}
