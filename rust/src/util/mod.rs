//! Zero-dependency utilities: PRNG, bit I/O, binary serialization, a tiny
//! CLI argument parser, a scoped thread pool and timing helpers.
//!
//! The build environment is fully offline (only the crates vendored next to
//! the `xla` crate are available), so the usual suspects (`rand`,
//! `clap`, `rayon`, `criterion`) are re-implemented here at the scale this
//! project needs.

pub mod prng;
pub mod bits;
pub mod bytes;
pub mod crc32c;
pub mod serialize;
pub mod cli;
pub mod pool;
pub mod timer;

pub use bits::{BitReader, BitWriter};
pub use bytes::{Blobs, BlobsBuilder, Bytes};
pub use prng::{Rng, Zipf};
pub use serialize::{ReadBuf, WriteBuf};

/// `ceil(log2(n))` for n >= 1; number of bits needed to address `[0, n)`.
/// By convention `bits_for(1) == 0` (a single value needs no bits).
pub fn bits_for(n: u64) -> u32 {
    if n <= 1 {
        0
    } else {
        64 - (n - 1).leading_zeros()
    }
}

/// `log2(n!)` in bits, via the log-gamma function (Stirling series).
/// This is the information-theoretic value of the ordering of an n-set.
pub fn log2_factorial(n: u64) -> f64 {
    if n < 2 {
        return 0.0;
    }
    // Exact summation below a threshold, Stirling above (abs err < 1e-10).
    if n < 256 {
        (2..=n).map(|i| (i as f64).log2()).sum()
    } else {
        let x = n as f64;
        let ln = x * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI * x).ln()
            + 1.0 / (12.0 * x)
            - 1.0 / (360.0 * x * x * x);
        ln / std::f64::consts::LN_2
    }
}

/// `log2(binomial(n, k))` — information content of a k-subset of [n).
pub fn log2_binomial(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    log2_factorial(n) - log2_factorial(k) - log2_factorial(n - k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_for_edges() {
        assert_eq!(bits_for(0), 0);
        assert_eq!(bits_for(1), 0);
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(4), 2);
        assert_eq!(bits_for(5), 3);
        assert_eq!(bits_for(1 << 20), 20);
        assert_eq!(bits_for((1 << 20) + 1), 21);
    }

    #[test]
    fn log2_factorial_small_exact() {
        assert_eq!(log2_factorial(0), 0.0);
        assert_eq!(log2_factorial(1), 0.0);
        assert!((log2_factorial(4) - (24f64).log2()).abs() < 1e-12);
        assert!((log2_factorial(10) - (3628800f64).log2()).abs() < 1e-9);
    }

    #[test]
    fn log2_factorial_stirling_continuous() {
        // Stirling and exact summation must agree at the crossover point.
        let exact: f64 = (2..=300u64).map(|i| (i as f64).log2()).sum();
        assert!((log2_factorial(300) - exact).abs() < 1e-6);
    }

    #[test]
    fn binomial_sanity() {
        assert!((log2_binomial(5, 2) - (10f64).log2()).abs() < 1e-9);
        // log2 C(1e6, 1000): n log2(N/n) + n log2(e) - O(log n) ballpark.
        let v = log2_binomial(1_000_000, 1000);
        assert!(v > 11_000.0 && v < 12_000.0, "{v}");
    }
}
