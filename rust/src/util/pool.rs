//! Scoped data-parallel helpers over std threads (no rayon offline).

/// Run `f(chunk_index, item_range)` over `n` items split across up to
/// `threads` OS threads, via `std::thread::scope`. `f` must be `Sync`.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(t, lo..hi));
        }
    });
}

/// Map each index in `[0, n)` to a value, in parallel, preserving order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    if n == 0 {
        return out;
    }
    let threads = threads.max(1).min(n);
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (off, v) in slice.iter_mut().enumerate() {
                    *v = f(t * chunk + off);
                }
            });
        }
    });
    out
}

/// Default worker count: physical parallelism reported by the OS.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_matches_serial() {
        let got = parallel_map(1000, 8, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_single_thread_and_empty() {
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(parallel_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn chunks_cover_exactly_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let hits: Vec<AtomicU32> = (0..777).map(|_| AtomicU32::new(0)).collect();
        parallel_chunks(777, 7, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
