//! Scoped data-parallel helpers over std threads (no rayon offline).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `f(worker, item_range)` over `n` items split across up to
/// `threads` OS threads via `std::thread::scope`.
///
/// Work is split into more chunks than workers (4× oversplit) and pulled
/// from a shared counter, so skewed per-item costs (e.g. uneven IVF
/// cluster sizes) rebalance instead of serializing on the slowest static
/// chunk. The first argument passed to `f` is the *worker* index in
/// `[0, threads)` — stable across every chunk that worker pulls, so
/// callers may key per-thread scratch off it (`f` may be invoked several
/// times per worker, with disjoint ranges). `f` must be `Sync`.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0, 0..n);
        return;
    }
    let chunks = (threads * 4).min(n);
    let chunk = n.div_ceil(chunks);
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for w in 0..threads {
            let f = &f;
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let lo = i * chunk;
                if lo >= n {
                    break;
                }
                f(w, lo..((i + 1) * chunk).min(n));
            });
        }
    });
}

/// Map each index in `[0, n)` to a value, in parallel, preserving order.
///
/// Results are written straight into the output vector's spare capacity
/// (`MaybeUninit` slots), so `T` needs neither `Default` nor `Clone` and
/// no placeholder pass runs over the buffer. If `f` panics, the panic
/// propagates out of the thread scope; already-written elements are
/// leaked (the length is only set after every slot is initialized), never
/// dropped twice.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<T> = Vec::with_capacity(n);
    if n == 0 {
        return out;
    }
    let threads = threads.max(1).min(n);
    let chunk = n.div_ceil(threads);
    {
        let slots = &mut out.spare_capacity_mut()[..n];
        if threads <= 1 {
            for (i, slot) in slots.iter_mut().enumerate() {
                slot.write(f(i));
            }
        } else {
            std::thread::scope(|s| {
                for (t, slice) in slots.chunks_mut(chunk).enumerate() {
                    let f = &f;
                    s.spawn(move || {
                        for (off, slot) in slice.iter_mut().enumerate() {
                            slot.write(f(t * chunk + off));
                        }
                    });
                }
            });
        }
    }
    // SAFETY: all `n` slots were initialized above — the serial loop runs
    // to completion, and the thread scope joins every worker (a worker
    // panic propagates before this point is reached).
    unsafe { out.set_len(n) };
    out
}

/// Default worker count: physical parallelism reported by the OS.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn parallel_map_matches_serial() {
        let got = parallel_map(1000, 8, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_single_thread_and_empty() {
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(parallel_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn parallel_map_without_default_or_clone() {
        // The relaxed bound: a type with neither Default nor Clone, with a
        // Drop impl to catch any double-drop of the MaybeUninit slots.
        struct Opaque(Box<usize>);
        let got = parallel_map(257, 4, |i| Opaque(Box::new(i * 3)));
        assert_eq!(got.len(), 257);
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v.0, i * 3);
        }
    }

    #[test]
    fn chunks_cover_exactly_once() {
        let hits: Vec<AtomicU32> = (0..777).map(|_| AtomicU32::new(0)).collect();
        parallel_chunks(777, 7, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunks_oversplit_but_worker_ids_bounded() {
        // More chunks than workers (load balancing), yet the worker index
        // stays within [0, threads) so scratch arrays can be keyed by it.
        let max_worker = AtomicUsize::new(0);
        let calls = AtomicUsize::new(0);
        parallel_chunks(1000, 4, |w, range| {
            assert!(!range.is_empty());
            max_worker.fetch_max(w, Ordering::Relaxed);
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert!(max_worker.load(Ordering::Relaxed) < 4);
        assert!(calls.load(Ordering::Relaxed) > 4, "expected oversplit chunks");
    }
}
