//! Shared immutable byte regions and blob tables — the storage primitive
//! behind zero-copy index persistence.
//!
//! [`Bytes`] is a cheaply-clonable view into an `Arc<Vec<u8>>`: the whole
//! index file is read into memory once, and every section (in particular
//! the already-compressed id/code streams) is a sub-range of that one
//! buffer.  [`Blobs`] lays many variable-length blobs end-to-end inside a
//! single region with an offset table, so a per-cluster compressed stream
//! is `blobs.get(c)` — a bounds-checked slice, never a copy.  At build
//! time the same types are produced by [`BlobsBuilder`]; at open time
//! they are reconstructed over the borrowed file buffer, which is what
//! makes `open` transcode-free.

use anyhow::{ensure, Result};
use std::sync::Arc;

/// An immutable, reference-counted byte region (`Arc<Vec<u8>>` + range).
#[derive(Clone)]
pub struct Bytes {
    data: Arc<Vec<u8>>,
    start: usize,
    len: usize,
}

impl Bytes {
    /// Wrap an owned buffer (no copy).
    pub fn from_vec(v: Vec<u8>) -> Bytes {
        let len = v.len();
        Bytes { data: Arc::new(v), start: 0, len }
    }

    /// A bounds-checked sub-region sharing the same backing allocation.
    pub fn slice(&self, start: usize, len: usize) -> Result<Bytes> {
        ensure!(
            start <= self.len && len <= self.len - start,
            "byte region [{start}, +{len}) out of bounds (region is {} bytes)",
            self.len
        );
        Ok(Bytes { data: self.data.clone(), start: self.start + start, len })
    }

    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        &self.data[self.start..self.start + self.len]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::fmt::Debug for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Bytes({} bytes at +{})", self.len, self.start)
    }
}

/// A table of variable-length blobs stored end-to-end in one [`Bytes`]
/// region, addressed through a monotone offset table (`count + 1`
/// entries, first 0, last = region length).
pub struct Blobs {
    region: Bytes,
    offsets: Vec<u64>,
}

impl Blobs {
    /// Reassemble from a borrowed region + offset table (the open path).
    /// Validates the table so later `get` calls cannot go out of bounds.
    pub fn from_parts(region: Bytes, offsets: Vec<u64>) -> Result<Blobs> {
        ensure!(!offsets.is_empty(), "blob offset table is empty");
        ensure!(offsets[0] == 0, "blob offsets must start at 0");
        ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "blob offsets must be non-decreasing"
        );
        ensure!(
            *offsets.last().unwrap() as usize == region.len(),
            "blob offsets end at {} but the region holds {} bytes",
            offsets.last().unwrap(),
            region.len()
        );
        Ok(Blobs { region, offsets })
    }

    /// Number of blobs.
    pub fn count(&self) -> usize {
        self.offsets.len() - 1
    }

    /// The `i`-th blob as a slice into the shared region.
    #[inline]
    pub fn get(&self, i: usize) -> &[u8] {
        let (a, b) = (self.offsets[i] as usize, self.offsets[i + 1] as usize);
        &self.region.as_slice()[a..b]
    }

    /// Total payload bytes across all blobs.
    pub fn total_bytes(&self) -> usize {
        self.region.len()
    }

    /// The offset table (for serialization).
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// The contiguous payload (for serialization — written verbatim).
    pub fn payload(&self) -> &[u8] {
        self.region.as_slice()
    }
}

/// Accumulates blobs into a contiguous buffer at build time.
#[derive(Default)]
pub struct BlobsBuilder {
    buf: Vec<u8>,
    offsets: Vec<u64>,
}

impl BlobsBuilder {
    pub fn new() -> Self {
        BlobsBuilder { buf: Vec::new(), offsets: vec![0] }
    }

    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
        self.offsets.push(self.buf.len() as u64);
    }

    pub fn finish(self) -> Blobs {
        Blobs { region: Bytes::from_vec(self.buf), offsets: self.offsets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_roundtrip_and_bounds() {
        let mut b = BlobsBuilder::new();
        b.push(b"abc");
        b.push(b"");
        b.push(b"defg");
        let blobs = b.finish();
        assert_eq!(blobs.count(), 3);
        assert_eq!(blobs.get(0), b"abc");
        assert_eq!(blobs.get(1), b"");
        assert_eq!(blobs.get(2), b"defg");
        assert_eq!(blobs.total_bytes(), 7);
        assert_eq!(blobs.offsets(), &[0, 3, 3, 7]);
    }

    #[test]
    fn from_parts_validates_table() {
        let region = Bytes::from_vec(vec![1, 2, 3, 4]);
        assert!(Blobs::from_parts(region.clone(), vec![0, 2, 4]).is_ok());
        assert!(Blobs::from_parts(region.clone(), vec![]).is_err(), "empty table");
        assert!(Blobs::from_parts(region.clone(), vec![1, 4]).is_err(), "must start at 0");
        assert!(Blobs::from_parts(region.clone(), vec![0, 3, 2, 4]).is_err(), "non-monotone");
        assert!(Blobs::from_parts(region, vec![0, 2, 5]).is_err(), "past the end");
    }

    #[test]
    fn slices_share_one_allocation() {
        let base = Bytes::from_vec((0u8..32).collect());
        let a = base.slice(4, 8).unwrap();
        let b = a.slice(2, 3).unwrap();
        assert_eq!(a.as_slice(), &(4u8..12).collect::<Vec<_>>()[..]);
        assert_eq!(b.as_slice(), &[6, 7, 8]);
        assert!(base.slice(30, 4).is_err());
        assert!(base.slice(33, 0).is_err());
    }
}
