//! CRC-32C (Castagnoli) — the checksum of the v2 zann container.
//!
//! Hand-rolled (the build environment is offline; no `crc32c` crate) as a
//! table-driven byte-at-a-time implementation of the reflected polynomial
//! `0x1EDC6F41` (reflected form `0x82F63B78`), the same parameterization
//! used by iSCSI, ext4 and the SSE4.2 `crc32` instruction: init
//! `0xFFFF_FFFF`, reflected input/output, final XOR `0xFFFF_FFFF`. The
//! table is built in a `const fn`, so there is no runtime init to race.

const POLY_REFLECTED: u32 = 0x82F6_3B78;

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY_REFLECTED } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// Streaming CRC-32C state, for checksumming discontiguous parts (the
/// container checksums `tag ‖ payload` without concatenating them).
#[derive(Clone, Debug)]
pub struct Crc32c {
    state: u32,
}

impl Default for Crc32c {
    fn default() -> Self {
        Crc32c::new()
    }
}

impl Crc32c {
    pub fn new() -> Crc32c {
        Crc32c { state: 0xFFFF_FFFF }
    }

    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32C of a byte slice.
pub fn crc32c(bytes: &[u8]) -> u32 {
    let mut h = Crc32c::new();
    h.update(bytes);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical check value of the CRC-32C parameterization plus
        // the RFC 3720 (iSCSI) appendix B.4 test vectors.
        assert_eq!(crc32c(b""), 0x0000_0000);
        assert_eq!(crc32c(b"a"), 0xC1D0_4330);
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(&[0u8; 32]), 0x8A91_36AA);
        assert_eq!(crc32c(&[0xFFu8; 32]), 0x62A8_AB43);
        let ascending: Vec<u8> = (0..32).collect();
        assert_eq!(crc32c(&ascending), 0x46DD_794E);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i.wrapping_mul(2654435761) >> 13) as u8).collect();
        let whole = crc32c(&data);
        for split in [0, 1, 7, 500, 999, 1000] {
            let mut h = Crc32c::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), whole, "split at {split}");
        }
    }

    #[test]
    fn single_bit_flips_change_the_checksum() {
        let data = [0x5Au8; 64];
        let base = crc32c(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut m = data;
                m[byte] ^= 1 << bit;
                assert_ne!(crc32c(&m), base, "flip at byte {byte} bit {bit}");
            }
        }
    }
}
