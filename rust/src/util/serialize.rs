//! Minimal binary (de)serialization: length-prefixed little-endian fields.
//!
//! Index files and codec blobs are written through [`WriteBuf`] and read
//! back with [`ReadBuf`]; no serde in the offline vendor set.

use anyhow::{bail, Result};

#[derive(Default)]
pub struct WriteBuf {
    pub bytes: Vec<u8>,
}

impl WriteBuf {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.bytes.push(v);
    }
    pub fn put_u32(&mut self, v: u32) {
        self.bytes.extend_from_slice(&v.to_le_bytes());
    }
    pub fn put_u64(&mut self, v: u64) {
        self.bytes.extend_from_slice(&v.to_le_bytes());
    }
    pub fn put_f32(&mut self, v: f32) {
        self.bytes.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32s(&mut self, vs: &[u32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_u32(v);
        }
    }
    pub fn put_u64s(&mut self, vs: &[u64]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_u64(v);
        }
    }
    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_f32(v);
        }
    }
    pub fn put_bytes(&mut self, vs: &[u8]) {
        self.put_u64(vs.len() as u64);
        self.bytes.extend_from_slice(vs);
    }
    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }
}

pub struct ReadBuf<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> ReadBuf<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        ReadBuf { bytes, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // Overflow-safe form: `pos + n` can wrap when a corrupt length
        // field claims a near-usize::MAX payload.
        if n > self.bytes.len() - self.pos {
            bail!("buffer underrun at {} (+{n} of {})", self.pos, self.bytes.len());
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.get_u64()? as usize;
        let mut v = Vec::with_capacity(n.min(1 << 24));
        for _ in 0..n {
            v.push(self.get_u32()?);
        }
        Ok(v)
    }
    pub fn get_u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.get_u64()? as usize;
        let mut v = Vec::with_capacity(n.min(1 << 23));
        for _ in 0..n {
            v.push(self.get_u64()?);
        }
        Ok(v)
    }
    pub fn get_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.get_u64()? as usize;
        let mut v = Vec::with_capacity(n.min(1 << 24));
        for _ in 0..n {
            v.push(self.get_f32()?);
        }
        Ok(v)
    }
    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_u64()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    pub fn get_str(&mut self) -> Result<String> {
        Ok(String::from_utf8(self.get_bytes()?)?)
    }

    pub fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_field_types() {
        let mut w = WriteBuf::new();
        w.put_u8(7);
        w.put_u32(0xdead_beef);
        w.put_u64(u64::MAX - 3);
        w.put_f32(3.5);
        w.put_u32s(&[1, 2, 3]);
        w.put_u64s(&[]);
        w.put_f32s(&[-1.0, 2.25]);
        w.put_bytes(b"blob");
        w.put_str("zann");
        let mut r = ReadBuf::new(&w.bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xdead_beef);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.get_f32().unwrap(), 3.5);
        assert_eq!(r.get_u32s().unwrap(), vec![1, 2, 3]);
        assert!(r.get_u64s().unwrap().is_empty());
        assert_eq!(r.get_f32s().unwrap(), vec![-1.0, 2.25]);
        assert_eq!(r.get_bytes().unwrap(), b"blob");
        assert_eq!(r.get_str().unwrap(), "zann");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn underrun_is_error() {
        let mut r = ReadBuf::new(&[1, 2]);
        assert!(r.get_u32().is_err());
    }

    #[test]
    fn huge_corrupt_length_is_an_error_not_a_panic() {
        // A length prefix of u64::MAX must not overflow the bounds check
        // (debug) or slice with an inverted range (release).
        let mut w = WriteBuf::new();
        w.put_u64(u64::MAX);
        w.put_u8(7);
        let mut r = ReadBuf::new(&w.bytes);
        assert!(r.get_bytes().is_err());
        let mut r = ReadBuf::new(&w.bytes);
        assert!(r.get_str().is_err());
    }
}
