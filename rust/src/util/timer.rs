//! Timing helpers for the bench harnesses (criterion is not vendored).

use std::time::Instant;

/// Run `f` once and return seconds elapsed.
pub fn time_once<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// Median wall-time over `runs` invocations (the paper reports medians
/// over 100 runs; benches here default lower and say so).
pub fn median_time<F: FnMut()>(runs: usize, mut f: F) -> f64 {
    assert!(runs > 0);
    let mut ts: Vec<f64> = (0..runs)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    ts.sort_by(|a, b| a.total_cmp(b));
    ts[ts.len() / 2]
}

/// Simple statistics over repeated timed runs.
pub struct Stats {
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
}

pub fn run_stats<F: FnMut()>(runs: usize, mut f: F) -> Stats {
    let mut ts: Vec<f64> = (0..runs)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    ts.sort_by(|a, b| a.total_cmp(b));
    Stats {
        median: ts[ts.len() / 2],
        mean: ts.iter().sum::<f64>() / ts.len() as f64,
        min: ts[0],
        max: *ts.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timers_are_positive_and_ordered() {
        let s = run_stats(5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.mean > 0.0);
        assert!(time_once(|| ()) >= 0.0);
        assert!(median_time(3, || ()) >= 0.0);
    }
}
