//! LSB-first bit reader/writer over a `Vec<u64>` backing store.
//!
//! Used by the fixed-width id packer, Elias-Fano lower bits and the wavelet
//! tree's per-level bitmaps.

/// Append-only bit writer (LSB-first within each u64 word).
#[derive(Default, Clone, Debug)]
pub struct BitWriter {
    words: Vec<u64>,
    /// Total number of bits written.
    len: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bits: usize) -> Self {
        BitWriter { words: Vec::with_capacity(bits.div_ceil(64)), len: 0 }
    }

    /// Write the low `n` bits of `v` (n <= 64).
    #[inline]
    pub fn write(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let v = if n == 64 { v } else { v & ((1u64 << n) - 1) };
        let bit = self.len & 63;
        if bit == 0 {
            self.words.push(v);
        } else {
            *self.words.last_mut().unwrap() |= v << bit;
            if bit + n as usize > 64 {
                self.words.push(v >> (64 - bit));
            }
        }
        self.len += n as usize;
    }

    /// Write a single bit.
    #[inline]
    pub fn push_bit(&mut self, b: bool) {
        self.write(b as u64, 1);
    }

    /// Unary code: `v` zeros followed by a one (as used by Elias-Fano
    /// upper bits).
    pub fn write_unary(&mut self, v: u64) {
        let mut rem = v;
        while rem >= 64 {
            self.write(0, 64);
            rem -= 64;
        }
        self.write(1u64 << rem, rem as u32 + 1);
    }

    pub fn len_bits(&self) -> usize {
        self.len
    }

    pub fn finish(self) -> BitBuf {
        BitBuf { words: self.words, len: self.len }
    }
}

/// Immutable bit buffer with random-access reads.
#[derive(Clone, Debug, Default)]
pub struct BitBuf {
    pub words: Vec<u64>,
    pub len: usize,
}

impl BitBuf {
    /// Read `n` bits starting at bit offset `pos` (LSB-first).
    #[inline]
    pub fn read(&self, pos: usize, n: u32) -> u64 {
        debug_assert!(n <= 64);
        if n == 0 {
            return 0;
        }
        debug_assert!(pos + n as usize <= self.len);
        let word = pos >> 6;
        let bit = pos & 63;
        let lo = self.words[word] >> bit;
        let v = if bit + n as usize <= 64 {
            lo
        } else {
            lo | (self.words[word + 1] << (64 - bit))
        };
        if n == 64 {
            v
        } else {
            v & ((1u64 << n) - 1)
        }
    }

    #[inline]
    pub fn get_bit(&self, pos: usize) -> bool {
        (self.words[pos >> 6] >> (pos & 63)) & 1 == 1
    }

    pub fn size_bits(&self) -> usize {
        self.len
    }

    /// Heap bytes occupied by the raw words.
    pub fn heap_bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// Read `n <= 64` bits (LSB-first) starting at bit `pos` directly from a
/// serialized byte blob — no intermediate [`BitBuf`] is built, which makes
/// this the allocation-free random-access primitive of the id-resolve hot
/// path. The blob must be the little-endian serialization of an LSB-first
/// word stream (what the codecs store), so byte order matches [`BitBuf`].
#[inline]
pub fn read_bits_at(bytes: &[u8], pos: usize, n: u32) -> u64 {
    debug_assert!(n <= 64);
    if n == 0 {
        return 0;
    }
    debug_assert!(pos + n as usize <= bytes.len() * 8, "read past blob end");
    let byte = pos >> 3;
    let shift = (pos & 7) as u32;
    let mut window = [0u8; 16];
    let take = bytes.len().saturating_sub(byte).min(16);
    window[..take].copy_from_slice(&bytes[byte..byte + take]);
    let lo = u64::from_le_bytes(window[0..8].try_into().unwrap());
    let hi = u64::from_le_bytes(window[8..16].try_into().unwrap());
    let v = if shift == 0 { lo } else { (lo >> shift) | (hi << (64 - shift)) };
    if n == 64 {
        v
    } else {
        v & ((1u64 << n) - 1)
    }
}

/// Sequential reader over a [`BitBuf`].
pub struct BitReader<'a> {
    buf: &'a BitBuf,
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a BitBuf) -> Self {
        BitReader { buf, pos: 0 }
    }

    pub fn at(buf: &'a BitBuf, pos: usize) -> Self {
        BitReader { buf, pos }
    }

    #[inline]
    pub fn read(&mut self, n: u32) -> u64 {
        let v = self.buf.read(self.pos, n);
        self.pos += n as usize;
        v
    }

    /// Read a unary code (count zeros up to the terminating one).
    pub fn read_unary(&mut self) -> u64 {
        let mut count = 0u64;
        loop {
            let word = self.pos >> 6;
            let bit = self.pos & 63;
            let w = self.buf.words[word] >> bit;
            if w == 0 {
                count += 64 - bit as u64;
                self.pos += 64 - bit;
            } else {
                let tz = w.trailing_zeros() as u64;
                count += tz;
                self.pos += tz as usize + 1;
                return count;
            }
        }
    }

    pub fn pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_fixed_width() {
        let mut w = BitWriter::new();
        let vals: Vec<(u64, u32)> = vec![
            (0, 1),
            (1, 1),
            (5, 3),
            (0xdeadbeef, 32),
            (u64::MAX, 64),
            (0, 0),
            (1234567, 21),
        ];
        for &(v, n) in &vals {
            w.write(v, n);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &(v, n) in &vals {
            let masked = if n == 0 {
                0
            } else if n == 64 {
                v
            } else {
                v & ((1 << n) - 1)
            };
            assert_eq!(r.read(n), masked, "width {n}");
        }
    }

    #[test]
    fn roundtrip_random_property() {
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let mut w = BitWriter::new();
            let mut expect = Vec::new();
            for _ in 0..rng.below(500) {
                let n = rng.below(65) as u32;
                let v = rng.next_u64();
                let masked = if n == 0 {
                    0
                } else if n == 64 {
                    v
                } else {
                    v & ((1 << n) - 1)
                };
                w.write(v, n);
                expect.push((masked, n));
            }
            let total: usize = expect.iter().map(|&(_, n)| n as usize).sum();
            let buf = w.finish();
            assert_eq!(buf.size_bits(), total);
            let mut r = BitReader::new(&buf);
            for (v, n) in expect {
                assert_eq!(r.read(n), v);
            }
        }
    }

    #[test]
    fn unary_roundtrip() {
        let mut w = BitWriter::new();
        let vals = [0u64, 1, 5, 63, 64, 65, 130, 1000, 2];
        for &v in &vals {
            w.write_unary(v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &v in &vals {
            assert_eq!(r.read_unary(), v);
        }
    }

    #[test]
    fn random_access_read() {
        let mut w = BitWriter::new();
        for i in 0..100u64 {
            w.write(i, 7);
        }
        let buf = w.finish();
        for i in (0..100usize).rev() {
            assert_eq!(buf.read(i * 7, 7), i as u64);
        }
    }

    #[test]
    fn read_bits_at_matches_bitbuf_read() {
        let mut rng = Rng::new(12);
        let mut w = BitWriter::new();
        let mut widths = Vec::new();
        for _ in 0..300 {
            let n = 1 + rng.below(64) as u32;
            w.write(rng.next_u64(), n);
            widths.push(n);
        }
        let buf = w.finish();
        // Serialize the words the way the codecs do (LE bytes).
        let mut bytes = Vec::with_capacity(buf.words.len() * 8);
        for word in &buf.words {
            bytes.extend_from_slice(&word.to_le_bytes());
        }
        let mut pos = 0usize;
        for &n in &widths {
            assert_eq!(read_bits_at(&bytes, pos, n), buf.read(pos, n), "pos={pos} n={n}");
            pos += n as usize;
        }
        // Reads near the very end of the blob (partial 16-byte window).
        let total = buf.size_bits();
        for back in 1..=total.min(64) {
            let n = back as u32;
            assert_eq!(read_bits_at(&bytes, total - back, n), buf.read(total - back, n));
        }
    }
}
