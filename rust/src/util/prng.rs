//! Small, fast, reproducible PRNG (splitmix64-seeded xoshiro256**).
//!
//! All randomness in the library — dataset synthesis, k-means init,
//! property tests, the zipf-skewed serve workload ([`Zipf`]) — flows
//! through [`Rng`] so every experiment is exactly reproducible from a
//! seed.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` (Lemire's multiply-shift, unbiased
    /// enough for simulation purposes; bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct values from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: u64, k: usize) -> Vec<u64> {
        assert!(k as u64 <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k as u64)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

/// Zipf(θ) sampler over `[0, n)` via a precomputed normalized CDF and
/// binary search — rank 0 is the hottest item. θ = 0 degenerates to the
/// uniform distribution; θ ≈ 1 is the classic web-workload skew used by
/// the serve bench for tenant/shard traffic.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, theta: f64) -> Zipf {
        assert!(n > 0, "zipf over an empty domain");
        assert!(theta >= 0.0 && theta.is_finite(), "zipf theta must be finite and >= 0");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(theta);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Draw one rank in `[0, n)`; rank 0 is the most frequent.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn below_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = rng.below(10) as usize;
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(1);
        let n = 200_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let n = 1 + rng.below(1000);
            let k = rng.below(n.min(100) + 1) as usize;
            let s = rng.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&v| v < n));
        }
    }

    #[test]
    fn zipf_is_deterministic_skewed_and_in_range() {
        let z = Zipf::new(16, 0.99);
        let mut rng = Rng::new(11);
        let mut counts = [0usize; 16];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 50_000);
        // Rank 0 dominates and the tail is monotonically lighter (with
        // slack for sampling noise on the tail ranks).
        assert!(counts[0] > counts[1] && counts[1] > counts[4] && counts[0] > 4 * counts[15]);
        // Same seed ⇒ same stream.
        let (mut a, mut b) = (Rng::new(5), Rng::new(5));
        for _ in 0..100 {
            assert_eq!(z.sample(&mut a), z.sample(&mut b));
        }
    }

    #[test]
    fn zipf_theta_zero_is_uniform() {
        let z = Zipf::new(8, 0.0);
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(9);
        let mut v: Vec<u32> = (0..1000).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>()); // astronomically unlikely
    }
}
