//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from the serving path.
//!
//! Python runs only at build time (`make artifacts`); at request time the
//! rust binary compiles the HLO *text* once per (entry, shape) via the
//! PJRT CPU client and executes batches through [`Engine`].  Executables
//! are not `Send`, so [`EngineHandle`] pins the engine to one device
//! thread and exposes a channel interface — the same topology a TPU-backed
//! deployment would use (one host thread owning the device queue).
//!
//! Every entry has a pure-rust fallback so the whole system functions (and
//! is testable) for shapes with no artifact; the coordinator reports which
//! path served each batch.
//!
//! The PJRT path itself is compiled only with the **`pjrt`** cargo feature
//! (it needs the offline-vendored `xla` crate). Without the feature —
//! the default, and what CI builds — [`Engine`] is a stub that reports
//! zero executables and always answers through [`coarse_fallback`], so
//! every caller (coordinator, CLI, examples, tests) works unchanged.

use anyhow::{Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

/// Shape key for a coarse-assignment executable: (batch, k, dim).
pub type CoarseKey = (usize, usize, usize);

/// Engine statistics (how many batches each path served).
#[derive(Default, Debug)]
pub struct EngineStats {
    pub pjrt_batches: AtomicU64,
    pub fallback_batches: AtomicU64,
}

/// The PJRT-owning engine. Construct on the thread that will use it.
#[cfg(feature = "pjrt")]
pub struct Engine {
    #[allow(dead_code)] // keeps the PJRT client alive for the executables
    client: xla::PjRtClient,
    coarse: HashMap<CoarseKey, xla::PjRtLoadedExecutable>,
    pub stats: Arc<EngineStats>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load every `coarse__b*_k*_d*.hlo.txt` in `dir` and compile it.
    pub fn load(dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut coarse = HashMap::new();
        if dir.is_dir() {
            for entry in std::fs::read_dir(dir)? {
                let path = entry?.path();
                let name = match path.file_name().and_then(|n| n.to_str()) {
                    Some(n) => n,
                    None => continue,
                };
                if let Some(key) = parse_coarse_name(name) {
                    let proto = xla::HloModuleProto::from_text_file(
                        path.to_str().context("non-utf8 path")?,
                    )
                    .with_context(|| format!("parse {name}"))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client.compile(&comp).with_context(|| format!("compile {name}"))?;
                    coarse.insert(key, exe);
                }
            }
        }
        Ok(Engine { client, coarse, stats: Arc::new(EngineStats::default()) })
    }

    pub fn num_executables(&self) -> usize {
        self.coarse.len()
    }

    pub fn has_coarse(&self, key: CoarseKey) -> bool {
        self.coarse.contains_key(&key)
    }

    /// Batched query→centroid squared-L2 distances.
    ///
    /// `queries` is `b × d` row-major (b must match an artifact batch for
    /// the PJRT path), `centroids` is `k × d`. Returns `b × k` distances
    /// and whether the PJRT path was used.
    pub fn coarse(
        &self,
        queries: &[f32],
        b: usize,
        d: usize,
        centroids: &[f32],
        k: usize,
    ) -> Result<(Vec<f32>, bool)> {
        debug_assert_eq!(queries.len(), b * d);
        debug_assert_eq!(centroids.len(), k * d);
        if let Some(exe) = self.coarse.get(&(b, k, d)) {
            let q = xla::Literal::vec1(queries).reshape(&[b as i64, d as i64])?;
            let c = xla::Literal::vec1(centroids).reshape(&[k as i64, d as i64])?;
            let result = exe.execute::<xla::Literal>(&[q, c])?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?; // lowered with return_tuple=True
            let v = out.to_vec::<f32>()?;
            anyhow::ensure!(v.len() == b * k, "bad output size {}", v.len());
            self.stats.pjrt_batches.fetch_add(1, Ordering::Relaxed);
            Ok((v, true))
        } else {
            self.stats.fallback_batches.fetch_add(1, Ordering::Relaxed);
            Ok((coarse_fallback(queries, b, d, centroids, k), false))
        }
    }
}

/// Stub engine compiled when the `pjrt` feature is off: no XLA client, no
/// executables, every batch is served by [`coarse_fallback`]. Keeps the
/// exact API of the PJRT engine so the coordinator and tests are
/// feature-agnostic.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    pub stats: Arc<EngineStats>,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Stub load: succeeds with zero executables regardless of `dir`
    /// (artifacts cannot be executed without the `pjrt` feature).
    pub fn load(_dir: &Path) -> Result<Engine> {
        Ok(Engine { stats: Arc::new(EngineStats::default()) })
    }

    pub fn num_executables(&self) -> usize {
        0
    }

    pub fn has_coarse(&self, _key: CoarseKey) -> bool {
        false
    }

    /// Batched query→centroid squared-L2 distances (always the rust path).
    pub fn coarse(
        &self,
        queries: &[f32],
        b: usize,
        d: usize,
        centroids: &[f32],
        k: usize,
    ) -> Result<(Vec<f32>, bool)> {
        debug_assert_eq!(queries.len(), b * d);
        debug_assert_eq!(centroids.len(), k * d);
        self.stats.fallback_batches.fetch_add(1, Ordering::Relaxed);
        Ok((coarse_fallback(queries, b, d, centroids, k), false))
    }
}

/// Pure-rust coarse distances (fallback path; also the test oracle).
///
/// Computed through the fused kernel of [`crate::quant::coarse`] —
/// identical arithmetic to `IvfIndex::search`'s internal coarse stage, so
/// results via either path are bit-identical (the serving tests compare
/// full result lists with `assert_eq!`).
pub fn coarse_fallback(queries: &[f32], b: usize, d: usize, centroids: &[f32], k: usize) -> Vec<f32> {
    debug_assert_eq!(centroids.len(), k * d);
    let norms = crate::quant::coarse::centroid_norms(centroids, d);
    let mut out = Vec::new();
    crate::quant::coarse::batch_dists_into(queries, b, centroids, d, &norms, 1, &mut out);
    debug_assert_eq!(out.len(), b * k);
    out
}

/// Steady-state fallback for the coordinator: precomputed centroid norms,
/// a reusable output buffer, and data-parallel queries across `threads`.
pub fn coarse_fallback_into(
    queries: &[f32],
    b: usize,
    d: usize,
    centroids: &[f32],
    norms: &[f32],
    threads: usize,
    out: &mut Vec<f32>,
) {
    crate::quant::coarse::batch_dists_into(queries, b, centroids, d, norms, threads, out);
}

// Without `pjrt` this is exercised only by the unit tests below.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn parse_coarse_name(name: &str) -> Option<CoarseKey> {
    // coarse__b{b}_k{k}_d{d}.hlo.txt
    let stem = name.strip_suffix(".hlo.txt")?;
    let rest = stem.strip_prefix("coarse__b")?;
    let (b, rest) = rest.split_once("_k")?;
    let (k, d) = rest.split_once("_d")?;
    Some((b.parse().ok()?, k.parse().ok()?, d.parse().ok()?))
}

/// Request message for the engine thread.
pub enum EngineMsg {
    Coarse {
        queries: Vec<f32>,
        b: usize,
        d: usize,
        centroids: Arc<Vec<f32>>,
        k: usize,
        reply: mpsc::SyncSender<Result<(Vec<f32>, bool)>>,
    },
    Shutdown,
}

/// Channel-based handle to an engine pinned on its own thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<EngineMsg>,
    pub stats: Arc<EngineStats>,
    pub num_executables: usize,
}

impl EngineHandle {
    /// Spawn the engine thread; blocks until artifacts are compiled.
    pub fn spawn(artifact_dir: &Path) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<EngineMsg>();
        let (ready_tx, ready_rx) = mpsc::sync_channel(1);
        let dir = artifact_dir.to_path_buf();
        std::thread::Builder::new()
            .name("zann-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok((e.stats.clone(), e.num_executables())));
                        e
                    }
                    Err(err) => {
                        let _ = ready_tx.send(Err(err));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        EngineMsg::Coarse { queries, b, d, centroids, k, reply } => {
                            let res = engine.coarse(&queries, b, d, &centroids, k);
                            let _ = reply.send(res);
                        }
                        EngineMsg::Shutdown => break,
                    }
                }
            })
            .context("spawn engine thread")?;
        let (stats, num_executables) = ready_rx.recv().context("engine thread died")??;
        Ok(EngineHandle { tx, stats, num_executables })
    }

    /// Synchronous batched coarse scoring through the engine thread. Takes
    /// the query matrix by reference so callers can keep one reusable
    /// batch buffer; the owned copy the channel needs is made here (and
    /// only on the engine path).
    pub fn coarse(
        &self,
        queries: &[f32],
        b: usize,
        d: usize,
        centroids: Arc<Vec<f32>>,
        k: usize,
    ) -> Result<(Vec<f32>, bool)> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(EngineMsg::Coarse { queries: queries.to_vec(), b, d, centroids, k, reply })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().context("engine reply dropped")?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(EngineMsg::Shutdown);
    }
}

/// Default artifact directory: `$ZANN_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var("ZANN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_artifact_names() {
        assert_eq!(parse_coarse_name("coarse__b64_k1024_d32.hlo.txt"), Some((64, 1024, 32)));
        assert_eq!(parse_coarse_name("coarse__b1_k256_d8.hlo.txt"), Some((1, 256, 8)));
        assert_eq!(parse_coarse_name("pqlut__b64_m8_ks256_ds4.hlo.txt"), None);
        assert_eq!(parse_coarse_name("manifest.json"), None);
    }

    #[test]
    fn fallback_matches_quant() {
        // The fused fallback agrees with the naive per-row loop to the
        // acceptance tolerance (1e-4 relative).
        use crate::util::Rng;
        let mut rng = Rng::new(100);
        let (b, d, k) = (3usize, 8usize, 5usize);
        let q: Vec<f32> = (0..b * d).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..k * d).map(|_| rng.normal()).collect();
        let out = coarse_fallback(&q, b, d, &c, k);
        for qi in 0..b {
            for ci in 0..k {
                let want = crate::quant::l2_sq(&q[qi * d..(qi + 1) * d], &c[ci * d..(ci + 1) * d]);
                assert!((out[qi * k + ci] - want).abs() <= 1e-4 * want.max(1.0));
            }
        }
    }

    #[test]
    fn fallback_into_matches_fallback() {
        use crate::util::Rng;
        let mut rng = Rng::new(101);
        let (b, d, k) = (7usize, 12usize, 33usize);
        let q: Vec<f32> = (0..b * d).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..k * d).map(|_| rng.normal()).collect();
        let want = coarse_fallback(&q, b, d, &c, k);
        let norms = crate::quant::coarse::centroid_norms(&c, d);
        let mut out = Vec::new();
        for threads in [1usize, 3] {
            coarse_fallback_into(&q, b, d, &c, &norms, threads, &mut out);
            assert_eq!(out, want, "threads={threads}");
        }
    }
}
