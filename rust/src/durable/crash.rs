//! Deterministic crash points for the injection harness.
//!
//! Durable I/O code calls [`point("site")`](point) at every boundary where a
//! real crash could interleave with the filesystem (before a write, between
//! write and fsync, between rename and directory fsync, ...). In production
//! the call is a branch on a thread-local that is always `None` — effectively
//! free. Under the harness, [`arm(n)`] schedules the n-th subsequent point on
//! *this thread* to fail with [`InjectedCrash`]; the caller then abandons the
//! store exactly as a killed process would, and the harness reopens the
//! directory to check recovery.
//!
//! The countdown is thread-local (not global) so parallel `cargo test`
//! threads cannot trip each other's injections. All durable I/O runs on the
//! calling thread, so the thread-local scope is exactly the store's scope.

use std::cell::Cell;
use std::fmt;

thread_local! {
    /// Remaining points before the armed crash fires; `None` = disarmed.
    static COUNTDOWN: Cell<Option<u64>> = const { Cell::new(None) };
    /// Site label of the point that fired since the last `arm`/`disarm`.
    static FIRED: Cell<Option<&'static str>> = const { Cell::new(None) };
}

/// Error returned by a crash point when its countdown expires. From the
/// store's perspective this is indistinguishable from the process dying at
/// that boundary: the operation reports failure and on-disk state is left
/// exactly as the interrupted syscall sequence would leave it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedCrash {
    /// Label of the crash point that fired.
    pub site: &'static str,
}

impl fmt::Display for InjectedCrash {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected crash at {}", self.site)
    }
}

impl std::error::Error for InjectedCrash {}

/// Arm the thread-local countdown: the `nth` crash point reached after this
/// call (0 = the very next one) fails with [`InjectedCrash`]. Clears any
/// previously recorded fired site.
pub fn arm(nth: u64) {
    COUNTDOWN.with(|c| c.set(Some(nth)));
    FIRED.with(|f| f.set(None));
}

/// Disarm the countdown and return the site that fired since the last
/// [`arm`], if any. The harness uses the return value — not error identity —
/// to distinguish an injected crash from a genuine failure, because the
/// vendored `anyhow` shim flattens error types to strings.
pub fn disarm() -> Option<&'static str> {
    COUNTDOWN.with(|c| c.set(None));
    FIRED.with(|f| f.take())
}

/// Site that fired since the last [`arm`], without disarming.
pub fn fired() -> Option<&'static str> {
    FIRED.with(|f| f.get())
}

/// A crash boundary. No-op unless armed on this thread; when the countdown
/// reaches zero, records `site`, disarms, and returns `Err(InjectedCrash)`.
/// Fires at most once per [`arm`] so recovery code running after the "crash"
/// is not re-interrupted.
pub fn point(site: &'static str) -> Result<(), InjectedCrash> {
    COUNTDOWN.with(|c| match c.get() {
        None => Ok(()),
        Some(0) => {
            c.set(None);
            FIRED.with(|f| f.set(Some(site)));
            Err(InjectedCrash { site })
        }
        Some(n) => {
            c.set(Some(n - 1));
            Ok(())
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_points_are_free_and_countdown_fires_once() {
        assert!(point("a").is_ok());
        assert_eq!(fired(), None);

        arm(2);
        assert!(point("a").is_ok());
        assert!(point("b").is_ok());
        let err = point("c").unwrap_err();
        assert_eq!(err.site, "c");
        assert_eq!(fired(), Some("c"));
        // Fired once; later points pass even without re-arming.
        assert!(point("d").is_ok());
        assert_eq!(disarm(), Some("c"));
        assert_eq!(disarm(), None);
    }

    #[test]
    fn arm_zero_fires_immediately_and_disarm_cancels() {
        arm(0);
        assert_eq!(point("x").unwrap_err().site, "x");

        arm(5);
        assert_eq!(disarm(), None);
        assert!(point("y").is_ok());
    }
}
