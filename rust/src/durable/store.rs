//! [`DurableDynamic`]: a `DynamicIvf` whose adds and deletes survive kill -9.
//!
//! Directory layout (all names resolved through the manifest):
//!
//! ```text
//! dir/MANIFEST          kind=dynamic, base=base-<g>.zann, wal=wal-<g>.log
//! dir/base-<g>.zann     checkpointed KIND_DYNAMIC container (atomic commit)
//! dir/wal-<g>.log       operations acknowledged since the checkpoint
//! ```
//!
//! Write path: every `add`/`delete` appends one WAL record and fsyncs
//! *before* touching the in-memory index — the WAL `Ok` is the
//! acknowledgement. [`DurableDynamic::checkpoint`] compacts, commits a new
//! base container and a fresh empty WAL under generation `g+1`, then flips
//! the manifest; old-generation files are removed only after the flip, so a
//! crash anywhere leaves one fully consistent generation reachable.
//!
//! Recovery ([`DurableDynamic::open`]): load the manifest, open the base
//! container, replay the WAL's valid prefix onto it (bit-identical to the
//! pre-crash index per the dynamic parity invariant), truncate any torn
//! tail, and reopen the log for append. After *any* I/O error (injected or
//! real) the handle must be dropped and the directory reopened — exactly
//! the contract a crashed process is held to.

use std::ops::Range;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::api::{persist, AnnIndex};
use crate::dynamic::DynamicIvf;

use super::atomic;
use super::crash;
use super::manifest::{self, Manifest};
use super::wal::{self, Wal, WalRecord};

/// Manifest `kind` value for a dynamic store directory.
pub const KIND_DYNAMIC_DIR: &str = "dynamic";

/// What [`DurableDynamic::open`] had to do to get back to a consistent state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Manifest generation the store opened into.
    pub generation: u64,
    /// WAL size after recovery (header + acknowledged records).
    pub wal_bytes: u64,
    /// Records replayed onto the base container.
    pub replayed_records: usize,
    /// Rows re-added during replay.
    pub replayed_rows: usize,
    /// Ids re-deleted during replay.
    pub replayed_deletes: usize,
    /// Torn-tail bytes truncated from the WAL (0 on a clean open).
    pub torn_bytes: u64,
    /// Wall-clock microseconds the open + replay took.
    pub recovery_us: u64,
}

/// A crash-safe wrapper around [`DynamicIvf`] (see module docs).
pub struct DurableDynamic {
    dir: PathBuf,
    index: DynamicIvf,
    wal: Wal,
    generation: u64,
}

fn base_name(generation: u64) -> String {
    format!("base-{generation}.zann")
}

fn wal_name(generation: u64) -> String {
    format!("wal-{generation}.log")
}

impl DurableDynamic {
    /// Initialize `dir` as generation 0 of a durable store seeded with
    /// `index`. The directory is created if needed and must not already
    /// hold a manifest.
    pub fn create(dir: &Path, index: DynamicIvf) -> Result<DurableDynamic> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create durable dir {}", dir.display()))?;
        ensure!(
            !manifest::manifest_path(dir).exists(),
            "durable dir {} already has a manifest",
            dir.display()
        );
        let bytes = index.to_bytes()?;
        atomic::commit_bytes(&dir.join(base_name(0)), &bytes)?;
        let wal = Wal::create(&dir.join(wal_name(0)))?;
        let m = Manifest {
            generation: 0,
            entries: vec![
                ("kind".into(), KIND_DYNAMIC_DIR.into()),
                ("base".into(), base_name(0)),
                ("wal".into(), wal_name(0)),
            ],
        };
        m.commit(dir)?;
        Ok(DurableDynamic { dir: dir.to_path_buf(), index, wal, generation: 0 })
    }

    /// Open `dir`, replaying acknowledged operations and truncating any
    /// torn WAL tail (see module docs for the full recovery contract).
    pub fn open(dir: &Path) -> Result<(DurableDynamic, RecoveryStats)> {
        let t0 = std::time::Instant::now();
        let m = Manifest::load(dir)?;
        ensure!(
            m.get("kind") == Some(KIND_DYNAMIC_DIR),
            "durable dir {}: manifest kind is {:?}, not a dynamic store",
            dir.display(),
            m.get("kind")
        );
        let base = m.get("base").context("manifest missing 'base' entry")?;
        let wal_file = m.get("wal").context("manifest missing 'wal' entry")?;
        let mut index = persist::open_dynamic(&dir.join(base))?;

        let wal_path = dir.join(wal_file);
        let replayed = wal::replay(&wal_path)?;
        let (mut rows_n, mut dels_n) = (0usize, 0usize);
        for rec in &replayed.records {
            apply(&mut index, rec)?;
            match rec {
                WalRecord::Add { dim, rows, .. } => rows_n += rows.len() / *dim as usize,
                WalRecord::Delete { ids } => dels_n += ids.len(),
            }
        }
        if replayed.torn_bytes > 0 {
            wal::truncate_to(&wal_path, replayed.valid_bytes)?;
        }
        let wal = Wal::open_append(&wal_path, replayed.valid_bytes)?;

        let stats = RecoveryStats {
            generation: m.generation,
            wal_bytes: wal.bytes(),
            replayed_records: replayed.records.len(),
            replayed_rows: rows_n,
            replayed_deletes: dels_n,
            torn_bytes: replayed.torn_bytes,
            recovery_us: t0.elapsed().as_micros() as u64,
        };
        crate::obs::histogram("zann_recovery_us", &[]).observe(stats.recovery_us);
        Ok((
            DurableDynamic { dir: dir.to_path_buf(), index, wal, generation: m.generation },
            stats,
        ))
    }

    /// Append rows (row-major, `dim()` floats each). The WAL fsync happens
    /// before the in-memory apply: when this returns `Ok`, the rows survive
    /// any subsequent crash.
    pub fn add(&mut self, rows: &[f32]) -> Result<Range<u32>> {
        let dim = self.index.dim();
        ensure!(!rows.is_empty(), "add: empty row batch");
        ensure!(
            rows.len() % dim == 0,
            "add: {} floats is not a whole number of {dim}-dim rows",
            rows.len()
        );
        let base = self.index.next_id();
        // Mirror the index's own id-space check *before* logging, so the WAL
        // never acknowledges a record the in-memory apply would reject.
        ensure!(
            base as u64 + (rows.len() / dim) as u64 <= u32::MAX as u64,
            "add: id space exhausted"
        );
        self.wal.append(&wal::encode_add(base, dim as u32, rows))?;
        self.index.add(rows)
    }

    /// Tombstone one id. A no-op delete (unknown or already-dead id) is not
    /// logged; a real one is durable once this returns `Ok(true)`.
    pub fn delete(&mut self, id: u32) -> Result<bool> {
        if !self.index.is_live(id) {
            return Ok(false);
        }
        self.wal.append(&wal::encode_delete(&[id]))?;
        let deleted = self.index.delete(id)?;
        debug_assert!(deleted, "live id {id} failed to delete after WAL ack");
        Ok(deleted)
    }

    /// Compact the index and roll the directory to generation `g+1`: commit
    /// the compacted container and a fresh empty WAL, flip the manifest,
    /// then drop the old generation's files. Crash-safe at every boundary —
    /// until the manifest flip the old generation (base + full WAL) is the
    /// one recovery sees.
    pub fn checkpoint(&mut self) -> Result<()> {
        self.index.compact()?;
        let bytes = self.index.to_bytes()?;
        let next = self.generation + 1;
        atomic::commit_bytes(&self.dir.join(base_name(next)), &bytes)?;
        let new_wal = Wal::create(&self.dir.join(wal_name(next)))?;
        crash::point("checkpoint.manifest")?;
        let m = Manifest {
            generation: next,
            entries: vec![
                ("kind".into(), KIND_DYNAMIC_DIR.into()),
                ("base".into(), base_name(next)),
                ("wal".into(), wal_name(next)),
            ],
        };
        m.commit(&self.dir)?;
        // The flip is the commit point; everything below is cleanup of the
        // now-unreachable old generation and may be lost to a crash.
        let old = self.generation;
        self.generation = next;
        self.wal = new_wal;
        crash::point("checkpoint.cleanup")?;
        let _ = std::fs::remove_file(self.dir.join(base_name(old)));
        let _ = std::fs::remove_file(self.dir.join(wal_name(old)));
        Ok(())
    }

    /// The underlying searchable index.
    pub fn index(&self) -> &DynamicIvf {
        &self.index
    }

    /// Current manifest generation.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Durable WAL size in bytes.
    pub fn wal_bytes(&self) -> u64 {
        self.wal.bytes()
    }

    /// Directory this store lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

/// Apply one replayed record to `index`, validating that the log and the
/// base container agree on id assignment and dimensionality.
pub fn apply(index: &mut DynamicIvf, rec: &WalRecord) -> Result<()> {
    match rec {
        WalRecord::Add { base, dim, rows } => {
            ensure!(
                *dim as usize == index.dim(),
                "wal replay: record dim {dim} != index dim {}",
                index.dim()
            );
            ensure!(
                *base == index.next_id(),
                "wal replay: add at base {base} but index next_id is {} \
                 (log does not belong to this base container)",
                index.next_id()
            );
            index.add(rows)?;
        }
        WalRecord::Delete { ids } => {
            for &id in ids {
                if id >= index.next_id() {
                    bail!(
                        "wal replay: delete of unassigned id {id} (next_id {})",
                        index.next_id()
                    );
                }
                index.delete(id)?;
            }
        }
    }
    Ok(())
}
