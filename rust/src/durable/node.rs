//! Durable directory layout for a sharded serving node.
//!
//! ```text
//! dir/MANIFEST              kind=node, router=ROUTER, shard<s>=<file>, gen g
//! dir/ROUTER                routing table (see below), committed atomically
//! dir/shard-<s>-g<g>.zann   one-shard KIND_SHARDED snapshot of shard s
//! ```
//!
//! Every shard swap writes the *new* shard container under the next
//! generation's name, then flips the manifest ([`commit_shard`]) — the flip
//! is the only commit point, so a crash mid-swap leaves the previous
//! generation fully intact and reachable; a half-swapped directory cannot
//! exist. [`open_node_dir`] reassembles the node's `ShardedIndex` strictly
//! through the manifest, so stale generations, commit temp files, and torn
//! leftovers are never even opened.
//!
//! ROUTER file format (LE): `[b"ZRTR"][version: u32 = 1][dim: u32]`
//! `[router: write_router bytes][crc: u32 = CRC-32C of all prior bytes]`.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::api::persist;
use crate::serve::persist::{read_router, write_router};
use crate::serve::sharded::{Router, ShardedIndex};
use crate::util::crc32c::Crc32c;
use crate::util::{ReadBuf, WriteBuf};

use super::atomic;
use super::crash;
use super::manifest::{self, Manifest};

/// Manifest `kind` value for a node directory.
pub const KIND_NODE_DIR: &str = "node";
/// File name of the routing table inside a node directory.
pub const ROUTER_FILE: &str = "ROUTER";
/// Magic prefix of the ROUTER file.
pub const ROUTER_MAGIC: [u8; 4] = *b"ZRTR";
/// ROUTER file format version.
pub const ROUTER_VERSION: u32 = 1;

fn shard_file(s: usize, generation: u64) -> String {
    format!("shard-{s}-g{generation}.zann")
}

fn encode_router(router: &Router, dim: usize) -> Vec<u8> {
    let mut w = WriteBuf::new();
    w.bytes.extend_from_slice(&ROUTER_MAGIC);
    w.put_u32(ROUTER_VERSION);
    w.put_u32(dim as u32);
    write_router(&mut w, router);
    let mut crc = Crc32c::new();
    crc.update(&w.bytes);
    let sum = crc.finalize();
    w.put_u32(sum);
    w.bytes
}

fn decode_router(bytes: &[u8]) -> Result<(Router, usize)> {
    ensure!(
        bytes.len() >= 4 + 4 + 4 + 4 && bytes[..4] == ROUTER_MAGIC,
        "router file: bad magic or short file ({} bytes)",
        bytes.len()
    );
    let body = &bytes[..bytes.len() - 4];
    let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    let mut crc = Crc32c::new();
    crc.update(body);
    ensure!(crc.finalize() == stored, "router file: CRC mismatch");
    let mut r = ReadBuf::new(&body[4..]);
    let version = r.get_u32()?;
    ensure!(version == ROUTER_VERSION, "router file: unsupported version {version}");
    let dim = r.get_u32()? as usize;
    ensure!(dim > 0, "router file: zero dim");
    let router = read_router(&mut r, dim)?;
    ensure!(r.remaining() == 0, "router file: trailing bytes");
    Ok((router, dim))
}

fn node_manifest(generation: u64, shard_files: &[String]) -> Manifest {
    let mut entries = vec![
        ("kind".to_string(), KIND_NODE_DIR.to_string()),
        ("router".to_string(), ROUTER_FILE.to_string()),
    ];
    for (s, f) in shard_files.iter().enumerate() {
        entries.push((format!("shard{s}"), f.clone()));
    }
    Manifest { generation, entries }
}

/// Current shard file names (`shard0..shardN-1`) recorded in `m`.
fn shard_files(m: &Manifest) -> Result<Vec<String>> {
    let mut files = Vec::new();
    while let Some(f) = m.get(&format!("shard{}", files.len())) {
        files.push(f.to_string());
    }
    ensure!(!files.is_empty(), "node manifest lists no shards");
    Ok(files)
}

/// Initialize `dir` as generation 0 of a node directory: router file plus
/// one single-shard snapshot container per shard (as produced by
/// `ServeNode::snapshot_shard`). The directory must not already hold a
/// manifest.
pub fn init_node_dir(
    dir: &Path,
    router: &Router,
    dim: usize,
    snapshots: &[Vec<u8>],
) -> Result<()> {
    ensure!(!snapshots.is_empty(), "node directory needs at least one shard");
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create node dir {}", dir.display()))?;
    ensure!(
        !manifest::manifest_path(dir).exists(),
        "node dir {} already has a manifest",
        dir.display()
    );
    atomic::commit_bytes(&dir.join(ROUTER_FILE), &encode_router(router, dim))?;
    let mut files = Vec::with_capacity(snapshots.len());
    for (s, snap) in snapshots.iter().enumerate() {
        let f = shard_file(s, 0);
        atomic::commit_bytes(&dir.join(&f), snap)?;
        files.push(f);
    }
    node_manifest(0, &files).commit(dir)
}

/// Swap shard `s`: commit `snapshot` under generation `g+1`'s file name,
/// flip the manifest, then drop the superseded file. Crash-safe — before
/// the flip, recovery sees generation `g` untouched.
pub fn commit_shard(dir: &Path, s: usize, snapshot: &[u8]) -> Result<u64> {
    let m = Manifest::load(dir)?;
    ensure!(
        m.get("kind") == Some(KIND_NODE_DIR),
        "durable dir {}: manifest kind is {:?}, not a node directory",
        dir.display(),
        m.get("kind")
    );
    let mut files = shard_files(&m)?;
    ensure!(s < files.len(), "shard {s} out of range ({} shards)", files.len());
    let next = m.generation + 1;
    let new_file = shard_file(s, next);
    atomic::commit_bytes(&dir.join(&new_file), snapshot)?;
    let old_file = std::mem::replace(&mut files[s], new_file);
    crash::point("node.manifest")?;
    node_manifest(next, &files).commit(dir)?;
    // Manifest flipped: generation `next` is now the one recovery sees.
    crash::point("node.cleanup")?;
    if old_file != files[s] {
        let _ = std::fs::remove_file(dir.join(old_file));
    }
    Ok(next)
}

/// Reopen a node directory into its current generation's `ShardedIndex`.
/// Returns the index and the manifest generation. Only files named by the
/// manifest are touched.
pub fn open_node_dir(dir: &Path) -> Result<(ShardedIndex, u64)> {
    let m = Manifest::load(dir)?;
    ensure!(
        m.get("kind") == Some(KIND_NODE_DIR),
        "durable dir {}: manifest kind is {:?}, not a node directory",
        dir.display(),
        m.get("kind")
    );
    let router_file = m.get("router").context("node manifest missing 'router' entry")?;
    let router_bytes = std::fs::read(dir.join(router_file))
        .with_context(|| format!("read router file in {}", dir.display()))?;
    let (router, dim) = decode_router(&router_bytes)?;

    let files = shard_files(&m)?;
    let mut shards = Vec::with_capacity(files.len());
    let mut id_maps = Vec::with_capacity(files.len());
    let mut checksummed = true;
    for (s, f) in files.iter().enumerate() {
        let snap = persist::open_sharded(&dir.join(f))
            .with_context(|| format!("opening shard {s} of node dir {}", dir.display()))?;
        ensure!(
            snap.num_shards() == 1,
            "shard {s} snapshot holds {} shards (expected 1)",
            snap.num_shards()
        );
        ensure!(
            snap.dim() == dim,
            "shard {s} snapshot has dim {} (router says {dim})",
            snap.dim()
        );
        checksummed &= snap.checksummed;
        let (_, mut inner, mut maps, _) = snap.into_parts();
        shards.push(inner.remove(0));
        id_maps.push(maps.remove(0));
    }
    let idx = ShardedIndex::from_parts(router, shards, id_maps, dim, checksummed)?;
    Ok((idx, m.generation))
}
