//! Crash-safe durability: write-ahead logging, atomic container commits,
//! and versioned directory manifests.
//!
//! The static index formats (`api/persist.rs`) make corruption *detectable*
//! (CRC-32C section framing, `ZEND` terminator); this module makes the write
//! path *recoverable*. Three pieces compose:
//!
//! - [`atomic`] — `commit_bytes` writes a sibling temp file, fsyncs it,
//!   renames it over the destination, and fsyncs the directory. A crash at
//!   any point leaves either the old file or the new file, never a torn one.
//! - [`wal`] — a CRC-32C-framed, fsync-on-append write-ahead log for
//!   `DynamicIvf` adds and deletes. An operation is acknowledged only after
//!   its record is on disk; replay truncates a torn tail back to the last
//!   valid frame and reapplies exactly the acknowledged prefix.
//! - [`manifest`] — a tiny generation-numbered key→file map, itself committed
//!   atomically, so multi-file directories (a dynamic store's base+WAL, a
//!   serving node's router+shards) flip between consistent generations.
//!
//! [`store::DurableDynamic`] ties the first two together for a single
//! mutable index; [`node`] provides the manifest-driven directory layout for
//! a sharded `ServeNode`. [`crash`] hosts the deterministic kill-point
//! machinery the crash-injection harness (`eval/crashes.rs`) drives.

pub mod atomic;
pub mod crash;
pub mod manifest;
pub mod node;
pub mod store;
pub mod wal;
