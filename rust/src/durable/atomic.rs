//! Atomic file commits: temp file → fsync → rename → fsync directory.
//!
//! [`commit_bytes`] is the single write primitive every container writer
//! routes through (`persist::save`, CLI `build`, dynamic checkpoints, node
//! shard swaps, manifest flips). The sequence guarantees that after a crash
//! at *any* instruction the destination path holds either the complete old
//! bytes or the complete new bytes:
//!
//! 1. write the payload to a sibling temp file (`.{name}.tmp-{pid}-{seq}`),
//! 2. `fsync` the temp file so the payload is on disk before it is visible,
//! 3. `rename` it over the destination (atomic on POSIX),
//! 4. `fsync` the parent directory so the rename itself is durable.
//!
//! On error (including an injected crash) the temp file is deliberately left
//! behind: cleaning it up would make the error path's on-disk state differ
//! from a real kill at the same point, which is exactly what the crash
//! harness verifies. Manifest-driven readers never look at temp names, and
//! the next successful commit of the same path reuses a fresh temp name.

use std::fs::{self, File};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use super::crash;

/// Monotonic suffix so concurrent commits to the same path never collide.
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// `fsync` a directory so a rename or create inside it is durable. On
/// platforms where directories cannot be fsynced the error is surfaced —
/// callers rely on this for their durability contract.
pub fn fsync_dir(dir: &Path) -> Result<()> {
    let d = File::open(dir).with_context(|| format!("open dir {} for fsync", dir.display()))?;
    d.sync_all()
        .with_context(|| format!("fsync dir {}", dir.display()))?;
    Ok(())
}

/// Atomically replace `path` with `bytes` (see module docs for the exact
/// syscall discipline). After `Ok(())` the new contents are durable; after
/// `Err` the destination still holds its previous contents (or still does
/// not exist), never a torn mix.
pub fn commit_bytes(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .context("commit_bytes: path has no utf-8 file name")?;
    let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = dir.join(format!(".{name}.tmp-{}-{seq}", std::process::id()));

    let mut f =
        File::create(&tmp).with_context(|| format!("create temp file {}", tmp.display()))?;
    // Simulated torn write: persist a prefix of the payload, then "die".
    // The destination is untouched, so recovery must still see old bytes.
    if let Err(e) = crash::point("commit.write") {
        let _ = f.write_all(&bytes[..bytes.len() / 3]);
        let _ = f.sync_all();
        return Err(e.into());
    }
    f.write_all(bytes)
        .with_context(|| format!("write temp file {}", tmp.display()))?;
    crash::point("commit.fsync_file")?;
    f.sync_all()
        .with_context(|| format!("fsync temp file {}", tmp.display()))?;
    drop(f);

    crash::point("commit.rename")?;
    fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    crash::point("commit.fsync_dir")?;
    fsync_dir(&dir)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("zann-atomic-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn commit_replaces_contents_atomically() {
        let d = tdir("basic");
        let p = d.join("file.bin");
        commit_bytes(&p, b"one").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"one");
        commit_bytes(&p, b"two-longer").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"two-longer");
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn crash_at_every_point_leaves_old_bytes_intact() {
        let d = tdir("crash");
        let p = d.join("file.bin");
        commit_bytes(&p, b"original contents").unwrap();

        for nth in 0.. {
            crash::arm(nth);
            let res = commit_bytes(&p, b"replacement payload, longer than before");
            let site = crash::disarm();
            match site {
                Some(site) => {
                    assert!(res.is_err());
                    // The destination must hold a *complete* generation: the
                    // old bytes before the rename boundary, the new bytes
                    // after it — never a torn mix.
                    let now = fs::read(&p).unwrap();
                    if site == "commit.fsync_dir" {
                        assert_eq!(now, b"replacement payload, longer than before");
                    } else {
                        assert_eq!(
                            now, b"original contents",
                            "torn commit visible after crash at point #{nth} ({site})"
                        );
                    }
                }
                None => {
                    // Countdown outlived the commit: it completed untouched.
                    res.unwrap();
                    assert_eq!(fs::read(&p).unwrap(), b"replacement payload, longer than before");
                    break;
                }
            }
        }
        let _ = fs::remove_dir_all(&d);
    }
}
