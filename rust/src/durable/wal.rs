//! CRC-32C-framed write-ahead log for dynamic ingest.
//!
//! File layout:
//!
//! ```text
//! [b"ZWAL"][version: u32 LE = 1]                      -- 8-byte header
//! repeated records:
//!   [len: u32 LE][crc: u32 LE = CRC-32C(payload)][payload: len bytes]
//! ```
//!
//! Record payloads (first byte is the op tag):
//!
//! - `REC_ADD = 1`:    `[1][base: u32][dim: u32][nf32: u32][rows: nf32 × f32 LE]`
//! - `REC_DELETE = 2`: `[2][count: u32][ids: count × u32 LE]`
//!
//! Discipline: [`Wal::append`] frames the payload, writes it, and fsyncs
//! before returning — an `Ok` return *is* the acknowledgement. A crash
//! mid-append leaves a torn tail: a short header, a short payload, or a
//! CRC mismatch. [`replay`] is **pure** — it never modifies the file — and
//! stops at the first invalid frame, reporting how many trailing bytes are
//! torn; [`truncate_to`] chops the tail off when the owner decides to
//! recover (so read-only inspection like `zann info` never mutates).

use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::obs::StaticCounter;
use crate::util::crc32c::Crc32c;
use crate::util::{ReadBuf, WriteBuf};

use super::{atomic, crash};

/// Magic + version prefix of every WAL file.
pub const WAL_MAGIC: [u8; 4] = *b"ZWAL";
/// Current (and only) WAL format version.
pub const WAL_VERSION: u32 = 1;
/// Byte length of the file header.
pub const WAL_HEADER: u64 = 8;

/// Op tag for an add-rows record.
pub const REC_ADD: u8 = 1;
/// Op tag for a delete-ids record.
pub const REC_DELETE: u8 = 2;

static WAL_APPENDS: StaticCounter = StaticCounter::new("zann_wal_appends_total");
static WAL_BYTES: StaticCounter = StaticCounter::new("zann_wal_bytes");
static WAL_REPLAYED: StaticCounter = StaticCounter::new("zann_wal_replayed_records");

/// A decoded WAL record.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// Rows appended starting at id `base` (row-major, `dim` floats each).
    Add { base: u32, dim: u32, rows: Vec<f32> },
    /// Ids tombstoned by this operation.
    Delete { ids: Vec<u32> },
}

/// Encode an add-rows payload. `rows.len()` must be a multiple of `dim`.
pub fn encode_add(base: u32, dim: u32, rows: &[f32]) -> Vec<u8> {
    debug_assert!(dim > 0 && rows.len() % dim as usize == 0);
    let mut w = WriteBuf::new();
    w.put_u8(REC_ADD);
    w.put_u32(base);
    w.put_u32(dim);
    w.put_u32(rows.len() as u32);
    for &v in rows {
        w.put_f32(v);
    }
    w.bytes
}

/// Encode a delete-ids payload.
pub fn encode_delete(ids: &[u32]) -> Vec<u8> {
    let mut w = WriteBuf::new();
    w.put_u8(REC_DELETE);
    w.put_u32(ids.len() as u32);
    for &id in ids {
        w.put_u32(id);
    }
    w.bytes
}

/// Decode one record payload. A payload that framed correctly (length and
/// CRC valid) but does not decode is a hard error, not a torn tail — it
/// means the writer and reader disagree on the format.
pub fn decode(payload: &[u8]) -> Result<WalRecord> {
    let mut r = ReadBuf::new(payload);
    let tag = r.get_u8().context("wal record: missing op tag")?;
    match tag {
        REC_ADD => {
            let base = r.get_u32()?;
            let dim = r.get_u32()?;
            let nf32 = r.get_u32()? as usize;
            ensure!(dim > 0, "wal add record: zero dim");
            ensure!(
                nf32 % dim as usize == 0,
                "wal add record: {nf32} floats not divisible by dim {dim}"
            );
            ensure!(
                r.remaining() == nf32 * 4,
                "wal add record: payload holds {} bytes, expected {}",
                r.remaining(),
                nf32 * 4
            );
            let mut rows = Vec::with_capacity(nf32);
            for _ in 0..nf32 {
                rows.push(r.get_f32()?);
            }
            Ok(WalRecord::Add { base, dim, rows })
        }
        REC_DELETE => {
            let count = r.get_u32()? as usize;
            ensure!(
                r.remaining() == count * 4,
                "wal delete record: payload holds {} bytes, expected {}",
                r.remaining(),
                count * 4
            );
            let mut ids = Vec::with_capacity(count);
            for _ in 0..count {
                ids.push(r.get_u32()?);
            }
            Ok(WalRecord::Delete { ids })
        }
        other => bail!("wal record: unknown op tag {other}"),
    }
}

/// An open, append-only WAL handle.
pub struct Wal {
    file: File,
    path: PathBuf,
    /// Bytes durably on disk (header + complete records).
    bytes: u64,
}

impl Wal {
    /// Create a fresh WAL at `path` (truncating any existing file), write the
    /// header, and fsync file + parent directory so the empty log itself is
    /// durable before any append is acknowledged against it.
    pub fn create(path: &Path) -> Result<Wal> {
        crash::point("wal.create")?;
        let mut file = File::create(path)
            .with_context(|| format!("create wal {}", path.display()))?;
        let mut hdr = [0u8; WAL_HEADER as usize];
        hdr[..4].copy_from_slice(&WAL_MAGIC);
        hdr[4..].copy_from_slice(&WAL_VERSION.to_le_bytes());
        file.write_all(&hdr)?;
        file.sync_all()
            .with_context(|| format!("fsync wal {}", path.display()))?;
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                atomic::fsync_dir(dir)?;
            }
        }
        Ok(Wal { file, path: path.to_path_buf(), bytes: WAL_HEADER })
    }

    /// Open an existing WAL for appending. `valid_bytes` is the durable
    /// prefix established by [`replay`] (+ [`truncate_to`] if the tail was
    /// torn); appends continue from there.
    pub fn open_append(path: &Path, valid_bytes: u64) -> Result<Wal> {
        let file = OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("open wal {} for append", path.display()))?;
        let len = file.metadata()?.len();
        ensure!(
            len == valid_bytes,
            "wal {}: file is {len} bytes but valid prefix is {valid_bytes}; truncate first",
            path.display()
        );
        Ok(Wal { file, path: path.to_path_buf(), bytes: valid_bytes })
    }

    /// Append one record and fsync. When `Ok` returns, the record is durable:
    /// this return is the acknowledgement the recovery contract protects. On
    /// error the file may hold a torn tail; the handle must be discarded and
    /// the log reopened through [`replay`].
    pub fn append(&mut self, payload: &[u8]) -> Result<()> {
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        let mut crc = Crc32c::new();
        crc.update(payload);
        frame.extend_from_slice(&crc.finalize().to_le_bytes());
        frame.extend_from_slice(payload);

        // Simulated torn append: a prefix of the frame reaches disk, then
        // the "process dies". Replay must give back exactly the old prefix.
        if let Err(e) = crash::point("wal.write") {
            let _ = self.file.write_all(&frame[..frame.len() * 2 / 3]);
            let _ = self.file.sync_all();
            return Err(e.into());
        }
        self.file
            .write_all(&frame)
            .with_context(|| format!("append to wal {}", self.path.display()))?;
        crash::point("wal.fsync")?;
        self.file
            .sync_all()
            .with_context(|| format!("fsync wal {}", self.path.display()))?;
        self.bytes += frame.len() as u64;
        WAL_APPENDS.inc();
        WAL_BYTES.add(frame.len() as u64);
        Ok(())
    }

    /// Durable size of the log in bytes (header + acknowledged records).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Path this WAL writes to.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Result of scanning a WAL file.
#[derive(Debug, Clone, PartialEq)]
pub struct Replay {
    /// Records in the valid prefix, in append order.
    pub records: Vec<WalRecord>,
    /// Byte length of the valid prefix (header + complete records).
    pub valid_bytes: u64,
    /// Bytes past the valid prefix (a torn tail from an interrupted append).
    pub torn_bytes: u64,
}

/// Scan `path` and decode its valid prefix. Pure: the file is never
/// modified, so read-only consumers (`zann info`) can call this safely.
/// Scanning stops at the first frame whose header is short, whose payload is
/// short, or whose CRC mismatches — everything after that point is reported
/// as `torn_bytes`. A corrupt *header* (bad magic/version) is an error, not
/// a torn tail: the header is fsynced at create time, so it can only be
/// wrong through external corruption.
pub fn replay(path: &Path) -> Result<Replay> {
    let buf = fs::read(path).with_context(|| format!("read wal {}", path.display()))?;
    ensure!(
        buf.len() as u64 >= WAL_HEADER && buf[..4] == WAL_MAGIC,
        "wal {}: bad magic or short header",
        path.display()
    );
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    ensure!(
        version == WAL_VERSION,
        "wal {}: unsupported version {version}",
        path.display()
    );

    let mut records = Vec::new();
    let mut pos = WAL_HEADER as usize;
    loop {
        if buf.len() - pos < 8 {
            break; // short frame header => torn tail (or clean EOF)
        }
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
        if buf.len() - pos - 8 < len {
            break; // short payload => torn tail
        }
        let payload = &buf[pos + 8..pos + 8 + len];
        let mut c = Crc32c::new();
        c.update(payload);
        if c.finalize() != crc {
            break; // CRC mismatch => torn tail
        }
        records.push(decode(payload)?);
        pos += 8 + len;
    }
    WAL_REPLAYED.add(records.len() as u64);
    Ok(Replay {
        records,
        valid_bytes: pos as u64,
        torn_bytes: (buf.len() - pos) as u64,
    })
}

/// Truncate `path` to its valid prefix, discarding a torn tail, and fsync.
/// Called by owners (not read-only inspectors) before reopening for append.
pub fn truncate_to(path: &Path, valid_bytes: u64) -> Result<()> {
    let f = OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("open wal {} for truncate", path.display()))?;
    f.set_len(valid_bytes)
        .with_context(|| format!("truncate wal {} to {valid_bytes}", path.display()))?;
    f.sync_all()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("zann-wal-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_and_pure_replay() {
        let d = tdir("rt");
        let p = d.join("wal.log");
        let mut w = Wal::create(&p).unwrap();
        w.append(&encode_add(0, 2, &[1.0, 2.0, 3.0, 4.0])).unwrap();
        w.append(&encode_delete(&[1])).unwrap();
        let on_disk = w.bytes();
        drop(w);

        let r = replay(&p).unwrap();
        assert_eq!(r.valid_bytes, on_disk);
        assert_eq!(r.torn_bytes, 0);
        assert_eq!(
            r.records,
            vec![
                WalRecord::Add { base: 0, dim: 2, rows: vec![1.0, 2.0, 3.0, 4.0] },
                WalRecord::Delete { ids: vec![1] },
            ]
        );
        // Pure: the file is unchanged byte-for-byte.
        let before = fs::read(&p).unwrap();
        replay(&p).unwrap();
        assert_eq!(before, fs::read(&p).unwrap());
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn torn_tail_at_every_offset_recovers_acknowledged_prefix() {
        let d = tdir("torn");
        let p = d.join("wal.log");
        let mut w = Wal::create(&p).unwrap();
        w.append(&encode_add(0, 3, &[0.5; 6])).unwrap();
        let acked = w.bytes();
        w.append(&encode_delete(&[0, 1, 2, 3])).unwrap();
        let full = fs::read(&p).unwrap();
        drop(w);

        // Cut the file anywhere inside the *last* record: replay must hand
        // back exactly the first record and flag the remainder as torn.
        for cut in acked as usize..full.len() {
            fs::write(&p, &full[..cut]).unwrap();
            let r = replay(&p).unwrap();
            assert_eq!(r.valid_bytes, acked, "cut at {cut}");
            assert_eq!(r.torn_bytes, cut as u64 - acked, "cut at {cut}");
            assert_eq!(r.records.len(), 1, "cut at {cut}");
            // Owner-side recovery: truncate, then appends work again.
            truncate_to(&p, r.valid_bytes).unwrap();
            let mut w2 = Wal::open_append(&p, r.valid_bytes).unwrap();
            w2.append(&encode_delete(&[9])).unwrap();
            let r2 = replay(&p).unwrap();
            assert_eq!(r2.records.len(), 2);
            assert_eq!(r2.torn_bytes, 0);
            fs::write(&p, &full).unwrap();
        }
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_payload_byte_is_a_torn_tail_not_garbage_rows() {
        let d = tdir("flip");
        let p = d.join("wal.log");
        let mut w = Wal::create(&p).unwrap();
        w.append(&encode_add(0, 2, &[1.0, 2.0])).unwrap();
        drop(w);
        let mut bytes = fs::read(&p).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        fs::write(&p, &bytes).unwrap();
        let r = replay(&p).unwrap();
        assert!(r.records.is_empty());
        assert!(r.torn_bytes > 0);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn bad_header_is_an_error() {
        let d = tdir("hdr");
        let p = d.join("wal.log");
        fs::write(&p, b"NOPE\x01\x00\x00\x00").unwrap();
        assert!(replay(&p).is_err());
        let _ = fs::remove_dir_all(&d);
    }
}
