//! Versioned directory manifests.
//!
//! A durable directory (a dynamic store, a serving node) holds several files
//! that must be seen as one consistent *generation*: a base container plus
//! its WAL, or a router plus N shard containers. The manifest is the single
//! small file that names the current generation's members; flipping it (via
//! [`atomic::commit_bytes`]) is the commit point for any multi-file change.
//! Readers resolve every file name through the manifest, so stale
//! generations and commit temp files are simply invisible.
//!
//! Format (all LE, written with `WriteBuf`):
//!
//! ```text
//! [b"ZMAN"][version: u32 = 1][generation: u64]
//! [count: u32] count × ([key: str][file: str])     -- str = u64 len + utf-8
//! [crc: u32 = CRC-32C of all prior bytes]
//! ```

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::util::crc32c::Crc32c;
use crate::util::{ReadBuf, WriteBuf};

use super::atomic;

/// File name of the manifest inside a durable directory.
pub const MANIFEST_FILE: &str = "MANIFEST";
/// Magic prefix of a manifest file.
pub const MANIFEST_MAGIC: [u8; 4] = *b"ZMAN";
/// Current manifest format version.
pub const MANIFEST_VERSION: u32 = 1;

/// The decoded manifest of a durable directory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Monotonic generation number; bumped on every flip.
    pub generation: u64,
    /// Ordered `key -> file name` entries (e.g. `"kind" -> "dynamic"`,
    /// `"base" -> "base-3.zann"`). Keys are unique.
    pub entries: Vec<(String, String)>,
}

impl Manifest {
    /// Value for `key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Serialize to bytes (including the CRC trailer).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WriteBuf::new();
        w.bytes.extend_from_slice(&MANIFEST_MAGIC);
        w.put_u32(MANIFEST_VERSION);
        w.put_u64(self.generation);
        w.put_u32(self.entries.len() as u32);
        for (k, v) in &self.entries {
            w.put_str(k);
            w.put_str(v);
        }
        let mut crc = Crc32c::new();
        crc.update(&w.bytes);
        let sum = crc.finalize();
        w.put_u32(sum);
        w.bytes
    }

    /// Parse manifest bytes, verifying magic, version, and CRC.
    pub fn decode(bytes: &[u8]) -> Result<Manifest> {
        ensure!(
            bytes.len() >= 4 + 4 + 8 + 4 + 4 && bytes[..4] == MANIFEST_MAGIC,
            "manifest: bad magic or short file ({} bytes)",
            bytes.len()
        );
        let body = &bytes[..bytes.len() - 4];
        let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        let mut crc = Crc32c::new();
        crc.update(body);
        ensure!(
            crc.finalize() == stored,
            "manifest: CRC mismatch (file is corrupt or torn)"
        );

        let mut r = ReadBuf::new(&body[4..]);
        let version = r.get_u32()?;
        ensure!(
            version == MANIFEST_VERSION,
            "manifest: unsupported version {version}"
        );
        let generation = r.get_u64()?;
        let count = r.get_u32()? as usize;
        let mut entries = Vec::with_capacity(count.min(1024));
        for _ in 0..count {
            let k = r.get_str()?;
            let v = r.get_str()?;
            ensure!(
                entries.iter().all(|(ek, _): &(String, String)| ek != &k),
                "manifest: duplicate key {k:?}"
            );
            entries.push((k, v));
        }
        ensure!(r.remaining() == 0, "manifest: trailing bytes after entries");
        Ok(Manifest { generation, entries })
    }

    /// Load and decode `dir/MANIFEST`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = manifest_path(dir);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        Manifest::decode(&bytes).with_context(|| format!("manifest {}", path.display()))
    }

    /// Atomically commit this manifest as `dir/MANIFEST`. This is the flip:
    /// once it returns, the directory's current generation is this one.
    pub fn commit(&self, dir: &Path) -> Result<()> {
        atomic::commit_bytes(&manifest_path(dir), &self.encode())
    }
}

/// Path of the manifest file inside `dir`.
pub fn manifest_path(dir: &Path) -> PathBuf {
    dir.join(MANIFEST_FILE)
}

/// Whether `dir` looks like a durable directory (has a manifest file).
pub fn is_durable_dir(dir: &Path) -> bool {
    dir.is_dir() && manifest_path(dir).is_file()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_get() {
        let m = Manifest {
            generation: 7,
            entries: vec![
                ("kind".into(), "dynamic".into()),
                ("base".into(), "base-7.zann".into()),
                ("wal".into(), "wal-7.log".into()),
            ],
        };
        let enc = m.encode();
        let back = Manifest::decode(&enc).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.get("base"), Some("base-7.zann"));
        assert_eq!(back.get("missing"), None);
    }

    #[test]
    fn every_flipped_byte_is_rejected() {
        let m = Manifest {
            generation: 1,
            entries: vec![("kind".into(), "node".into())],
        };
        let enc = m.encode();
        for i in 0..enc.len() {
            let mut bad = enc.clone();
            bad[i] ^= 0x20;
            assert!(Manifest::decode(&bad).is_err(), "flip at byte {i} accepted");
        }
        for cut in 0..enc.len() {
            assert!(Manifest::decode(&enc[..cut]).is_err(), "truncation at {cut} accepted");
        }
    }
}
