//! Asymmetric numeral systems (range variant), 64-bit head with 32-bit
//! stream words — the coder behind ROC, REC and the PQ-code compressor.
//!
//! The state is a stack: `encode` pushes symbols, `decode` pops them in
//! reverse order.  Crucially for bits-back coding (paper §3.2), `decode`
//! can also be called on an *arbitrary* distribution to "sample" a symbol
//! while removing `-log2 p(symbol)` bits from the state; re-encoding the
//! symbol restores the state exactly.  [`Ans::decode_uniform`] /
//! [`Ans::encode_uniform`] are that primitive for `Uniform([0, m))`, the
//! only model ROC needs; quantized-frequency models back REC's Pólya urn
//! and the Fig.-3 code compressor.
//!
//! Invariant: `head` is in `[2^32, 2^64)` except transiently before the
//! first renormalization-in; encode renormalizes *out* (pushing low 32 bits
//! to the stream) exactly when decode would renormalize *in*, which makes
//! every (encode, decode) pair a perfect inverse — the property all codecs
//! here rely on and the tests assert.

pub mod adaptive;
pub mod interleaved;

pub use adaptive::ReverseAdaptiveCoder;

/// Lower bound of the normalized interval.
const LOW: u64 = 1 << 32;

/// rANS coder state: 64-bit head plus a stack of 32-bit words.
#[derive(Clone, Debug)]
pub struct Ans {
    pub head: u64,
    pub stream: Vec<u32>,
}

impl Default for Ans {
    fn default() -> Self {
        Self::new()
    }
}

impl Ans {
    /// Fresh state. Starting at `LOW` costs 32 bits that are never
    /// recovered — the "initial bits" of the paper's §3.2 (visible in the
    /// NSG16 row of Table 1).
    pub fn new() -> Self {
        Ans { head: LOW, stream: Vec::new() }
    }

    /// Rescaled cumulative boundary: `C(z) = floor(z·2^32 / m)`.
    ///
    /// Every op's `(f, c, m)` interval is mapped to `[C(c), C(c+f))` out of
    /// a 2^32 total before touching the state. Streaming rANS renorm is
    /// only exactly bijective when the denominator divides the word base;
    /// with arbitrary `m` the floor in the renorm threshold desyncs
    /// encoder and decoder with ~2^-20 probability per op — invisible in
    /// small tests, fatal on a 10^6-op REC stream. The rescaling costs
    /// ≤ `m/(f·2^32)` bits/op of rate.
    #[inline]
    fn boundary(z: u64, m: u32) -> u64 {
        // z <= m < 2^32, so z << 32 < 2^64: plain u64 division suffices
        // (u128 division here costs ~3x on the ROC/REC hot path).
        debug_assert!(z <= m as u64);
        (z << 32) / m as u64
    }

    /// Encode a symbol with quantized frequency `f`, cumulative frequency
    /// `c` and total `m` (i.e. model probability f/m). Requires 0 < f <= m,
    /// c + f <= m.
    #[inline]
    pub fn encode(&mut self, f: u32, c: u32, m: u32) {
        debug_assert!(f > 0 && m > 0);
        debug_assert!(c as u64 + f as u64 <= m as u64);
        let c32 = Self::boundary(c as u64, m);
        let f32 = Self::boundary(c as u64 + f as u64, m) - c32;
        // Standard power-of-two renorm: total = 2^32, word = 32 bits.
        let limit = f32 << 32; // f32 <= 2^32 so this fits u64 iff f32 < 2^32
        if f32 < LOW {
            while self.head >= limit {
                self.stream.push(self.head as u32);
                self.head >>= 32;
            }
        } // f32 == 2^32 (probability one): no renorm, update is identity.
        self.head = (self.head / f32) * LOW + c32 + self.head % f32;
        // Note: in the bits-back regime (decode on a near-fresh state) the
        // head may legitimately sit below LOW with an empty stream; encode
        // then also ends below LOW. The (encode, decode) bijection holds
        // because the renormalization conditions mirror exactly.
    }

    /// Peek the current slot in `[0, m)`; the caller maps it to a symbol
    /// via the model's inverse CDF and calls [`Ans::pop`] with that
    /// symbol's (f, c).
    #[inline]
    pub fn peek(&self, m: u32) -> u32 {
        let slot32 = self.head & (LOW - 1);
        // Invert C: largest v with C(v) <= slot32 (the estimate below is
        // off by at most one).
        let mut v = ((slot32 as u128 * m as u128) >> 32) as u64;
        if Self::boundary(v + 1, m) <= slot32 {
            v += 1;
        }
        debug_assert!(Self::boundary(v, m) <= slot32 && slot32 < Self::boundary(v + 1, m));
        v as u32
    }

    /// Complete a decode started with [`Ans::peek`].
    #[inline]
    pub fn pop(&mut self, f: u32, c: u32, m: u32) {
        let c32 = Self::boundary(c as u64, m);
        let f32 = Self::boundary(c as u64 + f as u64, m) - c32;
        let slot32 = self.head & (LOW - 1);
        debug_assert!(c32 <= slot32 && slot32 < c32 + f32);
        self.head = f32 * (self.head >> 32) + slot32 - c32;
        while self.head < LOW {
            match self.stream.pop() {
                Some(w) => self.head = (self.head << 32) | w as u64,
                // Popping past the initial state: keep head as-is. Codecs
                // never do this for well-formed inputs.
                None => break,
            }
        }
    }

    /// Encode `x` under `Uniform([0, m))`. Adds ~log2(m) bits.
    #[inline]
    pub fn encode_uniform(&mut self, x: u32, m: u32) {
        debug_assert!(x < m);
        let c32 = Self::boundary(x as u64, m);
        let f32 = Self::boundary(x as u64 + 1, m) - c32;
        if f32 < LOW {
            let limit = f32 << 32;
            while self.head >= limit {
                self.stream.push(self.head as u32);
                self.head >>= 32;
            }
        }
        self.head = (self.head / f32) * LOW + c32 + self.head % f32;
    }

    /// Decode under `Uniform([0, m))`. Removes ~log2(m) bits. This is the
    /// bits-back "sampling" primitive. (Specialized: shares the boundary
    /// computations between peek and pop — the ROC/REC hot path.)
    #[inline]
    pub fn decode_uniform(&mut self, m: u32) -> u32 {
        let slot32 = self.head & (LOW - 1);
        let mut v = ((slot32 as u128 * m as u128) >> 32) as u64;
        let mut lo = Self::boundary(v, m);
        let mut hi = Self::boundary(v + 1, m);
        if hi <= slot32 {
            v += 1;
            lo = hi;
            hi = Self::boundary(v + 1, m);
        }
        self.head = (hi - lo) * (self.head >> 32) + slot32 - lo;
        while self.head < LOW {
            match self.stream.pop() {
                Some(w) => self.head = (self.head << 32) | w as u64,
                None => break,
            }
        }
        v as u32
    }

    /// Exact size of the serialized state in bits: stream words plus the
    /// 64-bit head. A fresh state therefore reports 64 bits — the
    /// "initial bits" overhead of §3.2 that short lists cannot amortize.
    pub fn size_bits(&self) -> usize {
        self.stream.len() * 32 + 64
    }

    /// Net information content in bits relative to a fresh state
    /// (fractional; useful for rate accounting in tests).
    pub fn content_bits(&self) -> f64 {
        self.stream.len() as f64 * 32.0 + (self.head as f64).log2() - 32.0
    }

    /// Serialize to bytes (stream words then head, little-endian).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.stream.len() * 4 + 12);
        out.extend_from_slice(&(self.stream.len() as u32).to_le_bytes());
        for w in &self.stream {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.extend_from_slice(&self.head.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        let mut ans = Ans::new();
        ans.read_from(bytes)?;
        Ok(ans)
    }

    /// Deserialize into an existing state, reusing the stream allocation —
    /// the per-cluster hot path decodes many blobs through one `Ans`
    /// without touching the heap once the stream capacity has grown.
    pub fn read_from(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        use anyhow::Context;
        let n = u32::from_le_bytes(bytes.get(0..4).context("len")?.try_into()?) as usize;
        // Check the claimed word count against the blob *before* reserving:
        // a corrupt length field must not become a multi-gigabyte
        // allocation.
        anyhow::ensure!(
            bytes.len() as u64 >= 4 + n as u64 * 4 + 8,
            "ans stream claims {n} words but the blob holds only {} bytes",
            bytes.len()
        );
        self.stream.clear();
        self.stream.reserve(n);
        for i in 0..n {
            let off = 4 + i * 4;
            self.stream
                .push(u32::from_le_bytes(bytes.get(off..off + 4).context("word")?.try_into()?));
        }
        let off = 4 + n * 4;
        self.head = u64::from_le_bytes(bytes.get(off..off + 8).context("head")?.try_into()?);
        Ok(())
    }
}

/// A quantized probability model over `[0, n)` symbols with total mass `m`
/// (not necessarily a power of two — rANS handles arbitrary denominators).
pub trait FreqModel {
    /// (frequency, cumulative frequency) of `x`.
    fn f_c(&self, x: u32) -> (u32, u32);
    /// Symbol whose cumulative interval contains `slot`.
    fn symbol_of(&self, slot: u32) -> u32;
    /// Total mass.
    fn total(&self) -> u32;
}

/// Encode `x` under a [`FreqModel`].
pub fn encode_sym<M: FreqModel>(ans: &mut Ans, model: &M, x: u32) {
    let (f, c) = model.f_c(x);
    ans.encode(f, c, model.total());
}

/// Decode a symbol under a [`FreqModel`].
pub fn decode_sym<M: FreqModel>(ans: &mut Ans, model: &M) -> u32 {
    let slot = ans.peek(model.total());
    let x = model.symbol_of(slot);
    let (f, c) = model.f_c(x);
    ans.pop(f, c, model.total());
    x
}

/// Dense count-based model (alphabet small enough to hold counts).
#[derive(Clone, Debug)]
pub struct CountModel {
    pub freqs: Vec<u32>,
    cum: Vec<u32>,
}

impl CountModel {
    /// Build from raw frequencies (each > 0 to be encodable; zeros allowed
    /// for symbols that never occur).
    pub fn new(freqs: Vec<u32>) -> Self {
        let mut cum = Vec::with_capacity(freqs.len() + 1);
        let mut acc = 0u32;
        for &f in &freqs {
            cum.push(acc);
            acc = acc.checked_add(f).expect("frequency overflow");
        }
        cum.push(acc);
        CountModel { freqs, cum }
    }

    /// Laplace-smoothed model from symbol counts.
    pub fn from_counts(counts: &[u64], alpha: u32) -> Self {
        // Scale counts down if they would overflow the u32 total.
        let total: u64 = counts.iter().sum::<u64>() + (alpha as u64) * counts.len() as u64;
        let shift = (64 - (total.leading_zeros() as usize)).saturating_sub(30);
        let freqs = counts
            .iter()
            .map(|&c| (((c >> shift) as u32).saturating_add(alpha)).max(alpha.max(1)))
            .collect();
        CountModel::new(freqs)
    }
}

impl FreqModel for CountModel {
    fn f_c(&self, x: u32) -> (u32, u32) {
        (self.freqs[x as usize], self.cum[x as usize])
    }

    fn symbol_of(&self, slot: u32) -> u32 {
        // Binary search the cumulative table: last index with cum <= slot.
        match self.cum.binary_search(&slot) {
            Ok(mut i) => {
                // Skip zero-frequency symbols that share the boundary.
                while self.freqs[i] == 0 {
                    i += 1;
                }
                i as u32
            }
            Err(i) => (i - 1) as u32,
        }
    }

    fn total(&self) -> u32 {
        *self.cum.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn uniform_roundtrip_lifo() {
        let mut ans = Ans::new();
        let mut rng = Rng::new(1);
        let mut sym = Vec::new();
        for _ in 0..10_000 {
            let m = 2 + rng.below(1 << 20) as u32;
            let x = rng.below(m as u64) as u32;
            ans.encode_uniform(x, m);
            sym.push((x, m));
        }
        for &(x, m) in sym.iter().rev() {
            assert_eq!(ans.decode_uniform(m), x);
        }
        // Fully drained back to the initial state.
        assert_eq!(ans.head, 1 << 32);
        assert!(ans.stream.is_empty());
    }

    #[test]
    fn uniform_rate_is_log_m() {
        let mut ans = Ans::new();
        let m = 1000u32;
        let n = 20_000;
        let mut rng = Rng::new(2);
        for _ in 0..n {
            ans.encode_uniform(rng.below(m as u64) as u32, m);
        }
        let bits = ans.content_bits();
        let ideal = n as f64 * (m as f64).log2();
        assert!((bits - ideal).abs() / ideal < 1e-3, "bits={bits} ideal={ideal}");
    }

    #[test]
    fn bits_back_decode_then_encode_restores_state() {
        // The fundamental invertible-sampling property.
        let mut ans = Ans::new();
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            ans.encode_uniform(rng.below(1 << 16) as u32, 1 << 16);
        }
        let before_head = ans.head;
        let before_stream = ans.stream.clone();
        let mut drawn = Vec::new();
        for i in 0..500u32 {
            drawn.push(ans.decode_uniform(i + 2));
        }
        for i in (0..500u32).rev() {
            ans.encode_uniform(drawn[i as usize], i + 2);
        }
        assert_eq!(ans.head, before_head);
        assert_eq!(ans.stream, before_stream);
    }

    #[test]
    fn count_model_roundtrip_and_rate() {
        // Skewed model: check both correctness and near-entropy rate.
        let freqs = vec![1u32, 2, 4, 8, 16, 32, 64, 128];
        let model = CountModel::new(freqs.clone());
        let total: u32 = freqs.iter().sum();
        let probs: Vec<f64> = freqs.iter().map(|&f| f as f64 / total as f64).collect();
        let entropy: f64 = probs.iter().map(|p| -p * p.log2()).sum();

        let mut rng = Rng::new(4);
        // Sample from the model itself.
        let syms: Vec<u32> = (0..50_000)
            .map(|_| {
                let r = rng.below(total as u64) as u32;
                model.symbol_of(r)
            })
            .collect();
        let mut ans = Ans::new();
        for &s in &syms {
            encode_sym(&mut ans, &model, s);
        }
        let rate = ans.content_bits() / syms.len() as f64;
        assert!((rate - entropy).abs() < 0.02, "rate={rate} H={entropy}");
        for &s in syms.iter().rev() {
            assert_eq!(decode_sym(&mut ans, &model), s);
        }
    }

    #[test]
    fn count_model_symbol_of_with_zero_freqs() {
        let model = CountModel::new(vec![0, 3, 0, 0, 5, 0, 1]);
        for slot in 0..model.total() {
            let x = model.symbol_of(slot);
            let (f, c) = model.f_c(x);
            assert!(f > 0);
            assert!(c <= slot && slot < c + f, "slot={slot} x={x}");
        }
    }

    #[test]
    fn large_stream_arbitrary_denominators_exact() {
        // Regression: with arbitrary (non-power-of-two) denominators the
        // pre-rescaling coder desynced with ~2^-20 probability per op —
        // invisible at small scale, fatal on REC-sized streams. Push a
        // million mixed ops through and drain back.
        let mut ans = Ans::new();
        let mut rng = Rng::new(0xbeef);
        let mut log = Vec::with_capacity(1_000_000);
        for i in 0..1_000_000u32 {
            // Denominators sweep awkward values incl. primes and near-2^32.
            let m = match i % 4 {
                0 => 218_560,
                1 => 3 + rng.below(1 << 27) as u32,
                2 => u32::MAX - rng.below(1000) as u32,
                _ => 2 + (i % 97),
            };
            let x = rng.below(m as u64) as u32;
            ans.encode_uniform(x, m);
            log.push((x, m));
        }
        for &(x, m) in log.iter().rev() {
            assert_eq!(ans.decode_uniform(m), x);
        }
        assert_eq!(ans.head, 1 << 32);
        assert!(ans.stream.is_empty());
    }

    #[test]
    fn serialization_roundtrip() {
        let mut ans = Ans::new();
        let mut rng = Rng::new(5);
        for _ in 0..1000 {
            ans.encode_uniform(rng.below(1 << 24) as u32, 1 << 24);
        }
        let bytes = ans.to_bytes();
        let back = Ans::from_bytes(&bytes).unwrap();
        assert_eq!(back.head, ans.head);
        assert_eq!(back.stream, ans.stream);
    }

    #[test]
    fn size_bits_accounting() {
        let ans = Ans::new();
        assert_eq!(ans.size_bits(), 64); // fresh state: 64-bit head, no words
        assert!(ans.content_bits().abs() < 1e-9);
    }

    #[test]
    fn interleaved_models_roundtrip() {
        // Mix uniform and count-model symbols in one state.
        let model = CountModel::new(vec![5, 1, 9, 2, 7]);
        let mut ans = Ans::new();
        let mut rng = Rng::new(6);
        let mut log = Vec::new();
        for _ in 0..5000 {
            if rng.f64() < 0.5 {
                let m = 2 + rng.below(1000) as u32;
                let x = rng.below(m as u64) as u32;
                ans.encode_uniform(x, m);
                log.push((true, x, m));
            } else {
                let x = rng.below(5) as u32;
                encode_sym(&mut ans, &model, x);
                log.push((false, x, 0));
            }
        }
        for &(uni, x, m) in log.iter().rev() {
            if uni {
                assert_eq!(ans.decode_uniform(m), x);
            } else {
                assert_eq!(decode_sym(&mut ans, &model), x);
            }
        }
    }
}
