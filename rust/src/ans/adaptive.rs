//! Adaptive (Pólya-urn) sequence coding over a LIFO ANS state.
//!
//! Implements the model of the paper's eq. (6)–(7): the probability of
//! symbol `x` at position `i` is `(1 + count_{<i}(x)) / (A + i)` where `A`
//! is the alphabet size — a uniform prior that sharpens as occurrences
//! accumulate.  Because ANS decodes in reverse encode order, the encoder
//! runs a *forward* pass to record each position's (f, c, m) triple under
//! the evolving counts, then feeds them to ANS in reverse; the decoder then
//! pops symbols in forward sequence order while updating the same counts.
//! Net effect: a one-pass-decodable adaptive coder, exactly what the
//! cluster-conditioned PQ-code compressor (Fig. 3) needs.

use crate::ans::Ans;
use crate::fenwick::Fenwick;

/// Reverse-order adaptive coder for sequences over `[0, alphabet)`.
pub struct ReverseAdaptiveCoder {
    pub alphabet: u32,
}

impl ReverseAdaptiveCoder {
    pub fn new(alphabet: u32) -> Self {
        assert!(alphabet > 0);
        ReverseAdaptiveCoder { alphabet }
    }

    /// Encode `seq` so that decoding yields it front-to-back.
    pub fn encode(&self, ans: &mut Ans, seq: &[u32]) {
        let a = self.alphabet as usize;
        // Forward pass: record (f, c, m) for every position.
        let mut weights = Fenwick::ones(a);
        let mut triples = Vec::with_capacity(seq.len());
        for (i, &x) in seq.iter().enumerate() {
            debug_assert!((x as usize) < a);
            let f = weights.get(x as usize) as u32;
            let c = weights.prefix_sum(x as usize) as u32;
            let m = self.alphabet + i as u32;
            debug_assert_eq!(m as u64, weights.total());
            triples.push((f, c, m));
            weights.add(x as usize, 1);
        }
        // Reverse pass: push onto the ANS stack.
        for &(f, c, m) in triples.iter().rev() {
            ans.encode(f, c, m);
        }
    }

    /// Decode `n` symbols (forward order).
    pub fn decode(&self, ans: &mut Ans, n: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(n);
        let mut weights = Fenwick::new(self.alphabet as usize);
        self.decode_with(ans, n, &mut weights, |_, x| out.push(x));
        out
    }

    /// Decode `n` symbols through a caller-provided urn (reset to the
    /// all-ones prior here) and an `emit(index, symbol)` sink — the
    /// allocation-free path used by the per-cluster PQ-code decoder, which
    /// writes symbols straight into a strided row-major buffer.
    pub fn decode_with(
        &self,
        ans: &mut Ans,
        n: usize,
        weights: &mut Fenwick,
        mut emit: impl FnMut(usize, u32),
    ) {
        let a = self.alphabet as usize;
        assert_eq!(weights.len(), a, "urn size must match the alphabet");
        weights.reset_ones();
        for i in 0..n {
            let m = self.alphabet + i as u32;
            let slot = ans.peek(m);
            let (x, _) = weights.slot_of(slot as u64);
            let f = weights.get(x) as u32;
            let c = weights.prefix_sum(x) as u32;
            ans.pop(f, c, m);
            weights.add(x, 1);
            emit(i, x as u32);
        }
    }

    /// Ideal code length of `seq` under the model, in bits (for tests and
    /// rate accounting).
    pub fn ideal_bits(&self, seq: &[u32]) -> f64 {
        let a = self.alphabet as usize;
        let mut counts = vec![0u64; a];
        let mut bits = 0.0;
        for (i, &x) in seq.iter().enumerate() {
            let p = (1 + counts[x as usize]) as f64 / (self.alphabet as u64 + i as u64) as f64;
            bits -= p.log2();
            counts[x as usize] += 1;
        }
        bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_random_sequences() {
        let mut rng = Rng::new(1);
        for &a in &[2u32, 16, 256, 1024] {
            for &n in &[0usize, 1, 10, 1000] {
                let coder = ReverseAdaptiveCoder::new(a);
                let seq: Vec<u32> = (0..n).map(|_| rng.below(a as u64) as u32).collect();
                let mut ans = Ans::new();
                coder.encode(&mut ans, &seq);
                let got = coder.decode(&mut ans, n);
                assert_eq!(got, seq, "a={a} n={n}");
                assert_eq!(ans.size_bits(), 64, "state drained");
            }
        }
    }

    #[test]
    fn rate_tracks_model_ideal() {
        let coder = ReverseAdaptiveCoder::new(256);
        // Skewed source: most symbols from a small subset.
        let mut rng = Rng::new(2);
        let seq: Vec<u32> = (0..20_000)
            .map(|_| {
                if rng.f64() < 0.9 {
                    rng.below(8) as u32
                } else {
                    rng.below(256) as u32
                }
            })
            .collect();
        let mut ans = Ans::new();
        coder.encode(&mut ans, &seq);
        let actual = ans.content_bits();
        let ideal = coder.ideal_bits(&seq);
        assert!(
            (actual - ideal).abs() < 0.01 * ideal + 64.0,
            "actual={actual} ideal={ideal}"
        );
        // And well below the 8 bits/symbol uncompressed rate.
        assert!(actual / (seq.len() as f64) < 4.0);
    }

    #[test]
    fn uniform_source_is_incompressible() {
        // Matches the paper's observation: unconditioned PQ codes are at
        // max entropy, so the adaptive coder can't beat log2(A).
        let coder = ReverseAdaptiveCoder::new(256);
        let mut rng = Rng::new(3);
        let seq: Vec<u32> = (0..30_000).map(|_| rng.below(256) as u32).collect();
        let mut ans = Ans::new();
        coder.encode(&mut ans, &seq);
        let rate = ans.content_bits() / seq.len() as f64;
        assert!(rate > 7.9 && rate < 8.1, "rate={rate}");
    }

    #[test]
    fn constant_sequence_compresses_hard() {
        let coder = ReverseAdaptiveCoder::new(256);
        let seq = vec![42u32; 10_000];
        let mut ans = Ans::new();
        coder.encode(&mut ans, &seq);
        // P(42 | i-1 prior 42s) = i/(256+i-1)->1; total bits ~ 256 ln(...)
        let rate = ans.content_bits() / seq.len() as f64;
        assert!(rate < 0.35, "rate={rate}");
        assert_eq!(coder.decode(&mut ans, seq.len()), seq);
    }
}
