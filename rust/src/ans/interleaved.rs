//! N-way interleaved rANS over one shared byte stream — the decode-side
//! throughput engine behind the `ans-i2`/`ans-i4`/`ans-i8` codecs.
//!
//! The single-state coder in [`crate::ans`] is exact but *serial*: every
//! decoded symbol depends on the head value left by the previous one, so
//! a modern core spends the whole list waiting on one dependency chain.
//! The standard fix (Giesen's interleaved rANS, also what Faiss-style
//! scan kernels assume of their entropy decoders) is `W` independent
//! states that round-robin over the symbols: symbol `i` belongs to state
//! `i mod W`, so `W` dependency chains are in flight at once and the
//! out-of-order core overlaps them "for free".
//!
//! Two properties make the shared stream work:
//!
//! * **Renorm mirroring.** Encoding walks the symbols in *reverse* order
//!   (`i = n−1 … 0`), each state pushing its renormalization words onto
//!   one shared LIFO word stack; decoding walks forward (`i = 0 … n−1`)
//!   and pops. Because a state's encode-renorm condition mirrors its
//!   decode-renorm condition exactly (the invariant the single-stream
//!   coder's tests pin), the pops at decode step `i` retrieve precisely
//!   the words pushed at encode step `i` — no per-state framing needed.
//! * **Division-free decode.** The uniform model's rescaled boundary
//!   `C(z) = ⌊z·2³²/m⌋` is the only place the coder divides. `m` is
//!   constant for a whole list, so decode precomputes `M = ⌊2⁹⁶/m⌋` and
//!   evaluates `C` as a 128-bit multiply plus a one-step fixup
//!   ([`UniformModel::boundary`] proves exactness inline); the decoder
//!   then performs no division at all.
//!
//! The encoder reproduces [`crate::ans::Ans::encode_uniform`]'s state
//! transition bit-for-bit (asserted by a test against the single-stream
//! coder at `W = 1`), so the serialized format is the natural extension
//! of the single-stream one: `u32` word count, the shared stream words
//! (LE), then the `W` final heads (LE `u64` each).

/// Lower bound of the normalized interval (mirrors `ans::LOW`).
const LOW: u64 = 1 << 32;

/// Supported interleaving widths (heads are kept in a fixed array).
pub const MAX_WAYS: usize = 8;

/// Exact size in bits of an interleaved stream's payload: stream words
/// plus `ways` 64-bit heads (each state pays the single-stream coder's
/// "initial bits" — short lists amortize it poorly, exactly like ROC).
pub fn size_bits(stream_words: usize, ways: usize) -> u64 {
    stream_words as u64 * 32 + ways as u64 * 64
}

/// `C(z) = ⌊z·2³²/m⌋` by long division — the encoder-side boundary,
/// identical to the single-stream coder's.
#[inline]
fn boundary_div(z: u64, m: u32) -> u64 {
    debug_assert!(z <= m as u64);
    (z << 32) / m as u64
}

/// Uniform([0, m)) model with a precomputed reciprocal for division-free
/// decoding.
#[derive(Clone, Copy)]
pub struct UniformModel {
    m: u32,
    /// `⌊2⁹⁶ / m⌋`; fits u128 for every m ≥ 1.
    magic: u128,
}

impl UniformModel {
    pub fn new(m: u32) -> UniformModel {
        debug_assert!(m > 0);
        UniformModel { m, magic: (1u128 << 96) / m as u128 }
    }

    /// Exact `⌊z·2³²/m⌋` without dividing. With `M = ⌊2⁹⁶/m⌋` the
    /// estimate `a = ⌊z·M/2⁶⁴⌋` satisfies `true−1 ≤ a ≤ true` (for
    /// `z ≤ m < 2³²`: `z·M ≤ 2⁹⁶` so the product fits u128, and
    /// `z·M/2⁶⁴ ≥ z·2³²/m − z/2⁶⁴ > true − 2`), so one fixup step —
    /// bump iff `(a+1)·m ≤ z·2³²` — lands on the floor exactly.
    #[inline]
    pub fn boundary(&self, z: u64) -> u64 {
        let mut a = ((z as u128 * self.magic) >> 64) as u64;
        if (a as u128 + 1) * self.m as u128 <= (z as u128) << 32 {
            a += 1;
        }
        debug_assert_eq!(a, boundary_div(z, self.m));
        a
    }

    /// One decode step on `head`, popping renorm words from `bytes` via
    /// `cursor` (a word index into the shared stream, counting down).
    #[inline]
    fn decode_step(&self, head: &mut u64, bytes: &[u8], cursor: &mut usize) -> u32 {
        let slot = *head & (LOW - 1);
        let mut v = ((slot as u128 * self.m as u128) >> 32) as u64;
        let mut lo = self.boundary(v);
        let mut hi = self.boundary(v + 1);
        if hi <= slot {
            v += 1;
            lo = hi;
            hi = self.boundary(v + 1);
        }
        *head = (hi - lo) * (*head >> 32) + slot - lo;
        while *head < LOW {
            if *cursor == 0 {
                // Popping past the initial state: malformed input; keep
                // the head as-is (same policy as the single-stream coder).
                break;
            }
            *cursor -= 1;
            let off = 4 + *cursor * 4;
            let w = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            *head = (*head << 32) | w as u64;
        }
        v as u32
    }
}

/// One encode step (identical state transition to
/// [`crate::ans::Ans::encode_uniform`]); renorm words go onto the shared
/// stack.
#[inline]
fn encode_step(head: &mut u64, stream: &mut Vec<u32>, x: u32, m: u32) {
    debug_assert!(x < m);
    let c32 = boundary_div(x as u64, m);
    let f32_ = boundary_div(x as u64 + 1, m) - c32;
    if f32_ < LOW {
        let limit = f32_ << 32;
        while *head >= limit {
            stream.push(*head as u32);
            *head >>= 32;
        }
    }
    *head = (*head / f32_) * LOW + c32 + *head % f32_;
}

/// Encode `symbols` under `Uniform([0, m))` with `ways` interleaved
/// states sharing one word stream. Returns the serialized blob:
/// `[u32 word count][stream words][ways × u64 heads]`, all LE.
pub fn encode_uniform(symbols: &[u32], m: u32, ways: usize) -> Vec<u8> {
    assert!((1..=MAX_WAYS).contains(&ways), "ways {ways} out of [1, {MAX_WAYS}]");
    let mut heads = [LOW; MAX_WAYS];
    let mut stream: Vec<u32> = Vec::new();
    // Reverse symbol order; state i % ways — the decode loop's mirror.
    for i in (0..symbols.len()).rev() {
        encode_step(&mut heads[i % ways], &mut stream, symbols[i], m);
    }
    let mut out = Vec::with_capacity(4 + stream.len() * 4 + ways * 8);
    out.extend_from_slice(&(stream.len() as u32).to_le_bytes());
    for w in &stream {
        out.extend_from_slice(&w.to_le_bytes());
    }
    for h in &heads[..ways] {
        out.extend_from_slice(&h.to_le_bytes());
    }
    out
}

/// Decode `n` symbols from a blob produced by [`encode_uniform`] with the
/// same `(m, ways)`, appending them to `out`. Stream words are read
/// in place from `bytes` (no copy, no scratch); the only state is the
/// `ways` heads and a word cursor.
///
/// The loop body is blocked over the `ways` states: each iteration of
/// the outer loop advances every chain by one symbol, so the `ways`
/// multiply/fixup chains are independent and retire in parallel on an
/// out-of-order core — this is the bulk-decode path the `bench-decode`
/// harness measures against the serial coders.
pub fn decode_uniform_into(bytes: &[u8], m: u32, n: usize, ways: usize, out: &mut Vec<u32>) {
    try_decode_uniform_into(bytes, m, n, ways, out).expect("corrupt ans-i blob")
}

/// Fallible variant of [`decode_uniform_into`] for **untrusted** blobs:
/// framing problems (a missing word count, a word count the blob cannot
/// hold, absent heads) are structured errors instead of panics. The
/// decode loop itself is already bounded — the shared cursor only counts
/// down and stops at zero, and every decoded symbol is `< m` by
/// construction — so after the frame checks no input can index out of
/// bounds, spin, or emit an out-of-range value. Nothing is appended to
/// `out` on `Err`.
pub fn try_decode_uniform_into(
    bytes: &[u8],
    m: u32,
    n: usize,
    ways: usize,
    out: &mut Vec<u32>,
) -> anyhow::Result<()> {
    anyhow::ensure!((1..=MAX_WAYS).contains(&ways), "ways {ways} out of [1, {MAX_WAYS}]");
    anyhow::ensure!(m > 0, "uniform model over an empty range");
    anyhow::ensure!(bytes.len() >= 4, "blob of {} bytes has no word count", bytes.len());
    let words = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    let need = 4u64 + words as u64 * 4 + ways as u64 * 8;
    anyhow::ensure!(
        bytes.len() as u64 >= need,
        "blob holds {} bytes, need {need} for {words} words + {ways} heads",
        bytes.len()
    );
    let heads_off = 4 + words * 4;
    let mut heads = [LOW; MAX_WAYS];
    for (w, h) in heads[..ways].iter_mut().enumerate() {
        let off = heads_off + w * 8;
        *h = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    }
    let model = UniformModel::new(m);
    let mut cursor = words;
    out.reserve(n);
    let full = n - n % ways;
    let mut i = 0;
    while i < full {
        // One symbol per state; the chains only couple through the shared
        // cursor, and a renorm pop is rare for large m.
        for head in heads[..ways].iter_mut() {
            out.push(model.decode_step(head, bytes, &mut cursor));
        }
        i += ways;
    }
    for head in heads[..n - full].iter_mut() {
        out.push(model.decode_step(head, bytes, &mut cursor));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ans::Ans;
    use crate::util::Rng;

    #[test]
    fn boundary_magic_is_exact() {
        // Adversarial denominators: tiny, prime-ish, near-2^32, powers of
        // two; z sweeps the extremes plus random interior points.
        let mut rng = Rng::new(0xd1f);
        let mut ms: Vec<u32> =
            vec![1, 2, 3, 5, 7, 255, 256, 257, 65535, 65536, 218_560, u32::MAX - 1, u32::MAX];
        for _ in 0..100 {
            ms.push(1 + rng.below((u32::MAX as u64) - 1) as u32);
        }
        for &m in &ms {
            let model = UniformModel::new(m);
            let mut zs = vec![0u64, 1, m as u64 / 2, (m as u64).saturating_sub(1), m as u64];
            for _ in 0..200 {
                zs.push(rng.below(m as u64 + 1));
            }
            for &z in &zs {
                assert_eq!(model.boundary(z), boundary_div(z, m), "m={m} z={z}");
            }
        }
    }

    #[test]
    fn roundtrip_all_ways_and_shapes() {
        let mut rng = Rng::new(0xd2f);
        for &m in &[1u32, 2, 17, 1000, 1 << 20, u32::MAX] {
            for &n in &[0usize, 1, 2, 3, 7, 8, 9, 63, 64, 65, 1000] {
                if n as u64 > m as u64 {
                    continue;
                }
                let mut syms: Vec<u32> =
                    rng.sample_distinct(m as u64, n).into_iter().map(|v| v as u32).collect();
                syms.sort_unstable();
                for ways in [1usize, 2, 3, 4, 8] {
                    let blob = encode_uniform(&syms, m, ways);
                    let mut out = Vec::new();
                    decode_uniform_into(&blob, m, n, ways, &mut out);
                    assert_eq!(out, syms, "m={m} n={n} ways={ways}");
                }
            }
        }
    }

    #[test]
    fn one_way_is_bit_identical_to_the_single_stream_coder() {
        // The interleaved encoder at W=1 must reproduce Ans::encode_uniform
        // exactly — stream words and head — which pins the per-state
        // transition to the single-stream format.
        let mut rng = Rng::new(0xd3f);
        let m = 1 << 20;
        let mut syms: Vec<u32> =
            rng.sample_distinct(m as u64, 500).into_iter().map(|v| v as u32).collect();
        syms.sort_unstable();
        let blob = encode_uniform(&syms, m, 1);
        let mut ans = Ans::new();
        for &x in syms.iter().rev() {
            ans.encode_uniform(x, m);
        }
        assert_eq!(blob, ans.to_bytes(), "W=1 framing/words/head must match Ans::to_bytes");
    }

    #[test]
    fn decode_order_is_ascending_for_every_way_count() {
        // Cross-way contract: every W decodes the same (sorted) sequence,
        // so the id codecs built on top are drop-in interchangeable.
        let mut rng = Rng::new(0xd4f);
        let m = 1 << 16;
        let mut syms: Vec<u32> =
            rng.sample_distinct(m as u64, 777).into_iter().map(|v| v as u32).collect();
        syms.sort_unstable();
        let mut reference = Vec::new();
        decode_uniform_into(&encode_uniform(&syms, m, 1), m, syms.len(), 1, &mut reference);
        for ways in [2usize, 4, 8] {
            let mut out = Vec::new();
            decode_uniform_into(&encode_uniform(&syms, m, ways), m, syms.len(), ways, &mut out);
            assert_eq!(out, reference, "ways={ways}");
        }
        assert_eq!(reference, syms);
    }

    #[test]
    fn rate_is_log2_m_plus_per_state_overhead() {
        let mut rng = Rng::new(0xd5f);
        let m = 1u32 << 20;
        let n = 4096usize;
        let mut syms: Vec<u32> =
            rng.sample_distinct(m as u64, n).into_iter().map(|v| v as u32).collect();
        syms.sort_unstable();
        for ways in [2usize, 8] {
            let blob = encode_uniform(&syms, m, ways);
            let bits = (blob.len() - 4) as f64 * 8.0;
            let ideal = n as f64 * 20.0 + ways as f64 * 64.0;
            assert!(
                bits >= n as f64 * 20.0 && bits < ideal + 64.0,
                "ways={ways}: {bits} bits vs ideal {ideal}"
            );
        }
    }
}
