//! `zann` — CLI for the compressed-id ANN system.
//!
//! Subcommands:
//!   bench-table1|bench-table2|bench-table3|bench-table4|bench-fig2|bench-fig3
//!                       — regenerate the paper's tables/figures
//!   bench-search-qps    — search throughput sweep over IVF *and* graph
//!                         backends (QPS + latency percentiles, writes
//!                         BENCH_search.json)
//!   build               — build an index (--backend ivf|nsg|hnsw) and
//!                         save it to the zann container (--out PATH)
//!   info                — print the stats header of a saved index
//!   serve               — reopen a saved index (zero transcode) and
//!                         serve a query batch through the coordinator,
//!                         verifying responses against direct search
//!   serve-demo          — build an index in memory and serve a batch
//!                         (PJRT coarse path if artifacts exist)
//!   sizes               — bits/id summary for one dataset/index
//!
//! Common flags: --n --nq --dim --k --seed --threads --dataset
//! (sift|deep|ssnpp) --codec --runs --full (paper-scale N=1e6)

use std::path::Path;
use std::sync::Arc;
use zann::api::{persist, AnnIndex, AnnScratch, GraphIndex, IndexStats, QueryParams};
use zann::codecs::CodecSpec;
use zann::coordinator::{Coordinator, ServeConfig};
use zann::datasets::generate;
use zann::eval::experiments::{self, Scale};
use zann::eval::{bench_entries, fmt3, Table};
use zann::graph::hnsw::{Hnsw, HnswParams};
use zann::graph::nsg::{Nsg, NsgParams};
use zann::index::{IvfBuildParams, IvfIndex, VectorMode};
use zann::runtime::{default_artifact_dir, EngineHandle};
use zann::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "bench-table1" => bench_entries::table1(&args),
        "bench-table2" => bench_entries::table2(&args),
        "bench-table3" => bench_entries::table3(&args),
        "bench-table4" => bench_entries::table4(&args),
        "bench-fig2" => bench_entries::fig2(&args),
        "bench-fig3" => bench_entries::fig3(&args),
        "bench-search-qps" => bench_entries::search_qps(&args),
        "sizes" => sizes(&args),
        "build" => build_cmd(&args),
        "info" => info_cmd(&args),
        "serve" => serve_cmd(&args),
        "serve-demo" => serve_demo(&args),
        _ => {
            eprintln!(
                "usage: zann <bench-table1|bench-table2|bench-table3|bench-table4|\n\
                 bench-fig2|bench-fig3|bench-search-qps|sizes|\n\
                 build --out PATH [--backend ivf|nsg|hnsw]|info PATH|serve PATH|\n\
                 serve-demo> [--n N] [--dataset sift|deep|ssnpp] [--codec NAME] ..."
            );
        }
    }
}

/// Parse `--codec` through the registry; on a typo, print the valid-name
/// list and exit instead of panicking deep inside an index build.
fn codec_or_exit(args: &Args, default: &str) -> String {
    let name = args.get_or("codec", default);
    match CodecSpec::parse(name) {
        Ok(spec) => spec.name().to_string(),
        Err(e) => {
            eprintln!("--codec: {e}");
            std::process::exit(2);
        }
    }
}

/// One parseable stats line shared by build/info/serve (ci.sh greps it).
fn print_stats(s: &IndexStats, file_bytes: Option<u64>) {
    let mut line = format!(
        "zann-index kind={} codec={} n={} dim={} edges={} id_bits={} code_bits={} link_bits={} \
         bits_per_id={:.3} payload_bytes={}",
        s.kind.name(),
        s.codec,
        s.n,
        s.dim,
        s.edges,
        s.id_bits,
        s.code_bits,
        s.link_bits,
        s.bits_per_id(),
        s.payload_bytes(),
    );
    if let Some(b) = file_bytes {
        line.push_str(&format!(" file_bytes={b}"));
    }
    println!("{line}");
}

/// Bits/id summary for one configuration.
fn sizes(args: &Args) {
    let scale = bench_entries::scale_from(args);
    let kind = bench_entries::datasets_from(args)[0];
    let k = args.usize("k", 1024);
    let rows = experiments::table1_ivf(&scale, kind, &[k], &experiments::T1_CODECS);
    let mut t = Table::new(&["index", "codec", "bits/id", "ratio vs unc64"]);
    for row in rows {
        for (codec, bpe) in &row.bpe {
            t.row(vec![format!("IVF{}", row.k), codec.clone(), fmt3(*bpe), fmt3(64.0 / bpe)]);
        }
    }
    println!("{}", t.render());
}

/// Build an index of any backend and persist it to the container format.
fn build_cmd(args: &Args) {
    let out = match args.get("out") {
        Some(p) => p.to_string(),
        None => {
            eprintln!("build: --out PATH is required");
            std::process::exit(2);
        }
    };
    let backend = args.get_or("backend", "ivf").to_string();
    let codec = codec_or_exit(args, "roc");
    let scale = bench_entries::scale_from(args);
    let kind = bench_entries::datasets_from(args)[0];
    println!("generating {} vectors ({}, dim {})...", scale.n, kind.name(), scale.dim);
    let ds = generate(kind, scale.n, 1, scale.dim, scale.seed);
    println!("building {backend} index ({codec} streams)...");
    let index: Box<dyn AnnIndex> = match backend.as_str() {
        "ivf" => {
            let m = args.usize("m", 8);
            let bits = args.usize("bits", 8) as u32;
            let vectors = match args.get_or("vectors", "flat") {
                "flat" => VectorMode::Flat,
                "pq" => VectorMode::Pq { m, bits },
                "pq-compressed" | "pqc" => VectorMode::PqCompressed { m, bits },
                other => {
                    eprintln!("build: unknown --vectors {other:?} (flat|pq|pq-compressed)");
                    std::process::exit(2);
                }
            };
            Box::new(IvfIndex::build(
                &ds.data,
                ds.dim,
                &IvfBuildParams {
                    k: args.usize("k", 1024.min((scale.n / 16).max(4))),
                    id_codec: codec.clone(),
                    vectors,
                    threads: scale.threads,
                    seed: scale.seed,
                    ..Default::default()
                },
            ))
        }
        "nsg" => {
            let r = args.usize("r", 32);
            let nsg = Nsg::build(
                &ds.data,
                ds.dim,
                &NsgParams {
                    r,
                    knn_k: r.max(48),
                    threads: scale.threads,
                    seed: scale.seed,
                    ..Default::default()
                },
            );
            match GraphIndex::from_nsg(&nsg, &ds.data, &codec) {
                Ok(g) => Box::new(g),
                Err(e) => {
                    eprintln!("build: {e}");
                    std::process::exit(2);
                }
            }
        }
        "hnsw" => {
            let h = Hnsw::build(
                &ds.data,
                ds.dim,
                &HnswParams { m: args.usize("m", 16), ef_construction: 100, seed: scale.seed },
            );
            match GraphIndex::from_hnsw(&h, &ds.data, &codec) {
                Ok(g) => Box::new(g),
                Err(e) => {
                    eprintln!("build: {e}");
                    std::process::exit(2);
                }
            }
        }
        other => {
            eprintln!("build: unknown --backend {other:?} (ivf|nsg|hnsw)");
            std::process::exit(2);
        }
    };
    let stats = index.stats();
    match index.save(Path::new(&out)) {
        Ok(bytes) => {
            print_stats(&stats, Some(bytes));
            println!(
                "saved {out}: {bytes} bytes for a {} byte payload ({} overhead)",
                stats.payload_bytes(),
                bytes.saturating_sub(stats.payload_bytes()),
            );
        }
        Err(e) => {
            eprintln!("build: save failed: {e}");
            std::process::exit(1);
        }
    }
}

/// Print the stats of a saved index (reopens it, so the line reflects
/// what a server would actually load).
fn info_cmd(args: &Args) {
    let path = match args.positional.get(1) {
        Some(p) => p.clone(),
        None => {
            eprintln!("usage: zann info PATH");
            std::process::exit(2);
        }
    };
    let index = match persist::open(Path::new(&path)) {
        Ok(i) => i,
        Err(e) => {
            eprintln!("info: {e:?}");
            std::process::exit(1);
        }
    };
    let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    print_stats(&index.stats(), Some(file_bytes));
}

/// Reopen a saved index and serve a seeded random query batch through
/// the coordinator, verifying every response against direct search.
fn serve_cmd(args: &Args) {
    let path = match args.positional.get(1) {
        Some(p) => p.clone(),
        None => {
            eprintln!("usage: zann serve PATH [--nq N] [--nprobe P] [--ef E] [--topk K]");
            std::process::exit(2);
        }
    };
    let index: Arc<dyn AnnIndex> = match persist::open(Path::new(&path)) {
        Ok(i) => Arc::from(i),
        Err(e) => {
            eprintln!("serve: {e:?}");
            std::process::exit(1);
        }
    };
    let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    print_stats(&index.stats(), Some(file_bytes));
    let engine = if index.coarse_info().is_some() {
        match EngineHandle::spawn(&default_artifact_dir()) {
            Ok(h) => {
                println!("engine up: {} PJRT executables", h.num_executables);
                Some(h)
            }
            Err(e) => {
                println!("engine unavailable ({e}); pure-rust coarse path");
                None
            }
        }
    } else {
        println!("graph backend: no coarse stage, direct scan path");
        None
    };
    let sp = QueryParams {
        k: args.usize("topk", 10),
        nprobe: args.usize("nprobe", 16),
        ef: args.usize("ef", 64),
    };
    let nq = args.usize("nq", 256);
    let dim = index.dim();
    let mut rng = zann::util::Rng::new(args.u64("seed", 42));
    let queries: Vec<Vec<f32>> =
        (0..nq).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect();
    let coord = Coordinator::start(
        index.clone(),
        engine,
        ServeConfig {
            batch_size: args.usize("batch", 64),
            search: sp.clone(),
            ..Default::default()
        },
    );
    let t0 = std::time::Instant::now();
    let responses = coord.client.search_many(queries.clone()).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    // Every rust-path response must match a direct search on the
    // reopened index — the end-to-end proof that open did not disturb
    // the stores. Batches scored by a PJRT executable are excluded from
    // the bit-exact check: only the pure-rust coarse kernel is
    // documented bit-identical to the direct path (XLA may differ in
    // the last ulp, legitimately reordering exact ties).
    let mut scratch = AnnScratch::default();
    let mut want = Vec::new();
    let mut ok = 0usize;
    let mut via_pjrt = 0usize;
    for (qi, resp) in responses.iter().enumerate() {
        if resp.via_pjrt {
            via_pjrt += 1;
            continue;
        }
        index.search_into(&queries[qi], &sp, &mut scratch, &mut want);
        if resp.results == want {
            ok += 1;
        }
    }
    let checked = responses.len() - via_pjrt;
    let note = if via_pjrt > 0 {
        format!(" ({via_pjrt} PJRT-scored responses skipped: not bit-comparable)")
    } else {
        String::new()
    };
    println!("serve: verified {ok}/{checked} responses identical to direct search{note}");
    println!(
        "served {} queries in {:.3}s ({:.0} qps); {}",
        responses.len(),
        wall,
        responses.len() as f64 / wall,
        coord.metrics.summary()
    );
    coord.stop();
    if ok != checked {
        eprintln!("serve: {} responses diverged from direct search", checked - ok);
        std::process::exit(1);
    }
}

/// End-to-end serving demo: index + coordinator + PJRT engine.
fn serve_demo(args: &Args) {
    let scale = bench_entries::scale_from(args);
    let kind = bench_entries::datasets_from(args)[0];
    let n = args.usize("n", 100_000);
    let nq = args.usize("nq", 1024);
    let _ = Scale::default();
    let codec = codec_or_exit(args, "roc");
    println!("generating {} vectors ({})...", n, kind.name());
    let ds = generate(kind, n, nq, scale.dim, scale.seed);
    println!("building IVF{} ({} ids)...", args.usize("k", 1024), codec);
    let idx = Arc::new(IvfIndex::build(
        &ds.data,
        ds.dim,
        &IvfBuildParams {
            k: args.usize("k", 1024),
            id_codec: codec,
            threads: scale.threads,
            seed: scale.seed,
            ..Default::default()
        },
    ));
    println!("id payload: {} bits/id", fmt3(idx.bits_per_id()));
    let engine = match EngineHandle::spawn(&default_artifact_dir()) {
        Ok(h) => {
            println!("engine up: {} PJRT executables", h.num_executables);
            Some(h)
        }
        Err(e) => {
            println!("engine unavailable ({e}); pure-rust coarse path");
            None
        }
    };
    let coord = Coordinator::start(
        idx,
        engine,
        ServeConfig {
            batch_size: 64,
            search: QueryParams { nprobe: args.usize("nprobe", 16), k: 10, ..Default::default() },
            ..Default::default()
        },
    );
    let queries: Vec<Vec<f32>> = (0..nq).map(|qi| ds.query(qi).to_vec()).collect();
    let t0 = std::time::Instant::now();
    let responses = coord.client.search_many(queries).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {} queries in {:.3}s ({:.0} qps); {}",
        responses.len(),
        wall,
        responses.len() as f64 / wall,
        coord.metrics.summary()
    );
    coord.stop();
}
