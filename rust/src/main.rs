//! `zann` — CLI for the compressed-id ANN system.
//!
//! Subcommands:
//!   bench-table1|bench-table2|bench-table3|bench-table4|bench-fig2|bench-fig3
//!                       — regenerate the paper's tables/figures
//!   bench-search-qps    — search throughput sweep (QPS + latency
//!                         percentiles, writes BENCH_search.json)
//!   serve-demo          — build an index and serve a batch through the
//!                         coordinator (PJRT coarse path if artifacts exist)
//!   sizes               — bits/id summary for one dataset/index
//!
//! Common flags: --n --nq --dim --k --seed --threads --dataset
//! (sift|deep|ssnpp) --codec --runs --full (paper-scale N=1e6)

use std::sync::Arc;
use zann::coordinator::{Coordinator, ServeConfig};
use zann::datasets::generate;
use zann::eval::experiments::{self, Scale};
use zann::eval::{bench_entries, fmt3, Table};
use zann::index::{IvfBuildParams, IvfIndex, SearchParams};
use zann::runtime::{default_artifact_dir, EngineHandle};
use zann::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "bench-table1" => bench_entries::table1(&args),
        "bench-table2" => bench_entries::table2(&args),
        "bench-table3" => bench_entries::table3(&args),
        "bench-table4" => bench_entries::table4(&args),
        "bench-fig2" => bench_entries::fig2(&args),
        "bench-fig3" => bench_entries::fig3(&args),
        "bench-search-qps" => bench_entries::search_qps(&args),
        "sizes" => sizes(&args),
        "serve-demo" => serve_demo(&args),
        _ => {
            eprintln!(
                "usage: zann <bench-table1|bench-table2|bench-table3|bench-table4|\n\
                 bench-fig2|bench-fig3|bench-search-qps|sizes|serve-demo> [--n N] \
                 [--dataset sift|deep|ssnpp] ..."
            );
        }
    }
}

/// Bits/id summary for one configuration.
fn sizes(args: &Args) {
    let scale = bench_entries::scale_from(args);
    let kind = bench_entries::datasets_from(args)[0];
    let k = args.usize("k", 1024);
    let rows = experiments::table1_ivf(&scale, kind, &[k], &experiments::T1_CODECS);
    let mut t = Table::new(&["index", "codec", "bits/id", "ratio vs unc64"]);
    for row in rows {
        for (codec, bpe) in &row.bpe {
            t.row(vec![format!("IVF{}", row.k), codec.clone(), fmt3(*bpe), fmt3(64.0 / bpe)]);
        }
    }
    println!("{}", t.render());
}

/// End-to-end serving demo: index + coordinator + PJRT engine.
fn serve_demo(args: &Args) {
    let scale = bench_entries::scale_from(args);
    let kind = bench_entries::datasets_from(args)[0];
    let n = args.usize("n", 100_000);
    let nq = args.usize("nq", 1024);
    let _ = Scale::default();
    println!("generating {} vectors ({})...", n, kind.name());
    let ds = generate(kind, n, nq, scale.dim, scale.seed);
    println!("building IVF{} ({} ids)...", args.usize("k", 1024), args.get_or("codec", "roc"));
    let idx = Arc::new(IvfIndex::build(
        &ds.data,
        ds.dim,
        &IvfBuildParams {
            k: args.usize("k", 1024),
            id_codec: args.get_or("codec", "roc").into(),
            threads: scale.threads,
            seed: scale.seed,
            ..Default::default()
        },
    ));
    println!("id payload: {} bits/id", fmt3(idx.bits_per_id()));
    let engine = match EngineHandle::spawn(&default_artifact_dir()) {
        Ok(h) => {
            println!("engine up: {} PJRT executables", h.num_executables);
            Some(h)
        }
        Err(e) => {
            println!("engine unavailable ({e}); pure-rust coarse path");
            None
        }
    };
    let coord = Coordinator::start(
        idx,
        engine,
        ServeConfig {
            batch_size: 64,
            search: SearchParams { nprobe: args.usize("nprobe", 16), k: 10 },
            ..Default::default()
        },
    );
    let queries: Vec<Vec<f32>> = (0..nq).map(|qi| ds.query(qi).to_vec()).collect();
    let t0 = std::time::Instant::now();
    let responses = coord.client.search_many(queries).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {} queries in {:.3}s ({:.0} qps); {}",
        responses.len(),
        wall,
        responses.len() as f64 / wall,
        coord.metrics.summary()
    );
    coord.stop();
}
